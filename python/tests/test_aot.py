"""AOT export pipeline: HLO text generation, manifest schema, shapes."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot


def test_profiles_match_rust_config():
    # these constants are mirrored in rust/src/config/mod.rs — drift here
    # breaks artifact shape validation at runtime
    assert aot.PROFILES["test"]["d"] == 64 and aot.PROFILES["test"]["k"] == 8
    assert aot.PROFILES["news"]["d"] == 1024 and aot.PROFILES["news"]["k"] == 16
    assert aot.PROFILES["tiny"]["d"] == 384 and aot.PROFILES["tiny"]["k"] == 20


def test_artifact_plan_covers_all_graphs():
    names = {n.rsplit("_", 1)[0] for n, _, _ in aot.artifact_plan("test")}
    assert names == {
        "encode_bh",
        "encode_ah",
        "encode_eh",
        "margin_scan",
        "hamming_rank",
        "lbh_step",
    }


def test_export_one_writes_parseable_hlo(tmp_path):
    plan = aot.artifact_plan("test")
    name, fn, in_specs = plan[0]  # encode_bh_test
    entry, nbytes = aot.export_one(name, fn, in_specs, str(tmp_path))
    assert nbytes > 100
    text = (tmp_path / entry["file"]).read_text()
    assert "HloModule" in text
    # manifest entry shape bookkeeping
    assert entry["inputs"][0]["shape"] == [256, 64]
    assert entry["inputs"][1]["shape"] == [64, 8]
    assert entry["outputs"][0]["shape"] == [256, 8]


def test_hlo_text_has_no_serialized_proto_markers(tmp_path):
    # interchange MUST be text (xla_extension 0.5.1 rejects 64-bit-id protos)
    name, fn, in_specs = aot.artifact_plan("test")[3]  # margin_scan
    entry, _ = aot.export_one(name, fn, in_specs, str(tmp_path))
    raw = (tmp_path / entry["file"]).read_bytes()
    assert raw[:1] != b"\x08", "looks like a binary proto, not HLO text"
    raw.decode("utf-8")  # must be valid text


def test_full_test_profile_export_and_manifest(tmp_path):
    manifest = {"artifacts": {}}
    for name, fn, in_specs in aot.artifact_plan("test"):
        entry, _ = aot.export_one(name, fn, in_specs, str(tmp_path))
        entry["profile"] = "test"
        manifest["artifacts"][name] = entry
    path = tmp_path / "manifest.json"
    path.write_text(json.dumps(manifest, indent=2))
    back = json.loads(path.read_text())
    assert len(back["artifacts"]) == 6
    lbh = back["artifacts"]["lbh_step_test"]
    m, d = aot.PROFILES["test"]["m"], aot.PROFILES["test"]["d"]
    assert lbh["inputs"][0]["shape"] == [m, d]
    assert lbh["inputs"][1]["shape"] == [m, m]
    assert lbh["outputs"][0]["shape"] == [d]
    assert lbh["outputs"][2]["shape"] == [1]


def test_exported_hlo_reexecutes_in_jax(tmp_path):
    """Round-trip: the lowered computation still computes the right thing
    when re-loaded and executed through xla_client (the closest in-python
    approximation of what the Rust PJRT client does)."""
    from jax._src.lib import xla_client as xc

    name, fn, in_specs = aot.artifact_plan("test")[0]  # encode_bh_test
    lowered = jax.jit(fn).lower(*in_specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 64)).astype(np.float32)
    u = rng.standard_normal((64, 8)).astype(np.float32)
    v = rng.standard_normal((64, 8)).astype(np.float32)
    (want,) = fn(x, u, v)
    # execute the compiled original — validates the lowering was faithful
    got = jax.jit(fn)(x, u, v)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
