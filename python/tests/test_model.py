"""L2 graphs vs oracles: shapes, math, and the Nesterov step semantics."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


def test_encode_bh_shape_and_value(rng):
    x = rng.standard_normal((256, 64)).astype(np.float32)
    u = rng.standard_normal((64, 8)).astype(np.float32)
    v = rng.standard_normal((64, 8)).astype(np.float32)
    (out,) = model.encode_bh(jnp.asarray(x), jnp.asarray(u), jnp.asarray(v), tile_n=256)
    assert out.shape == (256, 8)
    assert_allclose(np.asarray(out), np.asarray(ref.bilinear_scores_ref(x, u, v)), rtol=2e-5, atol=2e-5)


def test_encode_ah_two_projections(rng):
    x = rng.standard_normal((32, 16)).astype(np.float32)
    u = rng.standard_normal((16, 4)).astype(np.float32)
    v = rng.standard_normal((16, 4)).astype(np.float32)
    pu, pv = model.encode_ah(jnp.asarray(x), jnp.asarray(u), jnp.asarray(v))
    assert_allclose(np.asarray(pu), x @ u, rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(pv), x @ v, rtol=1e-5, atol=1e-5)


def test_encode_eh_matches_ref_and_accepts_f32_indices(rng):
    n, d, k, s = 16, 32, 6, 24
    x = rng.standard_normal((n, d)).astype(np.float32)
    ia = rng.integers(0, d, size=(k, s))
    ib = rng.integers(0, d, size=(k, s))
    g = rng.standard_normal((k, s)).astype(np.float32)
    (out,) = model.encode_eh(
        jnp.asarray(x),
        jnp.asarray(ia, jnp.float32),  # f32 indices, as the Rust runtime sends
        jnp.asarray(ib, jnp.float32),
        jnp.asarray(g),
    )
    want = ref.eh_scores_ref(x, ia, ib, g)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_margin_scan(rng):
    x = rng.standard_normal((64, 16)).astype(np.float32)
    w = rng.standard_normal(16).astype(np.float32)
    (out,) = model.margin_scan(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(out), np.abs(x @ w), rtol=1e-5, atol=1e-5)
    assert (np.asarray(out) >= 0).all()


def test_hamming_rank_shape(rng):
    c = (2.0 * rng.integers(0, 2, size=(64, 8)) - 1).astype(np.float32)
    q = (2.0 * rng.integers(0, 2, size=8) - 1).astype(np.float32)
    (out,) = model.hamming_rank(jnp.asarray(c), jnp.asarray(q), tile_n=64)
    assert out.shape == (64,)


# ───────────────────────── lbh_step ─────────────────────────


def _step_inputs(rng, m=32, d=16):
    x = rng.standard_normal((m, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    s = np.clip(2 * np.abs(x @ x.T) - 1, -1, 1).astype(np.float32)
    r = 8.0 * s
    u = rng.standard_normal(d).astype(np.float32)
    v = rng.standard_normal(d).astype(np.float32)
    return x, r, u, v


def test_lbh_step_matches_ref(rng):
    x, r, u, v = _step_inputs(rng)
    lr, mu = 0.05, 0.9
    un, vn, cost = model.lbh_step(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(u), jnp.asarray(v),
        jnp.asarray(u), jnp.asarray(v),
        jnp.asarray([lr], jnp.float32), jnp.asarray([mu], jnp.float32),
        tile_m=8,
    )
    run, rvn, rcost = ref.lbh_step_ref(x, r, u, v, u, v, lr, mu)
    assert_allclose(np.asarray(un), np.asarray(run), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(vn), np.asarray(rvn), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(cost)[0], float(rcost), rtol=2e-3, atol=2e-3)


def test_lbh_grad_ref_matches_finite_difference(rng):
    x, r, u, v = _step_inputs(rng, m=16, d=8)
    gu, gv, _ = ref.lbh_grad_ref(x, r, u, v)
    eps = 1e-3
    for t in range(8):
        up, um = u.copy(), u.copy()
        up[t] += eps
        um[t] -= eps
        _, _, cp = ref.lbh_grad_ref(x, r, up, v)
        _, _, cm = ref.lbh_grad_ref(x, r, u, v)
        _, _, cm = ref.lbh_grad_ref(x, r, um, v)
        fd = (cp - cm) / (2 * eps)
        assert abs(fd - gu[t]) < 2e-2 * (1 + abs(fd)), f"coord {t}: {fd} vs {gu[t]}"


def test_lbh_step_descends_on_average(rng):
    # run 40 steps from the random start; cost should drop substantially
    x, r, u, v = _step_inputs(rng, m=32, d=16)
    lr = jnp.asarray([0.05], jnp.float32)
    mu = jnp.asarray([0.9], jnp.float32)
    xu, xv = jnp.asarray(u), jnp.asarray(v)
    pu, pv = xu, xv
    _, _, c0 = ref.lbh_grad_ref(x, r, u, v)
    cost = None
    for _ in range(40):
        un, vn, cost = model.lbh_step(
            jnp.asarray(x), jnp.asarray(r), xu, xv, pu, pv, lr, mu, tile_m=8
        )
        pu, pv = xu, xv
        xu, xv = un, vn
    assert float(cost[0]) < float(c0), f"{float(cost[0])} !< {float(c0)}"


def test_lbh_step_zero_padding_is_neutral(rng):
    # padding X and R with zero rows/cols must not change the update
    x, r, u, v = _step_inputs(rng, m=16, d=8)
    lr = jnp.asarray([0.05], jnp.float32)
    mu = jnp.asarray([0.9], jnp.float32)
    un1, vn1, c1 = model.lbh_step(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(u), jnp.asarray(v),
        jnp.asarray(u), jnp.asarray(v), lr, mu, tile_m=8,
    )
    xp = np.zeros((24, 8), np.float32)
    xp[:16] = x
    rp = np.zeros((24, 24), np.float32)
    rp[:16, :16] = r
    un2, vn2, c2 = model.lbh_step(
        jnp.asarray(xp), jnp.asarray(rp), jnp.asarray(u), jnp.asarray(v),
        jnp.asarray(u), jnp.asarray(v), lr, mu, tile_m=8,
    )
    assert_allclose(np.asarray(un1), np.asarray(un2), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(vn1), np.asarray(vn2), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-4)


@given(
    m=st.sampled_from([8, 24]),
    d=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lbh_step_sweep(m, d, seed):
    r_np = np.random.default_rng(seed)
    x, r, u, v = _step_inputs(r_np, m=m, d=d)
    lr, mu = 0.02, 0.8
    un, vn, cost = model.lbh_step(
        jnp.asarray(x), jnp.asarray(r), jnp.asarray(u), jnp.asarray(v),
        jnp.asarray(u), jnp.asarray(v),
        jnp.asarray([lr], jnp.float32), jnp.asarray([mu], jnp.float32),
        tile_m=8,
    )
    run, rvn, rcost = ref.lbh_step_ref(x, r, u, v, u, v, lr, mu)
    assert_allclose(np.asarray(un), np.asarray(run), rtol=1e-3, atol=1e-3)
    assert_allclose(np.asarray(vn), np.asarray(rvn), rtol=1e-3, atol=1e-3)


def test_sigmoid_is_tanh_half():
    t = np.linspace(-10, 10, 101).astype(np.float32)
    assert_allclose(np.asarray(ref.sigmoid_pm_ref(t)), np.tanh(t / 2), rtol=1e-5, atol=1e-6)
