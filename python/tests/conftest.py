import os
import sys

import numpy as np
import pytest

# make `compile` importable when pytest runs from python/ or repo root
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture
def rng():
    return np.random.default_rng(2012)
