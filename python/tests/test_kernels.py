"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import bilinear_scores, hamming_distances, weighted_colsum
from compile.kernels.ref import (
    bilinear_scores_ref,
    hamming_ref,
    weighted_colsum_ref,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ───────────────────────── bilinear ─────────────────────────


def test_bilinear_matches_ref_basic(rng):
    x = rng.standard_normal((256, 64)).astype(np.float32)
    u = rng.standard_normal((64, 8)).astype(np.float32)
    v = rng.standard_normal((64, 8)).astype(np.float32)
    got = bilinear_scores(jnp.asarray(x), jnp.asarray(u), jnp.asarray(v), tile_n=64)
    want = bilinear_scores_ref(x, u, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(
    tiles=st.integers(1, 4),
    tile_n=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([8, 16, 64, 96]),
    k=st.sampled_from([1, 4, 8, 20]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bilinear_matches_ref_sweep(tiles, tile_n, d, k, seed):
    r = np.random.default_rng(seed)
    n = tiles * tile_n
    x = r.standard_normal((n, d)).astype(np.float32)
    u = r.standard_normal((d, k)).astype(np.float32)
    v = r.standard_normal((d, k)).astype(np.float32)
    got = bilinear_scores(jnp.asarray(x), jnp.asarray(u), jnp.asarray(v), tile_n=tile_n)
    want = bilinear_scores_ref(x, u, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_bilinear_sign_invariance_to_scale(rng):
    # the property the bilinear form exists for (§3.2 requirement 1)
    x = rng.standard_normal((64, 16)).astype(np.float32)
    u = rng.standard_normal((16, 8)).astype(np.float32)
    v = rng.standard_normal((16, 8)).astype(np.float32)
    s1 = np.sign(np.asarray(bilinear_scores(jnp.asarray(x), jnp.asarray(u), jnp.asarray(v), tile_n=64)))
    s2 = np.sign(
        np.asarray(bilinear_scores(jnp.asarray(-2.5 * x), jnp.asarray(u), jnp.asarray(v), tile_n=64))
    )
    np.testing.assert_array_equal(s1, s2)


def test_bilinear_rejects_bad_tiling(rng):
    x = jnp.zeros((100, 8))
    u = jnp.zeros((8, 4))
    with pytest.raises(AssertionError):
        bilinear_scores(x, u, u, tile_n=64)


def test_bilinear_zero_rows_give_zero_scores():
    x = jnp.zeros((32, 8))
    u = jnp.ones((8, 4))
    out = np.asarray(bilinear_scores(x, u, u, tile_n=32))
    assert (out == 0).all()


# ───────────────────────── weighted colsum (grad up-pass) ─────────────────────────


def test_colsum_matches_ref_basic(rng):
    x = rng.standard_normal((128, 48)).astype(np.float32)
    a = rng.standard_normal(128).astype(np.float32)
    got = weighted_colsum(jnp.asarray(x), jnp.asarray(a), tile_m=32)
    assert_allclose(np.asarray(got), np.asarray(weighted_colsum_ref(x, a)), rtol=2e-4, atol=2e-4)


@given(
    tiles=st.integers(1, 5),
    tile_m=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([4, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_colsum_sweep(tiles, tile_m, d, seed):
    r = np.random.default_rng(seed)
    m = tiles * tile_m
    x = r.standard_normal((m, d)).astype(np.float32)
    a = r.standard_normal(m).astype(np.float32)
    got = weighted_colsum(jnp.asarray(x), jnp.asarray(a), tile_m=tile_m)
    assert_allclose(np.asarray(got), np.asarray(weighted_colsum_ref(x, a)), rtol=5e-4, atol=5e-4)


def test_colsum_accumulation_across_tiles():
    # single nonzero row in each tile → accumulator must sum them
    x = np.zeros((4 * 8, 3), dtype=np.float32)
    x[0] = [1, 0, 0]
    x[8] = [0, 2, 0]
    x[16] = [0, 0, 3]
    x[24] = [4, 0, 0]
    a = np.ones(32, dtype=np.float32)
    got = np.asarray(weighted_colsum(jnp.asarray(x), jnp.asarray(a), tile_m=8))
    assert_allclose(got, [5.0, 2.0, 3.0])


# ───────────────────────── hamming ─────────────────────────


def test_hamming_matches_popcount(rng):
    n, k = 128, 20
    bits = rng.integers(0, 2, size=(n, k))
    qbits = rng.integers(0, 2, size=k)
    pm = (2.0 * bits - 1.0).astype(np.float32)
    qpm = (2.0 * qbits - 1.0).astype(np.float32)
    got = np.asarray(hamming_distances(jnp.asarray(pm), jnp.asarray(qpm), tile_n=32))
    want = (bits != qbits).sum(axis=1)
    assert_allclose(got, want.astype(np.float32), atol=1e-5)


@given(
    tiles=st.integers(1, 4),
    tile_n=st.sampled_from([8, 32]),
    k=st.sampled_from([1, 8, 20, 40]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hamming_sweep(tiles, tile_n, k, seed):
    r = np.random.default_rng(seed)
    n = tiles * tile_n
    bits = r.integers(0, 2, size=(n, k))
    qbits = r.integers(0, 2, size=k)
    pm = (2.0 * bits - 1.0).astype(np.float32)
    qpm = (2.0 * qbits - 1.0).astype(np.float32)
    got = np.asarray(hamming_distances(jnp.asarray(pm), jnp.asarray(qpm), tile_n=tile_n))
    want = np.asarray(hamming_ref(pm, qpm))
    assert_allclose(got, want, atol=1e-5)
    assert got.min() >= 0 and got.max() <= k


def test_hamming_identical_and_flipped():
    k = 16
    pm = np.ones((8, k), dtype=np.float32)
    same = np.asarray(hamming_distances(jnp.asarray(pm), jnp.ones(k, jnp.float32), tile_n=8))
    flip = np.asarray(hamming_distances(jnp.asarray(pm), -jnp.ones(k, jnp.float32), tile_n=8))
    assert (same == 0).all()
    assert (flip == k).all()
