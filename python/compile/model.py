"""L2 JAX graphs — everything the Rust coordinator executes through PJRT.

Each public function here is a *pure* JAX computation returning a tuple
(lowered with ``return_tuple=True``); ``aot.py`` exports one HLO-text
artifact per (function, shape-profile). The L1 Pallas kernels are called
from inside these graphs so they lower into the same HLO module.

Conventions shared with the Rust side (`rust/src/runtime/`):
* all tensors are float32 (index tensors arrive as f32 and are cast here —
  the Rust runtime only stages f32 buffers);
* scalars (lr, momentum) are shape-(1,) arrays;
* database tiles are fixed-shape; the coordinator zero-pads the last tile.
  Zero rows are safe everywhere: they encode to sign(0)=+1 codes that the
  coordinator discards, contribute nothing to gradients (φ(0)=0), and
  produce margin 0 entries that are sliced off.
"""

import jax.numpy as jnp

from .kernels import bilinear_scores, hamming_distances, weighted_colsum
from .kernels.ref import sigmoid_pm_ref


def encode_bh(x, u, v, *, tile_n=256):
    """BH/LBH pre-sign scores for a database tile (L1 bilinear kernel).

    x: (n, d); u, v: (d, k). Returns ((n, k) scores,).
    The Rust side packs ``score >= 0`` into code bits.
    """
    return (bilinear_scores(x, u, v, tile_n=tile_n),)


def encode_ah(x, u, v):
    """AH-Hash per-pair projections: (x@u, x@v), each (n, k).

    The coordinator interleaves the sign bits as (u_0, v_0, u_1, v_1, …)
    and flips v-bits for hyperplane queries (eq. 2).
    """
    return (x @ u, x @ v)


def encode_eh(x, idx_a, idx_b, g):
    """Dimension-sampled EH-Hash pre-sign scores (eq. 4 + §5.2 trick).

    x: (n, d); idx_a, idx_b: (k, s) float32 (cast to int here); g: (k, s).
    Bit j of x: Σ_i g[j,i]·x[a_{j,i}]·x[b_{j,i}]. Returns ((n, k),).
    """
    ia = idx_a.astype(jnp.int32)
    ib = idx_b.astype(jnp.int32)
    xa = x[:, ia]  # (n, k, s)
    xb = x[:, ib]
    return (jnp.einsum("nks,ks->nk", xa * xb, g),)


def margin_scan(x, w):
    """|X·w| for a database tile — the exhaustive-selection hot loop."""
    return (jnp.abs(x @ w),)


def hamming_rank(codes_pm, q_pm, *, tile_n=256):
    """Hamming distances between ±1 code rows and a ±1 query (L1 kernel)."""
    return (hamming_distances(codes_pm, q_pm, tile_n=tile_n),)


def _lbh_grad(x, r, u, v, *, tile_m):
    """eq. 17–18: b̃, σ, gradients, cost. Up-passes use the L1 kernel."""
    pu = x @ u
    pv = x @ v
    btil = sigmoid_pm_ref(pu * pv)
    rb = r @ btil
    sigma = rb * (1.0 - btil * btil)
    g_u = -weighted_colsum(x, sigma * pv, tile_m=tile_m)
    g_v = -weighted_colsum(x, sigma * pu, tile_m=tile_m)
    cost = -(btil @ rb)
    return g_u, g_v, cost


def lbh_step(x, r, u, v, u_prev, v_prev, lr, mu, *, tile_m=128):
    """One Nesterov step of the §4 per-bit solve.

    x: (m, d) training subsample (rows may be zero-padded);
    r: (m, m) residue matrix R_{j−1};
    u, v, u_prev, v_prev: (d,) current and previous iterates;
    lr, mu: (1,) learning rate and momentum.

    Returns (u_new, v_new, cost) with cost (1,) = −b̃ᵀRb̃ at the new point.
    """
    yu = u + mu[0] * (u - u_prev)
    yv = v + mu[0] * (v - v_prev)
    g_u, g_v, _ = _lbh_grad(x, r, yu, yv, tile_m=tile_m)
    u_new = yu - lr[0] * g_u
    v_new = yv - lr[0] * g_v
    _, _, cost = _lbh_grad(x, r, u_new, v_new, tile_m=tile_m)
    return (u_new, v_new, cost.reshape(1))
