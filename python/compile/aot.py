"""AOT export: lower every L2 graph to HLO text + write manifest.json.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is **HLO text**, not a serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly
(/opt/xla-example/README.md).

Shape profiles mirror `rust/src/config/mod.rs::DatasetProfile`:

  profile   d     k   tile_n   lbh m   (paper setting)
  test      64    8   256      128     (CI-scale)
  news      1024  16  1024     512     (20NG: 16 bits, m=500→512)
  tiny      384   20  2048     1024    (Tiny-1M: 20 bits, m≤5000, tiled)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


PROFILES = {
    "test": dict(d=64, k=8, tile_n=256, eh_tile=256, eh_s=64, m=128, tile_m=64),
    "news": dict(d=1024, k=16, tile_n=1024, eh_tile=256, eh_s=256, m=512, tile_m=128),
    "tiny": dict(d=384, k=20, tile_n=2048, eh_tile=512, eh_s=256, m=1024, tile_m=128),
}


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def artifact_plan(profile: str):
    """(name, fn, input_specs) for every artifact of one profile."""
    p = PROFILES[profile]
    d, k, tn, m = p["d"], p["k"], p["tile_n"], p["m"]
    eh_tile, eh_s, tile_m = p["eh_tile"], p["eh_s"], p["tile_m"]
    return [
        (
            f"encode_bh_{profile}",
            functools.partial(model.encode_bh, tile_n=tn),
            [spec(tn, d), spec(d, k), spec(d, k)],
        ),
        (
            f"encode_ah_{profile}",
            model.encode_ah,
            [spec(tn, d), spec(d, k), spec(d, k)],
        ),
        (
            f"encode_eh_{profile}",
            model.encode_eh,
            [spec(eh_tile, d), spec(k, eh_s), spec(k, eh_s), spec(k, eh_s)],
        ),
        (
            f"margin_scan_{profile}",
            model.margin_scan,
            [spec(tn, d), spec(d)],
        ),
        (
            f"hamming_rank_{profile}",
            functools.partial(model.hamming_rank, tile_n=tn),
            [spec(tn, k), spec(k)],
        ),
        (
            f"lbh_step_{profile}",
            functools.partial(model.lbh_step, tile_m=tile_m),
            [
                spec(m, d),
                spec(m, m),
                spec(d),
                spec(d),
                spec(d),
                spec(d),
                spec(1),
                spec(1),
            ],
        ),
    ]


def export_one(name, fn, in_specs, out_dir):
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *in_specs)
    entry = {
        "file": fname,
        "inputs": [{"shape": list(s.shape), "dtype": "f32"} for s in in_specs],
        "outputs": [
            {"shape": list(s.shape), "dtype": "f32"} for s in jax.tree_util.tree_leaves(out_shapes)
        ],
    }
    return entry, len(text)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--profiles",
        default="test,news,tiny",
        help="comma-separated subset of profiles to export",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"artifacts": {}}
    total = 0
    for profile in args.profiles.split(","):
        profile = profile.strip()
        if not profile:
            continue
        for name, fn, in_specs in artifact_plan(profile):
            entry, nbytes = export_one(name, fn, in_specs, args.out_dir)
            entry["profile"] = profile
            manifest["artifacts"][name] = entry
            total += nbytes
            print(f"  {name:<28} {nbytes/1024:8.1f} KiB")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts ({total/1e6:.1f} MB) to {args.out_dir}")


if __name__ == "__main__":
    main()
