"""L1 Pallas kernels for the Compact Hyperplane Hashing stack.

Every kernel is written for TPU geometry (tiles in multiples of the (8,128)
VPU/MXU lanes, matmuls with float32 accumulation) but is lowered with
``interpret=True`` so the CPU PJRT client can execute the resulting HLO --
real-TPU lowering would emit Mosaic custom-calls the CPU plugin cannot run
(see /opt/xla-example/README.md).
"""

from .bilinear import bilinear_scores
from .grad import weighted_colsum
from .hamming import hamming_distances

__all__ = ["bilinear_scores", "weighted_colsum", "hamming_distances"]
