"""Pure-jnp oracles for every Pallas kernel and L2 graph.

These are the correctness ground truth: deliberately naive, no tiling, no
pallas — just the math as written in the paper. pytest asserts the kernels
and the AOT-exported HLO agree with these to float32 tolerance.
"""

import jax.numpy as jnp


def bilinear_scores_ref(x, u, v):
    """(x@u) * (x@v) — pre-sign scores of the bilinear hash (eq. 6)."""
    return (x @ u) * (x @ v)


def weighted_colsum_ref(x, a):
    """xᵀ a."""
    return x.T @ a


def hamming_ref(codes_pm, q_pm):
    """(k − c·q)/2 over ±1 codes."""
    k = codes_pm.shape[1]
    return (k - codes_pm @ q_pm) * 0.5


def sigmoid_pm_ref(t):
    """φ(t) = 2/(1+e^{−t}) − 1 (eq. 16's surrogate), == tanh(t/2)."""
    return 2.0 / (1.0 + jnp.exp(-t)) - 1.0


def lbh_grad_ref(x, r, u, v):
    """Full eq. 17–18 chain: b̃, σ, (g_u, g_v) and the surrogate cost.

    Returns (g_u, g_v, cost) with cost = −b̃ᵀ R b̃.
    """
    pu = x @ u
    pv = x @ v
    btil = sigmoid_pm_ref(pu * pv)
    rb = r @ btil
    sigma = rb * (1.0 - btil * btil)
    g_u = -(x.T @ (sigma * pv))
    g_v = -(x.T @ (sigma * pu))
    cost = -(btil @ rb)
    return g_u, g_v, cost


def lbh_step_ref(x, r, u, v, u_prev, v_prev, lr, mu):
    """One Nesterov step of the §4 solve (matches model.lbh_step).

    Lookahead y = x + μ(x − x_prev); gradient at y; x_new = y − lr·g;
    returns (u_new, v_new, cost_at_new).
    """
    yu = u + mu * (u - u_prev)
    yv = v + mu * (v - v_prev)
    gu, gv, _ = lbh_grad_ref(x, r, yu, yv)
    u_new = yu - lr * gu
    v_new = yv - lr * gv
    _, _, cost = lbh_grad_ref(x, r, u_new, v_new)
    return u_new, v_new, cost


def margin_scan_ref(x, w):
    """|X·w| — un-normalized point-to-hyperplane margins."""
    return jnp.abs(x @ w)


def ah_project_ref(x, u, v):
    """AH-Hash per-pair projections (pre-sign): (x@u, x@v)."""
    return x @ u, x @ v


def eh_scores_ref(x, idx_a, idx_b, g):
    """Dimension-sampled EH pre-sign scores (paper §5.2 trick).

    Bit j of point x: Σ_i g[j,i] · x[a[j,i]] · x[b[j,i]].

    Args:
      x: (n, d); idx_a, idx_b: (k, s) int32; g: (k, s) float32.
    Returns:
      (n, k) scores.
    """
    xa = x[:, idx_a]  # (n, k, s)
    xb = x[:, idx_b]
    return jnp.einsum("nks,ks->nk", xa * xb, g)
