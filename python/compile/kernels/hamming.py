"""Hamming ranking as a ±1 matvec — MXU-friendly code-distance scan.

With codes stored as ±1 floats, agreement between a code row c and a query
q is ``c·q ∈ [−k, k]`` and the Hamming distance is ``(k − c·q)/2``. That
turns the classic popcount scan into a (n, k)×(k,) matvec — exactly the
shape a systolic array wants — which is how the paper's "largest Hamming
distance" retrieval generalizes to accelerators. The Rust coordinator uses
its POPCNT path for the small-k compact regime and can delegate large
ranking sweeps to this kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(c_ref, q_ref, o_ref, *, k):
    c = c_ref[...]                       # (tile_n, k) ±1
    q = q_ref[...]                       # (k, 1) ±1
    agree = jnp.dot(c, q, preferred_element_type=jnp.float32)  # (tile_n, 1)
    o_ref[...] = (k - agree) * 0.5


@functools.partial(jax.jit, static_argnames=("tile_n",))
def hamming_distances(codes_pm, q_pm, *, tile_n=256):
    """Hamming distances between ±1 code rows and a ±1 query code.

    Args:
      codes_pm: (n, k) float32 in {−1, +1}.
      q_pm: (k,) float32 in {−1, +1}.
      tile_n: rows per grid step (n must be divisible).

    Returns:
      (n,) float32 distances in [0, k].
    """
    n, k = codes_pm.shape
    assert q_pm.shape == (k,)
    assert n % tile_n == 0, f"n={n} not a multiple of tile_n={tile_n}"
    import functools as ft

    out = pl.pallas_call(
        ft.partial(_hamming_kernel, k=k),
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(codes_pm, q_pm.reshape(k, 1))
    return out.reshape(n)
