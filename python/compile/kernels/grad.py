"""Weighted column-sum kernel — the X-heavy half of the LBH gradient.

The gradient of the paper's smooth surrogate (eq. 18) is

    g_u = −Xᵀ (σ ⊙ (X v)),    g_v = −Xᵀ (σ ⊙ (X u)),
    σ   = (R b̃) ⊙ (1 − b̃ ⊙ b̃)

The two dense X-passes (one GEMV down, one weighted column-sum up) dominate
at m×d; the m×m product `R b̃` is a plain XLA dot in the L2 graph. This
kernel computes the up-pass

    out = Xᵀ a                                     (d,)

accumulating over a grid of m-tiles so X streams through VMEM once. The
accumulator lives in the output block (constant index_map), initialized on
the first grid step — the standard Pallas reduction idiom.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _colsum_kernel(x_ref, a_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]          # (tile_m, d)
    a = a_ref[...]          # (tile_m, 1)
    part = jnp.dot(x.T, a, preferred_element_type=jnp.float32)  # (d, 1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = part

    @pl.when(i != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("tile_m",))
def weighted_colsum(x, a, *, tile_m=128):
    """``xᵀ @ a`` with a tiled-accumulation Pallas kernel.

    Args:
      x: (m, d) float32.
      a: (m,) float32 weights.
      tile_m: rows per grid step (m must be divisible).

    Returns:
      (d,) float32.
    """
    m, d = x.shape
    assert a.shape == (m,), (x.shape, a.shape)
    assert m % tile_m == 0, f"m={m} not a multiple of tile_m={tile_m}"
    a2 = a.reshape(m, 1)
    out = pl.pallas_call(
        _colsum_kernel,
        grid=(m // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d, 1), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((d, 1), jnp.float32),
        interpret=True,
    )(x, a2)
    return out.reshape(d)
