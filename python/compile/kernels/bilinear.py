"""The bilinear-form kernel: pre-sign scores of BH/LBH hashing.

For a tile of database points ``X (n, d)`` and projection pairs
``U, V (d, k)`` the paper's bilinear hash (eq. 6) is
``h_j(x) = sgn(u_jᵀ x · xᵀ v_j)``, i.e. the elementwise product of two
GEMMs followed by a sign. The kernel computes the pre-sign scores

    S = (X·U) ⊙ (X·V)                                   (n, k)

and leaves the sign to the consumer (the Rust coordinator packs bits with
its own sgn(0)=+1 convention; the L2 training graph feeds the scores into
the sigmoid surrogate instead).

TPU shaping: the n-grid streams X tiles HBM→VMEM while U and V stay
resident in VMEM (their BlockSpec index_map is constant in the grid index),
so each projection byte is fetched once per launch. Both GEMMs target the
MXU with f32 accumulation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bilinear_kernel(x_ref, uv_ref, o_ref, *, k):
    # Single fused GEMM against [U | V] (d, 2k): one pass of the X tile
    # through the MXU instead of two — halves HBM traffic per tile and
    # doubles output-lane occupancy (2k of 128 lanes vs k). §Perf pass.
    x = x_ref[...]
    puv = jnp.dot(x, uv_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = puv[:, :k] * puv[:, k:]


@functools.partial(jax.jit, static_argnames=("tile_n",))
def bilinear_scores(x, u, v, *, tile_n=256):
    """Pre-sign bilinear scores ``(x@u) * (x@v)``.

    Args:
      x: (n, d) float32 — database tile (n must be divisible by tile_n).
      u, v: (d, k) float32 — projection pairs, one column per hash bit.
      tile_n: rows per grid step.

    Returns:
      (n, k) float32 scores; ``sign(scores)`` are the hash bits.
    """
    n, d = x.shape
    du, k = u.shape
    assert du == d and v.shape == (d, k), (x.shape, u.shape, v.shape)
    assert n % tile_n == 0, f"n={n} not a multiple of tile_n={tile_n}"
    grid = (n // tile_n,)
    uv = jnp.concatenate([u, v], axis=1)  # (d, 2k), VMEM-resident
    return pl.pallas_call(
        functools.partial(_bilinear_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 2 * k), lambda i: (0, 0)),  # resident across grid
        ],
        out_specs=pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x, uv)
