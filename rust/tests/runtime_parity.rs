//! Cross-layer integration: AOT artifacts (JAX+Pallas → HLO → PJRT) must
//! produce bit-identical hash codes and numerically identical scans to the
//! native Rust implementations.
//!
//! These tests are skipped (with a loud message) when `artifacts/` has not
//! been built — run `make artifacts` first.

use chh::data::{test_blobs, FeatureStore};
use chh::hash::{BhHash, HashFamily};
use chh::rng::Rng;
use chh::runtime::{BatchEncoder, MarginScanner, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    // tests run from the crate root; artifacts/ lives there
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable: {e:#}");
            return None;
        }
    };
    if !rt.has("encode_bh_test") {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(rt)
}

#[test]
fn pjrt_encode_matches_native_codes_exactly() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::seed_from_u64(31);
    // 600 points: pads the last 256-row tile
    let ds = test_blobs(600, 64, 4, &mut rng);
    let bh = BhHash::sample(64, 8, &mut rng);
    let native = bh.encode_all(ds.features());
    let enc = BatchEncoder::bilinear(&rt, "test").expect("encoder");
    assert_eq!(enc.tile_n(), 256);
    assert_eq!(enc.bits(), 8);
    let pjrt = enc.encode_all(ds.features(), &bh.pairs).expect("pjrt encode");
    assert_eq!(native.len(), pjrt.len());
    let mismatches = native
        .codes
        .iter()
        .zip(pjrt.codes.iter())
        .filter(|(a, b)| a != b)
        .count();
    // float32 GEMM reassociation can flip a score that is exactly at the
    // sign boundary; on random data this should essentially never happen
    assert!(
        mismatches <= native.len() / 500,
        "{mismatches}/{} code mismatches between native and PJRT",
        native.len()
    );
}

#[test]
fn pjrt_margin_scan_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::seed_from_u64(32);
    let ds = test_blobs(300, 64, 3, &mut rng);
    let w = chh::testing::unit_vec(&mut rng, 64);
    let scanner = MarginScanner::open(&rt, "test").expect("scanner");
    let got = scanner.scan(ds.features(), &w).expect("scan");
    assert_eq!(got.len(), 300);
    for i in 0..300 {
        let want = ds.features().row(i).dot(&w).abs();
        assert!(
            (got[i] - want).abs() < 1e-4 * (1.0 + want),
            "row {i}: pjrt {} native {}",
            got[i],
            want
        );
    }
}

#[test]
fn pjrt_hamming_rank_matches_popcount() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::seed_from_u64(33);
    let k = 8usize;
    let n = 256usize;
    // random codes as ±1 floats
    let mut codes_pm = vec![0f32; n * k];
    let mut codes: Vec<u64> = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.next_u64() & chh::hash::codes::mask(k);
        codes.push(c);
        for j in 0..k {
            codes_pm[i * k + j] = if (c >> j) & 1 == 1 { 1.0 } else { -1.0 };
        }
    }
    let q = rng.next_u64() & chh::hash::codes::mask(k);
    let q_pm: Vec<f32> = (0..k).map(|j| if (q >> j) & 1 == 1 { 1.0 } else { -1.0 }).collect();
    let out = rt
        .run_f32("hamming_rank_test", &[(&codes_pm, &[n, k]), (&q_pm, &[k])])
        .expect("run");
    for i in 0..n {
        let want = chh::hash::codes::hamming(codes[i], q, k) as f32;
        assert_eq!(out[0][i], want, "row {i}");
    }
}

#[test]
fn pjrt_lbh_step_matches_native_step() {
    let Some(rt) = runtime_or_skip() else { return };
    let meta = rt.meta("lbh_step_test").unwrap().clone();
    let m = meta.inputs[0].shape[0];
    let d = meta.inputs[0].shape[1];
    let mut rng = Rng::seed_from_u64(34);
    // unit-norm rows, similarity-derived R (same construction as training)
    let ds = test_blobs(m, d, 4, &mut rng);
    let mut xm = chh::linalg::Mat::zeros(m, d);
    for i in 0..m {
        ds.features().row(i).scatter_into(xm.row_mut(i));
    }
    xm.l2_normalize_rows();
    let s = chh::lbh::similarity_matrix(&xm, 0.8, 0.2);
    let mut r = s.clone();
    chh::linalg::scal(8.0, &mut r.data);
    let u = rng.gauss_vec(d);
    let v = rng.gauss_vec(d);
    let lr = [0.05f32];
    let mu = [0.9f32];
    let out = rt
        .run_f32(
            "lbh_step_test",
            &[
                (&xm.data, &[m, d]),
                (&r.data, &[m, m]),
                (&u, &[d]),
                (&v, &[d]),
                (&u, &[d]),
                (&v, &[d]),
                (&lr, &[1]),
                (&mu, &[1]),
            ],
        )
        .expect("run lbh_step");
    // native replica of the same Nesterov step (u_prev == u ⇒ lookahead = u)
    let (gu, gv) = chh::lbh::surrogate_grad(&xm, &r, &u, &v);
    let un: Vec<f32> = u.iter().zip(gu.iter()).map(|(a, g)| a - lr[0] * g).collect();
    let vn: Vec<f32> = v.iter().zip(gv.iter()).map(|(a, g)| a - lr[0] * g).collect();
    for i in 0..d {
        assert!(
            (out[0][i] - un[i]).abs() < 1e-3 * (1.0 + un[i].abs()),
            "u[{i}]: pjrt {} native {}",
            out[0][i],
            un[i]
        );
        assert!(
            (out[1][i] - vn[i]).abs() < 1e-3 * (1.0 + vn[i].abs()),
            "v[{i}]: pjrt {} native {}",
            out[1][i],
            vn[i]
        );
    }
    // cost output: compare against native surrogate at the new point
    let mut buf = Vec::new();
    let native_cost = chh::lbh::surrogate_eval(&xm, &r, &un, &vn, &mut buf);
    assert!(
        (out[2][0] - native_cost).abs() < 2e-2 * (1.0 + native_cost.abs()),
        "cost: pjrt {} native {}",
        out[2][0],
        native_cost
    );
}

#[test]
fn pjrt_trainer_produces_working_hash() {
    // Full PJRT-backed LBH training (every Nesterov step on XLA) must
    // produce a hash of comparable retrieval quality to the native trainer
    // on the same data/seed.
    let Some(rt) = runtime_or_skip() else { return };
    let stepper = match chh::runtime::LbhStepper::open(&rt, "test") {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return;
        }
    };
    let mut rng = Rng::seed_from_u64(40);
    let ds = test_blobs(600, stepper.dim, 4, &mut rng);
    let sample = rng.sample_indices(ds.len(), 96); // < artifact m → padded
    let refs: Vec<usize> = (0..ds.len()).collect();
    let trainer = chh::lbh::LbhTrainer::new(chh::lbh::LbhTrainConfig {
        bits: 8,
        iters_per_bit: 40,
        ..Default::default()
    });
    let mut rng_a = Rng::seed_from_u64(41);
    let (fam_pjrt, stats_pjrt) = trainer
        .train_pjrt(&stepper, ds.features(), &sample, &refs, &mut rng_a)
        .expect("pjrt training");
    let mut rng_b = Rng::seed_from_u64(41);
    let (fam_native, stats_native) = trainer.train(ds.features(), &sample, &refs, &mut rng_b);
    // same thresholds (identical rule on identical data)
    assert!((stats_pjrt.t1 - stats_native.t1).abs() < 1e-5);
    assert!((stats_pjrt.t2 - stats_native.t2).abs() < 1e-5);
    // both reduce per-bit cost to a similar level (float paths differ, so
    // compare aggregate quality, not bit-exact projections)
    let sum = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>();
    let c_p = sum(&stats_pjrt.discrete_costs);
    let c_n = sum(&stats_native.discrete_costs);
    assert!(
        c_p < 0.5 * c_n.min(0.0) || (c_p - c_n).abs() < 0.5 * c_n.abs().max(1.0),
        "pjrt discrete cost {c_p} vs native {c_n}"
    );
    // and the trained hash actually works as an index
    let index = chh::table::HyperplaneIndex::build(&fam_pjrt, ds.features(), 3);
    let w = chh::testing::unit_vec(&mut rng, stepper.dim);
    let hit = index.query(&fam_pjrt, &w, ds.features());
    assert!(hit.probed > 0);
    let _ = fam_native;
}

#[test]
fn manifest_covers_all_profiles() {
    let Some(rt) = runtime_or_skip() else { return };
    for profile in ["test", "news", "tiny"] {
        for kind in [
            "encode_bh",
            "encode_ah",
            "encode_eh",
            "margin_scan",
            "hamming_rank",
            "lbh_step",
        ] {
            let name = format!("{kind}_{profile}");
            assert!(rt.has(&name), "artifact {name} missing from manifest");
        }
    }
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let Some(rt) = runtime_or_skip() else { return };
    let bad = vec![0f32; 10];
    assert!(rt.run_f32("encode_bh_test", &[(&bad, &[10usize] as &[usize])]).is_err());
}
