//! Crash-recovery acceptance tests for the durability subsystem:
//!
//! * **Torn-tail fuzz** — truncate a WAL segment at *every* byte
//!   boundary and assert recovery always yields exactly the longest
//!   valid prefix of the journaled operations (never an error, never a
//!   partial frame applied).
//! * **Crash parity** — after concurrent inserts/removes (with
//!   checkpoints racing them) and a crash-style stop, the recovered
//!   index answers hyperplane queries **bit-identically** to the
//!   pre-crash index over every acknowledged operation.
//! * **Mid-log corruption** — a bad frame in a non-final segment stops
//!   replay at the valid prefix instead of erroring or reordering.

use std::path::PathBuf;
use std::sync::Arc;

use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::online::{QueryBudget, ShardedIndex};
use chh::rng::Rng;
use chh::testing::unit_vec;
use chh::wal::{frame, log, recover, DurableIndex, FsyncPolicy, Record, WalConfig};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("chh_wal_it_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn wal_cfg(dir: &PathBuf, segment_bytes: u64) -> WalConfig {
    WalConfig { dir: dir.clone(), fsync: FsyncPolicy::Always, segment_bytes, faults: None }
}

fn sorted_entries(index: &ShardedIndex) -> Vec<Vec<(u32, u64)>> {
    index
        .shards()
        .iter()
        .map(|s| {
            let mut e = s.live_entries();
            e.sort_unstable();
            e
        })
        .collect()
}

#[test]
fn torn_tail_fuzz_every_byte_boundary() {
    let dir = tmpdir("fuzz");
    let cfg = wal_cfg(&dir, 1 << 20);
    let d = DurableIndex::create(Arc::new(ShardedIndex::new(12, 2, 3)), &cfg).unwrap();
    // journal a deterministic op mix (removes may target absent ids —
    // they journal and replay as no-ops)
    let mut rng = Rng::seed_from_u64(5);
    let mut ops: Vec<Record> = Vec::new();
    for i in 0..40u32 {
        if i % 4 == 3 {
            let id = rng.below(40) as u32;
            let _ = d.remove(id).unwrap();
            ops.push(Record::Remove { id });
        } else {
            let code = rng.next_u64() & chh::hash::codes::mask(12);
            d.insert(i, code).unwrap();
            ops.push(Record::Insert { id: i, code });
        }
    }
    // crash-style stop: drop without a checkpoint, ops live only in WAL
    drop(d);
    let segs = log::list_segments(&dir).unwrap();
    assert_eq!(segs.len(), 1, "one big segment expected");
    let seg_path = segs[0].1.clone();
    let full = std::fs::read(&seg_path).unwrap();
    let mut boundaries = vec![0usize];
    for r in &ops {
        boundaries.push(boundaries.last().unwrap() + frame::frame_len(r));
    }
    assert_eq!(*boundaries.last().unwrap(), full.len(), "frame accounting");
    for cut in 0..=full.len() {
        std::fs::write(&seg_path, &full[..cut]).unwrap();
        let (back, report) =
            recover(&dir).unwrap_or_else(|e| panic!("cut at byte {cut}: recover errored {e:#}"));
        // the longest valid prefix = whole frames below the cut
        let j = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        assert_eq!(report.replayed, j, "cut at byte {cut}");
        assert_eq!(report.torn_bytes, (cut - boundaries[j]) as u64, "cut at byte {cut}");
        // the reported end position is the valid-prefix boundary — what
        // `chh recover --inspect --json` exposes as last_applied_seq/off
        assert_eq!(
            (report.end_seg, report.end_off),
            (1, boundaries[j] as u64),
            "cut at byte {cut}: end position"
        );
        let expect = ShardedIndex::new(12, 2, 3);
        for r in &ops[..j] {
            match *r {
                Record::Insert { id, code } => expect.insert(id, code),
                Record::Remove { id } => {
                    expect.remove(id);
                }
                Record::Checkpoint { .. } => unreachable!(),
            }
        }
        assert_eq!(back.len(), expect.len(), "cut at byte {cut}");
        assert_eq!(
            sorted_entries(&back),
            sorted_entries(&expect),
            "cut at byte {cut}: recovered state must be the valid prefix's state"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_parity_under_concurrent_churn_and_checkpoints() {
    let dir = tmpdir("parity");
    let mut rng = Rng::seed_from_u64(7);
    let ds = test_blobs(300, 16, 3, &mut rng);
    let fam = BhHash::sample(16, 10, &mut rng);
    let codes = Arc::new(fam.encode_all(ds.features()));
    // tiny segments: churn forces size-rolls, checkpoints force
    // rotation + GC, all while appenders run
    let cfg = wal_cfg(&dir, 2048);
    let d = Arc::new(
        DurableIndex::create(Arc::new(ShardedIndex::new(10, 3, 4)), &cfg).unwrap(),
    );
    let n = ds.len();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let d = d.clone();
        let codes = codes.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(100 + t);
            for _ in 0..150 {
                let id = rng.below(n) as u32;
                if rng.bernoulli(0.7) {
                    d.insert(id, codes.get(id as usize)).unwrap();
                } else {
                    let _ = d.remove(id).unwrap();
                }
            }
        }));
    }
    let ck = {
        let d = d.clone();
        std::thread::spawn(move || {
            for _ in 0..3 {
                std::thread::sleep(std::time::Duration::from_millis(5));
                d.checkpoint().unwrap();
            }
        })
    };
    for j in joins {
        j.join().unwrap();
    }
    ck.join().unwrap();
    // every op above was acknowledged (fsync: always) — snapshot the
    // pre-crash answers, then "crash" (drop without final checkpoint)
    let pre_index = d.index().clone();
    let pre_entries = sorted_entries(&pre_index);
    let pre_len = pre_index.len();
    drop(d);
    let (back, report) = recover(&dir).unwrap();
    assert!(report.snapshot_gen >= 1, "mid-run checkpoints produced snapshots");
    assert_eq!(back.len(), pre_len, "no acknowledged op may be lost");
    assert_eq!(sorted_entries(&back), pre_entries, "live (id, code) sets identical");
    // bit-identical serving: same hits, margins, and probe counters
    let budget = QueryBudget::new(256, 64);
    for q in 0..12 {
        let w = unit_vec(&mut rng, 16);
        let a = pre_index.query(&fam, &w, ds.features(), budget, |_| true);
        let b = back.query(&fam, &w, ds.features(), budget, |_| true);
        match (a.best, b.best) {
            (Some((ia, ma)), Some((ib, mb))) => {
                assert_eq!(ia, ib, "query {q}: best id");
                assert_eq!(ma.to_bits(), mb.to_bits(), "query {q}: bit-identical margin");
            }
            (None, None) => {}
            (x, y) => panic!("query {q}: best mismatch {x:?} vs {y:?}"),
        }
        assert_eq!(a.scanned, b.scanned, "query {q}: scanned");
        assert_eq!(a.probed, b.probed, "query {q}: probed");
        assert_eq!(a.nonempty, b.nonempty, "query {q}: nonempty");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mid_log_corruption_yields_valid_prefix_not_error() {
    let dir = tmpdir("midlog");
    // ~6 frames per 128-byte segment: 60 inserts spread over many files
    let cfg = wal_cfg(&dir, 128);
    let d = DurableIndex::create(Arc::new(ShardedIndex::new(10, 2, 2)), &cfg).unwrap();
    for id in 0..60u32 {
        d.insert(id, (id % 13) as u64).unwrap();
    }
    drop(d);
    let segs = log::list_segments(&dir).unwrap();
    assert!(segs.len() >= 3, "expected several segments, got {}", segs.len());
    // smash a byte in the middle of the second segment
    let victim = segs[1].1.clone();
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();
    let (back, report) = recover(&dir).unwrap();
    assert!(report.segments_skipped >= 1, "later segments must not be applied");
    assert!(report.torn_bytes > 0);
    // distinct ids, inserts only ⇒ live count == applied inserts, and
    // the applied set is a strict prefix of the op order
    assert_eq!(back.len(), report.inserts);
    assert!(report.inserts < 60 && report.inserts > 0);
    for shard in back.shards() {
        for (id, code) in shard.live_entries() {
            assert!(id < report.inserts as u32, "only prefix ids may be live");
            assert_eq!(code, (id % 13) as u64);
        }
    }
    // a lossy recovery must not be checkpointed implicitly: open()
    // refuses (the damaged segments are the only copy of the lost
    // tail), while open_forced() accepts the loss explicitly
    assert!(report.lossy());
    assert!(
        DurableIndex::open(&cfg).is_err(),
        "open() must refuse to checkpoint a lossy recovery"
    );
    let (d, forced_report) = DurableIndex::open_forced(&cfg).unwrap();
    assert_eq!(d.index().len(), report.inserts);
    assert_eq!(forced_report.inserts, report.inserts);
    drop(d);
    // forcing checkpointed the prefix: the dir is clean from here on
    let (_, clean) = recover(&dir).unwrap();
    assert!(!clean.lossy());
    assert_eq!(clean.replayed, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replay_rejects_over_k_codes() {
    // masked-scan regression: a CRC-valid insert frame whose code has
    // bits above the index's k (a log written by a mismatched index)
    // must be a hard recovery error, not a silently-applied scan skew
    let dir = tmpdir("overk");
    let cfg = wal_cfg(&dir, 1 << 20);
    let d = DurableIndex::create(Arc::new(ShardedIndex::new(10, 2, 2)), &cfg).unwrap();
    d.insert(1, 0b11_1111_1111).unwrap(); // all 10 bits set: still valid
    d.insert(2, 1 << 10).unwrap(); // bit above k — journals, must fail replay
    drop(d);
    let err = recover(&dir).unwrap_err().to_string();
    assert!(err.contains("exceeding 10 bits"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reopen_after_crash_then_clean_close_is_stable() {
    let dir = tmpdir("reopen");
    let cfg = wal_cfg(&dir, 1 << 20);
    {
        let d = DurableIndex::create(Arc::new(ShardedIndex::new(8, 2, 2)), &cfg).unwrap();
        for id in 0..50u32 {
            d.insert(id, (id % 5) as u64).unwrap();
        }
        drop(d); // crash
    }
    // restart: open() replays the suffix and folds it into a checkpoint
    let (d, report) = DurableIndex::open(&cfg).unwrap();
    assert_eq!(report.replayed, 50);
    assert_eq!(d.index().len(), 50);
    for id in 50..70u32 {
        d.insert(id, 1).unwrap();
    }
    d.close().unwrap();
    // after a clean close nothing replays, state is complete
    let (back, r2) = recover(&dir).unwrap();
    assert_eq!(r2.replayed, 0);
    assert_eq!(back.len(), 70);
    let _ = std::fs::remove_dir_all(&dir);
}
