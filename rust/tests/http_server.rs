//! End-to-end tests of the HTTP serving front-end: a live server on an
//! ephemeral port, driven by a raw TCP client. The core assertion is the
//! acceptance criterion of the serving subsystem — responses that crossed
//! the wire (JSON both ways, coalesced through the micro-batcher) are
//! **bit-identical** to direct `query_batch_pooled` calls on the same
//! index — plus mutation round-trips, malformed-input behavior and
//! graceful shutdown.

use std::sync::Arc;
use std::time::Duration;

use chh::coordinator::{OnlineRouter, QueryRequest, Router};
use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::online::{QueryBudget, ShardedIndex};
use chh::par::Pool;
use chh::replicate::{spawn_tailer, ReplicaConfig, ReplicaIndex};
use chh::rng::Rng;
use chh::server::{
    binproto, protocol, BatcherConfig, Durability, HttpClient, ReplicaRole, Server, ServerConfig,
    Stack,
};
use chh::table::HyperplaneIndex;
use chh::testing::unit_vec;
use chh::wal::{DurableIndex, FsyncPolicy, WalConfig};

const DIM: usize = 16;

fn server_cfg() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        max_conns: 32,
        conn_workers: 2,
        batch: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        },
        pool_workers: 2,
        // short idle reap so shutdown never waits long on parked clients
        idle_timeout: Duration::from_millis(300),
        slow_ms: 0,
        slow_log: None,
        audit_frac: 0.0,
    }
}

fn static_stack(n: usize, seed: u64) -> (Stack, Arc<Router>) {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = test_blobs(n, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let idx = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 4));
    let feats = Arc::new(ds.features().clone());
    let router = Arc::new(Router::new(fam, idx, feats, 1, 16));
    (Stack::Static(router.clone()), router)
}

fn online_stack(n: usize, seed: u64) -> (Stack, Arc<OnlineRouter>) {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = test_blobs(n, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let codes = fam.encode_all(ds.features());
    let idx = Arc::new(ShardedIndex::from_codes(&codes, 4, 3));
    let feats = Arc::new(ds.features().clone());
    let router = Arc::new(OnlineRouter::new(
        fam,
        idx,
        feats,
        1,
        16,
        QueryBudget::new(256, 64),
    ));
    (Stack::Online(router.clone()), router)
}

fn assert_hits_identical(wire: &chh::table::QueryHit, direct: &chh::table::QueryHit, ctx: &str) {
    match (wire.best, direct.best) {
        (Some((wi, wm)), Some((di, dm))) => {
            assert_eq!(wi, di, "{ctx}: best id");
            assert_eq!(wm.to_bits(), dm.to_bits(), "{ctx}: margin must be bit-identical");
        }
        (None, None) => {}
        (a, b) => panic!("{ctx}: best mismatch {a:?} vs {b:?}"),
    }
    assert_eq!(wire.scanned, direct.scanned, "{ctx}: scanned");
    assert_eq!(wire.probed, direct.probed, "{ctx}: probed");
    assert_eq!(wire.nonempty, direct.nonempty, "{ctx}: nonempty");
}

#[test]
fn static_wire_responses_match_query_batch_pooled() {
    let (stack, router) = static_stack(500, 11);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut rng = Rng::seed_from_u64(99);
    let ws: Vec<Vec<f32>> = (0..24).map(|_| unit_vec(&mut rng, DIM)).collect();
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(10)).unwrap();
    let mut wire_hits = Vec::new();
    for w in &ws {
        let resp = client.post("/query", &protocol::query_body(w)).expect("post /query");
        assert_eq!(resp.status, 200);
        wire_hits.push(protocol::parse_hit(&resp.body).expect("parse hit"));
    }
    drop(client);
    let reqs: Vec<QueryRequest> =
        ws.iter().map(|w| QueryRequest { w: w.clone(), exclude: None }).collect();
    let direct = router.query_batch_pooled(&reqs, &Pool::new(2));
    for (i, (wh, dh)) in wire_hits.iter().zip(direct.iter()).enumerate() {
        assert_hits_identical(wh, dh, &format!("static query {i}"));
    }
    handle.shutdown();
}

#[test]
fn concurrent_clients_through_the_batcher_stay_bit_identical() {
    let (stack, router) = static_stack(600, 21);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let threads = 6;
    let per = 15;
    let mut joins = Vec::new();
    for t in 0..threads {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(1000 + t as u64);
            let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
            client.set_timeout(Duration::from_secs(10)).unwrap();
            let mut out = Vec::new();
            for _ in 0..per {
                let w = unit_vec(&mut rng, DIM);
                let resp =
                    client.post("/query", &protocol::query_body(&w)).expect("post /query");
                assert_eq!(resp.status, 200);
                out.push((w, protocol::parse_hit(&resp.body).expect("parse hit")));
            }
            out
        }));
    }
    let all: Vec<(Vec<f32>, chh::table::QueryHit)> =
        joins.into_iter().flat_map(|j| j.join().expect("client thread")).collect();
    assert_eq!(all.len(), threads * per);
    // every wire answer — whatever batch it was coalesced into — must be
    // bit-identical to the direct pooled call for the same hyperplane
    let reqs: Vec<QueryRequest> =
        all.iter().map(|(w, _)| QueryRequest { w: w.clone(), exclude: None }).collect();
    let direct = router.query_batch_pooled(&reqs, &Pool::new(3));
    for (i, ((_, wh), dh)) in all.iter().zip(direct.iter()).enumerate() {
        assert_hits_identical(wh, dh, &format!("concurrent query {i}"));
    }
    // the batcher processed every query exactly once
    let mut stats_client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    let resp = stats_client.get("/stats").expect("get /stats");
    let v = chh::jsonio::Json::parse_bytes(&resp.body).expect("stats json");
    let batcher = v.get("batcher").expect("batcher section");
    assert_eq!(
        batcher.get("flushed").and_then(|x| x.as_usize()),
        Some(threads * per),
        "batcher must flush every submitted query exactly once"
    );
    let batches = batcher.get("batches").and_then(|x| x.as_usize()).unwrap();
    assert!(batches <= threads * per, "batch count can never exceed query count");
    drop(stats_client);
    handle.shutdown();
}

#[test]
fn online_wire_parity_insert_remove_and_topk() {
    let (stack, router) = online_stack(400, 31);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut rng = Rng::seed_from_u64(77);
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(10)).unwrap();

    // wire vs direct parity on the online stack
    let ws: Vec<Vec<f32>> = (0..10).map(|_| unit_vec(&mut rng, DIM)).collect();
    let mut wire_hits = Vec::new();
    for w in &ws {
        let resp = client.post("/query", &protocol::query_body(w)).expect("post /query");
        assert_eq!(resp.status, 200);
        wire_hits.push(protocol::parse_hit(&resp.body).expect("parse hit"));
    }
    let reqs: Vec<QueryRequest> =
        ws.iter().map(|w| QueryRequest { w: w.clone(), exclude: None }).collect();
    let direct = router.query_batch_pooled(&reqs, &Pool::new(2));
    for (i, (wh, dh)) in wire_hits.iter().zip(direct.iter()).enumerate() {
        assert_hits_identical(wh, dh, &format!("online query {i}"));
    }

    // topk over the wire == direct index call, bit for bit
    let w = ws[0].clone();
    let resp = client.post("/query_topk", &protocol::topk_body(&w, 7)).expect("post topk");
    assert_eq!(resp.status, 200);
    let wire_top = protocol::parse_topk_hits(&resp.body).expect("parse topk");
    let direct_top = router.index().query_topk(
        router.family().as_ref(),
        &w,
        router.feats(),
        7,
        router.budget(),
        |_| true,
    );
    assert_eq!(wire_top.len(), direct_top.len());
    for ((wi, wm), (di, dm)) in wire_top.iter().zip(direct_top.iter()) {
        assert_eq!(wi, di);
        assert_eq!(wm.to_bits(), dm.to_bits());
    }

    // remove the best hit over the wire; it must vanish from the index
    let (best, _) = wire_hits[0].best.expect("small blob query hits");
    let resp = client.post("/remove", &protocol::id_body(best as u32)).expect("post remove");
    assert_eq!(resp.status, 200);
    assert!(!router.index().contains(best as u32), "removed over the wire");
    let resp = client.post("/query", &protocol::query_body(&ws[0])).expect("re-query");
    let requeried = protocol::parse_hit(&resp.body).expect("parse hit");
    assert_ne!(
        requeried.best.map(|(i, _)| i),
        Some(best),
        "removed id must not be served again"
    );
    // double remove reports removed=false
    let resp = client.post("/remove", &protocol::id_body(best as u32)).expect("re-remove");
    let v = chh::jsonio::Json::parse_bytes(&resp.body).unwrap();
    assert_eq!(v.get("removed").and_then(|x| x.as_bool()), Some(false));

    // insert it back
    let resp = client.post("/insert", &protocol::id_body(best as u32)).expect("post insert");
    assert_eq!(resp.status, 200);
    assert!(router.index().contains(best as u32), "re-inserted over the wire");
    // out-of-store ids are rejected
    let resp = client.post("/insert", &protocol::id_body(1_000_000)).expect("bad insert");
    assert_eq!(resp.status, 400);

    drop(client);
    handle.shutdown();
}

#[test]
fn malformed_requests_get_clean_errors() {
    let (stack, _router) = static_stack(200, 41);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();

    // request-level garbage: 400 then close
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(b"total garbage\r\n\r\n").unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 400"), "got {text:?}");
    }

    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(5)).unwrap();
    // route-level errors keep the connection usable
    let resp = client.post("/no_such_route", "{}").unwrap();
    assert_eq!(resp.status, 404);
    let resp = client.get("/query").unwrap();
    assert_eq!(resp.status, 405, "GET on a POST route");
    let resp = client.post("/query", "{not json").unwrap();
    assert_eq!(resp.status, 400);
    let resp = client.post("/query", &protocol::query_body(&[1.0; 3])).unwrap();
    assert_eq!(resp.status, 400, "dimension mismatch");
    let resp = client.post("/query", r#"{"w": [[1],[2]]}"#).unwrap();
    assert_eq!(resp.status, 400, "non-numeric w");
    // the static stack refuses mutations
    let resp = client.post("/insert", &protocol::id_body(1)).unwrap();
    assert_eq!(resp.status, 400);
    // deeply nested payloads are rejected, not stack-overflowed
    let deep = format!("{}1{}", "[".repeat(4000), "]".repeat(4000));
    let resp = client.post("/query", &deep).unwrap();
    assert_eq!(resp.status, 400);
    // and a good request still works on the same connection
    let resp = client.post("/query", &protocol::query_body(&[0.5; DIM])).unwrap();
    assert_eq!(resp.status, 200);
    let resp = client.get("/healthz").unwrap();
    assert_eq!(resp.status, 200);
    drop(client);
    handle.shutdown();
}

#[test]
fn binary_wire_matches_json_wire_and_direct() {
    let (stack, router) = static_stack(500, 81);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut rng = Rng::seed_from_u64(123);
    let ws: Vec<Vec<f32>> = (0..16).map(|_| unit_vec(&mut rng, DIM)).collect();
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(10)).unwrap();
    let mut json_hits = Vec::new();
    let mut bin_hits = Vec::new();
    for w in &ws {
        // same hyperplane over both wires, interleaved on ONE connection:
        // negotiation is per-request, not per-socket
        let jresp = client.post("/query", &protocol::query_body(w)).expect("json /query");
        assert_eq!(jresp.status, 200);
        assert!(!jresp.binary, "json request gets a json response");
        json_hits.push(protocol::parse_hit(&jresp.body).expect("parse json hit"));
        let breq = binproto::encode_query(w, None);
        let bresp = client.post_binary("/query", &breq).expect("binary /query");
        assert_eq!(bresp.status, 200);
        assert!(bresp.binary, "binary request gets a binary response");
        bin_hits.push(binproto::decode_hit(&bresp.body).expect("decode binary hit"));
    }
    drop(client);
    let reqs: Vec<QueryRequest> =
        ws.iter().map(|w| QueryRequest { w: w.clone(), exclude: None }).collect();
    let direct = router.query_batch_pooled(&reqs, &Pool::new(2));
    for (i, ((jh, bh), dh)) in
        json_hits.iter().zip(bin_hits.iter()).zip(direct.iter()).enumerate()
    {
        assert_hits_identical(jh, dh, &format!("json wire vs direct {i}"));
        assert_hits_identical(bh, dh, &format!("binary wire vs direct {i}"));
        assert_hits_identical(bh, jh, &format!("binary wire vs json wire {i}"));
    }
    handle.shutdown();
}

#[test]
fn binary_online_topk_and_mutation_acks() {
    let (stack, router) = online_stack(400, 91);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut rng = Rng::seed_from_u64(456);
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(10)).unwrap();

    // topk: binary wire == json wire, bit for bit
    let w = unit_vec(&mut rng, DIM);
    let jresp = client.post("/query_topk", &protocol::topk_body(&w, 7)).expect("json topk");
    assert_eq!(jresp.status, 200);
    let jt = protocol::parse_topk_hits(&jresp.body).expect("parse json topk");
    let bresp =
        client.post_binary("/query_topk", &binproto::encode_topk(&w, 7, None)).expect("bin topk");
    assert_eq!(bresp.status, 200);
    let bt = binproto::decode_topk_hits(&bresp.body).expect("decode binary topk");
    assert_eq!(jt.len(), bt.len(), "topk lengths");
    for ((ji, jm), (bi, bm)) in jt.iter().zip(bt.iter()) {
        assert_eq!(ji, bi, "topk id");
        assert_eq!(jm.to_bits(), bm.to_bits(), "topk margin bits");
    }

    // binary mutations round-trip through typed acks
    let resp = client
        .post_binary("/remove", &binproto::encode_id(binproto::TAG_REMOVE, 3))
        .expect("bin remove");
    assert_eq!(resp.status, 200);
    assert!(resp.binary);
    let (removed, id, live) = binproto::decode_ack(&resp.body).expect("decode remove ack");
    assert!(removed, "first remove applies");
    assert_eq!(id, 3);
    assert_eq!(live as usize, router.index().len());
    assert!(!router.index().contains(3));
    // double remove acks removed=false
    let resp = client
        .post_binary("/remove", &binproto::encode_id(binproto::TAG_REMOVE, 3))
        .expect("bin re-remove");
    let (removed, _, _) = binproto::decode_ack(&resp.body).expect("decode re-remove ack");
    assert!(!removed, "second remove is a no-op");
    // insert it back
    let resp = client
        .post_binary("/insert", &binproto::encode_id(binproto::TAG_INSERT, 3))
        .expect("bin insert");
    assert_eq!(resp.status, 200);
    let (inserted, id, _) = binproto::decode_ack(&resp.body).expect("decode insert ack");
    assert!(inserted);
    assert_eq!(id, 3);
    assert!(router.index().contains(3));
    // out-of-store ids are rejected with a JSON error (errors are always
    // json, whatever the request wire)
    let resp = client
        .post_binary("/insert", &binproto::encode_id(binproto::TAG_INSERT, 1_000_000))
        .expect("bad bin insert");
    assert_eq!(resp.status, 400);
    assert!(!resp.binary, "errors come back as json");
    drop(client);
    handle.shutdown();
}

#[test]
fn malformed_binary_gets_clean_json_errors() {
    let (stack, _router) = static_stack(200, 101);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(5)).unwrap();
    let w = vec![0.5f32; DIM];
    // garbage body
    let resp = client.post_binary("/query", &[1, 2, 3]).unwrap();
    assert_eq!(resp.status, 400);
    assert!(!resp.binary, "decode errors are json");
    // every truncation of a valid frame fails cleanly and keeps the
    // connection usable
    let frame = binproto::encode_query(&w, None);
    for cut in [0, 1, 4, 5, 8, frame.len() - 1] {
        let resp = client.post_binary("/query", &frame[..cut]).unwrap();
        assert_eq!(resp.status, 400, "truncated at {cut}");
    }
    // wrong tag for the route
    let resp = client.post_binary("/query", &binproto::encode_topk(&w, 3, None)).unwrap();
    assert_eq!(resp.status, 400, "topk frame on /query");
    // dimension mismatch
    let resp = client.post_binary("/query", &binproto::encode_query(&[1.0; 3], None)).unwrap();
    assert_eq!(resp.status, 400, "dimension mismatch");
    // and a good request still works on the same connection
    let resp = client.post_binary("/query", &frame).unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.binary);
    drop(client);
    handle.shutdown();
}

#[test]
fn durable_server_graceful_shutdown_needs_no_replay() {
    let dir = std::env::temp_dir().join(format!("chh_http_wal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // online stack whose ShardedIndex is shared with a DurableIndex
    let mut rng = Rng::seed_from_u64(61);
    let ds = test_blobs(200, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let codes = fam.encode_all(ds.features());
    let idx = Arc::new(ShardedIndex::from_codes(&codes, 4, 3));
    let feats = Arc::new(ds.features().clone());
    let router = Arc::new(OnlineRouter::new(
        fam,
        idx.clone(),
        feats,
        1,
        16,
        QueryBudget::new(256, 64),
    ));
    let wal_cfg = WalConfig {
        dir: dir.clone(),
        fsync: FsyncPolicy::Always,
        segment_bytes: 1 << 20,
        faults: None,
    };
    let durable = Arc::new(DurableIndex::create(idx, &wal_cfg).expect("create wal dir"));
    let handle = Server::spawn_with_durability(
        Stack::Online(router.clone()),
        server_cfg(),
        Some(Durability { durable: durable.clone(), snapshot_every_ops: 0 }),
    )
    .expect("spawn durable server");
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(10)).unwrap();
    // mutate over the wire: 5 removes, 2 inserted back — all journaled
    for id in 0..5u32 {
        let resp = client.post("/remove", &protocol::id_body(id)).expect("post remove");
        assert_eq!(resp.status, 200);
    }
    for id in 0..2u32 {
        let resp = client.post("/insert", &protocol::id_body(id)).expect("post insert");
        assert_eq!(resp.status, 200);
    }
    assert_eq!(router.index().len(), 197);
    // /stats exposes the durability counters
    let resp = client.get("/stats").expect("get /stats");
    let v = chh::jsonio::Json::parse_bytes(&resp.body).expect("stats json");
    let dur = v.get("durability").expect("durability section");
    assert_eq!(dur.get("wal_records").and_then(|x| x.as_usize()), Some(7));
    assert_eq!(dur.get("last_snapshot_gen").and_then(|x| x.as_usize()), Some(0));
    assert!(dur.get("group_commit").is_some());
    assert_eq!(v.get("points").and_then(|x| x.as_usize()), Some(200));
    // graceful shutdown must flush + checkpoint before the server exits
    let resp = client.post("/shutdown", "").expect("post /shutdown");
    assert_eq!(resp.status, 200);
    drop(client);
    handle.wait();
    assert!(durable.snapshot_gen() >= 1, "shutdown wrote a checkpoint");
    drop(router);
    drop(durable);
    // a clean stop leaves nothing to replay, and no state is lost
    let (back, report) = chh::wal::recover(&dir).expect("recover after clean stop");
    assert_eq!(report.replayed, 0, "clean shutdown must replay zero records");
    assert_eq!(back.len(), 197, "recovered live count matches the served index");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replica_answers_reads_bit_identically_under_wire_churn() {
    let dir = std::env::temp_dir().join(format!("chh_http_repl_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // ── primary: durable online server over a prebuilt index ─────────
    let mut rng = Rng::seed_from_u64(71);
    let ds = test_blobs(300, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let codes = fam.encode_all(ds.features());
    let idx = Arc::new(ShardedIndex::from_codes(&codes, 4, 3));
    let feats = Arc::new(ds.features().clone());
    let budget = QueryBudget::new(256, 64);
    let wal_cfg = WalConfig {
        dir: dir.clone(),
        fsync: FsyncPolicy::Always,
        segment_bytes: 1 << 20,
        faults: None,
    };
    let durable = Arc::new(DurableIndex::create(idx.clone(), &wal_cfg).expect("create wal"));
    let prouter = Arc::new(OnlineRouter::new(
        fam.clone(),
        idx.clone(),
        feats.clone(),
        1,
        16,
        budget,
    ));
    let primary = Server::spawn_with_durability(
        Stack::Online(prouter),
        server_cfg(),
        Some(Durability { durable: durable.clone(), snapshot_every_ops: 0 }),
    )
    .expect("spawn primary");
    let paddr = primary.addr().to_string();

    // ── replica: bootstrap over HTTP, tail in the background, serve ──
    let rcfg = ReplicaConfig {
        poll: Duration::from_millis(5),
        ..ReplicaConfig::new(&paddr)
    };
    let replica = ReplicaIndex::bootstrap(&rcfg).expect("bootstrap replica");
    assert_eq!(replica.index().len(), 300, "base snapshot carries the prebuilt index");
    let tailer = spawn_tailer(replica.clone(), rcfg);
    // parity needs the same family + feature store the primary serves
    let rrouter = Arc::new(OnlineRouter::new(
        fam.clone(),
        replica.index().clone(),
        feats.clone(),
        1,
        16,
        budget,
    ));
    let replica_srv = Server::spawn_replica(
        Stack::Online(rrouter),
        server_cfg(),
        ReplicaRole {
            replica: replica.clone(),
            primary_addr: paddr.clone(),
            tailer: Some(tailer),
        },
    )
    .expect("spawn replica server");
    let raddr = replica_srv.addr().to_string();

    // ── concurrent wire mutations through the primary ────────────────
    let threads = 4;
    let mut joins = Vec::new();
    for t in 0..threads {
        let paddr = paddr.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(4000 + t as u64);
            let mut client = HttpClient::connect_retry(&paddr, Duration::from_secs(5)).unwrap();
            client.set_timeout(Duration::from_secs(10)).unwrap();
            for _ in 0..40 {
                let id = rng.below(300) as u32;
                let path = if rng.bernoulli(0.6) { "/insert" } else { "/remove" };
                let resp = client.post(path, &protocol::id_body(id)).expect("mutation");
                assert_eq!(resp.status, 200, "primary mutation under churn");
            }
        }));
    }
    for j in joins {
        j.join().expect("mutator thread");
    }

    // ── quiesce: the replica reaches the durable watermark ───────────
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    while !(replica.caught_up() && replica.index().len() == idx.len()) {
        assert!(
            std::time::Instant::now() < deadline,
            "replica never caught up: {:?} vs {:?}",
            replica.position(),
            durable.durable_watermark()
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // ── identical reads over the wire, bit for bit ───────────────────
    let mut pc = HttpClient::connect_retry(&paddr, Duration::from_secs(5)).unwrap();
    let mut rc = HttpClient::connect_retry(&raddr, Duration::from_secs(5)).unwrap();
    pc.set_timeout(Duration::from_secs(10)).unwrap();
    rc.set_timeout(Duration::from_secs(10)).unwrap();
    for q in 0..16 {
        let w = unit_vec(&mut rng, DIM);
        let ph = {
            let resp = pc.post("/query", &protocol::query_body(&w)).unwrap();
            assert_eq!(resp.status, 200);
            protocol::parse_hit(&resp.body).unwrap()
        };
        let rh = {
            let resp = rc.post("/query", &protocol::query_body(&w)).unwrap();
            assert_eq!(resp.status, 200);
            protocol::parse_hit(&resp.body).unwrap()
        };
        assert_hits_identical(&rh, &ph, &format!("replica query {q}"));
        let pt = {
            let resp = pc.post("/query_topk", &protocol::topk_body(&w, 9)).unwrap();
            protocol::parse_topk_hits(&resp.body).unwrap()
        };
        let rt = {
            let resp = rc.post("/query_topk", &protocol::topk_body(&w, 9)).unwrap();
            protocol::parse_topk_hits(&resp.body).unwrap()
        };
        assert_eq!(pt.len(), rt.len(), "topk {q} length");
        for ((pi, pm), (ri, rm)) in pt.iter().zip(rt.iter()) {
            assert_eq!(pi, ri, "topk {q} id");
            assert_eq!(pm.to_bits(), rm.to_bits(), "topk {q} margin bits");
        }
    }

    // ── role surfaces: 421 on replica mutations, stats sections ──────
    let resp = rc.post("/insert", &protocol::id_body(1)).unwrap();
    assert_eq!(resp.status, 421, "replica mutations are misdirected");
    let v = chh::jsonio::Json::parse_bytes(&resp.body).unwrap();
    assert_eq!(v.get("primary").and_then(|x| x.as_str()), Some(paddr.as_str()));
    let resp = rc.post("/remove", &protocol::id_body(1)).unwrap();
    assert_eq!(resp.status, 421);
    let stats = {
        let resp = rc.get("/stats").unwrap();
        chh::jsonio::Json::parse_bytes(&resp.body).unwrap()
    };
    assert_eq!(stats.get("role").and_then(|x| x.as_str()), Some("replica"));
    let repl = stats.get("replication").expect("replication section");
    assert_eq!(repl.get("caught_up").and_then(|x| x.as_bool()), Some(true));
    assert_eq!(repl.get("lag_bytes").and_then(|x| x.as_usize()), Some(0));
    assert_eq!(repl.get("lag_segments").and_then(|x| x.as_usize()), Some(0));
    assert!(repl.get("applied_records").and_then(|x| x.as_usize()).unwrap() >= 160);
    let pstats = {
        let resp = pc.get("/stats").unwrap();
        chh::jsonio::Json::parse_bytes(&resp.body).unwrap()
    };
    assert_eq!(pstats.get("role").and_then(|x| x.as_str()), Some("primary"));
    drop(pc);
    drop(rc);
    replica_srv.shutdown(); // joins the tailer
    primary.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn graceful_shutdown_over_http() {
    let (stack, _router) = static_stack(200, 51);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(5)).unwrap();
    // a query first, so shutdown happens on a warm server
    let resp = client.post("/query", &protocol::query_body(&[0.25; DIM])).unwrap();
    assert_eq!(resp.status, 200);
    let resp = client.post("/shutdown", "").expect("post /shutdown");
    assert_eq!(resp.status, 200);
    assert!(!resp.keep_alive, "shutdown response closes the connection");
    drop(client);
    // wait() must return: acceptor poked, connections drained, batcher
    // joined — a hang here fails the test by timeout
    handle.wait();
    // the listener is gone; fresh connections are refused (allow a beat
    // for the OS to tear the socket down)
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        std::net::TcpStream::connect(&addr).is_err(),
        "listener must be closed after shutdown"
    );
}
