//! Deployment-lifecycle integration: train → save → reload in a "fresh
//! process" (new objects, no shared state) → rebuild the index from saved
//! codes → identical query results. This is the offline-train /
//! online-serve split a production user runs.

use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{HashFamily, LbhHash};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::persist::{load_codes, load_model, save_codes, save_model, FamilyKind};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("chh_flow_{}_{name}", std::process::id()))
}

#[test]
fn train_save_reload_serve_roundtrip() {
    let mut rng = Rng::seed_from_u64(77);
    let ds = tiny1m_like(&TinyConfig { n: 3000, d: 64, ..Default::default() }, &mut rng);

    // ── offline: train + encode + persist ────────────────────────────
    let sample = rng.sample_indices(ds.len(), 256);
    let refs = rng.sample_indices(ds.len(), 2000);
    let trainer = LbhTrainer::new(LbhTrainConfig { bits: 14, iters_per_bit: 60, ..Default::default() });
    let (lbh, _) = trainer.train(ds.features(), &sample, &refs, &mut rng);
    let codes = lbh.encode_all(ds.features());
    let model_path = tmp("model");
    let codes_path = tmp("codes");
    save_model(&model_path, FamilyKind::Lbh, &lbh.pairs).unwrap();
    save_codes(&codes_path, &codes).unwrap();

    // ── online: reload into fresh objects ────────────────────────────
    let lbh2: LbhHash = load_model(&model_path).unwrap().into_lbh().unwrap();
    let codes2 = load_codes(&codes_path).unwrap();
    assert_eq!(codes2.codes, codes.codes, "persisted codes identical");
    let index_fresh = HyperplaneIndex::from_codes(codes2, 3);
    let index_orig = HyperplaneIndex::from_codes(codes, 3);

    // queries answered identically by the reloaded stack
    for _ in 0..25 {
        let w = chh::testing::unit_vec(&mut rng, 64);
        let a = index_orig.query_filtered(&lbh, &w, ds.features(), |_| true);
        let b = index_fresh.query_filtered(&lbh2, &w, ds.features(), |_| true);
        assert_eq!(a.best.map(|(i, _)| i), b.best.map(|(i, _)| i));
        assert_eq!(a.scanned, b.scanned);
        assert_eq!(a.nonempty, b.nonempty);
    }
    let _ = std::fs::remove_file(&model_path);
    let _ = std::fs::remove_file(&codes_path);
}

#[test]
fn saved_model_queries_match_without_codes_file() {
    // codes can always be regenerated from the model alone
    let mut rng = Rng::seed_from_u64(78);
    let ds = tiny1m_like(&TinyConfig { n: 1500, d: 32, ..Default::default() }, &mut rng);
    let fam = chh::hash::BhHash::sample(32, 10, &mut rng);
    let path = tmp("bh_model");
    save_model(&path, FamilyKind::Bh, &fam.pairs).unwrap();
    let fam2 = load_model(&path).unwrap().into_bh().unwrap();
    let c1 = fam.encode_all(ds.features());
    let c2 = fam2.encode_all(ds.features());
    assert_eq!(c1.codes, c2.codes);
    let _ = std::fs::remove_file(&path);
}
