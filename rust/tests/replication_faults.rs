//! Fault-injection acceptance tests for WAL-shipping replication:
//!
//! * **Frame-boundary kill/restart fuzz** — stream a primary's WAL to a
//!   replica one frame per connection (a fresh HTTP client per fetch =
//!   the stream killed and restarted at *every* frame boundary) and
//!   assert after each frame that the replica's state is exactly the
//!   corresponding prefix of acknowledged operations — never more,
//!   never reordered — and that the converged replica answers queries
//!   bit-identically to the primary.
//! * **GC resync** — a replica that falls behind a checkpoint's segment
//!   GC gets `bootstrap_required`, re-bootstraps from the fresh
//!   snapshot, and still converges to the primary's exact state.
//! * **Unacked ops never ship** — with an injected fsync fault, the op
//!   that was refused to its caller (and everything after it) stays off
//!   the stream: a replica serves only durable, acknowledged history.
//! * **Live tailer convergence** — the background tailer follows a
//!   primary under concurrent multi-threaded churn with checkpoints
//!   racing it, reaches lag 0, and matches the primary bit for bit.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use chh::coordinator::OnlineRouter;
use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::online::{QueryBudget, ShardedIndex};
use chh::replicate::{primary, spawn_tailer, wire, ReplicaConfig, ReplicaIndex};
use chh::rng::Rng;
use chh::server::{BatcherConfig, Durability, HttpClient, Server, ServerConfig, Stack};
use chh::testing::unit_vec;
use chh::wal::{frame, DurableIndex, FaultPlan, FsyncPolicy, Record, WalConfig};

const DIM: usize = 16;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("chh_repl_it_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn server_cfg() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        max_conns: 32,
        conn_workers: 2,
        batch: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        },
        pool_workers: 2,
        idle_timeout: Duration::from_millis(300),
        slow_ms: 0,
        slow_log: None,
        audit_frac: 0.0,
    }
}

fn sorted_entries(index: &ShardedIndex) -> Vec<Vec<(u32, u64)>> {
    index
        .shards()
        .iter()
        .map(|s| {
            let mut e = s.live_entries();
            e.sort_unstable();
            e
        })
        .collect()
}

/// Apply a record prefix to a fresh index with the primary's layout.
fn expect_index(ops: &[Record], bits: usize, radius: usize, shards: usize) -> ShardedIndex {
    let idx = ShardedIndex::new(bits, radius, shards);
    for r in ops {
        match *r {
            Record::Insert { id, code } => idx.insert(id, code),
            Record::Remove { id } => {
                idx.remove(id);
            }
            Record::Checkpoint { .. } => {}
        }
    }
    idx
}

fn assert_query_parity(
    a: &ShardedIndex,
    b: &ShardedIndex,
    fam: &dyn HashFamily,
    feats: &chh::data::FeatureStore,
    rng: &mut Rng,
    ctx: &str,
) {
    let budget = QueryBudget::new(256, 64);
    for q in 0..10 {
        let w = unit_vec(rng, DIM);
        let ha = a.query(fam, &w, feats, budget, |_| true);
        let hb = b.query(fam, &w, feats, budget, |_| true);
        match (ha.best, hb.best) {
            (Some((ia, ma)), Some((ib, mb))) => {
                assert_eq!(ia, ib, "{ctx}: query {q} best id");
                assert_eq!(
                    ma.to_bits(),
                    mb.to_bits(),
                    "{ctx}: query {q} margin must be bit-identical"
                );
            }
            (None, None) => {}
            (x, y) => panic!("{ctx}: query {q} best mismatch {x:?} vs {y:?}"),
        }
        assert_eq!(ha.scanned, hb.scanned, "{ctx}: query {q} scanned");
        assert_eq!(ha.probed, hb.probed, "{ctx}: query {q} probed");
        assert_eq!(ha.nonempty, hb.nonempty, "{ctx}: query {q} nonempty");
    }
}

/// A durable online primary behind a live HTTP server, plus the op
/// journal driven through it.
struct Primary {
    fam: Arc<dyn HashFamily>,
    feats: Arc<chh::data::FeatureStore>,
    index: Arc<ShardedIndex>,
    durable: Arc<DurableIndex>,
    handle: chh::server::ServerHandle,
    addr: String,
}

fn spawn_primary(dir: &PathBuf, seed: u64, segment_bytes: u64) -> Primary {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = test_blobs(200, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let feats = Arc::new(ds.features().clone());
    let index = Arc::new(ShardedIndex::new(10, 2, 3));
    let wal_cfg = WalConfig {
        dir: dir.clone(),
        fsync: FsyncPolicy::Always,
        segment_bytes,
        faults: None,
    };
    let durable = Arc::new(DurableIndex::create(index.clone(), &wal_cfg).expect("create wal"));
    let router = Arc::new(OnlineRouter::new(
        fam.clone(),
        index.clone(),
        feats.clone(),
        1,
        16,
        QueryBudget::new(256, 64),
    ));
    let handle = Server::spawn_with_durability(
        Stack::Online(router),
        server_cfg(),
        Some(Durability { durable: durable.clone(), snapshot_every_ops: 0 }),
    )
    .expect("spawn primary");
    let addr = handle.addr().to_string();
    Primary { fam, feats, index, durable, handle, addr }
}

/// Acknowledged insert/remove mix, returned as the journaled op order.
fn churn_ops(p: &Primary, rng: &mut Rng, n: usize) -> Vec<Record> {
    let mut ops = Vec::with_capacity(n);
    for i in 0..n {
        if i % 4 == 3 {
            let id = rng.below(200) as u32;
            let _ = p.durable.remove(id).unwrap();
            ops.push(Record::Remove { id });
        } else {
            let id = rng.below(200) as u32;
            let code = p.fam.encode_point(p.feats.row(id as usize));
            p.durable.insert(id, code).unwrap();
            ops.push(Record::Insert { id, code });
        }
    }
    ops
}

#[test]
fn stream_kill_and_restart_at_every_frame_boundary() {
    let dir = tmpdir("framekill");
    let p = spawn_primary(&dir, 17, 1 << 20);
    let mut rng = Rng::seed_from_u64(99);
    let ops = churn_ops(&p, &mut rng, 48);

    // bootstrap over HTTP: the base snapshot (gen 0) is the empty index
    let rcfg = ReplicaConfig::new(&p.addr);
    let replica = ReplicaIndex::bootstrap(&rcfg).expect("bootstrap");
    assert_eq!(replica.index().len(), 0, "gen-0 base snapshot is empty");
    assert_eq!(replica.position(), (1, 0));

    // one frame per connection: drop the client after every fetch (the
    // kill), reconnect fresh (the restart) — every frame boundary is a
    // kill point
    let mut applied = 0usize;
    let mut rounds = 0usize;
    while applied < ops.len() {
        rounds += 1;
        assert!(rounds < 10_000, "stream stopped making progress at op {applied}");
        let mut client =
            HttpClient::connect_retry(&p.addr, Duration::from_secs(5)).expect("reconnect");
        client.set_timeout(Duration::from_secs(5)).unwrap();
        let (seg, off) = replica.position();
        let resp = client
            .get(&format!("/wal/stream?seg={seg}&off={off}&max=1"))
            .expect("fetch stream");
        assert_eq!(resp.status, 200);
        let chunk = wire::decode_stream_chunk(&resp.body).expect("decode chunk");
        assert!(!chunk.bootstrap_required, "nothing was GC'd in this test");
        let n = replica.apply_chunk(&chunk).expect("apply");
        assert!(n <= 1, "max=1 must serve at most one frame");
        applied += n;
        drop(client); // kill the stream at this frame boundary
        // the replica is exactly the acknowledged prefix — never ahead,
        // never reordered
        let expect = expect_index(&ops[..applied], 10, 2, 3);
        assert_eq!(
            sorted_entries(replica.index()),
            sorted_entries(&expect),
            "after {applied} applied frames"
        );
    }

    // converged: bit-identical to the live primary
    assert_eq!(replica.applied_records(), ops.len() as u64);
    assert_eq!(sorted_entries(replica.index()), sorted_entries(&p.index));
    assert!(replica.caught_up(), "final chunk carried the watermark");
    assert_query_parity(
        &p.index,
        replica.index(),
        p.fam.as_ref(),
        &p.feats,
        &mut rng,
        "frame-boundary converged",
    );
    p.handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn segment_gc_forces_resync_and_replica_still_converges() {
    let dir = tmpdir("gcresync");
    let p = spawn_primary(&dir, 23, 1 << 20);
    let mut rng = Rng::seed_from_u64(7);
    let _ = churn_ops(&p, &mut rng, 20);

    let rcfg = ReplicaConfig::new(&p.addr);
    let replica = ReplicaIndex::bootstrap(&rcfg).expect("bootstrap");
    assert_eq!(replica.bootstraps(), 1);

    // the replica sleeps through a checkpoint: segment 1 gets GC'd
    let _ = churn_ops(&p, &mut rng, 10);
    p.durable.checkpoint().expect("checkpoint");
    let _ = churn_ops(&p, &mut rng, 10);

    let mut client =
        HttpClient::connect_retry(&p.addr, Duration::from_secs(5)).expect("connect");
    client.set_timeout(Duration::from_secs(5)).unwrap();
    let (seg, off) = replica.position();
    let resp = client
        .get(&format!("/wal/stream?seg={seg}&off={off}"))
        .expect("fetch stream");
    let chunk = wire::decode_stream_chunk(&resp.body).expect("decode");
    assert!(
        chunk.bootstrap_required,
        "a GC'd segment must demand a bootstrap, got {chunk:?}"
    );
    replica.resync(&mut client).expect("resync");
    assert_eq!(replica.bootstraps(), 2);

    // tail the remainder to convergence
    let mut rounds = 0;
    loop {
        rounds += 1;
        assert!(rounds < 10_000, "resynced replica never converged");
        let (seg, off) = replica.position();
        let resp = client
            .get(&format!("/wal/stream?seg={seg}&off={off}"))
            .expect("fetch stream");
        let chunk = wire::decode_stream_chunk(&resp.body).expect("decode");
        assert!(!chunk.bootstrap_required);
        let n = replica.apply_chunk(&chunk).expect("apply");
        if n == 0 && replica.position() == (seg, off) && replica.caught_up() {
            break;
        }
    }
    assert_eq!(sorted_entries(replica.index()), sorted_entries(&p.index));
    assert_query_parity(
        &p.index,
        replica.index(),
        p.fam.as_ref(),
        &p.feats,
        &mut rng,
        "post-resync",
    );
    p.handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_fsync_fault_never_ships_the_unacked_op() {
    let dir = tmpdir("fsyncfault");
    let faults = FaultPlan::new();
    let cfg = WalConfig {
        dir: dir.clone(),
        fsync: FsyncPolicy::Always,
        segment_bytes: 1 << 20,
        faults: Some(faults.clone()),
    };
    let d = DurableIndex::create(Arc::new(ShardedIndex::new(10, 2, 3)), &cfg).unwrap();
    let mut acked: Vec<Record> = Vec::new();
    for id in 0..12u32 {
        d.insert(id, (id % 7) as u64).unwrap();
        acked.push(Record::Insert { id, code: (id % 7) as u64 });
    }
    // the disk "dies": the next fsync (and all later ones) fail
    faults.fail_fsync_at(faults.fsyncs_seen() + 1);
    assert!(d.insert(500, 1).is_err(), "op on the dead disk must not be acked");
    assert!(d.insert(501, 1).is_err(), "sticky fail-stop refuses later ops too");
    // fail-stop contract: the op may linger in the primary's RAM...
    assert!(d.index().contains(500));
    // ...but the stream serves only the durable prefix — a replica can
    // never observe the unacknowledged op
    let (dseg, doff) = d.durable_watermark();
    let chunk =
        primary::stream_from_dir(&dir, 1, 0, primary::MAX_STREAM_BYTES, dseg, doff).unwrap();
    let read = frame::read_segment_bytes(&chunk.frames);
    assert!(!read.torn);
    assert_eq!(read.records, acked, "exactly the acknowledged ops, nothing after");
    let replica = ReplicaIndex::from_snapshot(ShardedIndex::new(10, 2, 3), 1);
    replica.apply_chunk(&chunk).unwrap();
    assert!(!replica.index().contains(500), "unacked op must never be served");
    assert_eq!(replica.index().len(), 12);
    drop(d);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_write_fault_behaves_the_same() {
    let dir = tmpdir("writefault");
    let faults = FaultPlan::new();
    let cfg = WalConfig {
        dir: dir.clone(),
        fsync: FsyncPolicy::Always,
        segment_bytes: 1 << 20,
        faults: Some(faults.clone()),
    };
    let d = DurableIndex::create(Arc::new(ShardedIndex::new(10, 2, 3)), &cfg).unwrap();
    for id in 0..5u32 {
        d.insert(id, 1).unwrap();
    }
    faults.fail_write_at(faults.writes_seen() + 1);
    assert!(d.insert(600, 1).is_err());
    let (dseg, doff) = d.durable_watermark();
    let chunk =
        primary::stream_from_dir(&dir, 1, 0, primary::MAX_STREAM_BYTES, dseg, doff).unwrap();
    let read = frame::read_segment_bytes(&chunk.frames);
    assert_eq!(read.records.len(), 5, "only the 5 acked inserts are streamable");
    drop(d);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn live_tailer_converges_under_concurrent_churn_and_checkpoints() {
    let dir = tmpdir("tailer");
    let p = spawn_primary(&dir, 41, 4096); // small segments: rolls mid-run
    let rcfg = ReplicaConfig {
        poll: Duration::from_millis(5),
        backoff: Duration::from_millis(20),
        ..ReplicaConfig::new(&p.addr)
    };
    let replica = ReplicaIndex::bootstrap(&rcfg).expect("bootstrap");
    let tailer = spawn_tailer(replica.clone(), rcfg);

    // concurrent churn through the durable primary while checkpoints
    // rotate + GC segments under the tailer
    let mut joins = Vec::new();
    for t in 0..3u64 {
        let durable = p.durable.clone();
        let fam = p.fam.clone();
        let feats = p.feats.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(1000 + t);
            for _ in 0..80 {
                let id = rng.below(200) as u32;
                if rng.bernoulli(0.7) {
                    let code = fam.encode_point(feats.row(id as usize));
                    durable.insert(id, code).unwrap();
                } else {
                    let _ = durable.remove(id).unwrap();
                }
            }
        }));
    }
    let ck = {
        let durable = p.durable.clone();
        std::thread::spawn(move || {
            for _ in 0..2 {
                std::thread::sleep(Duration::from_millis(10));
                durable.checkpoint().unwrap();
            }
        })
    };
    for j in joins {
        j.join().unwrap();
    }
    ck.join().unwrap();

    // quiesced: the tailer must reach the durable watermark and match
    let deadline = Instant::now() + Duration::from_secs(20);
    while !(replica.caught_up() && replica.index().len() == p.index.len()) {
        assert!(
            Instant::now() < deadline,
            "tailer never converged: pos {:?} vs watermark {:?}, {} vs {} live",
            replica.position(),
            p.durable.durable_watermark(),
            replica.index().len(),
            p.index.len()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(sorted_entries(replica.index()), sorted_entries(&p.index));
    let mut rng = Rng::seed_from_u64(5);
    assert_query_parity(
        &p.index,
        replica.index(),
        p.fam.as_ref(),
        &p.feats,
        &mut rng,
        "tailer converged",
    );
    tailer.stop();
    p.handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
