//! End-to-end tests of the observability layer on a live server: the
//! `/metrics` exposition stays well-formed Prometheus text under
//! concurrent query + mutation churn, counters are monotone across
//! scrapes, request ids round-trip, the slow-query log captures a
//! stage breakdown — and none of it changes an answer (traced wire
//! responses stay bit-identical to direct router calls).

use std::sync::Arc;
use std::time::Duration;

use chh::coordinator::{OnlineRouter, QueryRequest};
use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::online::{QueryBudget, ShardedIndex};
use chh::par::Pool;
use chh::rng::Rng;
use chh::server::{protocol, BatcherConfig, HttpClient, Server, ServerConfig, Stack};
use chh::testing::unit_vec;

const DIM: usize = 16;

fn server_cfg() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        max_conns: 32,
        conn_workers: 2,
        batch: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        },
        pool_workers: 2,
        idle_timeout: Duration::from_millis(300),
        slow_ms: 0,
        slow_log: None,
        audit_frac: 0.0,
    }
}

fn online_stack(n: usize, seed: u64) -> (Stack, Arc<OnlineRouter>) {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = test_blobs(n, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let codes = fam.encode_all(ds.features());
    let idx = Arc::new(ShardedIndex::from_codes(&codes, 4, 3));
    let feats = Arc::new(ds.features().clone());
    let router = Arc::new(OnlineRouter::new(
        fam,
        idx,
        feats,
        1,
        16,
        QueryBudget::new(256, 64),
    ));
    (Stack::Online(router.clone()), router)
}

/// Structural validation of one exposition body: every sample line
/// parses, every family has `# HELP` + `# TYPE`, histogram buckets are
/// cumulative-monotone and the `+Inf` bucket equals `_count`.
fn assert_well_formed(text: &str) {
    let mut helped = std::collections::HashSet::new();
    let mut typed = std::collections::HashSet::new();
    for line in text.lines().filter(|l| !l.is_empty()) {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            helped.insert(rest.split(' ').next().unwrap().to_string());
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap().to_string();
            let kind = it.next().expect("TYPE line carries a kind");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown TYPE {kind} for {name}"
            );
            typed.insert(name);
        } else {
            // sample line: `name{labels} value` — must split and parse
            let (series, val) = line.rsplit_once(' ').expect("sample has a value");
            assert!(
                val == "+Inf" || val.parse::<f64>().is_ok(),
                "unparseable value in {line:?}"
            );
            assert!(!series.is_empty());
            // the family (name up to '{' and any _bucket/_sum/_count
            // suffix) must have been announced
            let name = series.split('{').next().unwrap();
            let family = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            assert!(
                typed.contains(family) || typed.contains(name),
                "sample {series} precedes its # TYPE"
            );
        }
    }
    assert_eq!(helped, typed, "every family has both # HELP and # TYPE");

    // histogram structure: per series-prefix, buckets are monotone in le
    // order (the registry renders them in bound order) and end at +Inf
    // with exactly the _count value
    let scrape = chh::obs::parse_scrape(text);
    for (k, v) in &scrape {
        if let Some((name, rest)) = k.split_once('{') {
            if !name.ends_with("_bucket") {
                continue;
            }
            if rest.contains("le=\"+Inf\"") {
                let family = name.strip_suffix("_bucket").unwrap();
                // rebuild the matching _count key by dropping the le label
                let labels: Vec<&str> = rest
                    .trim_end_matches('}')
                    .split(',')
                    .filter(|kv| !kv.starts_with("le="))
                    .collect();
                let count_key = if labels.is_empty() {
                    format!("{family}_count")
                } else {
                    format!("{family}_count{{{}}}", labels.join(","))
                };
                let count = scrape
                    .iter()
                    .find(|(ck, _)| *ck == count_key)
                    .map(|(_, cv)| *cv)
                    .unwrap_or_else(|| panic!("no _count for {k}"));
                assert_eq!(*v, count, "+Inf bucket == _count for {k}");
            }
        }
    }
    // cumulative monotonicity: consecutive _bucket lines of one series
    // never decrease (they are rendered in ascending-le order)
    let mut prev: Option<(String, f64)> = None;
    for (k, v) in &scrape {
        let is_bucket = k.split('{').next().unwrap().ends_with("_bucket");
        if !is_bucket {
            prev = None;
            continue;
        }
        let series: String =
            k.split(',').filter(|p| !p.contains("le=")).collect::<Vec<_>>().join(",");
        if let Some((pk, pv)) = &prev {
            if *pk == series {
                assert!(v >= pv, "bucket counts must be cumulative: {k} {v} < {pv}");
            }
        }
        prev = Some((series, *v));
    }
}

#[test]
fn metrics_stay_well_formed_and_monotone_under_churn() {
    let (stack, router) = online_stack(400, 17);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();

    let churn = |n_queries: usize, seed: u64| {
        let mut rng = Rng::seed_from_u64(seed);
        let mut c = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        c.set_timeout(Duration::from_secs(10)).unwrap();
        let mut ws = Vec::new();
        let mut hits = Vec::new();
        for i in 0..n_queries {
            if i % 5 == 4 {
                // interleave mutations so gauge-backed families move too
                let id = rng.below(400) as u32;
                let path = if rng.bernoulli(0.5) { "/insert" } else { "/remove" };
                let resp = c.post(path, &protocol::id_body(id)).unwrap();
                assert_eq!(resp.status, 200);
            }
            let w = unit_vec(&mut rng, DIM);
            let resp = c.post("/query", &protocol::query_body(&w)).unwrap();
            assert_eq!(resp.status, 200);
            hits.push(protocol::parse_hit(&resp.body).unwrap());
            ws.push(w);
        }
        (ws, hits)
    };

    let mut mc = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    mc.set_timeout(Duration::from_secs(10)).unwrap();

    let (ws, wire_hits) = churn(25, 100);
    let r1 = mc.get("/metrics").expect("first scrape");
    assert_eq!(r1.status, 200);
    let t1 = String::from_utf8(r1.body).expect("exposition is utf-8");
    assert_well_formed(&t1);
    let s1 = chh::obs::parse_scrape(&t1);

    churn(25, 200);
    let r2 = mc.get("/metrics").expect("second scrape");
    let t2 = String::from_utf8(r2.body).unwrap();
    assert_well_formed(&t2);
    let s2 = chh::obs::parse_scrape(&t2);

    // every counter-like series (totals, hist buckets/counts/sums) that
    // existed in scrape 1 is monotone non-decreasing in scrape 2
    let mut compared = 0usize;
    for (k, v1) in &s1 {
        let name = k.split('{').next().unwrap();
        let counterish = name.ends_with("_total")
            || name.ends_with("_bucket")
            || name.ends_with("_count")
            || name.ends_with("_sum");
        if !counterish {
            continue;
        }
        let v2 = s2
            .iter()
            .find(|(k2, _)| k2 == k)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("series {k} vanished between scrapes"));
        assert!(v2 >= *v1, "counter went backwards: {k} {v1} -> {v2}");
        compared += 1;
    }
    assert!(compared > 50, "expected a rich counter surface, compared {compared}");

    // the load is visible: 50 queries served, stage hists observed them
    let q = chh::obs::series_value(&s2, "chh_http_requests_total", "route=\"/query\"");
    assert_eq!(q, Some(50.0));
    for stage in ["batch_wait", "serialize"] {
        let label = format!("stage=\"{stage}\"");
        let n = chh::obs::series_value(&s2, "chh_stage_seconds_count", &label);
        assert_eq!(n, Some(50.0), "per-request stage {stage}");
    }
    for stage in ["encode", "probe", "scan", "merge"] {
        let label = format!("stage=\"{stage}\"");
        let n = chh::obs::series_value(&s2, "chh_stage_seconds_count", &label).unwrap();
        assert!(
            (1.0..=50.0).contains(&n),
            "batch-level stage {stage} observed per flush, got {n}"
        );
    }
    assert_eq!(
        chh::obs::series_value(&s2, "chh_build_info", ""),
        Some(1.0),
        "build info gauge present"
    );
    assert!(
        chh::obs::series_value(&s2, "chh_index_points", "").unwrap() > 0.0,
        "index size gauge present"
    );

    // observability must not change answers: the traced wire responses
    // are bit-identical to a direct pooled router call
    let reqs: Vec<QueryRequest> =
        ws.iter().map(|w| QueryRequest { w: w.clone(), exclude: None }).collect();
    let direct = router.query_batch_pooled(&reqs, &Pool::new(2));
    for (i, (wh, dh)) in wire_hits.iter().zip(direct.iter()).enumerate() {
        assert_eq!(
            wh.best.map(|(id, m)| (id, m.to_bits())),
            dh.best.map(|(id, m)| (id, m.to_bits())),
            "traced query {i} must stay bit-identical"
        );
        assert_eq!(wh.scanned, dh.scanned, "query {i} scanned");
    }

    drop(mc);
    handle.shutdown();
}

#[test]
fn request_ids_round_trip_and_are_generated_when_absent() {
    let (stack, _router) = online_stack(200, 23);
    let handle = Server::spawn(stack, server_cfg()).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut c = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    c.set_timeout(Duration::from_secs(10)).unwrap();
    let body = protocol::query_body(&[0.5; DIM]);

    // client-supplied id is echoed verbatim
    let resp = c
        .request_with_id("POST", "/query", body.as_bytes(), "trace-me-42")
        .expect("query with id");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.request_id.as_deref(), Some("trace-me-42"));

    // absent id: the server generates one (16 hex chars) and echoes it
    let resp = c.post("/query", &body).expect("query without id");
    assert_eq!(resp.status, 200);
    let rid = resp.request_id.expect("server generated a request id");
    assert_eq!(rid.len(), 16, "generated id is 16 hex chars: {rid:?}");
    assert!(rid.chars().all(|ch| ch.is_ascii_hexdigit()), "hex id: {rid:?}");

    // distinct requests get distinct generated ids
    let rid2 = c.post("/query", &body).unwrap().request_id.unwrap();
    assert_ne!(rid, rid2);

    // errors are tagged too — a 404 still echoes the id
    let resp = c.request_with_id("POST", "/nope", b"{}", "err-id-7").unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(resp.request_id.as_deref(), Some("err-id-7"));
    drop(c);
    handle.shutdown();
}

#[test]
fn slow_log_captures_stage_breakdown_and_rotates_ids_through() {
    let dir = std::env::temp_dir().join(format!("chh_obs_slow_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let log_path = dir.join("slow.jsonl");

    // a lone query holds in the batcher for max_wait, so with a 30ms
    // hold and a 5ms threshold every query is deterministically "slow"
    let cfg = ServerConfig {
        batch: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(30),
            queue_cap: 256,
        },
        slow_ms: 5,
        slow_log: Some(log_path.clone()),
        ..server_cfg()
    };
    let (stack, _router) = online_stack(200, 29);
    let handle = Server::spawn(stack, cfg).expect("spawn server");
    let addr = handle.addr().to_string();
    let mut c = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    c.set_timeout(Duration::from_secs(10)).unwrap();
    let mut sent_ids = Vec::new();
    for i in 0..3 {
        let id = format!("slowtest-{i:07}");
        let resp = c
            .request_with_id("POST", "/query", protocol::query_body(&[0.5; DIM]).as_bytes(), &id)
            .expect("slow query");
        assert_eq!(resp.status, 200);
        sent_ids.push(id);
    }
    drop(c);
    handle.shutdown();

    let text = std::fs::read_to_string(&log_path).expect("slow log written");
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 3, "all 3 held queries logged, got {}", lines.len());
    for line in &lines {
        let v = chh::jsonio::Json::parse(line).expect("slow-log line is JSON");
        assert_eq!(v.get("route").and_then(|x| x.as_str()), Some("/query"));
        assert_eq!(v.get("status").and_then(|x| x.as_usize()), Some(200));
        let total = v.get("total_us").and_then(|x| x.as_f64()).unwrap();
        assert!(total >= 5_000.0, "logged request crossed the threshold: {total}");
        let stages = v.get("stages_us").expect("stage breakdown present");
        let wait = stages.get("batch_wait").and_then(|x| x.as_f64()).unwrap();
        assert!(wait >= 25_000.0, "batch_wait dominates the hold: {wait}");
        for s in ["encode", "probe", "scan", "merge", "serialize"] {
            assert!(stages.get(s).is_some(), "stage {s} in breakdown");
        }
    }
    // the logged request ids are exactly the ones the client sent
    let logged: Vec<String> = lines
        .iter()
        .map(|l| {
            chh::jsonio::Json::parse(l)
                .unwrap()
                .get("request_id")
                .and_then(|x| x.as_str())
                .unwrap()
                .to_string()
        })
        .collect();
    for id in &sent_ids {
        assert!(logged.contains(id), "sent id {id} appears in the slow log");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
