//! End-to-end parity of the data-parallel batch engine: every pooled
//! path (encode, build, batch query, eval, LBH training, sharded
//! fan-out) must be bit-identical to its `workers = 1` serial twin.

use chh::data::{newsgroups_like, test_blobs, NewsConfig};
use chh::eval::{evaluate, evaluate_with};
use chh::hash::{AhHash, BhHash, EhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::online::{QueryBudget, ShardedIndex};
use chh::par::Pool;
use chh::rng::Rng;
use chh::table::HyperplaneIndex;
use chh::testing::unit_vec;

const WORKER_COUNTS: [usize; 3] = [2, 3, 4];

#[test]
fn encode_parity_across_families_and_stores() {
    let mut rng = Rng::seed_from_u64(1);
    // n large enough that every family splits into several encode chunks
    let dense = test_blobs(5_000, 32, 4, &mut rng);
    let sparse = newsgroups_like(
        &NewsConfig { n: 3_000, vocab: 256, classes: 6, ..Default::default() },
        &mut rng,
    );
    let fams: Vec<Box<dyn HashFamily>> = vec![
        Box::new(BhHash::sample(32, 20, &mut rng)),
        Box::new(AhHash::sample(32, 10, &mut rng)),
        Box::new(EhHash::sampled(32, 12, 64, &mut rng)),
    ];
    let sfams: Vec<Box<dyn HashFamily>> = vec![
        Box::new(BhHash::sample(256, 20, &mut rng)),
        Box::new(AhHash::sample(256, 10, &mut rng)),
    ];
    for fam in &fams {
        let serial = fam.encode_all(dense.features());
        for w in WORKER_COUNTS {
            let par = fam.encode_all_pool(dense.features(), &Pool::new(w));
            assert_eq!(par.codes, serial.codes, "{} dense workers={w}", fam.name());
        }
    }
    for fam in &sfams {
        let serial = fam.encode_all(sparse.features());
        for w in WORKER_COUNTS {
            let par = fam.encode_all_pool(sparse.features(), &Pool::new(w));
            assert_eq!(par.codes, serial.codes, "{} sparse workers={w}", fam.name());
        }
    }
}

#[test]
fn index_build_and_query_batch_parity() {
    let mut rng = Rng::seed_from_u64(2);
    let ds = test_blobs(3_000, 24, 4, &mut rng);
    let fam = BhHash::sample(24, 14, &mut rng);
    let serial_idx = HyperplaneIndex::build(&fam, ds.features(), 3);
    let queries: Vec<Vec<f32>> = (0..40).map(|_| unit_vec(&mut rng, 24)).collect();
    let serial_hits = serial_idx.query_batch(&fam, &queries, ds.features(), &Pool::serial());
    for w in WORKER_COUNTS {
        let pool = Pool::new(w);
        let idx = HyperplaneIndex::build_with(&fam, ds.features(), 3, &pool);
        assert_eq!(idx.bucket_count(), serial_idx.bucket_count(), "workers={w}");
        let hits = idx.query_batch(&fam, &queries, ds.features(), &pool);
        assert_eq!(hits.len(), serial_hits.len());
        for (h, s) in hits.iter().zip(serial_hits.iter()) {
            assert_eq!(h.best, s.best, "workers={w}");
            assert_eq!(h.scanned, s.scanned);
            assert_eq!(h.probed, s.probed);
            assert_eq!(h.nonempty, s.nonempty);
        }
    }
}

#[test]
fn evaluate_parity_including_exhaustive_truth() {
    let mut rng = Rng::seed_from_u64(3);
    // n > one margin chunk so the exhaustive scan actually splits
    let ds = test_blobs(6_000, 16, 3, &mut rng);
    let fam = BhHash::sample(16, 12, &mut rng);
    let idx = HyperplaneIndex::build(&fam, ds.features(), 2);
    let queries: Vec<Vec<f32>> = (0..12).map(|_| unit_vec(&mut rng, 16)).collect();
    let serial = evaluate(&fam, &idx, ds.features(), &queries, 20);
    for w in WORKER_COUNTS {
        let par = evaluate_with(&fam, &idx, ds.features(), &queries, 20, &Pool::new(w));
        assert_eq!(par.mean_recall.to_bits(), serial.mean_recall.to_bits(), "workers={w}");
        assert_eq!(par.median_margin_ratio.to_bits(), serial.median_margin_ratio.to_bits());
        assert_eq!(par.mean_scanned.to_bits(), serial.mean_scanned.to_bits());
        assert_eq!(par.nonempty_frac.to_bits(), serial.nonempty_frac.to_bits());
    }
    let w0 = &queries[0];
    let serial_top = chh::eval::exhaustive_topk(ds.features(), w0, 50);
    for w in WORKER_COUNTS {
        let par_top = chh::eval::exhaustive_topk_with(ds.features(), w0, 50, &Pool::new(w));
        assert_eq!(par_top, serial_top, "workers={w}");
    }
}

#[test]
fn lbh_training_parity_and_projection_bits() {
    // identical projections (bit-for-bit), costs and residues at
    // workers = 1 vs workers > 1. m must clear TRAIN_PAR_MIN_M or the
    // trainer's small-sample gate would run everything serially and the
    // parity check would be vacuous.
    let m = chh::lbh::TRAIN_PAR_MIN_M + 64;
    let ds = test_blobs(m + 300, 16, 4, &mut Rng::seed_from_u64(4));
    let sample: Vec<usize> = (0..m).collect();
    let refs: Vec<usize> = (0..m + 300).collect();
    let run = |workers: usize| {
        let trainer = LbhTrainer::new(LbhTrainConfig {
            bits: 3,
            iters_per_bit: 12,
            workers,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(777);
        trainer.train(ds.features(), &sample, &refs, &mut rng)
    };
    let (h1, s1) = run(1);
    for w in WORKER_COUNTS {
        let (hw, sw) = run(w);
        assert_eq!(hw.pairs.u.data, h1.pairs.u.data, "u parity workers={w}");
        assert_eq!(hw.pairs.v.data, h1.pairs.v.data, "v parity workers={w}");
        assert_eq!(sw.bit_costs, s1.bit_costs, "surrogate costs workers={w}");
        assert_eq!(sw.discrete_costs, s1.discrete_costs, "discrete costs workers={w}");
        assert_eq!(sw.residue_after.to_bits(), s1.residue_after.to_bits());
        assert_eq!(sw.t1, s1.t1);
        assert_eq!(sw.t2, s1.t2);
    }
    // and the trained hashes encode identically
    let c1 = h1.encode_all(ds.features());
    let (h4, _) = run(4);
    let c4 = h4.encode_all_pool(ds.features(), &Pool::new(4));
    assert_eq!(c1.codes, c4.codes);
}

#[test]
fn lsh_multi_table_build_and_query_batch_parity() {
    let mut rng = Rng::seed_from_u64(6);
    let ds = test_blobs(2_000, 16, 3, &mut rng);
    let mut seeds: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
    let make = |t: usize| BhHash::sample(16, 8, &mut Rng::seed_from_u64(seeds[t]));
    let serial = chh::table::LshIndex::build(ds.features(), 10, make);
    let queries: Vec<Vec<f32>> = (0..24).map(|_| unit_vec(&mut rng, 16)).collect();
    let serial_hits = serial.query_batch(&queries, ds.features(), &Pool::serial());
    for w in WORKER_COUNTS {
        let pool = Pool::new(w);
        let idx = chh::table::LshIndex::build_with(ds.features(), 10, make, &pool);
        assert_eq!(idx.n_tables(), 10);
        assert_eq!(idx.memory_bytes(), serial.memory_bytes(), "workers={w}");
        let hits = idx.query_batch(&queries, ds.features(), &pool);
        assert_eq!(hits.len(), serial_hits.len());
        for (h, s) in hits.iter().zip(serial_hits.iter()) {
            assert_eq!(h.best, s.best, "workers={w}");
            assert_eq!(h.scanned, s.scanned);
            assert_eq!(h.nonempty, s.nonempty);
        }
    }
    seeds.clear();
}

#[test]
fn sharded_fanout_parity() {
    let mut rng = Rng::seed_from_u64(5);
    let ds = test_blobs(1_200, 16, 3, &mut rng);
    let fam = BhHash::sample(16, 12, &mut rng);
    let codes = fam.encode_all(ds.features());
    let idx = ShardedIndex::from_codes(&codes, 3, 6);
    let budget = QueryBudget::new(96, 48);
    for _ in 0..6 {
        let w = unit_vec(&mut rng, 16);
        let inline = idx.query(&fam, &w, ds.features(), budget, |_| true);
        for workers in WORKER_COUNTS {
            let pooled =
                idx.query_pool(&fam, &w, ds.features(), budget, |_| true, &Pool::new(workers));
            assert_eq!(pooled.best, inline.best, "workers={workers}");
            assert_eq!(pooled.scanned, inline.scanned);
            assert_eq!(pooled.probed, inline.probed);
        }
    }
}
