//! Acceptance tests for the online serving subsystem:
//!
//! 1. `ShardedIndex` with one shard and a probe budget covering the whole
//!    Hamming ball answers exactly like the static `HyperplaneIndex` built
//!    from the same codes.
//! 2. Under interleaved insert/remove churn — single-threaded and
//!    concurrent — a query never returns a removed id.

use std::collections::HashSet;
use std::sync::Arc;

use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::online::{QueryBudget, ShardedIndex};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;
use chh::testing::unit_vec;

#[test]
fn single_shard_full_budget_matches_static_index() {
    let mut rng = Rng::seed_from_u64(70);
    let ds = test_blobs(1200, 24, 4, &mut rng);
    let fam = BhHash::sample(24, 14, &mut rng);
    let codes = fam.encode_all(ds.features());
    let radius = 3;
    let static_idx = HyperplaneIndex::from_codes(codes.clone(), radius);
    let online_idx = ShardedIndex::from_codes(&codes, radius, 1);
    assert_eq!(online_idx.len(), ds.len());
    let budget = QueryBudget::new(static_idx.probe_volume() as usize, usize::MAX);
    for _ in 0..40 {
        let w = unit_vec(&mut rng, 24);
        let lookup = fam.encode_query(&w);
        let a = static_idx.query_code_filtered(lookup, &w, ds.features(), |_| true);
        let b = online_idx.query_code(lookup, None, &w, ds.features(), budget, |_| true);
        assert_eq!(
            a.best.map(|(i, _)| i),
            b.best.map(|(i, _)| i),
            "best candidate must match the static table"
        );
        if let (Some((_, ma)), Some((_, mb))) = (a.best, b.best) {
            assert!((ma - mb).abs() < 1e-7, "margins {ma} vs {mb}");
        }
        assert_eq!(a.scanned, b.scanned, "same candidate set scanned");
        assert_eq!(a.nonempty, b.nonempty);
        assert_eq!(a.probed, b.probed, "full budget probes the whole ball");
    }
}

#[test]
fn query_adaptive_probe_order_preserves_full_ball_results() {
    // reordering probes must not change the full-budget result set
    let mut rng = Rng::seed_from_u64(71);
    let ds = test_blobs(800, 16, 3, &mut rng);
    let fam = BhHash::sample(16, 12, &mut rng);
    let codes = fam.encode_all(ds.features());
    let static_idx = HyperplaneIndex::from_codes(codes.clone(), 3);
    let online_idx = ShardedIndex::from_codes(&codes, 3, 1);
    for _ in 0..25 {
        let w = unit_vec(&mut rng, 16);
        let a = static_idx.query_filtered(&fam, &w, ds.features(), |_| true);
        let b = online_idx.query(&fam, &w, ds.features(), QueryBudget::unlimited(), |_| true);
        assert_eq!(a.best.map(|(i, _)| i), b.best.map(|(i, _)| i));
        assert_eq!(a.scanned, b.scanned);
    }
}

#[test]
fn interleaved_churn_never_returns_removed_ids() {
    let mut rng = Rng::seed_from_u64(72);
    let ds = test_blobs(1000, 16, 4, &mut rng);
    let fam = BhHash::sample(16, 10, &mut rng);
    let mut online = ShardedIndex::new(10, 2, 3);
    online.set_compact_threshold(64); // force frequent epoch turnover
    let online = online;
    let mut live: HashSet<u32> = HashSet::new();
    // seed half the points
    for id in 0..500u32 {
        online.insert_point(&fam, id, ds.features().row(id as usize));
        live.insert(id);
    }
    let budget = QueryBudget::unlimited();
    let mut next = 500u32;
    for round in 0..60 {
        // interleave: a few inserts, a few removes, then queries
        for _ in 0..5 {
            if (next as usize) < ds.len() {
                online.insert_point(&fam, next, ds.features().row(next as usize));
                live.insert(next);
                next += 1;
            }
        }
        for _ in 0..5 {
            let victim = live.iter().next().copied();
            if let Some(victim) = victim {
                assert!(online.remove(victim), "live id {victim} must remove");
                live.remove(&victim);
            }
        }
        let w = unit_vec(&mut rng, 16);
        let hit = online.query(&fam, &w, ds.features(), budget, |_| true);
        if let Some((id, _)) = hit.best {
            assert!(
                live.contains(&(id as u32)),
                "round {round}: removed/never-inserted id {id} returned"
            );
        }
        assert_eq!(online.len(), live.len(), "round {round}: live count drift");
    }
    assert!(online.total_epoch() > 0, "compactions must have happened");
}

#[test]
fn concurrent_churn_respects_removals() {
    // writer removes a doomed set while readers query concurrently; after
    // the writer joins, no doomed id may ever be returned again
    let mut rng = Rng::seed_from_u64(73);
    let ds = test_blobs(1500, 16, 4, &mut rng);
    let fam = Arc::new(BhHash::sample(16, 10, &mut rng));
    let codes = fam.encode_all(ds.features());
    let index = Arc::new(ShardedIndex::from_codes(&codes, 4, 4));
    let feats = Arc::new(ds.features().clone());
    let doomed: Vec<u32> = (0..1500u32).filter(|i| i % 3 == 0).collect();
    let widx = index.clone();
    let doomed_w = doomed.clone();
    let writer = std::thread::spawn(move || {
        for id in doomed_w {
            widx.remove(id);
        }
        widx.compact();
    });
    // concurrent readers: results must always be in-bounds and finite
    let mut readers = Vec::new();
    for t in 0..3u64 {
        let idx = index.clone();
        let fam = fam.clone();
        let feats = feats.clone();
        readers.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(90 + t);
            for _ in 0..40 {
                let w = unit_vec(&mut rng, 16);
                let hit = idx.query(fam.as_ref(), &w, &feats, QueryBudget::unlimited(), |_| true);
                if let Some((id, m)) = hit.best {
                    assert!(id < 1500);
                    assert!(m.is_finite());
                }
            }
        }));
    }
    writer.join().unwrap();
    for r in readers {
        r.join().unwrap();
    }
    let doomed_set: HashSet<u32> = doomed.into_iter().collect();
    assert_eq!(index.len(), 1500 - doomed_set.len());
    for _ in 0..40 {
        let w = unit_vec(&mut rng, 16);
        let hit = index.query(fam.as_ref(), &w, ds.features(), QueryBudget::unlimited(), |_| true);
        if let Some((id, _)) = hit.best {
            assert!(!doomed_set.contains(&(id as u32)), "doomed id {id} returned");
        }
    }
}

#[test]
fn snapshot_roundtrip_preserves_query_results() {
    let mut rng = Rng::seed_from_u64(74);
    let ds = test_blobs(900, 16, 3, &mut rng);
    let fam = BhHash::sample(16, 12, &mut rng);
    let codes = fam.encode_all(ds.features());
    let index = ShardedIndex::from_codes(&codes, 3, 4);
    for id in (0..900u32).step_by(5) {
        index.remove(id);
    }
    let path = std::env::temp_dir().join(format!("chh_online_snap_{}", std::process::id()));
    chh::persist::save_sharded(&path, &index).unwrap();
    let back = chh::persist::load_sharded(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(back.len(), index.len());
    for _ in 0..25 {
        let w = unit_vec(&mut rng, 16);
        let a = index.query(&fam, &w, ds.features(), QueryBudget::unlimited(), |_| true);
        let b = back.query(&fam, &w, ds.features(), QueryBudget::unlimited(), |_| true);
        assert_eq!(a.best.map(|(i, _)| i), b.best.map(|(i, _)| i));
        assert_eq!(a.scanned, b.scanned);
    }
}
