//! Coordinator under load: correctness and liveness of the router when
//! many submitters share a small queue (backpressure), plus integration
//! with the active-learning exclusion protocol.

use std::collections::HashSet;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use chh::coordinator::{QueryRequest, Router};
use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;
use chh::testing::unit_vec;

fn build(n: usize, seed: u64) -> (Arc<dyn HashFamily>, Arc<HyperplaneIndex>, Arc<chh::data::FeatureStore>) {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = test_blobs(n, 24, 4, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(24, 12, &mut rng));
    let idx = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 4));
    (fam, idx, Arc::new(ds.features().clone()))
}

#[test]
fn no_query_lost_or_duplicated_under_contention() {
    let (fam, idx, feats) = build(1000, 1);
    let router = Arc::new(Router::new(fam, idx, feats, 3, 4));
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let r = router.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(t + 10);
            let mut ids = Vec::new();
            for _ in 0..50 {
                let resp = r
                    .submit(QueryRequest { w: unit_vec(&mut rng, 24), exclude: None })
                    .wait();
                ids.push(resp.id);
            }
            ids
        }));
    }
    let mut all_ids: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert_eq!(all_ids.len(), 300);
    all_ids.sort_unstable();
    all_ids.dedup();
    assert_eq!(all_ids.len(), 300, "response ids must be unique");
    assert_eq!(router.stats().completed.load(Ordering::Relaxed), 300);
}

#[test]
fn batched_one_vs_all_iteration_protocol() {
    // emulate one AL iteration: submit the 10 one-vs-all hyperplanes as a
    // batch with a shared labeled-set exclusion, get 10 candidates back
    let (fam, idx, feats) = build(2000, 2);
    let router = Router::new(fam, idx, feats.clone(), 2, 32);
    let mut rng = Rng::seed_from_u64(3);
    let labeled: HashSet<usize> = (0..50).collect();
    let labeled = Arc::new(labeled);
    let reqs: Vec<QueryRequest> = (0..10)
        .map(|_| QueryRequest { w: unit_vec(&mut rng, 24), exclude: Some(labeled.clone()) })
        .collect();
    let resps = router.submit_batch(reqs);
    assert_eq!(resps.len(), 10);
    for r in &resps {
        if let Some((idx, margin)) = r.hit.best {
            assert!(!labeled.contains(&idx), "labeled point returned");
            assert!(margin >= 0.0);
        }
    }
    router.shutdown();
}

#[test]
fn throughput_counters_consistent() {
    let (fam, idx, feats) = build(500, 4);
    let router = Router::new(fam, idx, feats, 2, 8);
    let mut rng = Rng::seed_from_u64(5);
    let n = 100;
    let mut nonempty_from_hits = 0u64;
    for _ in 0..n {
        let resp = router
            .submit(QueryRequest { w: unit_vec(&mut rng, 24), exclude: None })
            .wait();
        if !resp.hit.nonempty {
            nonempty_from_hits += 1;
        }
    }
    let st = router.stats();
    assert_eq!(st.submitted.load(Ordering::Relaxed), n);
    assert_eq!(st.completed.load(Ordering::Relaxed), n);
    assert_eq!(st.empty_lookups.load(Ordering::Relaxed), nonempty_from_hits);
    router.shutdown();
}

#[test]
fn router_survives_shutdown_with_pending_work() {
    let (fam, idx, feats) = build(300, 6);
    let router = Router::new(fam, idx, feats, 1, 2);
    let mut rng = Rng::seed_from_u64(7);
    // submit and wait for a few, then shutdown cleanly
    for _ in 0..5 {
        router
            .submit(QueryRequest { w: unit_vec(&mut rng, 24), exclude: None })
            .wait();
    }
    router.shutdown(); // must not hang or panic
}
