//! Transport-scaling smoke: the acceptance criterion of the event-loop
//! refactor is that idle keep-alive connections cost O(workers) threads,
//! not O(connections). This opens hundreds of idle sockets against a
//! live server, checks `/stats` connection accounting and (on Linux)
//! the process thread count, and verifies the server still answers
//! queries while holding them all.

#![cfg(unix)]

use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::rng::Rng;
use chh::server::{protocol, BatcherConfig, HttpClient, Server, ServerConfig, Stack};
use chh::table::HyperplaneIndex;

const DIM: usize = 16;
const IDLE_CONNS: usize = 300;

#[test]
fn idle_connections_cost_bounded_threads() {
    let mut rng = Rng::seed_from_u64(7);
    let ds = test_blobs(200, DIM, 3, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(DIM, 10, &mut rng));
    let idx = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 4));
    let feats = Arc::new(ds.features().clone());
    let router = Arc::new(chh::coordinator::Router::new(fam, idx, feats, 1, 16));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        max_conns: 1024,
        conn_workers: 4,
        batch: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        },
        pool_workers: 2,
        // long enough that the idle herd is never reaped mid-test
        idle_timeout: Duration::from_secs(60),
        slow_ms: 0,
        slow_log: None,
        audit_frac: 0.0,
    };
    let handle = Server::spawn(Stack::Static(router), cfg).expect("spawn server");
    let addr = handle.addr().to_string();

    // the idle herd: connected, never sending a byte
    let mut idle: Vec<TcpStream> = Vec::with_capacity(IDLE_CONNS);
    for i in 0..IDLE_CONNS {
        let s = TcpStream::connect(&addr).unwrap_or_else(|e| panic!("connect {i}: {e}"));
        idle.push(s);
    }

    // accepts are asynchronous: poll /stats until the herd is accounted
    let mut client = HttpClient::connect_retry(&addr, Duration::from_secs(5)).unwrap();
    client.set_timeout(Duration::from_secs(10)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    let transport = loop {
        let resp = client.get("/stats").expect("get /stats");
        assert_eq!(resp.status, 200);
        let v = chh::jsonio::Json::parse_bytes(&resp.body).expect("stats json");
        let t = v.get("transport").expect("transport section").clone();
        let open = t.get("open_connections").and_then(|x| x.as_usize()).unwrap_or(0);
        if open >= IDLE_CONNS {
            break t;
        }
        assert!(
            Instant::now() < deadline,
            "only {open}/{IDLE_CONNS} idle connections accounted in /stats"
        );
        std::thread::sleep(Duration::from_millis(25));
    };
    assert_eq!(
        transport.get("model").and_then(|x| x.as_str()),
        Some("event_loop"),
        "unix builds serve through the poll(2) event loop"
    );
    assert_eq!(transport.get("conn_workers").and_then(|x| x.as_usize()), Some(4));
    let accepted =
        transport.get("connections_accepted").and_then(|x| x.as_usize()).unwrap_or(0);
    assert!(accepted > IDLE_CONNS, "acceptor counted the herd (got {accepted})");
    // O(workers), not O(connections): with 300+ sockets parked the whole
    // process stays well under a hundred threads (a thread-per-connection
    // regression would put it past 300)
    if let Some(threads) = transport.get("threads").and_then(|x| x.as_usize()) {
        assert!(
            threads < 100,
            "{threads} process threads while holding {IDLE_CONNS} idle connections"
        );
    }

    // the server still answers queries while holding the herd
    let w = vec![0.5f32; DIM];
    let resp = client.post("/query", &protocol::query_body(&w)).expect("post /query");
    assert_eq!(resp.status, 200);
    protocol::parse_hit(&resp.body).expect("parse hit");

    drop(client);
    drop(idle);
    handle.shutdown();
}
