//! Forall-style parity suite for the vectorized hot-path kernels.
//!
//! The blocked encode GEMM ([`chh::linalg::project_block`]) and the
//! chunked popcount sweep ([`chh::hash::codes::hamming_sweep_into`])
//! replaced scalar per-element loops; these properties pin them
//! **bit-identical** to the scalar references across the shapes that
//! break blocked kernels — empty and singleton stores, lengths around
//! the block boundaries, k ∈ {1, 63, 64}, dense and sparse rows, and
//! every pooled worker count vs serial.

use chh::data::{newsgroups_like, FeatureStore, NewsConfig};
use chh::hash::codes::{hamming_sweep_into, mask, CodeArray, SCAN_BLOCK};
use chh::hash::{BhHash, HashFamily, ProjectionPairs, ENCODE_CHUNK};
use chh::linalg::Mat;
use chh::par::Pool;
use chh::prop_assert;
use chh::rng::Rng;
use chh::table::{HyperplaneIndex, QueryScratch};
use chh::testing::{forall, unit_vec};

const WORKER_COUNTS: [usize; 3] = [2, 3, 4];

/// The edge-case code lengths: single bit, one-below-word, full word.
const EDGE_K: [usize; 3] = [1, 63, 64];

fn random_dense(rng: &mut Rng, n: usize, d: usize) -> FeatureStore {
    FeatureStore::Dense(Mat::from_vec(n, d, rng.gauss_vec(n * d)))
}

fn random_sparse(rng: &mut Rng, n: usize, d: usize) -> FeatureStore {
    let mut b = chh::sparse::CsrBuilder::new(d);
    for _ in 0..n {
        let nnz = rng.below(d.min(8) + 1);
        let mut entries: Vec<(u32, f32)> = (0..nnz)
            .map(|_| (rng.below(d) as u32, rng.gauss_f32()))
            .collect();
        b.push_row(&mut entries);
    }
    FeatureStore::Sparse(b.finish())
}

/// Scalar reference for the batch encode: per-point `encode_point`.
fn pointwise_codes(fam: &dyn HashFamily, feats: &FeatureStore) -> Vec<u64> {
    (0..feats.len()).map(|i| fam.encode_point(feats.row(i))).collect()
}

#[test]
fn blocked_encode_matches_pointwise_dense_and_sparse() {
    forall("blocked encode == per-point encode", 24, |rng| {
        let d = rng.range(2, 48);
        let k = EDGE_K[rng.below(EDGE_K.len())];
        // straddle the GEMM row-block and (sometimes) the encode chunk
        let n = match rng.below(4) {
            0 => rng.below(9),               // under one row block
            1 => rng.range(9, 200),          // several row blocks
            2 => ENCODE_CHUNK - 1,           // chunk boundary −1
            _ => ENCODE_CHUNK + rng.below(40) + 1, // multiple chunks
        };
        let fam = BhHash::from_pairs(ProjectionPairs::sample(d, k, rng));
        let feats = if rng.bernoulli(0.5) {
            random_dense(rng, n, d)
        } else {
            random_sparse(rng, n, d)
        };
        let reference = pointwise_codes(&fam, &feats);
        let blocked = fam.encode_all(&feats);
        prop_assert!(
            blocked.codes == reference,
            "k={k} d={d} n={n}: blocked serial encode diverged"
        );
        for w in WORKER_COUNTS {
            let pooled = fam.encode_all_pool(&feats, &Pool::new(w));
            prop_assert!(
                pooled.codes == reference,
                "k={k} d={d} n={n} workers={w}: pooled encode diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn hamming_sweep_matches_scalar_reference() {
    forall("chunked sweep == scalar popcount loop", 48, |rng| {
        let k = EDGE_K[rng.below(EDGE_K.len())];
        // lengths straddling the sweep block: 0, 1, ±1 around the block,
        // and a few blocks plus a remainder
        let n = match rng.below(5) {
            0 => 0,
            1 => 1,
            2 => SCAN_BLOCK - 1,
            3 => SCAN_BLOCK,
            _ => SCAN_BLOCK * rng.range(1, 4) + rng.below(SCAN_BLOCK),
        };
        let km = mask(k);
        let codes: Vec<u64> = (0..n).map(|_| rng.next_u64() & km).collect();
        let q = rng.next_u64() & km;
        let reference: Vec<u32> = codes.iter().map(|&c| (c ^ q).count_ones()).collect();
        // stale scratch contents must be cleared, not appended to
        let mut out = vec![0xDEAD_u32; 7];
        hamming_sweep_into(&codes, q, &mut out);
        prop_assert!(out == reference, "k={k} n={n}: sweep diverged");
        // CodeArray::hamming_scan masks junk bits above k itself
        let mut arr = CodeArray::with_capacity(k, n);
        for &c in &codes {
            arr.push(c);
        }
        let junk = if k < 64 { rng.next_u64() & !km } else { 0 };
        arr.hamming_scan(q | junk, &mut out);
        prop_assert!(out == reference, "k={k} n={n}: hamming_scan ignored mask");
        Ok(())
    });
}

#[test]
fn rank_search_matches_fused_scalar_reference() {
    forall("rank_search == fused scalar reference", 16, |rng| {
        let d = rng.range(2, 24);
        let k = EDGE_K[rng.below(EDGE_K.len())];
        let n = rng.below(SCAN_BLOCK * 3);
        let feats = random_dense(rng, n, d);
        let fam = BhHash::from_pairs(ProjectionPairs::sample(d, k, rng));
        let index = HyperplaneIndex::build(&fam, &feats, 1);
        let w = unit_vec(rng, d);
        let lookup = fam.encode_query(&w);
        // random eligibility mask exercises the skip path
        let elig: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.8)).collect();
        // fused scalar reference: same traversal order and tie-breaks,
        // but per-element popcount and no shared scratch
        let qm = lookup & mask(k);
        let mut best_d = u32::MAX;
        let mut best: Option<(usize, f32)> = None;
        let w_norm = chh::linalg::nrm2(&w);
        let mut scanned = 0usize;
        for i in 0..n {
            let dist = (fam.encode_point(feats.row(i)) ^ qm).count_ones();
            if !elig[i] || dist > best_d {
                continue;
            }
            scanned += 1;
            let m = chh::linalg::margin_feat(feats.row(i), &w, w_norm);
            if dist < best_d || best.map_or(true, |(_, bm)| m < bm) {
                best_d = dist;
                best = Some((i, m));
            }
        }
        let hit = index.rank_search(lookup, &w, &feats, |i| elig[i]);
        prop_assert!(hit.scanned == scanned, "k={k} n={n}: scanned {} vs {scanned}", hit.scanned);
        prop_assert!(hit.nonempty == best.is_some(), "k={k} n={n}: nonempty");
        match (hit.best, best) {
            (None, None) => {}
            (Some((ia, ma)), Some((ib, mb))) => {
                prop_assert!(ia == ib, "k={k} n={n}: best id {ia} vs {ib}");
                prop_assert!(
                    ma.to_bits() == mb.to_bits(),
                    "k={k} n={n}: margin bits {ma} vs {mb}"
                );
            }
            (a, b) => prop_assert!(false, "k={k} n={n}: best {a:?} vs {b:?}"),
        }
        // junk bits above k in the lookup must not change the answer
        if k < 64 {
            let dirty = index.rank_search(lookup | (rng.next_u64() & !mask(k)), &w, &feats, |i| {
                elig[i]
            });
            prop_assert!(dirty.best == hit.best, "k={k}: over-k lookup bits leaked");
            prop_assert!(dirty.scanned == hit.scanned, "k={k}: scanned under dirty lookup");
        }
        Ok(())
    });
}

#[test]
fn scratch_reuse_matches_fresh_scratch_everywhere() {
    forall("shared scratch == fresh scratch", 12, |rng| {
        let d = rng.range(4, 20);
        let k = rng.range(4, 17);
        let n = rng.range(50, 400);
        let feats = random_dense(rng, n, d);
        let fam = BhHash::from_pairs(ProjectionPairs::sample(d, k, rng));
        let index = HyperplaneIndex::build(&fam, &feats, 2);
        // one scratch carried across interleaved query kinds vs the
        // thread-local plain variants — answers must be invariant
        let mut shared = QueryScratch::new();
        for q in 0..8 {
            let w = unit_vec(rng, d);
            let lookup = fam.encode_query(&w);
            let a = index.query_code_filtered_with(lookup, &w, &feats, |_| true, &mut shared);
            let b = index.query_code_filtered(lookup, &w, &feats, |_| true);
            prop_assert!(a.best == b.best, "q{q}: filtered best");
            prop_assert!(
                a.scanned == b.scanned && a.probed == b.probed && a.nonempty == b.nonempty,
                "q{q}: filtered counters"
            );
            let ra = index.rank_search_with(lookup, &w, &feats, |_| true, &mut shared);
            let rb = index.rank_search(lookup, &w, &feats, |_| true);
            prop_assert!(ra.best == rb.best && ra.scanned == rb.scanned, "q{q}: rank");
            let ta = index.query_topk_with(&fam, &w, &feats, 5, |_| true, &mut shared);
            let tb = index.query_topk(&fam, &w, &feats, 5, |_| true);
            prop_assert!(ta.len() == tb.len(), "q{q}: topk len");
            for (x, y) in ta.iter().zip(tb.iter()) {
                prop_assert!(
                    x.0 == y.0 && x.1.to_bits() == y.1.to_bits(),
                    "q{q}: topk entry {x:?} vs {y:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_singleton_stores() {
    let mut rng = Rng::seed_from_u64(11);
    for k in EDGE_K {
        let fam = BhHash::from_pairs(ProjectionPairs::sample(8, k, &mut rng));
        // empty store: encode yields zero codes, scans yield empty output
        let empty = FeatureStore::Dense(Mat::zeros(0, 8));
        assert_eq!(fam.encode_all(&empty).len(), 0, "k={k}");
        for w in WORKER_COUNTS {
            assert_eq!(fam.encode_all_pool(&empty, &Pool::new(w)).len(), 0, "k={k} w={w}");
        }
        let mut out = vec![1u32; 3];
        hamming_sweep_into(&[], mask(k), &mut out);
        assert!(out.is_empty(), "k={k}: sweep over empty codes");
        let index = HyperplaneIndex::build(&fam, &empty, 1);
        let w = unit_vec(&mut rng, 8);
        let hit = index.rank_search(fam.encode_query(&w), &w, &empty, |_| true);
        assert_eq!(hit.best, None, "k={k}");
        assert_eq!(hit.scanned, 0, "k={k}");
        assert!(!hit.nonempty, "k={k}");
        // singleton store: the one row must be found and match pointwise
        let single = random_dense(&mut rng, 1, 8);
        let codes = fam.encode_all(&single);
        assert_eq!(codes.codes, pointwise_codes(&fam, &single), "k={k}");
        let index1 = HyperplaneIndex::build(&fam, &single, 1);
        let hit1 = index1.rank_search(fam.encode_query(&w), &w, &single, |_| true);
        assert_eq!(hit1.best.map(|(i, _)| i), Some(0), "k={k}");
        assert_eq!(hit1.scanned, 1, "k={k}");
    }
}

#[test]
fn sparse_stores_hit_edge_code_lengths() {
    let mut rng = Rng::seed_from_u64(12);
    let ds = newsgroups_like(
        &NewsConfig { n: 1_500, vocab: 128, classes: 4, ..Default::default() },
        &mut rng,
    );
    for k in EDGE_K {
        let fam = BhHash::from_pairs(ProjectionPairs::sample(128, k, &mut rng));
        let reference = pointwise_codes(&fam, ds.features());
        assert_eq!(fam.encode_all(ds.features()).codes, reference, "k={k} serial");
        for w in WORKER_COUNTS {
            let pooled = fam.encode_all_pool(ds.features(), &Pool::new(w));
            assert_eq!(pooled.codes, reference, "k={k} workers={w}");
        }
    }
}
