//! Cluster-serving acceptance tests:
//!
//! * **Single-partition parity pin** — a router fronting a 1-partition
//!   map must answer `/query` and `/query_topk` bit-identically (ids,
//!   margin bits, scanned/probed counters) to the partition answering
//!   directly, and to the index math itself: the cluster layer adds
//!   zero semantic drift.
//! * **Two-partition merge** — scatter-gather answers equal
//!   [`chh::online::merge_hits`] over the per-partition answers, the
//!   top-k merge keeps the margin-then-id tie-break, mutations land on
//!   the owning partition, out-of-map ids are refused with 400, and
//!   the live map is inspectable (`GET /map`) and atomically
//!   replaceable (`POST /map`, replays refused).
//! * **Kill a partition** — a dead partition degrades the answer
//!   (`"partial": true`, health gauge 0, partial counter bumped)
//!   instead of silently shortening it; every partition dead is a 503.
//! * **Stale map** — a mutation hitting a demoted node (now a read
//!   replica) follows the 421 redirect to the advertised primary and
//!   counts a stale-map retry.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use chh::cluster::{ClusterConfig, ClusterRouter, Partition, PartitionMap};
use chh::coordinator::OnlineRouter;
use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::online::{merge_hits, QueryBudget, ShardedIndex};
use chh::rng::Rng;
use chh::server::{
    protocol, BatcherConfig, HttpClient, Server, ServerConfig, ServerHandle, Stack,
};
use chh::testing::unit_vec;

const DIM: usize = 16;
const BITS: usize = 10;
const RADIUS: usize = 2;
const SHARDS: usize = 3;
const N: usize = 200;

fn server_cfg() -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        max_conns: 32,
        conn_workers: 2,
        batch: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
        },
        pool_workers: 2,
        idle_timeout: Duration::from_millis(300),
        slow_ms: 0,
        slow_log: None,
        audit_frac: 0.0,
    }
}

fn cluster_cfg() -> ClusterConfig {
    ClusterConfig {
        connect_timeout: Duration::from_millis(250),
        io_timeout: Duration::from_secs(5),
        probe_wait: Duration::from_secs(5),
    }
}

/// The world the tests run in: one dataset/family/budget shared by
/// every in-process partition, so codes and fingerprints agree exactly
/// as they would for servers started with the same profile/bits/seed.
struct World {
    fam: Arc<dyn HashFamily>,
    feats: Arc<chh::data::FeatureStore>,
    budget: QueryBudget,
}

fn world(seed: u64) -> World {
    let mut rng = Rng::seed_from_u64(seed);
    let ds = test_blobs(N, DIM, 3, &mut rng);
    World {
        fam: Arc::new(BhHash::sample(DIM, BITS, &mut rng)),
        feats: Arc::new(ds.features().clone()),
        budget: QueryBudget::new(256, 64),
    }
}

/// One partition primary: an online index holding `[start, end)`
/// behind a live HTTP server.
struct Node {
    index: Arc<ShardedIndex>,
    handle: ServerHandle,
    addr: String,
}

fn spawn_partition(w: &World, start: u32, end: u32) -> Node {
    spawn_partition_cfg(w, start, end, server_cfg())
}

fn spawn_partition_cfg(w: &World, start: u32, end: u32, cfg: ServerConfig) -> Node {
    let index = Arc::new(ShardedIndex::new(BITS, RADIUS, SHARDS));
    for id in start..end {
        index.insert_point(w.fam.as_ref(), id, w.feats.row(id as usize));
    }
    index.compact();
    index.set_default_budget(w.budget);
    let router = Arc::new(OnlineRouter::new(
        w.fam.clone(),
        index.clone(),
        w.feats.clone(),
        1,
        16,
        w.budget,
    ));
    let handle = Server::spawn_with_durability(Stack::Online(router), cfg, None)
        .expect("spawn partition");
    let addr = handle.addr().to_string();
    Node { index, handle, addr }
}

fn family_check(w: &World) -> u32 {
    chh::replicate::family_fingerprint(w.fam.as_ref(), DIM)
}

fn map_for(w: &World, version: u64, parts: &[(u32, u32, &str)]) -> PartitionMap {
    PartitionMap {
        version,
        partitions: parts
            .iter()
            .map(|&(start, end, addr)| Partition {
                start,
                end,
                primary: addr.to_string(),
                replicas: Vec::new(),
                family_check: family_check(w),
            })
            .collect(),
    }
}

fn spawn_router(w: &World, parts: &[(u32, u32, &str)]) -> (Arc<ClusterRouter>, ServerHandle) {
    spawn_router_cfg(w, parts, server_cfg())
}

fn spawn_router_cfg(
    w: &World,
    parts: &[(u32, u32, &str)],
    cfg: ServerConfig,
) -> (Arc<ClusterRouter>, ServerHandle) {
    let map = map_for(w, 1, parts);
    let router =
        Arc::new(ClusterRouter::connect(map, None, cluster_cfg()).expect("router connect"));
    let handle = Server::spawn_cluster(router.clone(), cfg).expect("spawn router");
    (router, handle)
}

fn client(addr: &str) -> HttpClient {
    let mut c = HttpClient::connect_retry(addr, Duration::from_secs(5)).expect("connect");
    c.set_timeout(Duration::from_secs(5)).unwrap();
    c
}

fn bits_of(hits: &[(usize, f32)]) -> Vec<(usize, u32)> {
    hits.iter().map(|&(i, m)| (i, m.to_bits())).collect()
}

fn partial_flag(body: &[u8]) -> Option<bool> {
    chh::jsonio::Json::parse_bytes(body).ok()?.get("partial")?.as_bool()
}

#[test]
fn one_partition_router_answers_bit_identically_to_the_single_node() {
    let w = world(11);
    let part = spawn_partition(&w, 0, N as u32);
    let (_cr, rhandle) = spawn_router(&w, &[(0, N as u32, &part.addr)]);
    let raddr = rhandle.addr().to_string();
    let mut via = client(&raddr);
    let mut direct = client(&part.addr);
    let mut rng = Rng::seed_from_u64(7);
    for q in 0..20 {
        let wv = unit_vec(&mut rng, DIM);
        let body = protocol::query_body(&wv);
        let r = via.post("/query", &body).expect("router /query");
        assert_eq!(r.status, 200, "query {q}");
        let d = direct.post("/query", &body).expect("direct /query");
        assert_eq!(d.status, 200, "query {q} direct");
        let hr = protocol::parse_hit(&r.body).expect("router hit");
        let hd = protocol::parse_hit(&d.body).expect("direct hit");
        // the routed answer must match the node's own answer bit for
        // bit — ids, margin bits, and the scanned/probed counters
        assert_eq!(
            hr.best.map(|(i, m)| (i, m.to_bits())),
            hd.best.map(|(i, m)| (i, m.to_bits())),
            "query {q} best"
        );
        assert_eq!(hr.scanned, hd.scanned, "query {q} scanned");
        assert_eq!(hr.probed, hd.probed, "query {q} probed");
        assert_eq!(hr.nonempty, hd.nonempty, "query {q} nonempty");
        // and the index math itself, not just the other HTTP stack
        let hx = part.index.query(w.fam.as_ref(), &wv, &w.feats, w.budget, |_| true);
        assert_eq!(
            hr.best.map(|(i, m)| (i, m.to_bits())),
            hx.best.map(|(i, m)| (i, m.to_bits())),
            "query {q} vs index"
        );
        assert_eq!((hr.scanned, hr.probed), (hx.scanned, hx.probed), "query {q} counters");
        // a full answer advertises itself as such
        assert_eq!(partial_flag(&r.body), Some(false), "query {q} partial flag");

        let tbody = protocol::topk_body(&wv, 8);
        let rt = via.post("/query_topk", &tbody).expect("router /query_topk");
        assert_eq!(rt.status, 200, "topk {q}");
        let dt = direct.post("/query_topk", &tbody).expect("direct /query_topk");
        let got = protocol::parse_topk_hits(&rt.body).expect("router topk");
        let want = protocol::parse_topk_hits(&dt.body).expect("direct topk");
        assert_eq!(bits_of(&got), bits_of(&want), "topk {q}");
        assert_eq!(partial_flag(&rt.body), Some(false), "topk {q} partial flag");
    }
    rhandle.shutdown();
    part.handle.shutdown();
}

#[test]
fn two_partitions_merge_exactly_and_mutations_land_on_the_owner() {
    let w = world(23);
    let a = spawn_partition(&w, 0, 120);
    let b = spawn_partition(&w, 120, N as u32);
    let (_cr, rhandle) =
        spawn_router(&w, &[(0, 120, &a.addr), (120, N as u32, &b.addr)]);
    let raddr = rhandle.addr().to_string();
    let mut via = client(&raddr);
    let mut da = client(&a.addr);
    let mut db = client(&b.addr);
    let mut rng = Rng::seed_from_u64(3);
    for q in 0..15 {
        let wv = unit_vec(&mut rng, DIM);
        let r = via.post("/query", &protocol::query_body(&wv)).expect("router /query");
        assert_eq!(r.status, 200, "query {q}");
        let hr = protocol::parse_hit(&r.body).expect("router hit");
        let ha = a.index.query(w.fam.as_ref(), &wv, &w.feats, w.budget, |_| true);
        let hb = b.index.query(w.fam.as_ref(), &wv, &w.feats, w.budget, |_| true);
        let want = merge_hits(&[ha, hb]);
        assert_eq!(
            hr.best.map(|(i, m)| (i, m.to_bits())),
            want.best.map(|(i, m)| (i, m.to_bits())),
            "query {q} best must be the global margin minimum"
        );
        assert_eq!(hr.scanned, want.scanned, "query {q} scanned must sum");
        assert_eq!(hr.probed, want.probed, "query {q} probed must sum");
        assert_eq!(hr.nonempty, want.nonempty, "query {q} nonempty");
        assert_eq!(partial_flag(&r.body), Some(false), "query {q} partial flag");

        // top-k: concat the per-partition short lists, sort by margin
        // then id (the OnlineRouter tie-break), truncate — the router's
        // merge must reproduce that exactly
        let tbody = protocol::topk_body(&wv, 8);
        let rt = via.post("/query_topk", &tbody).expect("router /query_topk");
        assert_eq!(rt.status, 200, "topk {q}");
        let ta = protocol::parse_topk_hits(&da.post("/query_topk", &tbody).unwrap().body)
            .expect("partition a topk");
        let tb = protocol::parse_topk_hits(&db.post("/query_topk", &tbody).unwrap().body)
            .expect("partition b topk");
        let mut want: Vec<(usize, f32)> = ta.into_iter().chain(tb).collect();
        want.sort_by(|x, y| {
            x.1.partial_cmp(&y.1).unwrap_or(std::cmp::Ordering::Equal).then(x.0.cmp(&y.0))
        });
        want.truncate(8);
        let got = protocol::parse_topk_hits(&rt.body).expect("router topk");
        assert_eq!(bits_of(&got), bits_of(&want), "topk {q}");
    }

    // mutations are routed by id range to the owning partition
    let before = b.index.len();
    let r = via.post("/remove", &protocol::id_body(150)).expect("remove");
    assert_eq!(r.status, 200);
    assert_eq!(b.index.len(), before - 1, "the owner applied the remove");
    assert_eq!(a.index.len(), 120, "the other partition is untouched");
    let r = via.post("/insert", &protocol::id_body(150)).expect("insert");
    assert_eq!(r.status, 200);
    assert_eq!(b.index.len(), before, "the owner applied the insert");
    // an id no partition owns is refused, never silently dropped
    let r = via.post("/insert", &protocol::id_body(5000)).expect("bad insert");
    assert_eq!(r.status, 400, "out-of-map id must 400");

    // the live map is inspectable and atomically replaceable
    let m = via.get("/map").expect("GET /map");
    assert_eq!(m.status, 200);
    let mj = chh::jsonio::Json::parse_bytes(&m.body).expect("map json");
    assert_eq!(mj.get("version").and_then(|v| v.as_usize()), Some(1));
    let next = map_for(&w, 2, &[(0, 120, &a.addr), (120, N as u32, &b.addr)]);
    let r = via.post("/map", &next.to_string_compact()).expect("POST /map");
    assert_eq!(r.status, 200, "a newer map installs: {}", String::from_utf8_lossy(&r.body));
    let r = via.post("/map", &next.to_string_compact()).expect("POST /map replay");
    assert_eq!(r.status, 409, "a replayed map version is refused");

    rhandle.shutdown();
    a.handle.shutdown();
    b.handle.shutdown();
}

#[test]
fn a_dead_partition_degrades_the_answer_instead_of_shortening_it() {
    let w = world(31);
    let a = spawn_partition(&w, 0, 120);
    let b = spawn_partition(&w, 120, N as u32);
    let (_cr, rhandle) =
        spawn_router(&w, &[(0, 120, &a.addr), (120, N as u32, &b.addr)]);
    let raddr = rhandle.addr().to_string();
    let mut via = client(&raddr);
    let mut rng = Rng::seed_from_u64(5);
    // a healthy round first, so the kill also covers dead *pooled*
    // connections, not just fresh dials
    let wv = unit_vec(&mut rng, DIM);
    let r = via.post("/query", &protocol::query_body(&wv)).expect("warm query");
    assert_eq!(r.status, 200);
    assert_eq!(partial_flag(&r.body), Some(false));

    b.handle.shutdown();
    let wv = unit_vec(&mut rng, DIM);
    let r = via.post("/query", &protocol::query_body(&wv)).expect("degraded query");
    assert_eq!(r.status, 200, "the survivor must keep answering");
    assert_eq!(partial_flag(&r.body), Some(true), "a degraded answer must say so");
    let hr = protocol::parse_hit(&r.body).expect("degraded hit");
    let want = a.index.query(w.fam.as_ref(), &wv, &w.feats, w.budget, |_| true);
    assert_eq!(
        hr.best.map(|(i, m)| (i, m.to_bits())),
        want.best.map(|(i, m)| (i, m.to_bits())),
        "the partial answer is exactly the survivor's answer"
    );

    // the degradation is observable: the partial counter moved and the
    // dead partition's health gauge reads 0
    let mut mc = client(&raddr);
    let m = mc.get("/metrics").expect("GET /metrics");
    assert_eq!(m.status, 200);
    let scrape = chh::obs::parse_scrape(&String::from_utf8_lossy(&m.body));
    let val = |name: &str, label: &str| {
        chh::obs::series_value(&scrape, name, label)
            .unwrap_or_else(|| panic!("metric {name}{{{label}}} missing"))
    };
    assert!(val("chh_router_partial_answers_total", "") >= 1.0, "partial counter");
    assert_eq!(val("chh_cluster_partition_healthy", "partition=\"1\""), 0.0);
    assert_eq!(val("chh_cluster_partition_healthy", "partition=\"0\""), 1.0);

    // every partition dead is an error, not an empty 200
    a.handle.shutdown();
    let r = via.post("/query", &protocol::query_body(&wv)).expect("all-dead query");
    assert_eq!(r.status, 503, "no partitions left must be a 503");
    rhandle.shutdown();
}

#[test]
fn a_stale_map_follows_the_421_redirect_and_counts_it() {
    let w = world(47);
    let dir = std::env::temp_dir()
        .join(format!("chh_cluster_it_stalemap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // a durable primary holding the whole id space...
    let index = Arc::new(ShardedIndex::new(BITS, RADIUS, SHARDS));
    for id in 0..N as u32 {
        index.insert_point(w.fam.as_ref(), id, w.feats.row(id as usize));
    }
    index.compact();
    index.set_default_budget(w.budget);
    let wal_cfg = chh::wal::WalConfig::new(&dir);
    let durable =
        Arc::new(chh::wal::DurableIndex::create(index.clone(), &wal_cfg).expect("create wal"));
    let prouter = Arc::new(OnlineRouter::new(
        w.fam.clone(),
        index.clone(),
        w.feats.clone(),
        1,
        16,
        w.budget,
    ));
    let phandle = Server::spawn_with_durability(
        Stack::Online(prouter),
        server_cfg(),
        Some(chh::server::Durability { durable, snapshot_every_ops: 0 }),
    )
    .expect("spawn primary");
    let paddr = phandle.addr().to_string();
    // ...and a read replica of it, also behind HTTP
    let rcfg = chh::replicate::ReplicaConfig::new(&paddr);
    let replica = chh::replicate::ReplicaIndex::bootstrap(&rcfg).expect("bootstrap");
    let rindex = replica.index().clone();
    rindex.set_default_budget(w.budget);
    let rrouter = Arc::new(OnlineRouter::new(
        w.fam.clone(),
        rindex,
        w.feats.clone(),
        1,
        16,
        w.budget,
    ));
    let rephandle = Server::spawn_replica(
        Stack::Online(rrouter),
        server_cfg(),
        chh::server::ReplicaRole {
            replica,
            primary_addr: paddr.clone(),
            tailer: None,
        },
    )
    .expect("spawn replica");
    let repaddr = rephandle.addr().to_string();

    // the map is stale: it still names the demoted node (now a read
    // replica) as the partition primary
    let map = map_for(&w, 1, &[(0, N as u32, &repaddr)]);
    let cluster = ClusterRouter::connect(map, None, cluster_cfg()).expect("router connect");
    let before = index.len();
    let (applied, _live) =
        cluster.mutate(false, 3, None).expect("the mutation must follow the 421 redirect");
    assert!(applied, "id 3 was live on the primary");
    assert_eq!(index.len(), before - 1, "the op landed on the real primary");
    assert!(
        cluster.stats().stale_map_retries.load(Ordering::Relaxed) >= 1,
        "the stale-map retry is counted"
    );
    rephandle.shutdown();
    phandle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn routed_slow_lines_correlate_router_and_partitions_under_one_request_id() {
    let w = world(59);
    let dir =
        std::env::temp_dir().join(format!("chh_cluster_it_slowlog_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir slow-log dir");
    // `slow_ms: 0` with a sink configured means *every* request is
    // logged — the trace-everything mode the CI smoke also relies on
    let log_cfg = |name: &str| ServerConfig {
        slow_ms: 0,
        slow_log: Some(dir.join(name)),
        ..server_cfg()
    };
    let a = spawn_partition_cfg(&w, 0, 120, log_cfg("pa.jsonl"));
    let b = spawn_partition_cfg(&w, 120, N as u32, log_cfg("pb.jsonl"));
    let (_cr, rhandle) = spawn_router_cfg(
        &w,
        &[(0, 120, &a.addr), (120, N as u32, &b.addr)],
        log_cfg("router.jsonl"),
    );
    let raddr = rhandle.addr().to_string();
    let mut via = client(&raddr);
    let mut rng = Rng::seed_from_u64(13);
    for q in 0..5 {
        let wv = unit_vec(&mut rng, DIM);
        let r = via.post("/query", &protocol::query_body(&wv)).expect("router /query");
        assert_eq!(r.status, 200, "query {q}");
    }

    // the router-side spans also land in the per-partition wait
    // histograms and straggler counters
    let mut mc = client(&raddr);
    let m = mc.get("/metrics").expect("GET /metrics");
    assert_eq!(m.status, 200);
    let scrape = chh::obs::parse_scrape(&String::from_utf8_lossy(&m.body));
    let mut stragglers = 0.0;
    for p in ["0", "1"] {
        let label = format!("partition=\"{p}\"");
        let waits = chh::obs::series_value(&scrape, "chh_partition_seconds_count", &label)
            .unwrap_or_else(|| panic!("chh_partition_seconds_count{{{label}}} missing"));
        assert!(waits >= 5.0, "partition {p} wait observed only {waits} times");
        stragglers += chh::obs::series_value(&scrape, "chh_router_stragglers_total", &label)
            .unwrap_or_else(|| panic!("chh_router_stragglers_total{{{label}}} missing"));
    }
    assert!(stragglers >= 5.0, "every fan-out elects one straggler, saw {stragglers}");

    // shutdown flushes nothing extra — appends are synchronous — but it
    // guarantees no more lines race the reads below
    rhandle.shutdown();
    a.handle.shutdown();
    b.handle.shutdown();

    let query_lines = |name: &str| -> Vec<chh::jsonio::Json> {
        let text = std::fs::read_to_string(dir.join(name)).expect("read slow log");
        text.lines()
            .filter_map(|l| chh::jsonio::Json::parse(l).ok())
            .filter(|j| j.get("route").and_then(|r| r.as_str()) == Some("/query"))
            .collect()
    };
    let id_of = |j: &chh::jsonio::Json| -> String {
        j.get("request_id")
            .and_then(|v| v.as_str())
            .expect("slow line carries request_id")
            .to_string()
    };
    let part_ids: std::collections::HashSet<String> = query_lines("pa.jsonl")
        .iter()
        .chain(query_lines("pb.jsonl").iter())
        .map(&id_of)
        .collect();
    let routed = query_lines("router.jsonl");
    assert_eq!(routed.len(), 5, "one router line per /query");
    for line in &routed {
        let rid = id_of(line);
        assert!(!rid.is_empty(), "router line has an id");
        // the router line carries both partitions' echoed breakdowns...
        let spans = line
            .get("partitions")
            .and_then(|p| p.as_arr())
            .expect("router slow line carries partition spans");
        assert_eq!(spans.len(), 2, "both partitions answered");
        for s in spans {
            assert!(s.get("wait_us").and_then(|v| v.as_f64()).is_some());
            let stages = s
                .get("stages_us")
                .and_then(|v| v.as_obj())
                .expect("span carries the partition's stage breakdown");
            assert!(!stages.is_empty(), "echoed stages are non-empty");
        }
        // ...and the same id appears in the partitions' own slow logs,
        // so the tiers correlate with grep alone
        assert!(part_ids.contains(&rid), "request id {rid} missing from partition logs");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn auditing_changes_no_wire_bytes_and_publishes_quality_gauges() {
    let w = world(67);
    let plain = spawn_partition(&w, 0, N as u32);
    let audited = spawn_partition_cfg(
        &w,
        0,
        N as u32,
        ServerConfig { audit_frac: 1.0, ..server_cfg() },
    );
    let mut cp = client(&plain.addr);
    let mut ca = client(&audited.addr);
    let mut rng = Rng::seed_from_u64(29);
    let queries = 20;
    for q in 0..queries {
        let wv = unit_vec(&mut rng, DIM);
        let body = protocol::query_body(&wv);
        let rp = cp.post("/query", &body).expect("plain /query");
        let ra = ca.post("/query", &body).expect("audited /query");
        assert_eq!(rp.status, 200, "query {q}");
        assert_eq!(ra.status, 200, "query {q} audited");
        // the auditor rides the serving path but must never touch it:
        // the response bodies are byte-identical
        assert_eq!(rp.body, ra.body, "query {q} wire bytes must not change under audit");
    }

    // the auditor drains asynchronously — poll until every offered
    // query was re-answered, then check the quality gauges
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut mc = client(&audited.addr);
    let scrape = loop {
        let m = mc.get("/metrics").expect("GET /metrics");
        assert_eq!(m.status, 200);
        let scrape = chh::obs::parse_scrape(&String::from_utf8_lossy(&m.body));
        let done = chh::obs::series_value(&scrape, "chh_audit_queries_total", "")
            .expect("audited counter registered");
        let dropped =
            chh::obs::series_value(&scrape, "chh_audit_dropped_total", "").unwrap_or(0.0);
        if done + dropped >= queries as f64 {
            break scrape;
        }
        assert!(std::time::Instant::now() < deadline, "auditor stalled at {done}");
        std::thread::sleep(Duration::from_millis(20));
    };
    let recall = chh::obs::series_value(&scrape, "chh_audit_recall", "")
        .expect("chh_audit_recall registered");
    assert!((0.0..=1.0).contains(&recall), "recall is a fraction, got {recall}");
    let rank = chh::obs::series_value(&scrape, "chh_audit_rank_of_best", "")
        .expect("chh_audit_rank_of_best registered");
    // 1-based when any served best was ranked, 0.0 before the first one
    assert!(rank == 0.0 || rank >= 1.0, "rank of best is 1-based, got {rank}");
    assert!(
        scrape.iter().any(|(k, _)| k.starts_with("chh_probe_model_calibration{")),
        "calibration series registered"
    );
    plain.handle.shutdown();
    audited.handle.shutdown();
}
