//! End-to-end active-learning integration at test scale: dataset → hash
//! training → index → AL loop → metrics, asserting the paper's qualitative
//! orderings (the quantitative curves are the bench targets).

use std::sync::Arc;

use chh::active::{AlConfig, AlEngine, Strategy};
use chh::data::test_blobs;
use chh::hash::{BhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::rng::Rng;
use chh::svm::SvmConfig;
use chh::table::HyperplaneIndex;

fn engine_cfg(iters: usize) -> AlConfig {
    AlConfig {
        al_iters: iters,
        init_per_class: 4,
        eval_every: iters / 4,
        svm: SvmConfig::default(),
    }
}

#[test]
fn exhaustive_selects_smaller_margins_than_random() {
    let mut rng = Rng::seed_from_u64(100);
    let ds = test_blobs(600, 32, 3, &mut rng);
    let engine = AlEngine::new(&ds, engine_cfg(30));
    let ex = engine.run_experiment(2, Some(2), 7, |_| Strategy::Exhaustive);
    let ra = engine.run_experiment(2, Some(2), 7, |_| Strategy::Random);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&ex.margin_curve) < mean(&ra.margin_curve),
        "exhaustive {} !< random {}",
        mean(&ex.margin_curve),
        mean(&ra.margin_curve)
    );
}

#[test]
fn lbh_margins_beat_random_and_track_exhaustive() {
    // Fig 3(b)/4(b) shape: LBH's selected margins sit between exhaustive
    // and random, much closer to exhaustive.
    let mut rng = Rng::seed_from_u64(101);
    let ds = test_blobs(800, 32, 3, &mut rng);
    let engine = AlEngine::new(&ds, engine_cfg(30));

    let make_lbh = |rng: &mut Rng| {
        let sample = rng.sample_indices(ds.len(), 96);
        let reference: Vec<usize> = (0..ds.len()).collect();
        let trainer = LbhTrainer::new(LbhTrainConfig {
            bits: 10,
            iters_per_bit: 50,
            ..Default::default()
        });
        let (fam, _) = trainer.train(ds.features(), &sample, &reference, rng);
        let fam: Arc<dyn HashFamily> = Arc::new(fam);
        let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 3));
        Strategy::Hash { family: fam, index }
    };
    let lbh = engine.run_experiment(2, Some(2), 13, make_lbh);
    let ra = engine.run_experiment(2, Some(2), 13, |_| Strategy::Random);
    let ex = engine.run_experiment(2, Some(2), 13, |_| Strategy::Exhaustive);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (m_lbh, m_ra, m_ex) = (mean(&lbh.margin_curve), mean(&ra.margin_curve), mean(&ex.margin_curve));
    assert!(m_lbh < m_ra, "lbh margin {m_lbh} !< random {m_ra}");
    assert!(m_ex <= m_lbh + 1e-9, "exhaustive is the lower envelope");
}

#[test]
fn lbh_retrieves_nearer_hyperplane_neighbors_than_randomized_bh() {
    // The paper's core learning claim (driving Figs 3/4): with the SAME
    // bilinear form and the same code budget, *learned* projections return
    // near-to-hyperplane neighbors with smaller true margins than random
    // projections, for SVM-style hyperplane queries in the compact
    // (sparse-occupancy) regime. Averaged over one-vs-all hyperplanes and
    // 3 projection draws to keep the comparison deterministic.
    let mut rng = Rng::seed_from_u64(102);
    let cfg = chh::data::TinyConfig { n: 2500, d: 48, ..Default::default() };
    let ds = chh::data::tiny1m_like(&cfg, &mut rng);
    let k = 16;
    let radius = 3;

    // one-vs-all SVM hyperplanes on a labeled subsample — realistic queries
    let mut svm_ws: Vec<Vec<f32>> = Vec::new();
    for c in 0..10u16 {
        let idx: Vec<usize> = rng.sample_indices(ds.len(), 400);
        let y: Vec<f32> = idx
            .iter()
            .map(|&i| if ds.labels()[i] == c { 1.0 } else { -1.0 })
            .collect();
        let mut svm = chh::svm::LinearSvm::new(ds.dim());
        svm.train(ds.features(), &idx, &y, &SvmConfig::default());
        svm_ws.push(svm.w);
    }

    let mut m_lbh = 0.0f64;
    let mut m_bh = 0.0f64;
    for draw in 0..2u64 {
        let mut rng_d = Rng::seed_from_u64(500 + draw);
        let sample = rng_d.sample_indices(ds.len(), 512);
        let refs: Vec<usize> = (0..ds.len()).collect();
        let trainer = LbhTrainer::new(LbhTrainConfig { bits: k, ..Default::default() });
        let (lbh, _) = trainer.train(ds.features(), &sample, &refs, &mut rng_d);
        let idx_lbh = HyperplaneIndex::build(&lbh, ds.features(), radius);
        let bh = BhHash::sample(ds.dim(), k, &mut rng_d);
        let idx_bh = HyperplaneIndex::build(&bh, ds.features(), radius);
        for w in &svm_ws {
            let h1 = idx_lbh.query_filtered(&lbh, w, ds.features(), |_| true);
            let h2 = idx_bh.query_filtered(&bh, w, ds.features(), |_| true);
            m_lbh += h1.best.map(|(_, m)| m as f64).unwrap_or(0.5);
            m_bh += h2.best.map(|(_, m)| m as f64).unwrap_or(0.5);
        }
    }
    assert!(
        m_lbh < m_bh,
        "LBH retrieval margin {m_lbh} !< BH {m_bh} (summed over queries)"
    );
}

#[test]
fn map_curves_have_sane_range_for_all_strategies() {
    let mut rng = Rng::seed_from_u64(103);
    let ds = test_blobs(400, 16, 2, &mut rng);
    let engine = AlEngine::new(&ds, engine_cfg(16));
    for strat in ["random", "exhaustive", "bh"] {
        let res = engine.run_experiment(1, Some(1), 3, |rng| match strat {
            "random" => Strategy::Random,
            "exhaustive" => Strategy::Exhaustive,
            _ => {
                let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(ds.dim(), 8, rng));
                let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 2));
                Strategy::Hash { family: fam, index }
            }
        });
        assert!(!res.map_curve.is_empty(), "{strat}: empty MAP curve");
        for &(_, ap) in &res.map_curve {
            assert!((0.0..=1.0).contains(&ap), "{strat}: AP {ap} out of range");
        }
        // blobs are separable: the classifier must end up informative
        assert!(
            res.map_curve.last().unwrap().1 > 0.3,
            "{strat}: final MAP {} too low",
            res.map_curve.last().unwrap().1
        );
    }
}

#[test]
fn sparse_news_like_pipeline_runs() {
    // the sparse-store path through SVM + hashing + AL
    let mut rng = Rng::seed_from_u64(104);
    let cfg = chh::data::NewsConfig { n: 400, vocab: 512, classes: 4, ..Default::default() };
    let ds = chh::data::newsgroups_like(&cfg, &mut rng);
    let engine = AlEngine::new(&ds, engine_cfg(16));
    let res = engine.run_experiment(1, Some(2), 5, |rng| {
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(ds.dim(), 10, rng));
        let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 3));
        Strategy::Hash { family: fam, index }
    });
    assert_eq!(res.margin_curve.len(), 16);
    assert!(res.map_curve.last().unwrap().1 > 0.0);
}
