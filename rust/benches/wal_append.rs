//! WAL append throughput: the durable-insert hot path under each fsync
//! policy, plus group-commit behavior with concurrent appenders.
//!
//! Run: `cargo bench --bench wal_append`
//! (`CHH_BENCH_FULL=1` runs 5× the ops; `--json <path>` writes records.)
//!
//! What to look for: `always` is fsync-bound per *batch* — with one
//! appender that means one fsync per op, with N concurrent appenders
//! group commit amortizes one fsync over the whole burst, so ops/s
//! should climb with concurrency while mean batch size grows.

use std::sync::Arc;
use std::time::Instant;

use chh::bench::JsonReport;
use chh::hash::codes::mask;
use chh::jsonio::Json;
use chh::online::ShardedIndex;
use chh::rng::Rng;
use chh::wal::{DurableIndex, FsyncPolicy, WalConfig};

fn durable_in(dir: std::path::PathBuf, fsync: FsyncPolicy) -> DurableIndex {
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = WalConfig { dir, fsync, segment_bytes: 64 << 20, faults: None };
    DurableIndex::create(Arc::new(ShardedIndex::new(16, 2, 4)), &cfg)
        .expect("create bench wal dir")
}

fn main() {
    let mut json = JsonReport::new("wal_append");
    let full = chh::bench::full_scale();
    let n_ops = if full { 20_000 } else { 4_000 };
    let base = std::env::temp_dir().join(format!("chh_bench_wal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).expect("bench tmp dir");
    println!("wal_append: {n_ops} acknowledged ops per case  ({})", base.display());

    // ── single appender per policy ───────────────────────────────────
    let policies =
        [FsyncPolicy::Always, FsyncPolicy::EveryN(64), FsyncPolicy::IntervalMs(5)];
    let mut rows = Vec::new();
    for policy in policies {
        let dir = base.join(format!("seq_{policy}").replace(':', "_"));
        let d = durable_in(dir, policy);
        let mut rng = Rng::seed_from_u64(1);
        let t0 = Instant::now();
        for i in 0..n_ops {
            d.insert((i % 65_536) as u32, rng.next_u64() & mask(16)).unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        let st = d.wal_stats();
        let fsyncs = st.fsyncs.load(std::sync::atomic::Ordering::Relaxed);
        let bytes = st.bytes.load(std::sync::atomic::Ordering::Relaxed);
        rows.push(vec![
            policy.to_string(),
            format!("{:.0}", n_ops as f64 / secs),
            format!("{:.2}", secs * 1e6 / n_ops as f64),
            format!("{fsyncs}"),
            format!("{bytes}"),
        ]);
        json.push(
            "append_seq",
            vec![
                ("policy", Json::from(policy.to_string())),
                ("ops", Json::from(n_ops)),
                ("ops_per_s", Json::Num(n_ops as f64 / secs)),
                ("mean_us", Json::Num(secs * 1e6 / n_ops as f64)),
                ("fsyncs", Json::from(fsyncs as usize)),
                ("wal_bytes", Json::from(bytes as usize)),
                ("fsync_us", st.fsync_hist.summary_json(1e3)),
            ],
        );
        drop(d);
    }
    chh::report::print_rows(
        "single appender: durable insert (journal + apply + ack)",
        &["fsync", "ops/s", "mean(us)", "fsyncs", "wal bytes"],
        &rows,
    );

    // ── concurrent appenders: group commit under fsync=always ────────
    let mut rows = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        let dir = base.join(format!("conc_{threads}"));
        let d = Arc::new(durable_in(dir, FsyncPolicy::Always));
        let per = n_ops / threads;
        let t0 = Instant::now();
        let joins: Vec<_> = (0..threads)
            .map(|t| {
                let d = d.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::seed_from_u64(7 + t as u64);
                    for i in 0..per {
                        d.insert(((t * per + i) % 65_536) as u32, rng.next_u64() & mask(16))
                            .unwrap();
                    }
                })
            })
            .collect();
        for j in joins {
            j.join().expect("bench appender");
        }
        let secs = t0.elapsed().as_secs_f64();
        let total = per * threads;
        let (mean_batch, p95_batch, max_batch, _) = d.wal_stats().batch_stats();
        let fsyncs = d.wal_stats().fsyncs.load(std::sync::atomic::Ordering::Relaxed);
        rows.push(vec![
            format!("{threads}"),
            format!("{:.0}", total as f64 / secs),
            format!("{mean_batch:.2}"),
            format!("{p95_batch:.0}"),
            format!("{max_batch:.0}"),
            format!("{fsyncs}"),
        ]);
        json.push(
            "group_commit",
            vec![
                ("threads", Json::from(threads)),
                ("ops", Json::from(total)),
                ("ops_per_s", Json::Num(total as f64 / secs)),
                ("mean_batch", Json::Num(mean_batch)),
                ("p95_batch", Json::Num(p95_batch)),
                ("max_batch", Json::Num(max_batch)),
                ("fsyncs", Json::from(fsyncs as usize)),
                ("fsync_us", d.wal_stats().fsync_hist.summary_json(1e3)),
                ("commit_batch", d.wal_stats().commit_batch.summary_json(1.0)),
            ],
        );
        drop(d);
    }
    chh::report::print_rows(
        "group commit: concurrent appenders, fsync=always (one fsync per burst)",
        &["threads", "ops/s", "mean batch", "p95 batch", "max batch", "fsyncs"],
        &rows,
    );

    let _ = std::fs::remove_dir_all(&base);
    if let Some(path) = json.finish().expect("write --json results") {
        println!("json results → {}", path.display());
    }
}
