//! Reproduces paper Fig. 4 (Tiny-1M): MAP / minimum-margin / nonempty
//! lookup results on the dense GIST-like corpus.
//!
//! Paper settings: 1.06M points, 10 CIFAR classes + "other", 20-bit codes
//! (40 for AH), Hamming radius 4, 50 init labels/class, 300 iterations,
//! 5 runs, LBH m=5000. Default here: n=30k, reduced iterations.
//! `CHH_BENCH_FULL=1` runs n=1M (needs ~4 GB and several hours on 1 core).
//!
//! Run: `cargo bench --bench fig4_tiny`

use std::sync::Arc;

use chh::active::{AlConfig, AlEngine, Strategy};
use chh::config::{DatasetProfile, ExperimentConfig};
use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{AhHash, BhHash, EhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::report::{ascii_plot, write_csv, Series};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

fn main() {
    let full = chh::bench::full_scale();
    let mut cfg = ExperimentConfig::for_profile(DatasetProfile::Tiny);
    if full {
        cfg.n = 1_060_000;
        cfg.lbh_m = Some(2048); // m=5000 is quadratic in the trainer; 2048 tiles fit
    } else {
        cfg.n = 30_000;
        cfg.al_iters = 120;
        cfg.runs = 2;
        cfg.max_classes = Some(5);
        cfg.lbh_m = Some(1024);
    }
    println!(
        "fig4_tiny: n={} k={} radius={} iters={} runs={} (full={full})",
        cfg.n,
        cfg.bits(),
        cfg.radius(),
        cfg.al_iters,
        cfg.runs
    );
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = tiny1m_like(&TinyConfig { n: cfg.n, ..Default::default() }, &mut rng);
    let engine = AlEngine::new(&data, AlConfig::from_experiment(&cfg));

    let mut map_series = Vec::new();
    let mut margin_series = Vec::new();
    let mut csv_rows = Vec::new();
    let mut nonempty_rows = Vec::new();
    for strat in ["random", "exhaustive", "ah", "eh", "bh", "lbh"] {
        let t0 = std::time::Instant::now();
        let res = engine.run_experiment(cfg.runs, cfg.max_classes, cfg.seed, |rng| {
            build(strat, &cfg, &data, rng)
        });
        eprintln!("  {strat:<11} done in {:.1}s", t0.elapsed().as_secs_f64());
        let mut ms = Series::new(&res.strategy);
        for &(it, m) in &res.map_curve {
            ms.push(it as f64, m);
            csv_rows.push(vec![res.strategy.clone(), it.to_string(), format!("{m:.6}")]);
        }
        map_series.push(ms);
        let mut gs = Series::new(&res.strategy);
        for (it, &m) in res.margin_curve.iter().enumerate() {
            if it % 5 == 0 {
                gs.push(it as f64, m);
            }
        }
        margin_series.push(gs);
        nonempty_rows.push(vec![
            res.strategy.clone(),
            format!(
                "{:.1}",
                res.nonempty_per_class.iter().sum::<f64>()
                    / res.nonempty_per_class.len().max(1) as f64
            ),
            format!("{}", cfg.al_iters),
            format!("{:.2}s", res.select_secs),
        ]);
    }
    println!("{}", ascii_plot("Fig 4(a): MAP learning curves (tiny1m-like)", &map_series, 64, 16));
    println!(
        "{}",
        ascii_plot("Fig 4(b): minimum-margin curves (lower = better)", &margin_series, 64, 16)
    );
    chh::report::print_rows(
        "Fig 4(c): mean nonempty lookups per class",
        &["strategy", "nonempty", "of iters", "select time"],
        &nonempty_rows,
    );
    write_csv("fig4_map.csv", &["strategy", "iter", "map"], &csv_rows).expect("csv");
    write_csv(
        "fig4_nonempty.csv",
        &["strategy", "nonempty_mean", "iters", "select_secs"],
        &nonempty_rows,
    )
    .expect("csv");
}

fn build(name: &str, cfg: &ExperimentConfig, data: &chh::data::Dataset, rng: &mut Rng) -> Strategy {
    let bits = cfg.bits();
    let radius = cfg.radius();
    match name {
        "random" => Strategy::Random,
        "exhaustive" => Strategy::Exhaustive,
        "ah" => {
            let fam: Arc<dyn HashFamily> = Arc::new(AhHash::sample(data.dim(), bits, rng));
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        "eh" => {
            let fam: Arc<dyn HashFamily> = Arc::new(EhHash::sampled(data.dim(), bits, 256, rng));
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        "bh" => {
            let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), bits, rng));
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        "lbh" => {
            let m = cfg.lbh_m();
            let sample = rng.sample_indices(data.len(), m);
            let refs = rng.sample_indices(data.len(), data.len().min(4000));
            let trainer = LbhTrainer::new(LbhTrainConfig { bits, ..Default::default() });
            let (fam, _) = trainer.train(data.features(), &sample, &refs, rng);
            let fam: Arc<dyn HashFamily> = Arc::new(fam);
            let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), data.features(), radius));
            Strategy::Hash { family: fam, index }
        }
        other => panic!("unknown strategy {other}"),
    }
}
