//! Ablation: Hamming lookup radius r (DESIGN.md abl-r).
//!
//! Radius trades probe count (Σ C(k,i) buckets) against candidate recall;
//! the paper picks r=3 (k=16) and r=4 (k=20). The sweep exposes the
//! empty-ball cliff below and the scan-cost blowup above.
//!
//! Run: `cargo bench --bench ablation_radius`

use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::codes::ball_volume;
use chh::hash::{BhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::report::write_csv;
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn main() {
    let full = chh::bench::full_scale();
    let n = if full { 100_000 } else { 20_000 };
    let k = 16;
    let queries = 40;
    let mut rng = Rng::seed_from_u64(11);
    println!("ablation_radius: n={n} k={k} queries={queries}");
    let data = tiny1m_like(&TinyConfig { n, d: 128, ..Default::default() }, &mut rng);

    let ws: Vec<Vec<f32>> = (0..queries)
        .map(|q| {
            let c = (q % 10) as u16;
            let idx = rng.sample_indices(n, 400);
            let y: Vec<f32> =
                idx.iter().map(|&i| if data.labels()[i] == c { 1.0 } else { -1.0 }).collect();
            let mut svm = LinearSvm::new(data.dim());
            svm.train(data.features(), &idx, &y, &SvmConfig::default());
            svm.w
        })
        .collect();

    // families trained/sampled once; radius only affects the probe
    let bh = BhHash::sample(data.dim(), k, &mut rng);
    let sample = rng.sample_indices(n, 512);
    let refs = rng.sample_indices(n, 4000);
    let (lbh, _) = LbhTrainer::new(LbhTrainConfig { bits: k, ..Default::default() })
        .train(data.features(), &sample, &refs, &mut rng);

    let mut rows = Vec::new();
    for radius in 0..=5usize {
        for (name, fam) in [("BH", &bh as &dyn HashFamily), ("LBH", &lbh as &dyn HashFamily)] {
            let index = HyperplaneIndex::build(fam, data.features(), radius);
            let (mut msum, mut scanned, mut empty, mut probe_t) = (0.0f64, 0usize, 0usize, 0.0f64);
            for w in &ws {
                let t0 = std::time::Instant::now();
                let hit = index.query_filtered(fam, w, data.features(), |_| true);
                probe_t += t0.elapsed().as_secs_f64();
                scanned += hit.scanned;
                match hit.best {
                    Some((_, m)) => msum += m as f64,
                    None => {
                        empty += 1;
                        msum += 0.5;
                    }
                }
            }
            rows.push(vec![
                radius.to_string(),
                name.into(),
                format!("{:.5}", msum / ws.len() as f64),
                format!("{}", scanned / ws.len()),
                format!("{empty}"),
                format!("{:.3}", probe_t / ws.len() as f64 * 1e3),
                format!("{}", ball_volume(k, radius)),
            ]);
        }
    }
    chh::report::print_rows(
        "ablation: Hamming radius r (k=16)",
        &["r", "method", "margin", "cands", "empty", "ms/query", "buckets probed"],
        &rows,
    );
    write_csv(
        "ablation_radius.csv",
        &["r", "method", "margin", "cands", "empty", "ms_per_query", "buckets"],
        &rows,
    )
    .expect("csv");
}
