//! Micro-benchmarks of every hot-path primitive — the §Perf baseline.
//!
//! Covers: native dot/encode, sparse encode, Hamming scan (POPCNT),
//! Hamming-ball enumeration, table probes, SVM epochs, LBH gradient, and
//! the PJRT batch-encode path when artifacts are present.
//!
//! Run: `cargo bench --bench micro`

use std::hint::black_box;

use chh::bench::{print_table, Bench};
use chh::data::{newsgroups_like, tiny1m_like, NewsConfig, TinyConfig};
use chh::hash::codes::{CodeArray, HammingBall};
use chh::hash::{BhHash, EhHash, HashFamily};
use chh::linalg::dot;
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn main() {
    let b = Bench::default();
    let mut rows = Vec::new();
    let mut rng = Rng::seed_from_u64(42);

    // ── linalg ────────────────────────────────────────────────────────
    let x = rng.gauss_vec(384);
    let y = rng.gauss_vec(384);
    rows.push(b.run("dot d=384", || {
        black_box(dot(black_box(&x), black_box(&y)));
    }));

    // ── encode: dense BH / EH, sparse BH ─────────────────────────────
    let tiny = tiny1m_like(&TinyConfig { n: 4096, ..Default::default() }, &mut rng);
    let bh = BhHash::sample(384, 20, &mut rng);
    rows.push(b.run("bh encode_point d=384 k=20", || {
        black_box(bh.encode_point(tiny.features().row(7)));
    }));
    rows.push(b.run("bh encode_all n=4096", || {
        black_box(bh.encode_all(tiny.features()));
    }));
    let eh = EhHash::sampled(384, 20, 256, &mut rng);
    rows.push(b.run("eh(s=256) encode_point", || {
        black_box(eh.encode_point(tiny.features().row(7)));
    }));
    let news = newsgroups_like(
        &NewsConfig { n: 2048, vocab: 1024, classes: 8, ..Default::default() },
        &mut rng,
    );
    let bh_sparse = BhHash::sample(1024, 16, &mut rng);
    rows.push(b.run("bh encode_point sparse d=1024", || {
        black_box(bh_sparse.encode_point(news.features().row(3)));
    }));

    // ── hamming scan + ball enumeration ──────────────────────────────
    let mut codes = CodeArray::new(20);
    for _ in 0..100_000 {
        codes.push(rng.next_u64() & chh::hash::codes::mask(20));
    }
    let q = rng.next_u64() & chh::hash::codes::mask(20);
    let mut out = Vec::new();
    rows.push(b.run("hamming_scan n=100k k=20", || {
        codes.hamming_scan(black_box(q), &mut out);
        black_box(out.len());
    }));
    rows.push(b.run("ball enumeration k=20 r=4 (6196)", || {
        black_box(HammingBall::new(20, 4).count());
    }));

    // ── table probe ──────────────────────────────────────────────────
    let index = HyperplaneIndex::build(&bh, tiny.features(), 4);
    let w = chh::testing::unit_vec(&mut rng, 384);
    rows.push(b.run("index.query n=4096 k=20 r=4", || {
        black_box(index.query(&bh, black_box(&w), tiny.features()));
    }));
    let mut cand = Vec::new();
    let lookup = bh.encode_query(&w);
    rows.push(b.run("candidates_into (ball probe only)", || {
        index.candidates_into(black_box(lookup), usize::MAX, &mut cand);
        black_box(cand.len());
    }));

    // ── SVM ──────────────────────────────────────────────────────────
    let idx: Vec<usize> = (0..1000).collect();
    let yv: Vec<f32> =
        idx.iter().map(|&i| if tiny.labels()[i] == 0 { 1.0 } else { -1.0 }).collect();
    let cfg = SvmConfig { max_epochs: 5, tol: 0.0, ..Default::default() };
    rows.push(b.run("svm 5 epochs n=1000 d=384", || {
        let mut svm = LinearSvm::new(384);
        svm.train(tiny.features(), &idx, &yv, &cfg);
        black_box(svm.w[0]);
    }));

    // ── LBH gradient (m=256) ─────────────────────────────────────────
    let mut xm = chh::linalg::Mat::zeros(256, 384);
    for i in 0..256 {
        tiny.features().row(i).scatter_into(xm.row_mut(i));
    }
    xm.l2_normalize_rows();
    let s = chh::lbh::similarity_matrix(&xm, 0.8, 0.2);
    let u = rng.gauss_vec(384);
    let v = rng.gauss_vec(384);
    rows.push(b.run("lbh surrogate_grad m=256 d=384", || {
        black_box(chh::lbh::surrogate_grad(&xm, &s, black_box(&u), black_box(&v)));
    }));

    // ── PJRT batch encode (artifacts path) ───────────────────────────
    match chh::runtime::Runtime::open_default() {
        Ok(rt) if rt.has("encode_bh_tiny") => {
            let enc = chh::runtime::BatchEncoder::bilinear(&rt, "tiny").unwrap();
            let pairs = BhHash::sample(384, 20, &mut rng).pairs;
            // warm compile outside the timing loop
            let _ = enc.encode_all(tiny.features(), &pairs);
            rows.push(b.run("pjrt encode_all n=4096 (tile 2048)", || {
                black_box(enc.encode_all(tiny.features(), &pairs).unwrap());
            }));
            let scanner = chh::runtime::MarginScanner::open(&rt, "tiny").unwrap();
            let _ = scanner.scan(tiny.features(), &w);
            rows.push(b.run("pjrt margin_scan n=4096", || {
                black_box(scanner.scan(tiny.features(), black_box(&w)).unwrap());
            }));
        }
        _ => eprintln!("(PJRT artifacts unavailable — skipping pjrt micro rows)"),
    }

    print_table("micro benchmarks", &rows);
    let csv: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{:.9}", r.mean.as_secs_f64()),
                format!("{:.9}", r.p50.as_secs_f64()),
                format!("{:.9}", r.p95.as_secs_f64()),
                r.iters.to_string(),
            ]
        })
        .collect();
    chh::report::write_csv("micro.csv", &["case", "mean_s", "p50_s", "p95_s", "iters"], &csv)
        .expect("csv");
}
