//! Batch-path throughput: the data-parallel engine vs its serial twin on
//! every offline hot path — full-database encode, batch hyperplane
//! queries, retrieval eval (exhaustive ground truth included) and LBH
//! training. Parity is asserted inline: the pooled runs must produce the
//! exact serial results while beating serial wall-clock.
//!
//! Run: `cargo bench --bench batch_throughput`
//! (`CHH_BENCH_FULL=1` for paper-scale n.)

use std::hint::black_box;

use chh::bench::{fmt_dur, print_table, Bench, BenchStats, JsonReport};
use chh::jsonio::Json;
use chh::data::{tiny1m_like, TinyConfig};
use chh::eval::{evaluate, evaluate_with};
use chh::hash::codes::mask;
use chh::hash::{BhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::par::Pool;
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

const WORKERS: usize = 4;

fn speedup_row(name: &str, serial: &BenchStats, pooled: &BenchStats) -> Vec<String> {
    vec![
        name.to_string(),
        fmt_dur(serial.mean),
        fmt_dur(pooled.mean),
        format!("{:.2}x", serial.mean_secs() / pooled.mean_secs().max(1e-12)),
    ]
}

fn main() {
    let full = chh::bench::full_scale();
    let n = if full { 200_000 } else { 30_000 };
    let b = if full { Bench::default() } else { Bench::quick() };
    let mut rng = Rng::seed_from_u64(2012);
    let data = tiny1m_like(&TinyConfig { n, ..Default::default() }, &mut rng);
    let bh = BhHash::sample(384, 20, &mut rng);
    let serial = Pool::serial();
    let pooled = Pool::new(WORKERS);
    let mut rows = Vec::new();
    let mut summary = Vec::new();

    // ── encode_all: the database-wide GEMM path ──────────────────────
    let enc_serial = b.run(&format!("encode_all n={n} serial"), || {
        black_box(bh.encode_all_pool(data.features(), &serial));
    });
    let enc_pooled = b.run(&format!("encode_all n={n} workers={WORKERS}"), || {
        black_box(bh.encode_all_pool(data.features(), &pooled));
    });
    assert_eq!(
        bh.encode_all_pool(data.features(), &serial).codes,
        bh.encode_all_pool(data.features(), &pooled).codes,
        "encode parity"
    );
    summary.push(speedup_row("encode_all", &enc_serial, &enc_pooled));
    rows.push(enc_serial);
    rows.push(enc_pooled);

    // ── encode kernel: blocked GEMM vs the per-point scalar loop ─────
    // (both single-threaded — isolates the cache-blocking win from the
    // pool fan-out measured above)
    let ek_scalar = b.run(&format!("encode_kernel n={n} scalar"), || {
        let codes: Vec<u64> =
            (0..data.len()).map(|i| bh.encode_point(data.features().row(i))).collect();
        black_box(codes);
    });
    let ek_blocked = b.run(&format!("encode_kernel n={n} blocked"), || {
        black_box(bh.encode_all_pool(data.features(), &serial));
    });
    let scalar_codes: Vec<u64> =
        (0..data.len()).map(|i| bh.encode_point(data.features().row(i))).collect();
    assert_eq!(
        bh.encode_all_pool(data.features(), &serial).codes,
        scalar_codes,
        "blocked encode kernel parity"
    );
    summary.push(speedup_row("encode_kernel", &ek_scalar, &ek_blocked));
    rows.push(ek_scalar);
    rows.push(ek_blocked);

    // ── scan kernel: chunked popcount sweep vs naive allocating loop ─
    let codes = bh.encode_all_pool(data.features(), &pooled);
    let scan_w = chh::testing::unit_vec(&mut rng, 384);
    let scan_q = bh.encode_query(&scan_w);
    let sk_scalar = b.run(&format!("scan_kernel n={n} scalar"), || {
        let qm = scan_q & mask(codes.k);
        let out: Vec<u32> = codes.codes.iter().map(|&c| (c ^ qm).count_ones()).collect();
        black_box(out);
    });
    let mut scan_out: Vec<u32> = Vec::new();
    let sk_chunked = b.run(&format!("scan_kernel n={n} chunked"), || {
        codes.hamming_scan(scan_q, &mut scan_out);
        black_box(scan_out.len());
    });
    let qm = scan_q & mask(codes.k);
    let scan_ref: Vec<u32> = codes.codes.iter().map(|&c| (c ^ qm).count_ones()).collect();
    codes.hamming_scan(scan_q, &mut scan_out);
    assert_eq!(scan_out, scan_ref, "chunked scan kernel parity");
    summary.push(speedup_row("scan_kernel", &sk_scalar, &sk_chunked));
    rows.push(sk_scalar);
    rows.push(sk_chunked);

    // ── quantized encode: the approximate i8 path (--quantized) ──────
    // no parity assert — the path is sign-approximate by design; report
    // per-bit agreement with the exact f32 codes instead
    let qp = bh.pairs.quantize();
    let qe = b.run(&format!("encode_quantized n={n} workers={WORKERS}"), || {
        black_box(qp.encode_all_pool(data.features(), &pooled));
    });
    let quant = qp.encode_all_pool(data.features(), &pooled);
    let bits = codes.k as u64;
    let agree: u64 = codes
        .codes
        .iter()
        .zip(quant.codes.iter())
        .map(|(&a, &b)| bits - u64::from((a ^ b).count_ones()))
        .sum();
    println!(
        "quantized per-bit agreement: {:.4} (approximate path, not parity-pinned)",
        agree as f64 / (codes.len() as u64 * bits).max(1) as f64
    );
    rows.push(qe);

    // ── query_batch: one AL round's worth of hyperplanes ─────────────
    let index = HyperplaneIndex::build_with(&bh, data.features(), 4, &pooled);
    let queries: Vec<Vec<f32>> =
        (0..64).map(|_| chh::testing::unit_vec(&mut rng, 384)).collect();
    let qb_serial = b.run("query_batch q=64 serial", || {
        black_box(index.query_batch(&bh, &queries, data.features(), &serial));
    });
    let qb_pooled = b.run(&format!("query_batch q=64 workers={WORKERS}"), || {
        black_box(index.query_batch(&bh, &queries, data.features(), &pooled));
    });
    summary.push(speedup_row("query_batch", &qb_serial, &qb_pooled));
    rows.push(qb_serial);
    rows.push(qb_pooled);

    // ── evaluate: recall@T with exhaustive ground truth ──────────────
    let eval_queries: Vec<Vec<f32>> =
        (0..12).map(|_| chh::testing::unit_vec(&mut rng, 384)).collect();
    let ev_serial = b.run("evaluate q=12 t=20 serial", || {
        black_box(evaluate(&bh, &index, data.features(), &eval_queries, 20));
    });
    let ev_pooled = b.run(&format!("evaluate q=12 t=20 workers={WORKERS}"), || {
        black_box(evaluate_with(&bh, &index, data.features(), &eval_queries, 20, &pooled));
    });
    summary.push(speedup_row("evaluate", &ev_serial, &ev_pooled));
    rows.push(ev_serial);
    rows.push(ev_pooled);

    // ── LBH training: surrogate grad/eval + O(m²) residue ────────────
    // m must clear the trainer's TRAIN_PAR_MIN_M gate or both runs are
    // serial and the comparison is vacuous
    let m = if full { 2048 } else { chh::lbh::TRAIN_PAR_MIN_M + 256 };
    let sample: Vec<usize> = (0..m).collect();
    let refs: Vec<usize> = (0..data.len().min(2000)).collect();
    let train_with = |workers: usize| {
        let trainer = LbhTrainer::new(LbhTrainConfig {
            bits: 4,
            iters_per_bit: 20,
            workers,
            ..Default::default()
        });
        let mut trng = Rng::seed_from_u64(99);
        trainer.train(data.features(), &sample, &refs, &mut trng)
    };
    let (tr_serial_out, tr_serial) =
        Bench::once(&format!("lbh train m={m} k=4 serial"), || train_with(1));
    let (tr_pooled_out, tr_pooled) =
        Bench::once(&format!("lbh train m={m} k=4 workers={WORKERS}"), || train_with(WORKERS));
    assert_eq!(
        tr_serial_out.0.pairs.u.data, tr_pooled_out.0.pairs.u.data,
        "training parity"
    );
    summary.push(speedup_row("lbh_train", &tr_serial, &tr_pooled));
    rows.push(tr_serial);
    rows.push(tr_pooled);

    print_table(&format!("batch throughput (n={n}, {WORKERS} workers)"), &rows);
    chh::report::print_rows(
        "serial vs pooled wall-clock",
        &["path", "serial", "pooled", "speedup"],
        &summary,
    );
    chh::report::write_csv("batch_throughput.csv", &["path", "serial", "pooled", "speedup"], &summary)
        .expect("csv");
    let mut json = JsonReport::new("batch_throughput");
    for s in &rows {
        json.push_stats(s);
    }
    for row in &summary {
        json.push(
            "speedup",
            vec![
                ("path", Json::from(row[0].as_str())),
                ("serial", Json::from(row[1].as_str())),
                ("pooled", Json::from(row[2].as_str())),
                ("speedup", Json::from(row[3].as_str())),
            ],
        );
    }
    if let Some(path) = json.finish().expect("write --json results") {
        println!("json results → {}", path.display());
    }
}
