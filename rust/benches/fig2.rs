//! Reproduces paper Fig. 2: (a) collision probability p₁ vs r for the
//! three randomized families, with Monte-Carlo validation; (b) query-time
//! exponent ρ vs r at ε = 3.
//!
//! Expected shape (paper): BH's p₁ is exactly 2× AH's at every r and the
//! highest of the three; EH's ρ is slightly below BH's, both below AH's.
//!
//! Run: `cargo bench --bench fig2`

use chh::hash::collision::*;
use chh::report::{ascii_plot, write_csv, Series};
use chh::rng::Rng;

fn main() {
    let points = 25usize;
    let eps = 3.0;
    let mc_trials = if chh::bench::full_scale() { 40_000 } else { 4_000 };
    let mut rng = Rng::seed_from_u64(2012);

    // ── Fig 2(a): p1 vs r ────────────────────────────────────────────
    let mut s_ah = Series::new("AH (analytic)");
    let mut s_eh = Series::new("EH (analytic)");
    let mut s_bh = Series::new("BH (analytic)");
    let mut s_mc = Series::new("BH (Monte-Carlo)");
    let mut rows = Vec::new();
    for i in 0..=points {
        let r = R_MAX * i as f64 / points as f64;
        let alpha = r.sqrt();
        let (a, e, b) = (p_ah(r), p_eh(r), p_bh(r));
        s_ah.push(r, a);
        s_eh.push(r, e);
        s_bh.push(r, b);
        let mc = if i % 5 == 0 {
            let est = mc_bh(alpha, 32, mc_trials, &mut rng);
            s_mc.push(r, est);
            format!("{est:.4}")
        } else {
            String::new()
        };
        rows.push(vec![
            format!("{r:.4}"),
            format!("{a:.4}"),
            format!("{e:.4}"),
            format!("{b:.4}"),
            format!("{:.3}", b / a.max(1e-12)),
            mc,
        ]);
    }
    chh::report::print_rows(
        "Fig 2(a): collision probability p1(r) — BH column must be 2x AH",
        &["r", "AH", "EH", "BH", "BH/AH", "BH mc"],
        &rows,
    );
    println!(
        "{}",
        ascii_plot(
            "Fig 2(a): p1 vs r",
            &[s_ah, s_eh, s_bh.clone(), s_mc],
            60,
            14
        )
    );
    write_csv(
        "fig2a.csv",
        &["r", "p_ah", "p_eh", "p_bh"],
        &(0..=60)
            .map(|i| {
                let r = R_MAX * i as f64 / 60.0;
                vec![
                    format!("{r:.6}"),
                    format!("{:.6}", p_ah(r)),
                    format!("{:.6}", p_eh(r)),
                    format!("{:.6}", p_bh(r)),
                ]
            })
            .collect::<Vec<_>>(),
    )
    .expect("csv");

    // ── Fig 2(b): rho vs r at eps = 3 ────────────────────────────────
    let mut s_rah = Series::new("AH rho");
    let mut s_reh = Series::new("EH rho");
    let mut s_rbh = Series::new("BH rho");
    let mut rows_b = Vec::new();
    for i in 1..points {
        // keep r(1+eps) inside the p>0 domain of AH (the binding one)
        let r = (R_MAX / (1.0 + eps)) * 0.999 * i as f64 / points as f64;
        let (ra, re, rb) = (rho(p_ah, r, eps), rho(p_eh, r, eps), rho(p_bh, r, eps));
        if ra.is_finite() {
            s_rah.push(r, ra);
        }
        if re.is_finite() {
            s_reh.push(r, re);
        }
        if rb.is_finite() {
            s_rbh.push(r, rb);
        }
        let fmt = |v: f64| if v.is_nan() { "-".into() } else { format!("{v:.4}") };
        rows_b.push(vec![format!("{r:.4}"), fmt(ra), fmt(re), fmt(rb)]);
    }
    chh::report::print_rows(
        "Fig 2(b): query-time exponent rho(r), eps=3 — EH <= BH < AH",
        &["r", "AH", "EH", "BH"],
        &rows_b,
    );
    println!("{}", ascii_plot("Fig 2(b): rho vs r (eps=3)", &[s_rah, s_reh, s_rbh], 60, 14));
    write_csv(
        "fig2b.csv",
        &["r", "rho_ah", "rho_eh", "rho_bh"],
        &rows_b.iter().map(|r| r.clone()).collect::<Vec<_>>(),
    )
    .expect("csv");

    // machine-checkable reproduction assertions (the paper's claims)
    for i in 0..=20 {
        let r = R_MAX * i as f64 / 20.0;
        assert!((p_bh(r) - 2.0 * p_ah(r)).abs() < 1e-12, "Lemma 1 doubling at r={r}");
        assert!(p_bh(r) + 1e-12 >= p_eh(r), "BH highest p1 at r={r}");
    }
    println!("\nFig 2 reproduction checks passed: p1_BH = 2*p1_AH and BH is the p1 envelope.");
}
