//! Reproduces the paper's supplementary Tables 1–3 (computational
//! efficiency): preprocessing time, per-query search time, and the
//! speedup/memory comparison against exhaustive scan — "LBH-Hash takes
//! comparable preprocessing time as EH-Hash and achieves fast search".
//!
//! Run: `cargo bench --bench tables_efficiency`
//! (`CHH_BENCH_FULL=1` uses n=200k instead of 30k.)

use std::sync::Arc;
use std::time::Instant;

use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{AhHash, BhHash, EhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::linalg::{margin_feat, nrm2};
use chh::metrics::Histogram;
use chh::report::write_csv;
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn main() {
    let full = chh::bench::full_scale();
    let n = if full { 200_000 } else { 30_000 };
    let k = 20;
    let radius = 4; // paper's Tiny-1M setting
    let queries = 100;
    let mut rng = Rng::seed_from_u64(2012);
    println!("tables_efficiency: n={n} d=384 k={k} radius={radius} queries={queries}");
    let data = tiny1m_like(&TinyConfig { n, ..Default::default() }, &mut rng);

    // SVM hyperplane queries (the AL workload)
    let mut ws: Vec<Vec<f32>> = Vec::new();
    for q in 0..queries {
        let c = (q % 10) as u16;
        let idx = rng.sample_indices(n, 500);
        let y: Vec<f32> =
            idx.iter().map(|&i| if data.labels()[i] == c { 1.0 } else { -1.0 }).collect();
        let mut svm = LinearSvm::new(data.dim());
        svm.train(data.features(), &idx, &y, &SvmConfig::default());
        ws.push(svm.w);
    }

    // ── Table 1: preprocessing (train + encode + build table) ────────
    let mut t1_rows = Vec::new();
    let mut indexes: Vec<(String, Arc<dyn HashFamily>, HyperplaneIndex)> = Vec::new();
    {
        let t0 = Instant::now();
        let fam: Arc<dyn HashFamily> = Arc::new(AhHash::sample(data.dim(), k, &mut rng));
        let idx = HyperplaneIndex::build(fam.as_ref(), data.features(), radius);
        t1_rows.push(vec!["AH-Hash".into(), "0.00".into(), format!("{:.2}", t0.elapsed().as_secs_f64()), format!("{}", idx.memory_bytes())]);
        indexes.push(("AH-Hash".into(), fam, idx));
    }
    {
        let t0 = Instant::now();
        let fam: Arc<dyn HashFamily> = Arc::new(EhHash::sampled(data.dim(), k, 256, &mut rng));
        let idx = HyperplaneIndex::build(fam.as_ref(), data.features(), radius);
        t1_rows.push(vec!["EH-Hash".into(), "0.00".into(), format!("{:.2}", t0.elapsed().as_secs_f64()), format!("{}", idx.memory_bytes())]);
        indexes.push(("EH-Hash".into(), fam, idx));
    }
    {
        let t0 = Instant::now();
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), k, &mut rng));
        let idx = HyperplaneIndex::build(fam.as_ref(), data.features(), radius);
        t1_rows.push(vec!["BH-Hash".into(), "0.00".into(), format!("{:.2}", t0.elapsed().as_secs_f64()), format!("{}", idx.memory_bytes())]);
        indexes.push(("BH-Hash".into(), fam, idx));
    }
    {
        let _t0 = Instant::now();
        let m = 1024.min(n / 4);
        let sample = rng.sample_indices(n, m);
        let refs = rng.sample_indices(n, n.min(4000));
        let trainer = LbhTrainer::new(LbhTrainConfig { bits: k, ..Default::default() });
        let (fam, stats) = trainer.train(data.features(), &sample, &refs, &mut rng);
        let train_secs = stats.train_secs;
        let t_enc = Instant::now();
        let fam: Arc<dyn HashFamily> = Arc::new(fam);
        let idx = HyperplaneIndex::build(fam.as_ref(), data.features(), radius);
        t1_rows.push(vec![
            "LBH-Hash".into(),
            format!("{train_secs:.2}"),
            format!("{:.2}", t_enc.elapsed().as_secs_f64()),
            format!("{}", idx.memory_bytes()),
        ]);
        indexes.push(("LBH-Hash".into(), fam, idx));
    }
    chh::report::print_rows(
        "Table 1: preprocessing (train secs, encode+build secs, index bytes)",
        &["method", "train(s)", "encode+build(s)", "memory(B)"],
        &t1_rows,
    );
    write_csv("table1_preprocess.csv", &["method", "train_s", "build_s", "mem_bytes"], &t1_rows)
        .expect("csv");

    // ── Table 2: per-query search time + quality ─────────────────────
    let mut t2_rows = Vec::new();
    let mut exh_mean = 0.0f64;
    let exh_hist = {
        let mut h = Histogram::new();
        let mut msum = 0.0f64;
        for w in &ws {
            let t0 = Instant::now();
            let wn = nrm2(w);
            let mut best = f32::INFINITY;
            for i in 0..n {
                let m = margin_feat(data.features().row(i), w, wn);
                if m < best {
                    best = m;
                }
            }
            h.record(t0.elapsed().as_secs_f64());
            msum += best as f64;
        }
        exh_mean = msum / ws.len() as f64;
        h
    };
    t2_rows.push(vec![
        "Exhaustive".into(),
        format!("{:.3}", exh_hist.mean() * 1e3),
        format!("{:.3}", exh_hist.percentile(95.0) * 1e3),
        format!("{exh_mean:.5}"),
        format!("{n}"),
        "1.0".into(),
    ]);
    let exh_time = exh_hist.mean();
    for (name, fam, idx) in &indexes {
        let mut h = Histogram::new();
        let mut msum = 0.0f64;
        let mut scanned = 0usize;
        let mut empty = 0usize;
        for w in &ws {
            let t0 = Instant::now();
            let hit = idx.query_filtered(fam.as_ref(), w, data.features(), |_| true);
            h.record(t0.elapsed().as_secs_f64());
            scanned += hit.scanned;
            match hit.best {
                Some((_, m)) => msum += m as f64,
                None => {
                    empty += 1;
                    msum += 0.5; // random-selection fallback penalty proxy
                }
            }
        }
        t2_rows.push(vec![
            name.clone(),
            format!("{:.3}", h.mean() * 1e3),
            format!("{:.3}", h.percentile(95.0) * 1e3),
            format!("{:.5}", msum / ws.len() as f64),
            format!("{}", scanned / ws.len()),
            format!("{:.0}", exh_time / h.mean().max(1e-12)),
        ]);
        println!("  {name}: {empty}/{} empty lookups", ws.len());
    }
    chh::report::print_rows(
        "Table 2: search (mean ms, p95 ms, mean margin, candidates, speedup vs exhaustive)",
        &["method", "mean(ms)", "p95(ms)", "margin", "cands", "speedup"],
        &t2_rows,
    );
    write_csv(
        "table2_search.csv",
        &["method", "mean_ms", "p95_ms", "margin", "cands", "speedup"],
        &t2_rows,
    )
    .expect("csv");

    // ── Table 3: storage summary ─────────────────────────────────────
    let raw_bytes = n * data.dim() * 4;
    let mut t3_rows = vec![vec![
        "raw features".into(),
        format!("{:.1}", raw_bytes as f64 / 1e6),
        "-".into(),
    ]];
    for (name, _, idx) in &indexes {
        t3_rows.push(vec![
            name.clone(),
            format!("{:.1}", idx.memory_bytes() as f64 / 1e6),
            format!("{:.1}x", raw_bytes as f64 / idx.memory_bytes() as f64),
        ]);
    }
    chh::report::print_rows(
        "Table 3: memory (MB, compression vs raw f32 features)",
        &["structure", "MB", "compression"],
        &t3_rows,
    );
    write_csv("table3_memory.csv", &["structure", "mb", "compression"], &t3_rows).expect("csv");
}
