//! Ablation: code length k vs retrieval quality & cost (DESIGN.md abl-k).
//!
//! The paper fixes k=16/20 "no more than 30"; this sweep shows the
//! compact-regime trade-off that motivates that choice: more bits sharpen
//! buckets (fewer, better candidates) until the Hamming ball goes empty.
//!
//! Run: `cargo bench --bench ablation_bits`

use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{BhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::linalg::{margin_feat, nrm2};
use chh::report::write_csv;
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn main() {
    let full = chh::bench::full_scale();
    let n = if full { 100_000 } else { 20_000 };
    let radius = 3;
    let queries = 40;
    let mut rng = Rng::seed_from_u64(7);
    println!("ablation_bits: n={n} radius={radius} queries={queries}");
    let data = tiny1m_like(&TinyConfig { n, d: 128, ..Default::default() }, &mut rng);

    let ws: Vec<Vec<f32>> = (0..queries)
        .map(|q| {
            let c = (q % 10) as u16;
            let idx = rng.sample_indices(n, 400);
            let y: Vec<f32> =
                idx.iter().map(|&i| if data.labels()[i] == c { 1.0 } else { -1.0 }).collect();
            let mut svm = LinearSvm::new(data.dim());
            svm.train(data.features(), &idx, &y, &SvmConfig::default());
            svm.w
        })
        .collect();
    let opt: f64 = ws
        .iter()
        .map(|w| {
            let wn = nrm2(w);
            (0..n)
                .map(|i| margin_feat(data.features().row(i), w, wn))
                .fold(f32::INFINITY, f32::min) as f64
        })
        .sum::<f64>()
        / ws.len() as f64;

    let mut rows = Vec::new();
    for &k in &[8usize, 12, 16, 20, 24, 28] {
        for method in ["bh", "lbh"] {
            let fam: Box<dyn HashFamily> = match method {
                "bh" => Box::new(BhHash::sample(data.dim(), k, &mut rng)),
                _ => {
                    let sample = rng.sample_indices(n, 512);
                    let refs = rng.sample_indices(n, 4000);
                    let (f, _) = LbhTrainer::new(LbhTrainConfig { bits: k, ..Default::default() })
                        .train(data.features(), &sample, &refs, &mut rng);
                    Box::new(f)
                }
            };
            let index = HyperplaneIndex::build(fam.as_ref(), data.features(), radius);
            let (mut msum, mut scanned, mut empty, mut probe_t) = (0.0f64, 0usize, 0usize, 0.0f64);
            for w in &ws {
                let t0 = std::time::Instant::now();
                let hit = index.query_filtered(fam.as_ref(), w, data.features(), |_| true);
                probe_t += t0.elapsed().as_secs_f64();
                scanned += hit.scanned;
                match hit.best {
                    Some((_, m)) => msum += m as f64,
                    None => {
                        empty += 1;
                        msum += 0.5;
                    }
                }
            }
            rows.push(vec![
                k.to_string(),
                method.to_uppercase(),
                format!("{:.5}", msum / ws.len() as f64),
                format!("{}", scanned / ws.len()),
                format!("{empty}"),
                format!("{:.3}", probe_t / ws.len() as f64 * 1e3),
                format!("{}", index.probe_volume()),
            ]);
        }
    }
    chh::report::print_rows(
        &format!("ablation: code length k (optimal margin = {opt:.5})"),
        &["k", "method", "margin", "cands", "empty", "ms/query", "ball"],
        &rows,
    );
    write_csv(
        "ablation_bits.csv",
        &["k", "method", "margin", "cands", "empty", "ms_per_query", "ball"],
        &rows,
    )
    .expect("csv");
}
