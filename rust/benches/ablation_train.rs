//! Ablation: LBH training-sample count m and per-bit iteration budget
//! (DESIGN.md abl-m). The paper uses m=500 (20NG) and m=5000 (Tiny-1M);
//! the trainer is O(m²) per iteration, so this is the main training knob.
//!
//! Run: `cargo bench --bench ablation_train`

use chh::data::{tiny1m_like, TinyConfig};

use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::report::write_csv;
use chh::rng::Rng;
use chh::svm::{LinearSvm, SvmConfig};
use chh::table::HyperplaneIndex;

fn main() {
    let full = chh::bench::full_scale();
    let n = if full { 100_000 } else { 20_000 };
    let k = 16;
    let radius = 3;
    let queries = 30;
    let mut rng = Rng::seed_from_u64(13);
    println!("ablation_train: n={n} k={k} radius={radius}");
    let data = tiny1m_like(&TinyConfig { n, d: 128, ..Default::default() }, &mut rng);

    let ws: Vec<Vec<f32>> = (0..queries)
        .map(|q| {
            let c = (q % 10) as u16;
            let idx = rng.sample_indices(n, 400);
            let y: Vec<f32> =
                idx.iter().map(|&i| if data.labels()[i] == c { 1.0 } else { -1.0 }).collect();
            let mut svm = LinearSvm::new(data.dim());
            svm.train(data.features(), &idx, &y, &SvmConfig::default());
            svm.w
        })
        .collect();

    let mut rows = Vec::new();
    // m sweep at the default iteration budget
    for &m in &[64usize, 128, 256, 512, 1024] {
        run_case(&data, &ws, k, radius, m, 300, &mut rng, &mut rows);
    }
    // iteration sweep at m=512
    for &iters in &[50usize, 150, 600] {
        run_case(&data, &ws, k, radius, 512, iters, &mut rng, &mut rows);
    }
    chh::report::print_rows(
        "ablation: LBH training samples m / Nesterov iterations",
        &["m", "iters/bit", "train(s)", "margin", "cands", "residue capt %"],
        &rows,
    );
    write_csv(
        "ablation_train.csv",
        &["m", "iters", "train_s", "margin", "cands", "residue_pct"],
        &rows,
    )
    .expect("csv");
}

#[allow(clippy::too_many_arguments)]
fn run_case(
    data: &chh::data::Dataset,
    ws: &[Vec<f32>],
    k: usize,
    radius: usize,
    m: usize,
    iters: usize,
    rng: &mut Rng,
    rows: &mut Vec<Vec<String>>,
) {
    let sample = rng.sample_indices(data.len(), m);
    let refs = rng.sample_indices(data.len(), data.len().min(4000));
    let trainer =
        LbhTrainer::new(LbhTrainConfig { bits: k, iters_per_bit: iters, ..Default::default() });
    let (fam, stats) = trainer.train(data.features(), &sample, &refs, rng);
    let index = HyperplaneIndex::build(&fam, data.features(), radius);
    let (mut msum, mut scanned) = (0.0f64, 0usize);
    for w in ws {
        let hit = index.query_filtered(&fam, w, data.features(), |_| true);
        scanned += hit.scanned;
        msum += hit.best.map(|(_, m)| m as f64).unwrap_or(0.5);
    }
    rows.push(vec![
        m.to_string(),
        iters.to_string(),
        format!("{:.2}", stats.train_secs),
        format!("{:.5}", msum / ws.len() as f64),
        format!("{}", scanned / ws.len()),
        format!("{:.1}", 100.0 * (1.0 - stats.residue_after / stats.residue_before)),
    ]);
}
