//! Online-serving benchmark: insert + query throughput of the sharded
//! dynamic index under 50/50 churn, plus the probe-budget/latency
//! trade-off of probability-ordered multi-probe vs the full Hamming ball.
//!
//! Run: `cargo bench --bench online_churn`
//! (`CHH_BENCH_FULL=1` uses n=200k instead of 30k.)

use std::time::Instant;

use chh::bench::JsonReport;
use chh::data::{tiny1m_like, TinyConfig};
use chh::hash::{BhHash, HashFamily};
use chh::jsonio::Json;
use chh::metrics::Histogram;
use chh::online::{QueryBudget, ShardedIndex};
use chh::report::write_csv;
use chh::rng::Rng;
use chh::testing::unit_vec;

fn main() {
    let mut json = JsonReport::new("online_churn");
    let full = chh::bench::full_scale();
    let n = if full { 200_000 } else { 30_000 };
    let d = 128;
    let k = 20;
    let radius = 4;
    let shards = 8;
    let mut rng = Rng::seed_from_u64(2012);
    println!("online_churn: n={n} d={d} k={k} r={radius} shards={shards}");
    let data = tiny1m_like(&TinyConfig { n, d, ..Default::default() }, &mut rng);
    let fam = BhHash::sample(d, k, &mut rng);
    let codes = fam.encode_all(data.features());

    // ── bulk load ────────────────────────────────────────────────────
    let warm = n / 2;
    let index = ShardedIndex::new(k, radius, shards);
    let t0 = Instant::now();
    for id in 0..warm {
        index.insert(id as u32, codes.get(id));
    }
    index.compact();
    let load_secs = t0.elapsed().as_secs_f64();
    println!(
        "bulk load: {warm} inserts in {load_secs:.3}s ({:.0} inserts/s), memory ~ {:.1} MB",
        warm as f64 / load_secs,
        index.memory_bytes() as f64 / 1e6
    );
    json.push(
        "bulk_load",
        vec![
            ("inserts", Json::from(warm)),
            ("secs", Json::Num(load_secs)),
            ("inserts_per_s", Json::Num(warm as f64 / load_secs)),
            ("memory_bytes", Json::from(index.memory_bytes())),
        ],
    );

    // ── probe budget sweep (read-only) ───────────────────────────────
    let queries: Vec<Vec<f32>> = (0..100).map(|_| unit_vec(&mut rng, d)).collect();
    let full_ball = index.planner().full_volume() as usize;
    let mut rows = Vec::new();
    for &(probes, top) in
        &[(full_ball, usize::MAX), (1024, usize::MAX), (256, 64), (64, 32), (16, 16)]
    {
        let budget = QueryBudget::new(probes, top);
        let mut h = Histogram::new();
        let mut hits = 0usize;
        let mut margin_sum = 0.0f64;
        let mut scanned = 0usize;
        for w in &queries {
            let t0 = Instant::now();
            let hit = index.query(&fam, w, data.features(), budget, |_| true);
            h.record(t0.elapsed().as_secs_f64());
            scanned += hit.scanned;
            if let Some((_, m)) = hit.best {
                hits += 1;
                margin_sum += m as f64;
            }
        }
        rows.push(vec![
            format!("T={probes} top={}", if top == usize::MAX { "inf".into() } else { top.to_string() }),
            format!("{:.1}", h.mean() * 1e6),
            format!("{:.1}", h.percentile(95.0) * 1e6),
            format!("{}", scanned / queries.len()),
            format!("{hits}/{}", queries.len()),
            format!("{:.5}", margin_sum / hits.max(1) as f64),
        ]);
        json.push(
            "probe_sweep",
            vec![
                ("probes", Json::from(probes.min(u32::MAX as usize))),
                ("top", Json::from(top.min(u32::MAX as usize))),
                ("mean_us", Json::Num(h.mean() * 1e6)),
                ("p95_us", Json::Num(h.percentile(95.0) * 1e6)),
                ("cands_per_q", Json::from(scanned / queries.len())),
                ("hits", Json::from(hits)),
                ("queries", Json::from(queries.len())),
                ("mean_margin", Json::Num(margin_sum / hits.max(1) as f64)),
            ],
        );
    }
    chh::report::print_rows(
        "probe budget sweep (best-first multi-probe, read-only)",
        &["budget", "mean(us)", "p95(us)", "cands", "hit rate", "mean margin"],
        &rows,
    );
    write_csv(
        "online_probe_sweep.csv",
        &["budget", "mean_us", "p95_us", "cands", "hits", "margin"],
        &rows,
    )
    .expect("csv");

    // ── 50/50 churn: inserts+removes interleaved with queries ────────
    let budget = QueryBudget::new(1024, 64);
    let churn_ops = if full { 200_000 } else { 40_000 };
    let mut next = warm;
    let mut removed = 0usize;
    let mut qh = Histogram::new();
    let mut q = 0usize;
    let t0 = Instant::now();
    for op in 0..churn_ops {
        if op % 2 == 0 && next < n {
            index.insert(next as u32, codes.get(next));
            next += 1;
        } else {
            let victim = rng.below(next) as u32;
            if index.remove(victim) {
                removed += 1;
            }
        }
        if op % 8 == 0 {
            let w = &queries[q % queries.len()];
            q += 1;
            let tq = Instant::now();
            let hit = index.query(&fam, w, data.features(), budget, |_| true);
            qh.record(tq.elapsed().as_secs_f64());
            std::hint::black_box(hit);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let churn_rows = vec![vec![
        format!("{churn_ops}"),
        format!("{q}"),
        format!("{:.0}", (churn_ops + q) as f64 / secs),
        format!("{:.1}", qh.mean() * 1e6),
        format!("{:.1}", qh.percentile(95.0) * 1e6),
        format!("{removed}"),
        format!("{}", index.len()),
        format!("{}", index.total_epoch()),
    ]];
    chh::report::print_rows(
        "50/50 churn (insert+remove) with interleaved queries",
        &["ops", "queries", "ops/s", "q mean(us)", "q p95(us)", "removed", "live", "epochs"],
        &churn_rows,
    );
    write_csv(
        "online_churn.csv",
        &["ops", "queries", "ops_per_s", "q_mean_us", "q_p95_us", "removed", "live", "epochs"],
        &churn_rows,
    )
    .expect("csv");
    json.push(
        "churn",
        vec![
            ("ops", Json::from(churn_ops)),
            ("queries", Json::from(q)),
            ("ops_per_s", Json::Num((churn_ops + q) as f64 / secs)),
            ("q_mean_us", Json::Num(qh.mean() * 1e6)),
            ("q_p95_us", Json::Num(qh.percentile(95.0) * 1e6)),
            ("removed", Json::from(removed)),
            ("live", Json::from(index.len())),
            ("epochs", Json::from(index.total_epoch() as usize)),
        ],
    );
    if let Some(path) = json.finish().expect("write --json results") {
        println!("json results → {}", path.display());
    }
}
