//! Experiment configuration.
//!
//! A single [`ExperimentConfig`] drives the AL benchmarks; the two dataset
//! profiles mirror the paper's §5.1 setup (20 Newsgroups and Tiny-1M) with
//! the synthetic-data substitutions documented in DESIGN.md §2.

use crate::cli::Parsed;

/// Which synthetic dataset profile to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetProfile {
    /// 20-Newsgroups-like sparse tf-idf corpus.
    News,
    /// Tiny-1M-like dense GIST corpus.
    Tiny,
    /// Small dense profile for tests/CI.
    Test,
}

impl DatasetProfile {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "news" => Some(DatasetProfile::News),
            "tiny" => Some(DatasetProfile::Tiny),
            "test" => Some(DatasetProfile::Test),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetProfile::News => "news",
            DatasetProfile::Tiny => "tiny",
            DatasetProfile::Test => "test",
        }
    }

    /// Feature dimensionality of the profile (must match the AOT artifacts).
    pub fn dim(&self) -> usize {
        match self {
            DatasetProfile::News => 1024,
            DatasetProfile::Tiny => 384,
            DatasetProfile::Test => 64,
        }
    }

    /// Paper §5.2 hash-code lengths: 16 bits on 20NG, 20 on Tiny-1M
    /// (AH-Hash uses 2× because it is a dual-bit function).
    pub fn code_bits(&self) -> usize {
        match self {
            DatasetProfile::News => 16,
            DatasetProfile::Tiny => 20,
            DatasetProfile::Test => 8,
        }
    }

    /// Paper §5.2 Hamming lookup radii: 3 on 20NG, 4 on Tiny-1M.
    pub fn hamming_radius(&self) -> usize {
        match self {
            DatasetProfile::News => 3,
            DatasetProfile::Tiny => 4,
            DatasetProfile::Test => 2,
        }
    }

    /// Initially labeled samples per class (paper: 5 on 20NG, 50 on Tiny).
    pub fn init_per_class(&self) -> usize {
        match self {
            DatasetProfile::News => 5,
            DatasetProfile::Tiny => 50,
            DatasetProfile::Test => 3,
        }
    }

    /// LBH training sample count m (paper: 500 on 20NG, 5000 on Tiny-1M).
    pub fn lbh_samples(&self) -> usize {
        match self {
            DatasetProfile::News => 500,
            DatasetProfile::Tiny => 5000,
            DatasetProfile::Test => 128,
        }
    }
}

/// Full configuration of one active-learning experiment run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub profile: DatasetProfile,
    /// database size (points in the unlabeled pool + initial labels)
    pub n: usize,
    /// active-learning iterations (paper: 300)
    pub al_iters: usize,
    /// independent runs / random initializations (paper: 5)
    pub runs: usize,
    /// hash code length k (None = profile default)
    pub bits: Option<usize>,
    /// Hamming search radius (None = profile default)
    pub radius: Option<usize>,
    /// LBH training subset size m (None = profile default)
    pub lbh_m: Option<usize>,
    /// SVM regularization C
    pub svm_c: f32,
    /// master seed
    pub seed: u64,
    /// cap on classes evaluated (None = all; benches use fewer)
    pub max_classes: Option<usize>,
    /// evaluate AP every this many AL iterations (1 = every iteration)
    pub eval_every: usize,
    /// data-parallel worker threads for the batch paths (encode, batch
    /// query, eval, LBH training): 0 = all cores, 1 = serial. Results are
    /// bit-identical for every setting (see docs/PARALLEL.md).
    pub workers: usize,
    /// use the i8-quantized projection path for batch encodes. Approximate
    /// (NOT bit-identical to the f32 kernels) but deterministic; excluded
    /// from parity-pinned serving paths. See docs/PERF.md.
    pub quantized: bool,
}

impl ExperimentConfig {
    pub fn for_profile(profile: DatasetProfile) -> Self {
        let n = match profile {
            DatasetProfile::News => 18_846,
            DatasetProfile::Tiny => 100_000,
            DatasetProfile::Test => 2_000,
        };
        ExperimentConfig {
            profile,
            n,
            al_iters: 300,
            runs: 5,
            bits: None,
            radius: None,
            lbh_m: None,
            svm_c: 0.1,
            seed: 2012,
            max_classes: None,
            eval_every: 10,
            workers: 0,
            quantized: false,
        }
    }

    pub fn bits(&self) -> usize {
        self.bits.unwrap_or_else(|| self.profile.code_bits())
    }

    pub fn radius(&self) -> usize {
        self.radius.unwrap_or_else(|| self.profile.hamming_radius())
    }

    pub fn lbh_m(&self) -> usize {
        let m = self.lbh_m.unwrap_or_else(|| self.profile.lbh_samples());
        m.min(self.n / 2)
    }

    /// Shared CLI options for experiment subcommands.
    pub fn cli_opts(args: crate::cli::Args) -> crate::cli::Args {
        args.opt("profile", "test", "dataset profile: news | tiny | test")
            .opt("n", "0", "database size (0 = profile default)")
            .opt("iters", "300", "active-learning iterations")
            .opt("runs", "5", "independent runs")
            .opt("bits", "0", "hash code bits (0 = profile default)")
            .opt("radius", "-1", "Hamming lookup radius (-1 = profile default)")
            .opt("lbh-m", "0", "LBH training samples m (0 = profile default)")
            .opt("svm-c", "0.1", "SVM regularization C")
            .opt("seed", "2012", "master RNG seed")
            .opt("classes", "0", "max classes evaluated (0 = all)")
            .opt("eval-every", "10", "AP evaluation interval")
            .opt("workers", "0", "batch-path worker threads (0 = all cores, 1 = serial)")
            .flag("quantized", "i8-quantized batch encode (approximate; see docs/PERF.md)")
    }

    /// Build from parsed CLI options registered by [`Self::cli_opts`].
    pub fn from_parsed(p: &Parsed) -> anyhow::Result<Self> {
        let profile = DatasetProfile::parse(p.str("profile"))
            .ok_or_else(|| anyhow::anyhow!("bad --profile {}", p.str("profile")))?;
        let mut cfg = ExperimentConfig::for_profile(profile);
        let n = p.usize("n")?;
        if n > 0 {
            cfg.n = n;
        }
        cfg.al_iters = p.usize("iters")?;
        cfg.runs = p.usize("runs")?;
        let bits = p.usize("bits")?;
        if bits > 0 {
            cfg.bits = Some(bits);
        }
        let radius = p.str("radius").parse::<i64>().unwrap_or(-1);
        if radius >= 0 {
            cfg.radius = Some(radius as usize);
        }
        let m = p.usize("lbh-m")?;
        if m > 0 {
            cfg.lbh_m = Some(m);
        }
        cfg.svm_c = p.f64("svm-c")? as f32;
        cfg.seed = p.u64("seed")?;
        let classes = p.usize("classes")?;
        if classes > 0 {
            cfg.max_classes = Some(classes);
        }
        cfg.eval_every = p.usize("eval-every")?.max(1);
        cfg.workers = p.usize("workers")?;
        cfg.quantized = p.flag("quantized");
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;

    #[test]
    fn profile_parse_roundtrip() {
        for p in [DatasetProfile::News, DatasetProfile::Tiny, DatasetProfile::Test] {
            assert_eq!(DatasetProfile::parse(p.name()), Some(p));
        }
        assert_eq!(DatasetProfile::parse("bogus"), None);
    }

    #[test]
    fn paper_parameters() {
        // §5.2: 16 bits radius 3 on 20NG; 20 bits radius 4 on Tiny-1M.
        assert_eq!(DatasetProfile::News.code_bits(), 16);
        assert_eq!(DatasetProfile::News.hamming_radius(), 3);
        assert_eq!(DatasetProfile::Tiny.code_bits(), 20);
        assert_eq!(DatasetProfile::Tiny.hamming_radius(), 4);
        assert_eq!(DatasetProfile::News.init_per_class(), 5);
        assert_eq!(DatasetProfile::Tiny.init_per_class(), 50);
        assert_eq!(DatasetProfile::News.lbh_samples(), 500);
        assert_eq!(DatasetProfile::Tiny.lbh_samples(), 5000);
    }

    #[test]
    fn defaults_and_overrides() {
        let cfg = ExperimentConfig::for_profile(DatasetProfile::News);
        assert_eq!(cfg.bits(), 16);
        assert_eq!(cfg.n, 18_846);
        let mut cfg2 = cfg.clone();
        cfg2.bits = Some(24);
        assert_eq!(cfg2.bits(), 24);
    }

    #[test]
    fn lbh_m_capped_by_n() {
        let mut cfg = ExperimentConfig::for_profile(DatasetProfile::Tiny);
        cfg.n = 1000;
        assert_eq!(cfg.lbh_m(), 500);
    }

    #[test]
    fn from_cli() {
        let args = ExperimentConfig::cli_opts(Args::new("t", "t"));
        let toks: Vec<String> =
            ["--profile", "tiny", "--n", "50k", "--bits", "24", "--radius", "2", "--workers", "3"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let p = args.parse(&toks).unwrap();
        let cfg = ExperimentConfig::from_parsed(&p).unwrap();
        assert_eq!(cfg.profile, DatasetProfile::Tiny);
        assert_eq!(cfg.n, 50_000);
        assert_eq!(cfg.bits(), 24);
        assert_eq!(cfg.radius(), 2);
        assert_eq!(cfg.workers, 3);
        assert!(!cfg.quantized, "quantized is opt-in");
        let toks2: Vec<String> =
            ["--quantized"].iter().map(|s| s.to_string()).collect();
        let args2 = ExperimentConfig::cli_opts(Args::new("t", "t"));
        let p2 = args2.parse(&toks2).unwrap();
        assert!(ExperimentConfig::from_parsed(&p2).unwrap().quantized);
    }

    #[test]
    fn workers_defaults_to_auto() {
        let cfg = ExperimentConfig::for_profile(DatasetProfile::Test);
        assert_eq!(cfg.workers, 0, "0 = all cores");
    }
}
