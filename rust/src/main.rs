//! `chh` — command-line driver for the Compact Hyperplane Hashing stack.
//!
//! Subcommands:
//! * `info`            — artifact registry + environment summary
//! * `fig2`            — collision-probability / ρ curves (paper Fig. 2)
//! * `al-run`          — one active-learning experiment (Figs. 3/4 rows)
//! * `train-hash`      — train LBH projections and report diagnostics
//! * `serve`           — run the hyperplane-query router on synthetic load
//! * `serve-online`    — sharded dynamic index under 50/50 churn + queries
//! * `serve-http`      — HTTP front-end with dynamic micro-batching
//!   (`--wal-dir`: WAL-backed durability; `--replica-of`: read replica
//!   tailing a primary's WAL stream)
//! * `recover`         — rebuild an online index from a WAL directory
//! * `loadgen`         — open/closed-loop load generator for serve-http
//!   (`--replicas`: round-robin read fan-out across a replica fleet)
//! * `encode`          — batch-encode a synthetic dataset (native vs PJRT)

use std::sync::Arc;

use chh::active::{AlConfig, AlEngine, Strategy};
use chh::cli::Args;
use chh::config::{DatasetProfile, ExperimentConfig};
use chh::data::Dataset;
use chh::hash::{AhHash, BhHash, EhHash, HashFamily};
use chh::lbh::{LbhTrainConfig, LbhTrainer};
use chh::rng::Rng;
use chh::table::HyperplaneIndex;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "info" => cmd_info(&rest),
        "fig2" => cmd_fig2(&rest),
        "al-run" => cmd_al_run(&rest),
        "train-hash" => cmd_train_hash(&rest),
        "serve" => cmd_serve(&rest),
        "serve-online" => cmd_serve_online(&rest),
        "serve-http" => cmd_serve_http(&rest),
        "route" => cmd_route(&rest),
        "partition-split" => cmd_partition_split(&rest),
        "recover" => cmd_recover(&rest),
        "loadgen" => cmd_loadgen(&rest),
        "encode" => cmd_encode(&rest),
        "eval" => cmd_eval(&rest),
        "theorem2" => cmd_theorem2(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "chh — Compact Hyperplane Hashing with Bilinear Functions (ICML 2012)\n\
     \n\
     subcommands:\n\
       info          artifact registry + environment summary\n\
       fig2          collision probability p1 and exponent rho curves\n\
       al-run        active-learning experiment (one strategy)\n\
       train-hash    train LBH projections, print diagnostics\n\
       serve         hyperplane-query router under synthetic load\n\
       serve-online  sharded dynamic index under churn + query load\n\
       serve-http    HTTP/1.1 front-end (--wal-dir: durability; --replica-of: read replica)\n\
       route         scatter-gather router over a partitioned fleet (--map)\n\
       partition-split  carve one WAL-backed partition into two, emit the next map\n\
       recover       rebuild an online index from a WAL directory\n\
       loadgen       load generator for serve-http (--replicas / --routers fan-out)\n\
       encode        batch-encode a synthetic dataset (native vs PJRT)\n\
       eval          retrieval quality (recall@T, margin ratio) per family\n\
       theorem2      randomized multi-table LSH vs the compact single table\n\
     \n\
     run `chh <subcommand> --help` for options"
        .to_string()
}

/// Build the configured dataset.
pub fn make_dataset(cfg: &ExperimentConfig, rng: &mut Rng) -> Dataset {
    match cfg.profile {
        DatasetProfile::News => {
            let c = chh::data::NewsConfig { n: cfg.n, vocab: cfg.profile.dim(), ..Default::default() };
            chh::data::newsgroups_like(&c, rng)
        }
        DatasetProfile::Tiny => {
            let c = chh::data::TinyConfig { n: cfg.n, d: cfg.profile.dim(), ..Default::default() };
            chh::data::tiny1m_like(&c, rng)
        }
        DatasetProfile::Test => chh::data::test_blobs(cfg.n, cfg.profile.dim(), 5, rng),
    }
}

/// Construct a strategy by name, training/building whatever it needs.
pub fn make_strategy(
    name: &str,
    cfg: &ExperimentConfig,
    data: &Dataset,
    rng: &mut Rng,
) -> anyhow::Result<Strategy> {
    let bits = cfg.bits();
    let radius = cfg.radius();
    let d = data.dim();
    let pool = chh::par::Pool::new(cfg.workers);
    Ok(match name {
        "random" => Strategy::Random,
        "exhaustive" => Strategy::Exhaustive,
        "ah" => {
            // dual-bit: k pairs → 2k bits total (paper uses 2× bits for AH)
            let fam: Arc<dyn HashFamily> = Arc::new(AhHash::sample(d, bits, rng));
            let index =
                Arc::new(HyperplaneIndex::build_with(fam.as_ref(), data.features(), radius, &pool));
            Strategy::Hash { family: fam, index }
        }
        "eh" => {
            let s = (d.min(256)).max(16);
            let fam: Arc<dyn HashFamily> = Arc::new(EhHash::sampled(d, bits, s, rng));
            let index =
                Arc::new(HyperplaneIndex::build_with(fam.as_ref(), data.features(), radius, &pool));
            Strategy::Hash { family: fam, index }
        }
        "bh" => {
            let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(d, bits, rng));
            let index =
                Arc::new(HyperplaneIndex::build_with(fam.as_ref(), data.features(), radius, &pool));
            Strategy::Hash { family: fam, index }
        }
        "lbh" => {
            let m = cfg.lbh_m();
            let sample = rng.sample_indices(data.len(), m);
            let reference = rng.sample_indices(data.len(), data.len().min(4000));
            let trainer =
                LbhTrainer::new(LbhTrainConfig { bits, workers: cfg.workers, ..Default::default() });
            let (fam, _stats) = trainer.train(data.features(), &sample, &reference, rng);
            let fam: Arc<dyn HashFamily> = Arc::new(fam);
            let index =
                Arc::new(HyperplaneIndex::build_with(fam.as_ref(), data.features(), radius, &pool));
            Strategy::Hash { family: fam, index }
        }
        other => anyhow::bail!("unknown strategy '{other}' (random|exhaustive|ah|eh|bh|lbh)"),
    })
}

fn cmd_info(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::new("chh info", "artifact registry + environment summary");
    let _p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    println!("chh {} — Compact Hyperplane Hashing", env!("CARGO_PKG_VERSION"));
    match chh::runtime::Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts dir: {}", rt.dir().display());
            let names = rt.names();
            if names.is_empty() {
                println!("no artifacts found — run `make artifacts` (native fallbacks active)");
            } else {
                for n in names {
                    let m = rt.meta(&n).unwrap();
                    let ins: Vec<String> =
                        m.inputs.iter().map(|s| format!("{:?}", s.shape)).collect();
                    println!("  {n:<24} inputs {}", ins.join(" "));
                }
            }
        }
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    Ok(())
}

fn cmd_fig2(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::new("chh fig2", "paper Fig.2: p1 and rho vs r")
        .opt("points", "25", "curve sample points")
        .opt("eps", "3.0", "LSH approximation epsilon")
        .opt("mc-trials", "0", "Monte-Carlo trials per point (0 = analytic only)")
        .opt("seed", "2012", "rng seed");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let pts = p.usize("points")?;
    let eps = p.f64("eps")?;
    let trials = p.usize("mc-trials")?;
    let mut rng = Rng::seed_from_u64(p.u64("seed")?);
    chh::report::print_rows(
        "Fig 2(a): collision probability p1(r)",
        &["r", "AH", "EH", "BH", "BH/AH"],
        &fig2a_rows(pts, trials, &mut rng),
    );
    chh::report::print_rows(
        "Fig 2(b): query-time exponent rho(r), eps",
        &["r", "AH", "EH", "BH"],
        &fig2b_rows(pts, eps),
    );
    Ok(())
}

fn fig2a_rows(pts: usize, mc_trials: usize, rng: &mut Rng) -> Vec<Vec<String>> {
    use chh::hash::collision::*;
    (0..=pts)
        .map(|i| {
            let r = R_MAX * i as f64 / pts as f64;
            let mut row = vec![
                format!("{r:.4}"),
                format!("{:.4}", p_ah(r)),
                format!("{:.4}", p_eh(r)),
                format!("{:.4}", p_bh(r)),
                format!("{:.2}", p_bh(r) / p_ah(r).max(1e-12)),
            ];
            if mc_trials > 0 {
                let alpha = r.sqrt();
                row.push(format!("mc_bh={:.4}", mc_bh(alpha, 32, mc_trials, rng)));
            }
            row
        })
        .collect()
}

fn fig2b_rows(pts: usize, eps: f64) -> Vec<Vec<String>> {
    use chh::hash::collision::*;
    (1..pts)
        .filter_map(|i| {
            let r = R_MAX / (1.0 + eps) * i as f64 / pts as f64;
            let fmt = |v: f64| if v.is_nan() { "-".to_string() } else { format!("{v:.4}") };
            Some(vec![
                format!("{r:.4}"),
                fmt(rho(p_ah, r, eps)),
                fmt(rho(p_eh, r, eps)),
                fmt(rho(p_bh, r, eps)),
            ])
        })
        .collect()
}

fn cmd_al_run(rest: &[String]) -> anyhow::Result<()> {
    let args = ExperimentConfig::cli_opts(Args::new("chh al-run", "active-learning experiment"))
        .opt("strategy", "lbh", "random|exhaustive|ah|eh|bh|lbh");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let strat_name = p.str("strategy").to_string();
    let mut rng = Rng::seed_from_u64(cfg.seed);
    eprintln!("generating {} dataset (n={}, d={})...", cfg.profile.name(), cfg.n, cfg.profile.dim());
    let data = make_dataset(&cfg, &mut rng);
    let engine = AlEngine::new(&data, AlConfig::from_experiment(&cfg));
    eprintln!("running {} × {} classes × {} iters...", cfg.runs, data.eval_classes(), cfg.al_iters);
    let cfg2 = cfg.clone();
    let res = engine.run_experiment(cfg.runs, cfg.max_classes, cfg.seed, |rng| {
        make_strategy(&strat_name, &cfg2, &data, rng).expect("strategy")
    });
    print_al_result(&res);
    Ok(())
}

fn print_al_result(res: &chh::active::AlResult) {
    let rows: Vec<Vec<String>> = res
        .map_curve
        .iter()
        .map(|&(it, ap)| vec![it.to_string(), format!("{ap:.4}")])
        .collect();
    chh::report::print_rows(&format!("{} MAP curve", res.strategy), &["iter", "MAP"], &rows);
    let margin_mean: f64 =
        res.margin_curve.iter().sum::<f64>() / res.margin_curve.len().max(1) as f64;
    println!(
        "mean selected margin {:.5}   select {:.2}s   train {:.2}s   scanned {}",
        margin_mean, res.select_secs, res.train_secs, res.scanned_total
    );
    println!(
        "nonempty lookups per class: {:?}",
        res.nonempty_per_class.iter().map(|v| *v as i64).collect::<Vec<_>>()
    );
}

fn cmd_eval(rest: &[String]) -> anyhow::Result<()> {
    let args = ExperimentConfig::cli_opts(Args::new(
        "chh eval",
        "retrieval quality of each hash family (recall@T vs exhaustive)",
    ))
    .opt("queries", "30", "number of SVM hyperplane queries")
    .opt("topk", "20", "T for recall@T");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let queries = p.usize("queries")?;
    let topk = p.usize("topk")?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = make_dataset(&cfg, &mut rng);
    // realistic hyperplanes: one-vs-all SVMs on random labeled subsets
    let ws: Vec<Vec<f32>> = (0..queries)
        .map(|q| {
            let c = (q % data.eval_classes()) as u16;
            let idx = rng.sample_indices(data.len(), 400.min(data.len() / 2));
            let y: Vec<f32> =
                idx.iter().map(|&i| if data.labels()[i] == c { 1.0 } else { -1.0 }).collect();
            let mut svm = chh::svm::LinearSvm::new(data.dim());
            svm.train(data.features(), &idx, &y, &chh::svm::SvmConfig::default());
            svm.w
        })
        .collect();
    let mut rows = Vec::new();
    for name in ["ah", "eh", "bh", "lbh"] {
        let strat = make_strategy(name, &cfg, &data, &mut rng)?;
        let (family, index) = match &strat {
            chh::active::Strategy::Hash { family, index } => (family.clone(), index.clone()),
            _ => unreachable!(),
        };
        let s = chh::eval::evaluate_with(
            family.as_ref(),
            &index,
            data.features(),
            &ws,
            topk,
            &chh::par::Pool::new(cfg.workers),
        );
        rows.push(vec![
            family.name().to_string(),
            format!("{:.3}", s.mean_recall),
            format!("{:.2}", s.median_margin_ratio),
            format!("{:.0}", s.mean_scanned),
            format!("{:.2}", s.nonempty_frac),
        ]);
    }
    chh::report::print_rows(
        &format!("retrieval quality (recall@{topk}, n={}, k={}, r={})", cfg.n, cfg.bits(), cfg.radius()),
        &["family", "recall", "margin ratio", "scanned", "nonempty"],
        &rows,
    );
    Ok(())
}

fn cmd_theorem2(rest: &[String]) -> anyhow::Result<()> {
    let args = ExperimentConfig::cli_opts(Args::new(
        "chh theorem2",
        "randomized multi-table LSH (Theorem 2) vs compact single table",
    ))
    .opt("r", "0.05", "target distance r = alpha^2")
    .opt("eps", "3.0", "approximation factor epsilon")
    .opt("queries", "20", "number of hyperplane queries");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let r = p.f64("r")?;
    let eps = p.f64("eps")?;
    let queries = p.usize("queries")?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = make_dataset(&cfg, &mut rng);
    use chh::hash::collision::{p_bh, theorem2_params};
    let Some((tables, bits)) = theorem2_params(p_bh, r, eps, data.len()) else {
        anyhow::bail!("r(1+eps) out of domain for BH at r={r}, eps={eps}");
    };
    // cap to something runnable; the point is the comparison shape
    let tables = tables.min(200);
    let bits = bits.min(24);
    println!(
        "Theorem 2 parameters for n={}, r={r}, eps={eps}:  L={tables} tables x k={bits} bits",
        data.len()
    );
    let pool = chh::par::Pool::new(cfg.workers);
    let t0 = std::time::Instant::now();
    let mut seeds: Vec<u64> = (0..tables).map(|_| rng.next_u64()).collect();
    let lsh = chh::table::LshIndex::build_with(
        data.features(),
        tables,
        |t| BhHash::sample(data.dim(), bits, &mut Rng::seed_from_u64(seeds[t])),
        &pool,
    );
    seeds.clear();
    let lsh_build = t0.elapsed();
    let t0 = std::time::Instant::now();
    let compact = BhHash::sample(data.dim(), cfg.bits(), &mut rng);
    let cindex = HyperplaneIndex::build(&compact, data.features(), cfg.radius());
    let compact_build = t0.elapsed();
    let mut rows = Vec::new();
    let (mut lm, mut cm, mut lt, mut ct) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for _ in 0..queries {
        let w = chh::testing::unit_vec(&mut rng, data.dim());
        let t0 = std::time::Instant::now();
        let hl = lsh.query_filtered(&w, data.features(), |_| true);
        lt += t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let hc = cindex.query(&compact, &w, data.features());
        ct += t0.elapsed().as_secs_f64();
        lm += hl.best.map(|(_, m)| m as f64).unwrap_or(0.5);
        cm += hc.best.map(|(_, m)| m as f64).unwrap_or(0.5);
    }
    let q = queries as f64;
    rows.push(vec![
        format!("LSH {tables}x{bits}b"),
        format!("{:.2}s", lsh_build.as_secs_f64()),
        format!("{:.3}ms", lt / q * 1e3),
        format!("{:.5}", lm / q),
    ]);
    rows.push(vec![
        format!("compact 1x{}b r{}", cfg.bits(), cfg.radius()),
        format!("{:.2}s", compact_build.as_secs_f64()),
        format!("{:.3}ms", ct / q * 1e3),
        format!("{:.5}", cm / q),
    ]);
    chh::report::print_rows(
        "randomized multi-table vs compact single-table (BH functions)",
        &["index", "build", "query", "mean margin"],
        &rows,
    );
    let (lsh_mb, compact_mb) =
        (lsh.memory_bytes() as f64 / 1e6, cindex.memory_bytes() as f64 / 1e6);
    println!(
        "\nmemory: LSH tables {lsh_mb:.2} MB vs compact {compact_mb:.2} MB \
         ({:.1}x) — the storage/computation argument of §4 against \
         Theorem 2's n^rho tables.",
        lsh_mb / compact_mb.max(1e-9)
    );
    Ok(())
}

fn cmd_train_hash(rest: &[String]) -> anyhow::Result<()> {
    let args = ExperimentConfig::cli_opts(Args::new("chh train-hash", "train LBH projections"))
        .opt("iters-per-bit", "300", "Nesterov iterations per bit")
        .opt("save", "", "write the trained model to this path");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = make_dataset(&cfg, &mut rng);
    let m = cfg.lbh_m();
    let sample = rng.sample_indices(data.len(), m);
    let reference = rng.sample_indices(data.len(), data.len().min(4000));
    let trainer = LbhTrainer::new(LbhTrainConfig {
        bits: cfg.bits(),
        iters_per_bit: p.usize("iters-per-bit")?,
        workers: cfg.workers,
        ..Default::default()
    });
    let (fam, stats) = trainer.train(data.features(), &sample, &reference, &mut rng);
    let save = p.str("save");
    if !save.is_empty() {
        chh::persist::save_model(
            std::path::Path::new(save),
            chh::persist::FamilyKind::Lbh,
            &fam.pairs,
        )?;
        println!("saved trained model to {save}");
    }
    println!(
        "trained k={} on m={} samples in {:.2}s  (t1={:.3}, t2={:.3})",
        cfg.bits(),
        m,
        stats.train_secs,
        stats.t1,
        stats.t2
    );
    println!(
        "residue ‖R‖²: {:.1} → {:.1} ({:.1}% captured)",
        stats.residue_before,
        stats.residue_after,
        100.0 * (1.0 - stats.residue_after / stats.residue_before)
    );
    for (j, (s, d)) in stats.bit_costs.iter().zip(stats.discrete_costs.iter()).enumerate() {
        println!("  bit {j:>2}: surrogate cost {s:>12.1}   discrete {d:>12.1}");
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let args = ExperimentConfig::cli_opts(Args::new("chh serve", "router under synthetic load"))
        .opt("queries", "1000", "number of hyperplane queries")
        .opt("batch", "16", "queries per submitted batch")
        .flag("pooled", "answer batches on the data-parallel pool instead of the worker queue");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let queries = p.usize("queries")?;
    let pooled_mode = p.flag("pooled");
    // --workers (from the shared experiment options) doubles as the
    // router thread count here
    let workers = chh::par::effective(cfg.workers);
    let batch = p.usize("batch")?.max(1);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = make_dataset(&cfg, &mut rng);
    let pool = chh::par::Pool::new(cfg.workers);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), cfg.bits(), &mut rng));
    let index =
        Arc::new(HyperplaneIndex::build_with(fam.as_ref(), data.features(), cfg.radius(), &pool));
    let feats = Arc::new(data.features().clone());
    let router = chh::coordinator::Router::new(fam, index, feats, workers, 64);
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < queries {
        let take = batch.min(queries - done);
        let reqs: Vec<_> = (0..take)
            .map(|_| chh::coordinator::QueryRequest {
                w: chh::testing::unit_vec(&mut rng, data.dim()),
                exclude: None,
            })
            .collect();
        if pooled_mode {
            let _ = router.query_batch_pooled(&reqs, &pool);
        } else {
            let _ = router.submit_batch(reqs);
        }
        done += take;
    }
    let secs = t0.elapsed().as_secs_f64();
    let st = router.stats();
    if pooled_mode {
        // the pooled path bypasses the queue, so there are no latencies
        println!(
            "{queries} queries in {secs:.3}s  ({:.0} qps, pooled batch path)  empty {}",
            queries as f64 / secs,
            st.empty_lookups.load(std::sync::atomic::Ordering::Relaxed)
        );
    } else {
        let pct = st.latency_percentiles(&[50.0, 95.0]);
        println!(
            "{queries} queries in {secs:.3}s  ({:.0} qps)  p50 {:.1}µs  p95 {:.1}µs  empty {}",
            queries as f64 / secs,
            pct[0] * 1e6,
            pct[1] * 1e6,
            st.empty_lookups.load(std::sync::atomic::Ordering::Relaxed)
        );
    }
    router.shutdown();
    Ok(())
}

fn cmd_serve_online(rest: &[String]) -> anyhow::Result<()> {
    use chh::online::{QueryBudget, ShardedIndex};
    let args = ExperimentConfig::cli_opts(Args::new(
        "chh serve-online",
        "sharded dynamic index under concurrent churn + query load",
    ))
    .opt("queries", "2000", "number of hyperplane queries")
    .opt("shards", "8", "index shards")
    .opt("probes", "0", "per-query probe budget (0 = full Hamming ball)")
    .opt("top", "64", "stop probing once this many candidates are ranked")
    .opt("churn-ops", "0", "insert/remove ops run concurrently (0 = n/2)")
    .opt("snapshot", "", "save the post-churn shard snapshot to this path")
    .flag("pooled", "answer batches on the data-parallel pool instead of the worker queue");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let queries = p.usize("queries")?;
    let pooled_mode = p.flag("pooled");
    // --workers (shared experiment option) sets the router thread count
    let workers = chh::par::effective(cfg.workers);
    let shards = p.usize("shards")?.max(1);
    let top = p.usize("top")?.max(1);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = make_dataset(&cfg, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), cfg.bits(), &mut rng));
    let index = Arc::new(ShardedIndex::new(cfg.bits(), cfg.radius(), shards));
    let warm = data.len() * 3 / 4;
    let t0 = std::time::Instant::now();
    for i in 0..warm {
        index.insert_point(fam.as_ref(), i as u32, data.features().row(i));
    }
    index.compact();
    let probes = match p.usize("probes")? {
        0 => index.planner().full_volume() as usize,
        v => v,
    };
    let budget = QueryBudget::new(probes, top);
    println!(
        "serve-online: n={} warm={warm} k={} r={} shards={shards} probes={probes} top={top}  (built in {:.2}s)",
        data.len(),
        cfg.bits(),
        cfg.radius(),
        t0.elapsed().as_secs_f64()
    );
    let feats = Arc::new(data.features().clone());
    let router = chh::coordinator::OnlineRouter::new(
        fam.clone(),
        index.clone(),
        feats.clone(),
        workers,
        256,
        budget,
    );
    // concurrent churn: 50/50 inserts (new points) and removes (random live)
    let churn_ops = match p.usize("churn-ops")? {
        0 => data.len() / 2,
        v => v,
    };
    let churn_idx = index.clone();
    let churn_fam = fam.clone();
    let churn_feats = feats.clone();
    let churn_seed = cfg.seed ^ 0xC0FFEE;
    let churn = std::thread::spawn(move || {
        let mut rng = Rng::seed_from_u64(churn_seed);
        let n = churn_feats.len();
        let mut inserted = warm;
        for op in 0..churn_ops {
            if op % 2 == 0 && inserted < n {
                churn_idx.insert_point(
                    churn_fam.as_ref(),
                    inserted as u32,
                    churn_feats.row(inserted),
                );
                inserted += 1;
            } else {
                let victim = rng.below(inserted.max(1)) as u32;
                churn_idx.remove(victim);
            }
        }
        churn_ops
    });
    let pool = chh::par::Pool::new(cfg.workers);
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < queries {
        let take = 16.min(queries - done);
        let reqs: Vec<_> = (0..take)
            .map(|_| chh::coordinator::QueryRequest {
                w: chh::testing::unit_vec(&mut rng, data.dim()),
                exclude: None,
            })
            .collect();
        if pooled_mode {
            let _ = router.query_batch_pooled(&reqs, &pool);
        } else {
            let _ = router.submit_batch(reqs);
        }
        done += take;
    }
    let secs = t0.elapsed().as_secs_f64();
    let ops = churn.join().expect("churn thread");
    let st = router.stats();
    use std::sync::atomic::Ordering::Relaxed;
    println!(
        "{queries} queries + {ops} churn ops in {secs:.3}s  ({:.0} qps{})",
        queries as f64 / secs,
        if pooled_mode { ", pooled batch path" } else { "" }
    );
    if !pooled_mode {
        // the pooled path bypasses the queue, so there are no latencies
        let pct = st.latency_percentiles(&[50.0, 95.0]);
        println!(
            "  latency   : p50 {:.1}µs  p95 {:.1}µs  mean {:.1}µs",
            pct[0] * 1e6,
            pct[1] * 1e6,
            st.latency_mean() * 1e6
        );
    }
    println!(
        "  scanned/q : {:.1}   empty {}   live points {}",
        st.candidates_scanned.load(Relaxed) as f64 / queries.max(1) as f64,
        st.empty_lookups.load(Relaxed),
        index.len()
    );
    println!(
        "  epochs    : {:?}  (memory ~ {:.1} MB)",
        index.epochs(),
        index.memory_bytes() as f64 / 1e6
    );
    let snap = p.str("snapshot");
    if !snap.is_empty() {
        chh::persist::save_sharded(std::path::Path::new(snap), &index)?;
        println!("  snapshot  : saved to {snap}");
    }
    router.shutdown();
    Ok(())
}

/// Resolve the online serving budget: an explicit `--probes` wins;
/// `--probes 0` defers to the budget stored with the index (restored
/// from a snapshot / WAL recovery), falling back to the full Hamming
/// ball when none was stored.
fn resolve_budget(
    p: &chh::cli::Parsed,
    index: &chh::online::ShardedIndex,
) -> anyhow::Result<chh::online::QueryBudget> {
    use chh::online::QueryBudget;
    let stored = index.default_budget();
    let cli_top = p.usize("top")?.max(1);
    Ok(match p.usize("probes")? {
        0 if stored.probes != usize::MAX => QueryBudget::new(
            stored.probes,
            if stored.top != usize::MAX { stored.top } else { cli_top },
        ),
        0 => QueryBudget::new(index.planner().full_volume() as usize, cli_top),
        v => QueryBudget::new(v, cli_top),
    })
}

fn cmd_serve_http(rest: &[String]) -> anyhow::Result<()> {
    use chh::online::ShardedIndex;
    use chh::server::{BatcherConfig, Server, ServerConfig, Stack};
    let args = ExperimentConfig::cli_opts(Args::new(
        "chh serve-http",
        "HTTP/1.1 front-end over the routers with dynamic micro-batching",
    ))
    .opt("addr", "127.0.0.1:8080", "listen address (port 0 = ephemeral)")
    .opt("mode", "static", "index mode: static | online")
    .opt("shards", "8", "online: index shards")
    .opt("probes", "0", "online: per-shard probe budget (0 = full Hamming ball)")
    .opt("top", "64", "online: stop probing a shard once this many candidates are ranked")
    .opt("snapshot", "", "online: load a shard snapshot saved by serve-online (same profile/seed!)")
    .opt("max-batch", "32", "micro-batcher: flush at this many queued queries")
    .opt("max-wait-us", "200", "micro-batcher: flush once the oldest query waited this long")
    .opt("queue-cap", "1024", "micro-batcher admission queue bound (overflow -> 503)")
    .opt("max-conns", "4096", "concurrent connection cap (overflow -> 503)")
    .opt("conn-workers", "16", "event-loop request workers (the transport's thread budget)")
    .opt("wal-dir", "", "online: durable directory — journal mutations, recover on restart")
    .opt("fsync", "always", "wal durability of acked mutations: always | every:<n> | interval:<ms>")
    .opt(
        "snapshot-every",
        "0",
        "wal: background checkpoint after this many mutations (0 = shutdown only)",
    )
    .opt(
        "replica-of",
        "",
        "online: run as a read replica of this primary (tail its WAL stream; \
         start with the SAME profile/n/bits/seed)",
    )
    .opt("poll-ms", "20", "replica: stream poll interval once caught up (ms)")
    .opt(
        "slow-ms",
        "0",
        "slow-query threshold: requests slower than this are logged with their \
         per-stage breakdown (0 = off, or every request when --slow-log is set)",
    )
    .opt("slow-log", "", "slow-query JSON-lines path (size-rotated); stderr when unset")
    .opt(
        "audit-frac",
        "0",
        "re-answer this fraction of served /query requests in a background auditor and \
         publish recall/margin/calibration gauges on /metrics (0 = off, 1 = every query)",
    )
    .opt(
        "id-start",
        "0",
        "cluster partition: fresh build inserts ids [id-start, id-end) only",
    )
    .opt("id-end", "0", "cluster partition: one past the last owned id (0 = all n points)")
    .opt("for-secs", "0", "serve this long then exit (0 = until POST /shutdown)");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    eprintln!("building {} dataset (n={}, d={})...", cfg.profile.name(), cfg.n, cfg.profile.dim());
    let data = make_dataset(&cfg, &mut rng);
    let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(data.dim(), cfg.bits(), &mut rng));
    let feats = Arc::new(data.features().clone());
    let pool = chh::par::Pool::new(cfg.workers);
    let mode = p.str("mode").to_string();
    let wal_dir = p.str("wal-dir").to_string();
    let replica_of = p.str("replica-of").to_string();
    anyhow::ensure!(
        wal_dir.is_empty() || mode == "online",
        "--wal-dir requires --mode online (the static index is immutable)"
    );
    anyhow::ensure!(
        replica_of.is_empty() || mode == "online",
        "--replica-of requires --mode online"
    );
    anyhow::ensure!(
        replica_of.is_empty() || wal_dir.is_empty(),
        "--replica-of and --wal-dir are mutually exclusive (replicas keep no local WAL; \
         the primary's directory is the source of truth)"
    );
    let id_start = p.usize("id-start")?;
    let id_end_opt = p.usize("id-end")?;
    let id_range_set = id_start > 0 || id_end_opt > 0;
    anyhow::ensure!(
        !id_range_set || mode == "online",
        "--id-start/--id-end partition a fresh online build (--mode online)"
    );
    anyhow::ensure!(
        !id_range_set || replica_of.is_empty(),
        "--id-start/--id-end apply to a fresh build; a replica mirrors its primary's range"
    );
    anyhow::ensure!(
        !id_range_set || p.str("snapshot").is_empty(),
        "--id-start/--id-end apply to a fresh build, not a loaded snapshot"
    );
    let mut durability: Option<chh::server::Durability> = None;
    let mut replica_role: Option<chh::server::ReplicaRole> = None;
    let stack = match mode.as_str() {
        "static" => {
            let index = Arc::new(HyperplaneIndex::build_with(
                fam.as_ref(),
                data.features(),
                cfg.radius(),
                &pool,
            ));
            // the queue workers are idle here — the HTTP path answers
            // through the batcher's pooled flush — so 1 thread suffices
            let router =
                chh::coordinator::Router::new(fam.clone(), index, feats.clone(), 1, 64);
            Stack::Static(Arc::new(router))
        }
        "online" => {
            let snapshot_every = p.u64("snapshot-every")?;
            let wal_cfg = if wal_dir.is_empty() {
                None
            } else {
                let mut c = chh::wal::WalConfig::new(&wal_dir);
                c.fsync = p.str("fsync").parse()?;
                Some(c)
            };
            let validate = |index: &ShardedIndex, what: &str| -> anyhow::Result<()> {
                anyhow::ensure!(
                    index.bits() == fam.bits(),
                    "{what} holds {}-bit codes but the sampled family emits {} \
                     (use the profile/bits/seed it was built with)",
                    index.bits(),
                    fam.bits()
                );
                let n = feats.len();
                for s in index.shards() {
                    for (id, _) in s.live_entries() {
                        anyhow::ensure!(
                            (id as usize) < n,
                            "{what} id {id} outside the serving feature store (n={n})"
                        );
                    }
                }
                Ok(())
            };
            if !replica_of.is_empty() {
                anyhow::ensure!(
                    p.str("snapshot").is_empty(),
                    "--replica-of bootstraps from the primary; --snapshot is not used"
                );
                // parity requires the replica to encode queries and rank
                // margins exactly like the primary: same feature store
                // (profile/n/seed) and same sampled family (bits/seed).
                // Check what the primary advertises before bootstrapping.
                let mut probe = chh::server::HttpClient::connect_retry(
                    &replica_of,
                    std::time::Duration::from_secs(10),
                )
                .map_err(|e| anyhow::anyhow!("connecting to primary {replica_of}: {e}"))?;
                probe.set_timeout(std::time::Duration::from_secs(10))?;
                let resp = probe
                    .get("/stats")
                    .map_err(|e| anyhow::anyhow!("GET /stats on primary: {e}"))?;
                anyhow::ensure!(resp.status == 200, "primary /stats returned {}", resp.status);
                let s = chh::jsonio::Json::parse_bytes(&resp.body)
                    .map_err(|e| anyhow::anyhow!("parsing primary /stats: {e}"))?;
                let sfield = |k: &str| s.get(k).and_then(|x| x.as_usize());
                anyhow::ensure!(
                    s.get("mode").and_then(|m| m.as_str()) == Some("online"),
                    "primary must serve --mode online"
                );
                anyhow::ensure!(
                    s.get("durability").is_some(),
                    "primary has no WAL (start it with --wal-dir) — nothing to replicate"
                );
                anyhow::ensure!(
                    sfield("dim") == Some(data.dim()) && sfield("points") == Some(data.len()),
                    "primary serves dim={:?} points={:?} but this replica built dim={} \
                     points={} — start the replica with the primary's profile/n/seed",
                    sfield("dim"),
                    sfield("points"),
                    data.dim(),
                    data.len()
                );
                anyhow::ensure!(
                    sfield("bits") == Some(fam.bits())
                        && s.get("family").and_then(|f| f.as_str()) == Some(fam.name()),
                    "primary hashes with {:?}/{:?} bits but this replica sampled {}/{} — \
                     match --bits and --seed",
                    s.get("family").and_then(|f| f.as_str()),
                    sfield("bits"),
                    fam.name(),
                    fam.bits()
                );
                // name+bits match can still hide a --seed mismatch (same
                // shape, different hyperplanes) — compare the content
                // fingerprint of the actual sampled family
                let local_check =
                    chh::replicate::family_fingerprint(fam.as_ref(), data.dim()) as usize;
                anyhow::ensure!(
                    sfield("family_check") == Some(local_check),
                    "primary's hash family fingerprint {:?} != this replica's {local_check} \
                     — the sampled hyperplanes differ; start the replica with the \
                     primary's --seed (and --bits/--profile)",
                    sfield("family_check")
                );
                drop(probe);
            }
            // an existing durable directory wins over --snapshot and the
            // fresh build: the server resumes exactly where it crashed
            let (index, budget) = if !replica_of.is_empty() {
                let mut rcfg = chh::replicate::ReplicaConfig::new(&replica_of);
                rcfg.poll = std::time::Duration::from_millis(p.u64("poll-ms")?.max(1));
                let replica = chh::replicate::ReplicaIndex::bootstrap(&rcfg)
                    .map_err(|e| anyhow::anyhow!("bootstrapping from {replica_of}: {e:#}"))?;
                let index = replica.index().clone();
                validate(&index, "bootstrap snapshot")?;
                let budget = resolve_budget(&p, &index)?;
                index.set_default_budget(budget);
                eprintln!(
                    "serve-http: bootstrapped replica of {replica_of} ({} live points)",
                    index.len()
                );
                let tailer = chh::replicate::spawn_tailer(replica.clone(), rcfg);
                replica_role = Some(chh::server::ReplicaRole {
                    replica,
                    primary_addr: replica_of.clone(),
                    tailer: Some(tailer),
                });
                (index, budget)
            } else {
                match &wal_cfg {
                    Some(c) if chh::wal::is_wal_dir(&c.dir) => {
                        anyhow::ensure!(
                            !id_range_set,
                            "--id-start/--id-end apply to a fresh build; {} already holds \
                             recovered state (its range was fixed at creation)",
                            c.dir.display()
                        );
                        let (durable, report) = chh::wal::DurableIndex::open(c)?;
                        eprintln!(
                            "serve-http: recovered {}: {}",
                            c.dir.display(),
                            report.summary()
                        );
                        let index = durable.index().clone();
                        validate(&index, "recovered state")?;
                        let budget = resolve_budget(&p, &index)?;
                        // write the resolved budget back so an explicit
                        // --probes override survives the next checkpoint
                        index.set_default_budget(budget);
                        durability = Some(chh::server::Durability {
                            durable: Arc::new(durable),
                            snapshot_every_ops: snapshot_every,
                        });
                        (index, budget)
                    }
                    _ => {
                        let snap = p.str("snapshot");
                        let index = if snap.is_empty() {
                            let index = ShardedIndex::new(
                                cfg.bits(),
                                cfg.radius(),
                                p.usize("shards")?.max(1),
                            );
                            let id_end = if id_end_opt == 0 { data.len() } else { id_end_opt };
                            anyhow::ensure!(
                                id_start < id_end && id_end <= data.len(),
                                "--id-start {id_start} / --id-end {id_end} must satisfy \
                                 start < end <= n ({})",
                                data.len()
                            );
                            for i in id_start..id_end {
                                index.insert_point(fam.as_ref(), i as u32, data.features().row(i));
                            }
                            index.compact();
                            index
                        } else {
                            let index = chh::persist::load_sharded(std::path::Path::new(snap))?;
                            validate(&index, "snapshot")?;
                            index
                        };
                        let budget = resolve_budget(&p, &index)?;
                        // carry the operational budget in the index so
                        // snapshots (and the WAL base snapshot) restore it
                        index.set_default_budget(budget);
                        let index = Arc::new(index);
                        if let Some(c) = &wal_cfg {
                            let durable =
                                Arc::new(chh::wal::DurableIndex::create(index.clone(), c)?);
                            eprintln!(
                                "serve-http: durable dir {} initialized (base snapshot gen 0)",
                                c.dir.display()
                            );
                            durability = Some(chh::server::Durability {
                                durable,
                                snapshot_every_ops: snapshot_every,
                            });
                        }
                        (index, budget)
                    }
                }
            };
            let router = chh::coordinator::OnlineRouter::new(
                fam.clone(),
                index,
                feats.clone(),
                1,
                64,
                budget,
            );
            Stack::Online(Arc::new(router))
        }
        other => anyhow::bail!("unknown --mode '{other}' (static|online)"),
    };
    let max_batch = p.usize("max-batch")?.max(1);
    let max_wait_us = p.u64("max-wait-us")?;
    let server_cfg = ServerConfig {
        addr: p.str("addr").to_string(),
        max_conns: p.usize("max-conns")?.max(1),
        conn_workers: p.usize("conn-workers")?.max(1),
        batch: BatcherConfig {
            max_batch,
            max_wait: std::time::Duration::from_micros(max_wait_us),
            queue_cap: p.usize("queue-cap")?.max(1),
        },
        pool_workers: cfg.workers,
        idle_timeout: std::time::Duration::from_secs(5),
        slow_ms: p.u64("slow-ms")?,
        slow_log: {
            let sl = p.str("slow-log");
            if sl.is_empty() { None } else { Some(std::path::PathBuf::from(sl)) }
        },
        audit_frac: p.f64("audit-frac")?,
    };
    let handle = match replica_role {
        Some(role) => Server::spawn_replica(stack, server_cfg, role)?,
        None => Server::spawn_with_durability(stack, server_cfg, durability)?,
    };
    println!(
        "serve-http: listening on {} (mode={mode}, n={}, dim={}, k={}, r={}, \
         batch<={max_batch}, wait<={max_wait_us}us{})",
        handle.addr(),
        data.len(),
        data.dim(),
        cfg.bits(),
        cfg.radius(),
        if !replica_of.is_empty() {
            format!(", replica-of={replica_of}")
        } else if wal_dir.is_empty() {
            String::new()
        } else {
            format!(", wal={wal_dir} fsync={}", p.str("fsync"))
        }
    );
    let for_secs = p.u64("for-secs")?;
    if for_secs > 0 {
        let stopper = handle.stopper();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_secs(for_secs));
            stopper.trigger();
        });
    }
    handle.wait();
    println!("serve-http: stopped");
    Ok(())
}

fn cmd_route(rest: &[String]) -> anyhow::Result<()> {
    use chh::cluster::{ClusterConfig, ClusterRouter, PartitionMap};
    use chh::server::{Server, ServerConfig};
    use std::time::Duration;
    let args = Args::new(
        "chh route",
        "stateless scatter-gather router over a partitioned primary fleet (JSON upstream)",
    )
    .opt("map", "", "partition-map JSON path (required; see docs/CLUSTER.md)")
    .opt("addr", "127.0.0.1:8090", "listen address (port 0 = ephemeral)")
    .opt("max-conns", "4096", "concurrent connection cap (overflow -> 503)")
    .opt("conn-workers", "16", "event-loop request workers (the transport's thread budget)")
    .opt("connect-timeout-ms", "1000", "downstream partition TCP connect timeout")
    .opt("io-timeout-ms", "5000", "downstream partition request timeout")
    .opt("probe-secs", "10", "startup: wait this long for each partition to answer /stats")
    .opt(
        "slow-ms",
        "0",
        "slow-query threshold: requests slower than this are logged with the full \
         cross-tier breakdown (0 = off, or every request when --slow-log is set)",
    )
    .opt("slow-log", "", "slow-query JSON-lines path (size-rotated); stderr when unset")
    .opt("for-secs", "0", "serve this long then exit (0 = until POST /shutdown)");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let map_path = p.str("map").to_string();
    anyhow::ensure!(!map_path.is_empty(), "--map is required (write one with partition-split)");
    let map = PartitionMap::load(std::path::Path::new(&map_path))
        .map_err(|e| anyhow::anyhow!("loading {map_path}: {e:#}"))?;
    let ccfg = ClusterConfig {
        connect_timeout: Duration::from_millis(p.u64("connect-timeout-ms")?.max(1)),
        io_timeout: Duration::from_millis(p.u64("io-timeout-ms")?.max(1)),
        probe_wait: Duration::from_secs(p.u64("probe-secs")?),
    };
    eprintln!(
        "route: probing {} partitions from {map_path} (map v{})...",
        map.partitions.len(),
        map.version
    );
    let router = ClusterRouter::connect(map, Some(std::path::PathBuf::from(&map_path)), ccfg)?;
    let meta = router.meta().clone();
    let (nparts, id_space) = (router.partition_count(), router.id_space());
    let server_cfg = ServerConfig {
        addr: p.str("addr").to_string(),
        max_conns: p.usize("max-conns")?.max(1),
        conn_workers: p.usize("conn-workers")?.max(1),
        slow_ms: p.u64("slow-ms")?,
        slow_log: {
            let sl = p.str("slow-log");
            if sl.is_empty() { None } else { Some(std::path::PathBuf::from(sl)) }
        },
        ..ServerConfig::default()
    };
    let handle = Server::spawn_cluster(std::sync::Arc::new(router), server_cfg)?;
    println!(
        "route: listening on {} ({nparts} partitions over ids 0..{id_space}, dim={}, k={}, \
         family={})",
        handle.addr(),
        meta.dim,
        meta.bits,
        meta.family,
    );
    let for_secs = p.u64("for-secs")?;
    if for_secs > 0 {
        let stopper = handle.stopper();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs(for_secs));
            stopper.trigger();
        });
    }
    handle.wait();
    println!("route: stopped");
    Ok(())
}

fn cmd_partition_split(rest: &[String]) -> anyhow::Result<()> {
    use chh::cluster::{split_partition, PartitionMap, SplitTarget};
    let args = Args::new(
        "chh partition-split",
        "carve one stopped WAL-backed partition into two and emit the next-version map",
    )
    .opt("map", "", "current partition-map JSON path (required)")
    .opt("partition", "0", "index of the partition to split (position in the map)")
    .opt("mid", "0", "split id: left keeps [start, mid), right takes [mid, end)")
    .opt("src-wal", "", "the partition's durable directory (stop its server first)")
    .opt("left-wal", "", "fresh durable directory for the left half (must not exist as a WAL)")
    .opt("right-wal", "", "fresh durable directory for the right half")
    .opt("left-addr", "", "primary address the left half will serve on")
    .opt("right-addr", "", "primary address the right half will serve on")
    .opt("left-replicas", "", "comma-separated replica addrs for the left half")
    .opt("right-replicas", "", "comma-separated replica addrs for the right half")
    .opt("out-map", "", "write the next-version map here (default: overwrite --map)");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    for req in ["map", "src-wal", "left-wal", "right-wal", "left-addr", "right-addr"] {
        anyhow::ensure!(!p.str(req).is_empty(), "--{req} is required");
    }
    let map_path = p.str("map").to_string();
    let map = PartitionMap::load(std::path::Path::new(&map_path))
        .map_err(|e| anyhow::anyhow!("loading {map_path}: {e:#}"))?;
    let pi = p.usize("partition")?;
    let mid = u32::try_from(p.usize("mid")?)?;
    let replicas = |key: &str| -> Vec<String> {
        p.str(key).split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
    };
    let left = SplitTarget {
        addr: p.str("left-addr").to_string(),
        replicas: replicas("left-replicas"),
    };
    let right = SplitTarget {
        addr: p.str("right-addr").to_string(),
        replicas: replicas("right-replicas"),
    };
    let (next, report) = split_partition(
        &map,
        pi,
        mid,
        std::path::Path::new(p.str("src-wal")),
        std::path::Path::new(p.str("left-wal")),
        std::path::Path::new(p.str("right-wal")),
        &left,
        &right,
    )?;
    let out = {
        let o = p.str("out-map");
        if o.is_empty() { map_path.clone() } else { o.to_string() }
    };
    next.save(std::path::Path::new(&out))?;
    println!(
        "partition-split: partition {pi} split at id {mid} -> left {} points ({}), \
         right {} points ({})",
        report.left_points,
        left.addr,
        report.right_points,
        right.addr
    );
    println!(
        "partition-split: map v{} -> v{} written to {out} — start the two new primaries \
         on their WAL dirs, then POST the map to each router's /map to flip traffic",
        map.version, report.new_version
    );
    Ok(())
}

fn cmd_recover(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::new(
        "chh recover",
        "rebuild an online index from a durable WAL directory (snapshot + replay)",
    )
    .opt("wal-dir", "", "durable directory written by serve-http --wal-dir (required)")
    .opt(
        "fsync",
        "always",
        "fsync policy used while writing the post-recovery checkpoint",
    )
    .opt("save", "", "also save the recovered index to this standalone snapshot path")
    .opt("json", "", "write a machine-readable recovery report to this path")
    .flag("inspect", "read-only: report what recovery finds, write nothing back")
    .flag("force", "checkpoint even a lossy recovery, discarding what could not be applied");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let dir = p.str("wal-dir").to_string();
    anyhow::ensure!(!dir.is_empty(), "--wal-dir is required");
    let dirp = std::path::Path::new(&dir);
    let (index, report, wal_hists) = if p.flag("inspect") {
        let (index, report) = chh::wal::recover(dirp)?;
        (Arc::new(index), report, None)
    } else {
        // open() recovers, then folds the replayed suffix into a fresh
        // checkpoint and collects covered segments — a subsequent
        // restart (or SIGKILL) replays nothing. A lossy recovery is
        // refused unless --force explicitly accepts the loss.
        let mut wal_cfg = chh::wal::WalConfig::new(dirp);
        wal_cfg.fsync = p.str("fsync").parse()?;
        let (durable, report) = if p.flag("force") {
            chh::wal::DurableIndex::open_forced(&wal_cfg)?
        } else {
            chh::wal::DurableIndex::open(&wal_cfg)?
        };
        let index = durable.index().clone();
        // fsync / group-commit histograms of the post-recovery
        // checkpoint write — captured before the drop closes the log
        let ws = durable.wal_stats().clone();
        let wal_hists = Some(chh::jsonio::obj(vec![
            ("fsync_us", ws.fsync_hist.summary_json(1e3)),
            ("commit_batch", ws.commit_batch.summary_json(1.0)),
        ]));
        // open() already checkpointed; a plain drop closes the log
        drop(durable);
        (index, report, wal_hists)
    };
    println!("recover: {}", report.summary());
    let b = index.default_budget();
    let fmt_budget = |v: usize| {
        if v == usize::MAX { "inf".to_string() } else { v.to_string() }
    };
    println!(
        "recover: k={} radius={} shards={} live={}  (compact-threshold={}, budget T={} top={})",
        index.bits(),
        index.radius(),
        index.shard_count(),
        index.len(),
        index.compact_threshold(),
        fmt_budget(b.probes),
        fmt_budget(b.top),
    );
    let save = p.str("save");
    if !save.is_empty() {
        chh::persist::save_sharded(std::path::Path::new(save), &index)?;
        println!("recover: standalone snapshot -> {save}");
    }
    let json_path = p.str("json");
    if !json_path.is_empty() {
        use chh::jsonio::{obj, Json};
        let doc = obj(vec![
            ("tool", Json::from("recover")),
            ("wal_dir", Json::from(dir.as_str())),
            ("report", report.to_json()),
            // the WAL position replay stopped at — replication tests use
            // this (with --inspect) to assert convergence points
            ("last_applied_seq", Json::from(report.end_seg as usize)),
            ("last_applied_off", Json::from(report.end_off as usize)),
            ("bits", Json::from(index.bits())),
            ("radius", Json::from(index.radius())),
            ("shards", Json::from(index.shard_count())),
            ("live", Json::from(index.len())),
            // checkpoint-write WAL histograms (null under --inspect,
            // which opens nothing for writing)
            ("wal", wal_hists.unwrap_or(Json::Null)),
        ]);
        std::fs::write(json_path, doc.to_string_pretty())?;
        println!("recover: json report -> {json_path}");
    }
    if report.lossy() && !p.flag("force") {
        anyhow::bail!(
            "lossy recovery: the longest valid prefix was recovered, but part of the \
             log could not be applied ({} segments skipped{}) — rerun with --force to \
             accept the loss and checkpoint the prefix",
            report.segments_skipped,
            if report.snapshot_fallback { ", snapshot fallback" } else { "" }
        );
    }
    Ok(())
}

fn cmd_loadgen(rest: &[String]) -> anyhow::Result<()> {
    use chh::metrics::Histogram;
    use chh::server::{binproto, protocol};
    use chh::server::HttpClient;
    use std::time::{Duration, Instant};
    let args = Args::new("chh loadgen", "open/closed-loop load generator for chh serve-http")
        .opt("addr", "127.0.0.1:8080", "server address (the primary: mutations always go here)")
        .opt(
            "replicas",
            "",
            "comma-separated replica addrs; reads round-robin across primary + replicas",
        )
        .opt(
            "routers",
            "",
            "comma-separated router-tier addrs (chh route); ALL traffic, mutations \
             included, round-robins across them (JSON wire only)",
        )
        .opt("queries", "1000", "total queries to send")
        .opt("concurrency", "8", "client connections (one thread each)")
        .opt("mode", "closed", "closed (back-to-back) | open (paced by --rate)")
        .opt("rate", "2000", "open loop: total target queries/sec")
        .opt("topk", "0", "use /query_topk with this T instead of /query (0 = /query)")
        .opt(
            "mutate-frac",
            "0",
            "send this fraction of requests as /insert + /remove mutations (online servers)",
        )
        .opt("seed", "2012", "rng seed for the query hyperplanes")
        .opt(
            "protocol",
            "json",
            "wire protocol: json | binary | both (both replays the identical request \
             stream on each wire and compares answers + throughput side by side)",
        )
        .opt("json", "", "write machine-readable results to this path")
        .flag("shutdown", "POST /shutdown to the server when done");
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let routers: Vec<String> = p
        .str("routers")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    anyhow::ensure!(
        routers.is_empty() || p.str("replicas").trim().is_empty(),
        "--routers and --replicas are mutually exclusive (the router tier already \
         fans out to each partition's replica set)"
    );
    // the probe/metrics/shutdown anchor: the primary, or the first router
    let addr = match routers.first() {
        Some(r) => r.clone(),
        None => p.str("addr").to_string(),
    };
    let queries = p.usize("queries")?;
    let conc = p.usize("concurrency")?.max(1);
    let open_loop = match p.str("mode") {
        "closed" => false,
        "open" => true,
        other => anyhow::bail!("unknown --mode '{other}' (closed|open)"),
    };
    let rate = p.f64("rate")?;
    let topk = p.usize("topk")?;
    let mutate_frac = p.f64("mutate-frac")?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&mutate_frac),
        "--mutate-frac must be in [0, 1]"
    );
    let seed = p.u64("seed")?;
    let proto_str = p.str("protocol").to_string();
    // each pass is `binary?`; `both` runs json first, then binary, with
    // identical rng seeds so the two wires see the same request stream
    let passes: Vec<bool> = match proto_str.as_str() {
        "json" => vec![false],
        "binary" => vec![true],
        "both" => vec![false, true],
        other => anyhow::bail!("unknown --protocol '{other}' (json|binary|both)"),
    };
    anyhow::ensure!(
        routers.is_empty() || proto_str == "json",
        "--routers requires --protocol json (the router tier answers JSON upstream; \
         the binary wire is partition-internal)"
    );
    // learn the index dimensionality (and readiness) from /stats
    let mut probe = HttpClient::connect_retry(&addr, Duration::from_secs(10))
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    probe.set_timeout(Duration::from_secs(10))?;
    let resp = probe.get("/stats").map_err(|e| anyhow::anyhow!("GET /stats: {e}"))?;
    anyhow::ensure!(resp.status == 200, "GET /stats returned {}", resp.status);
    let stats = chh::jsonio::Json::parse_bytes(&resp.body)
        .map_err(|e| anyhow::anyhow!("parsing /stats: {e}"))?;
    let dim = stats
        .get("dim")
        .and_then(|d| d.as_usize())
        .ok_or_else(|| anyhow::anyhow!("/stats has no dim field"))?;
    let server_mode =
        stats.get("mode").and_then(|m| m.as_str()).unwrap_or("?").to_string();
    // valid /insert id range, needed only when driving mutations
    let points = stats.get("points").and_then(|x| x.as_usize()).unwrap_or(0);
    if mutate_frac > 0.0 {
        anyhow::ensure!(
            server_mode == "online" || server_mode == "cluster",
            "--mutate-frac needs an online or cluster-mode server (got mode={server_mode})"
        );
        anyhow::ensure!(points > 0, "/stats reports no points to mutate");
    }
    if !routers.is_empty() {
        anyhow::ensure!(
            server_mode == "cluster",
            "--routers targets must run `chh route` (got mode={server_mode})"
        );
    }
    // one-shot build/identity line so runs are attributable to a binary
    if let Ok(hz) = probe.get("/healthz") {
        if let Ok(h) = chh::jsonio::Json::parse_bytes(&hz.body) {
            let s = |k: &str| h.get(k).and_then(|v| v.as_str()).unwrap_or("?").to_string();
            println!(
                "loadgen: server {} v{} ({}) role={} uptime={:.0}s",
                s("mode"),
                s("version"),
                s("git_hash"),
                s("role"),
                h.get("uptime_secs").and_then(|v| v.as_f64()).unwrap_or(0.0),
            );
        }
    }
    drop(probe);
    // rotation targets: the whole router tier, or the primary plus any
    // replicas. Router mode sends mutations through the rotation too —
    // every router can route them to the owning partition.
    let route_all = !routers.is_empty();
    let read_addrs: Vec<String> = if route_all {
        routers.clone()
    } else {
        let mut v = vec![addr.clone()];
        for r in p.str("replicas").split(',').map(str::trim).filter(|s| !s.is_empty()) {
            v.push(r.to_string());
        }
        v
    };
    /// One `/metrics` scrape, parsed; `None` when the target is down or
    /// answers anything but 200 (the report then skips its deltas).
    fn scrape_metrics(a: &str) -> Option<Vec<(String, f64)>> {
        let mut c = HttpClient::connect_with_timeout(a, Duration::from_secs(2)).ok()?;
        let _ = c.set_timeout(Duration::from_secs(5));
        let r = c.get("/metrics").ok().filter(|r| r.status == 200)?;
        Some(chh::obs::parse_scrape(&String::from_utf8_lossy(&r.body)))
    }
    // scrape every rotation target before the run so each target's
    // post-run scrape can be reported as deltas attributable to this
    // load (per-target: a straggling router/replica shows its own table)
    let scrapes_before: Vec<Option<Vec<(String, f64)>>> =
        read_addrs.iter().map(|a| scrape_metrics(a)).collect();
    println!(
        "loadgen: {queries} queries (dim={dim}, wire={proto_str}) -> {addr} [{server_mode}]  \
         {} loop, {conc} connections{}{}",
        if open_loop { "open" } else { "closed" },
        if open_loop { format!(", target {rate:.0} q/s") } else { String::new() },
        if route_all {
            format!(", all traffic round-robin over {} routers", read_addrs.len())
        } else if read_addrs.len() > 1 {
            format!(", reads round-robin over {} targets", read_addrs.len())
        } else {
            String::new()
        }
    );

    /// One lazily-(re)connected keep-alive client. Honors
    /// `Connection: close` (shed 503s and shutdown replies close the
    /// socket — keeping a dead connection burns the next request as a
    /// spurious transport failure) and drops the client on errors so
    /// the next request reconnects instead of failing forever.
    struct Conn {
        addr: String,
        client: Option<HttpClient>,
        /// TCP connects performed — a keep-alive regression shows up as
        /// this count climbing toward the request count
        established: usize,
        /// transport failures (connect or request) against this target —
        /// per-target attribution for a flapping router/replica
        errors: usize,
    }

    /// One request body on either wire; [`Conn::post`] picks the matching
    /// `HttpClient` entry point (and `Content-Type`) per variant.
    enum Body {
        Json(String),
        Bin(Vec<u8>),
    }

    impl Conn {
        fn new(addr: String) -> Conn {
            Conn { addr, client: None, established: 0, errors: 0 }
        }

        fn post(&mut self, path: &str, body: &Body) -> Option<chh::server::http::Response> {
            if self.client.is_none() {
                // bounded connect: a dead replica in the rotation costs
                // 1s per touch, not the OS's multi-minute SYN schedule
                let c = match HttpClient::connect_with_timeout(&self.addr, Duration::from_secs(1))
                {
                    Ok(c) => c,
                    Err(_) => {
                        self.errors += 1;
                        return None;
                    }
                };
                let _ = c.set_timeout(Duration::from_secs(30));
                self.client = Some(c);
                self.established += 1;
            }
            let c = self.client.as_mut().expect("client just connected");
            let sent = match body {
                Body::Json(s) => c.post(path, s),
                Body::Bin(b) => c.post_binary(path, b),
            };
            match sent {
                Ok(resp) => {
                    if !resp.keep_alive {
                        self.client = None;
                    }
                    Some(resp)
                }
                Err(_) => {
                    self.client = None;
                    self.errors += 1;
                    None
                }
            }
        }
    }

    /// Digest of one answer's observable semantics — id, margin bits,
    /// scanned/probed counters — FNV-1a over a canonical byte string.
    /// Per-answer digests are XOR-folded across requests and threads, so
    /// the fold is order-independent and two passes over the same request
    /// stream on different wires must produce the same fingerprint.
    fn answer_fingerprint(binary: bool, topk: bool, body: &[u8]) -> Option<u64> {
        fn eat(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h ^= u64::from(b);
                *h = h.wrapping_mul(0x100000001b3);
            }
        }
        let mut h = 0xcbf29ce484222325u64;
        if topk {
            let hits = if binary {
                binproto::decode_topk_hits(body).ok()?
            } else {
                protocol::parse_topk_hits(body).ok()?
            };
            eat(&mut h, &(hits.len() as u64).to_le_bytes());
            for (id, m) in hits {
                eat(&mut h, &(id as u64).to_le_bytes());
                eat(&mut h, &m.to_bits().to_le_bytes());
            }
        } else {
            let hit = if binary {
                binproto::decode_hit(body).ok()?
            } else {
                protocol::parse_hit(body).ok()?
            };
            match hit.best {
                Some((id, m)) => {
                    eat(&mut h, &[1]);
                    eat(&mut h, &(id as u64).to_le_bytes());
                    eat(&mut h, &m.to_bits().to_le_bytes());
                }
                None => eat(&mut h, &[0]),
            }
            eat(&mut h, &(hit.scanned as u64).to_le_bytes());
            eat(&mut h, &(hit.probed as u64).to_le_bytes());
            eat(&mut h, &[u8::from(hit.nonempty)]);
        }
        Some(h)
    }

    /// What one worker thread hands back when it joins.
    struct ThreadOut {
        hist: Histogram,
        ok: usize,
        rejected: usize,
        failed: usize,
        mutations: usize,
        conns: usize,
        fingerprint: u64,
        /// per rotation target: (connections established, transport errors)
        targets: Vec<(usize, usize)>,
    }

    /// Accumulated result of one protocol pass.
    struct PassOut {
        proto: &'static str,
        hist: Histogram,
        ok: usize,
        rejected: usize,
        failed: usize,
        mutations: usize,
        conns: usize,
        fingerprint: u64,
        secs: f64,
    }

    let t0 = Instant::now();
    let mut pass_outs: Vec<PassOut> = Vec::new();
    // per rotation target, summed across threads and passes:
    // (connections established, transport errors)
    let mut target_totals: Vec<(usize, usize)> = vec![(0, 0); read_addrs.len()];
    for (pi, &binary) in passes.iter().enumerate() {
        let proto = if binary { "binary" } else { "json" };
        if passes.len() > 1 {
            println!("loadgen: pass {}/{} ({proto} wire)", pi + 1, passes.len());
        }
        let pass_t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..conc {
            let n_t = queries / conc + usize::from(t < queries % conc);
            let addr = addr.clone();
            let read_addrs = read_addrs.clone();
            handles.push(std::thread::spawn(
                move || -> ThreadOut {
                    let mut h = Histogram::new();
                    let (mut ok, mut rejected, mut failed) = (0usize, 0usize, 0usize);
                    let mut mok = 0usize;
                    let mut fp = 0u64;
                    // the seed depends on the thread, not the pass: under
                    // `both` each wire replays the identical request
                    // stream, so the answer fingerprints must agree
                    let mut rng = Rng::seed_from_u64(seed ^ (0x9E3779B9 + t as u64));
                    let mut primary = Conn::new(addr);
                    let mut readers: Vec<Conn> =
                        read_addrs.into_iter().map(Conn::new).collect();
                    // the server may still be binding: prime one connection
                    // with a retry window before the timed run (a router in
                    // cluster mode — the mutation primary otherwise)
                    let prime = if route_all {
                        let k = t % readers.len();
                        &mut readers[k]
                    } else {
                        &mut primary
                    };
                    if let Ok(c) =
                        HttpClient::connect_retry(&prime.addr, Duration::from_secs(5))
                    {
                        let _ = c.set_timeout(Duration::from_secs(30));
                        prime.client = Some(c);
                        prime.established += 1;
                    }
                    // stagger the rotation so concurrent threads spread
                    // their first reads across the fleet
                    let mut rr = t;
                    let interval = if open_loop { conc as f64 / rate.max(1e-9) } else { 0.0 };
                    let start = Instant::now();
                    for i in 0..n_t {
                        if open_loop {
                            let due = start + Duration::from_secs_f64(i as f64 * interval);
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                        }
                        let is_mutation = mutate_frac > 0.0 && rng.bernoulli(mutate_frac);
                        let (path, body) = if is_mutation {
                            // 50/50 insert/remove over random store ids —
                            // the durable-serving churn shape
                            let id = rng.below(points) as u32;
                            let (path, tag) = if rng.bernoulli(0.5) {
                                ("/insert", binproto::TAG_INSERT)
                            } else {
                                ("/remove", binproto::TAG_REMOVE)
                            };
                            let body = if binary {
                                Body::Bin(binproto::encode_id(tag, id))
                            } else {
                                Body::Json(protocol::id_body(id))
                            };
                            (path, body)
                        } else {
                            let w = chh::testing::unit_vec(&mut rng, dim);
                            if topk > 0 {
                                let body = if binary {
                                    Body::Bin(binproto::encode_topk(&w, topk, None))
                                } else {
                                    Body::Json(protocol::topk_body(&w, topk))
                                };
                                ("/query_topk", body)
                            } else {
                                let body = if binary {
                                    Body::Bin(binproto::encode_query(&w, None))
                                } else {
                                    Body::Json(protocol::query_body(&w))
                                };
                                ("/query", body)
                            }
                        };
                        let q0 = Instant::now();
                        // mutations hit the primary directly (replicas
                        // answer them 421) — except through a router tier,
                        // where every router can route them by id; reads
                        // round-robin across the fleet either way
                        let resp = if is_mutation && !route_all {
                            primary.post(path, &body)
                        } else {
                            let k = rr % readers.len();
                            rr += 1;
                            readers[k].post(path, &body)
                        };
                        match resp {
                            Some(resp) => match resp.status {
                                200 if is_mutation => mok += 1,
                                200 => match answer_fingerprint(binary, topk > 0, &resp.body) {
                                    Some(d) => {
                                        h.record(q0.elapsed().as_secs_f64());
                                        ok += 1;
                                        fp ^= d;
                                    }
                                    // a 200 whose body does not decode is
                                    // a wire bug, not a slow request
                                    None => failed += 1,
                                },
                                503 => rejected += 1,
                                _ => failed += 1,
                            },
                            None => failed += 1,
                        }
                    }
                    let conns = primary.established
                        + readers.iter().map(|r| r.established).sum::<usize>();
                    ThreadOut {
                        hist: h,
                        ok,
                        rejected,
                        failed,
                        mutations: mok,
                        conns,
                        fingerprint: fp,
                        targets: readers.iter().map(|r| (r.established, r.errors)).collect(),
                    }
                },
            ));
        }
        let mut hist = Histogram::new();
        let (mut ok, mut rejected, mut failed, mut mutations, mut conns) =
            (0usize, 0usize, 0usize, 0usize, 0usize);
        let mut fp = 0u64;
        for hd in handles {
            let to = hd.join().expect("loadgen worker");
            hist.merge(&to.hist);
            ok += to.ok;
            rejected += to.rejected;
            failed += to.failed;
            mutations += to.mutations;
            conns += to.conns;
            fp ^= to.fingerprint;
            for (i, (est, err)) in to.targets.into_iter().enumerate() {
                target_totals[i].0 += est;
                target_totals[i].1 += err;
            }
        }
        pass_outs.push(PassOut {
            proto,
            hist,
            ok,
            rejected,
            failed,
            mutations,
            conns,
            fingerprint: fp,
            secs: pass_t0.elapsed().as_secs_f64(),
        });
    }
    let secs = t0.elapsed().as_secs_f64();
    let rows: Vec<Vec<String>> = pass_outs
        .iter()
        .map(|po| {
            vec![
                po.proto.to_string(),
                format!("{}", po.ok),
                format!("{}", po.rejected),
                format!("{}", po.failed),
                format!("{:.0}", po.ok as f64 / po.secs.max(1e-9)),
                format!("{:.1}", po.hist.percentile(50.0) * 1e6),
                format!("{:.1}", po.hist.percentile(95.0) * 1e6),
                format!("{:.1}", po.hist.percentile(99.0) * 1e6),
                format!("{:.1}", po.hist.mean() * 1e6),
                format!("{}", po.conns),
            ]
        })
        .collect();
    chh::report::print_rows(
        &format!(
            "loadgen: {} loop, {conc} connections, {secs:.2}s wall",
            if open_loop { "open" } else { "closed" }
        ),
        &[
            "proto", "ok", "503", "failed", "qps", "p50(us)", "p95(us)", "p99(us)", "mean(us)",
            "conns",
        ],
        &rows,
    );
    let ok: usize = pass_outs.iter().map(|po| po.ok).sum();
    let rejected: usize = pass_outs.iter().map(|po| po.rejected).sum();
    let failed: usize = pass_outs.iter().map(|po| po.failed).sum();
    let mutations: usize = pass_outs.iter().map(|po| po.mutations).sum();
    let conns_total: usize = pass_outs.iter().map(|po| po.conns).sum();
    let mut hist = Histogram::new();
    for po in &pass_outs {
        hist.merge(&po.hist);
    }
    let (p50, p95, p99) = (
        hist.percentile(50.0) * 1e6,
        hist.percentile(95.0) * 1e6,
        hist.percentile(99.0) * 1e6,
    );
    if mutate_frac > 0.0 {
        println!("mutations: {mutations} applied (acked durable per the server's fsync policy)");
    }
    if read_addrs.len() > 1 || route_all {
        let rows: Vec<Vec<String>> = read_addrs
            .iter()
            .zip(&target_totals)
            .map(|(a, &(est, err))| vec![a.clone(), format!("{est}"), format!("{err}")])
            .collect();
        chh::report::print_rows(
            &format!("per-target ({})", if route_all { "routers" } else { "read fan-out" }),
            &["target", "conns", "errors"],
            &rows,
        );
    }
    if pass_outs.len() == 2 {
        let (j, b) = (&pass_outs[0], &pass_outs[1]);
        println!(
            "binary vs json: {:.2}x throughput ({:.0} vs {:.0} qps), p99 {:.1}us vs {:.1}us",
            (b.ok as f64 / b.secs.max(1e-9)) / (j.ok as f64 / j.secs.max(1e-9)).max(1e-9),
            b.ok as f64 / b.secs.max(1e-9),
            j.ok as f64 / j.secs.max(1e-9),
            b.hist.percentile(99.0) * 1e6,
            j.hist.percentile(99.0) * 1e6,
        );
        // with no mutations the index never changes between passes, so
        // the two wires must return bit-identical answers (a shed 503
        // would drop one answer from a fold, hence the clean-run guard)
        if mutate_frac == 0.0 && j.failed + b.failed + j.rejected + b.rejected == 0 {
            anyhow::ensure!(
                j.fingerprint == b.fingerprint,
                "protocol parity violation: json answer fingerprint {:#018x} != binary {:#018x}",
                j.fingerprint,
                b.fingerprint
            );
            println!(
                "parity: json and binary answers bit-identical (fingerprint {:#018x})",
                j.fingerprint
            );
        }
    }
    // post-run scrape of every rotation target: server-side stage
    // deltas sit next to the client-side percentiles, so "where did the
    // time go" needs no second tool — and with several routers or
    // replicas, per-target tables show which member burned the time
    let scrapes_after: Vec<Option<Vec<(String, f64)>>> =
        read_addrs.iter().map(|a| scrape_metrics(a)).collect();
    let query_route_label =
        if topk > 0 { "route=\"/query_topk\"" } else { "route=\"/query\"" };
    // one stage-delta doc per target that answered both scrapes
    let mut target_server_json: Vec<chh::jsonio::Json> = Vec::new();
    for (ti, a) in read_addrs.iter().enumerate() {
        let (Some(before), Some(after)) = (&scrapes_before[ti], &scrapes_after[ti]) else {
            target_server_json.push(chh::jsonio::Json::Null);
            continue;
        };
        let delta = |name: &str, label: &str| -> f64 {
            chh::obs::series_value(after, name, label).unwrap_or(0.0)
                - chh::obs::series_value(before, name, label).unwrap_or(0.0)
        };
        let mut rows = Vec::new();
        let mut stage_json = Vec::new();
        for &stage in chh::server::STAGES {
            let label = format!("stage=\"{stage}\"");
            let n = delta("chh_stage_seconds_count", &label);
            let sum = delta("chh_stage_seconds_sum", &label);
            let mean_us = sum * 1e6 / n.max(1.0);
            rows.push(vec![
                stage.to_string(),
                format!("{n:.0}"),
                format!("{mean_us:.1}"),
                format!("{:.1}", sum * 1e3),
            ]);
            stage_json.push((
                stage,
                chh::jsonio::obj(vec![
                    ("observations", chh::jsonio::Json::Num(n)),
                    ("mean_us", chh::jsonio::Json::Num(mean_us)),
                    ("total_ms", chh::jsonio::Json::Num(sum * 1e3)),
                ]),
            ));
        }
        chh::report::print_rows(
            &if read_addrs.len() == 1 {
                "server stages (/metrics delta over this run)".to_string()
            } else {
                format!("server stages at {a} (/metrics delta over this run)")
            },
            &["stage", "obs", "mean(us)", "total(ms)"],
            &rows,
        );
        let served = delta("chh_http_requests_total", query_route_label);
        target_server_json.push(chh::jsonio::obj(vec![
            ("queries_served", chh::jsonio::Json::Num(served)),
            ("stages", chh::jsonio::obj(stage_json)),
        ]));
    }
    // the anchor target's doc keeps the historical top-level slot
    let server_json: Option<chh::jsonio::Json> = target_server_json
        .first()
        .filter(|j| !matches!(j, chh::jsonio::Json::Null))
        .cloned();
    let json_path = p.str("json");
    if !json_path.is_empty() {
        use chh::jsonio::{obj, Json};
        // one sub-document per wire pass — the serving-perf trajectory
        // (BENCH_serving.json) reads qps/p99 for each protocol from here
        let proto_docs: Vec<(&str, Json)> = pass_outs
            .iter()
            .map(|po| {
                (
                    po.proto,
                    obj(vec![
                        ("ok", Json::from(po.ok)),
                        ("rejected_503", Json::from(po.rejected)),
                        ("failed", Json::from(po.failed)),
                        ("mutations_ok", Json::from(po.mutations)),
                        ("wall_secs", Json::Num(po.secs)),
                        ("qps", Json::Num(po.ok as f64 / po.secs.max(1e-9))),
                        ("p50_us", Json::Num(po.hist.percentile(50.0) * 1e6)),
                        ("p95_us", Json::Num(po.hist.percentile(95.0) * 1e6)),
                        ("p99_us", Json::Num(po.hist.percentile(99.0) * 1e6)),
                        ("mean_us", Json::Num(po.hist.mean() * 1e6)),
                        ("connections_established", Json::from(po.conns)),
                        (
                            "answer_fingerprint",
                            Json::from(format!("{:#018x}", po.fingerprint)),
                        ),
                    ]),
                )
            })
            .collect();
        let doc = obj(vec![
            ("tool", Json::from("loadgen")),
            ("mode", Json::from(if open_loop { "open" } else { "closed" })),
            ("protocol", Json::from(proto_str.as_str())),
            ("queries", Json::from(queries)),
            ("concurrency", Json::from(conc)),
            ("ok", Json::from(ok)),
            ("mutations_ok", Json::from(mutations)),
            ("rejected_503", Json::from(rejected)),
            ("failed", Json::from(failed)),
            ("connections_established", Json::from(conns_total)),
            ("wall_secs", Json::Num(secs)),
            ("qps", Json::Num(ok as f64 / secs.max(1e-9))),
            ("p50_us", Json::Num(p50)),
            ("p95_us", Json::Num(p95)),
            ("p99_us", Json::Num(p99)),
            ("mean_us", Json::Num(hist.mean() * 1e6)),
            ("protocols", obj(proto_docs)),
            (
                "targets",
                Json::Arr(
                    read_addrs
                        .iter()
                        .zip(&target_totals)
                        .zip(&target_server_json)
                        .map(|((a, &(est, err)), server)| {
                            obj(vec![
                                ("addr", Json::from(a.as_str())),
                                ("connections_established", Json::from(est)),
                                ("transport_errors", Json::from(err)),
                                // this target's own /metrics stage deltas
                                // (null when a scrape failed)
                                ("server", server.clone()),
                            ])
                        })
                        .collect(),
                ),
            ),
            // server-side /metrics deltas (null if a scrape failed)
            ("server", server_json.unwrap_or(Json::Null)),
        ]);
        std::fs::write(json_path, doc.to_string_pretty())?;
        println!("json results -> {json_path}");
    }
    if p.flag("shutdown") {
        let mut c = HttpClient::connect(&addr)
            .map_err(|e| anyhow::anyhow!("reconnecting for shutdown: {e}"))?;
        let resp = c
            .post("/shutdown", "")
            .map_err(|e| anyhow::anyhow!("POST /shutdown: {e}"))?;
        anyhow::ensure!(resp.status == 200, "POST /shutdown returned {}", resp.status);
        println!("loadgen: server shutdown requested");
    }
    anyhow::ensure!(
        ok + mutations > 0,
        "no request succeeded ({rejected} rejected, {failed} failed)"
    );
    Ok(())
}

fn cmd_encode(rest: &[String]) -> anyhow::Result<()> {
    let args = ExperimentConfig::cli_opts(Args::new("chh encode", "batch encode: native vs PJRT"));
    let p = args.parse(rest).map_err(|h| anyhow::anyhow!("{h}"))?;
    let cfg = ExperimentConfig::from_parsed(&p)?;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = make_dataset(&cfg, &mut rng);
    let bh = BhHash::sample(data.dim(), cfg.bits(), &mut rng);
    let t0 = std::time::Instant::now();
    let serial = bh.encode_all(data.features());
    let serial_secs = t0.elapsed().as_secs_f64();
    println!("native encode (serial):     {} points in {serial_secs:.3}s", serial.len());
    let pool = chh::par::Pool::new(cfg.workers);
    let t0 = std::time::Instant::now();
    let native = bh.encode_all_pool(data.features(), &pool);
    let native_secs = t0.elapsed().as_secs_f64();
    anyhow::ensure!(native.codes == serial.codes, "pooled encode diverged from serial");
    println!(
        "native encode ({} workers): {} points in {native_secs:.3}s ({:.2}x, codes identical)",
        pool.workers(),
        native.len(),
        serial_secs / native_secs.max(1e-9)
    );
    if cfg.quantized {
        let qp = bh.pairs.quantize();
        let t0 = std::time::Instant::now();
        let quant = qp.encode_all_pool(data.features(), &pool);
        let quant_secs = t0.elapsed().as_secs_f64();
        // the quantized path is approximate: report per-bit agreement
        // with the exact f32 codes instead of asserting parity
        let bits = cfg.bits() as u64;
        let agree: u64 = native
            .codes
            .iter()
            .zip(quant.codes.iter())
            .map(|(&a, &b)| bits - u64::from((a ^ b).count_ones()))
            .sum();
        let total = (native.len() as u64 * bits).max(1);
        println!(
            "quantized encode ({} workers): {} points in {quant_secs:.3}s \
             ({:.2}x vs f32 pooled, per-bit agreement {:.4})",
            pool.workers(),
            quant.len(),
            native_secs / quant_secs.max(1e-9),
            agree as f64 / total as f64
        );
    }
    match chh::runtime::Runtime::open_default() {
        Ok(rt) => match chh::runtime::BatchEncoder::bilinear(&rt, cfg.profile.name()) {
            Ok(enc) => {
                let t1 = std::time::Instant::now();
                let pjrt = enc.encode_all(data.features(), &bh.pairs)?;
                let pjrt_secs = t1.elapsed().as_secs_f64();
                let agree = native
                    .codes
                    .iter()
                    .zip(pjrt.codes.iter())
                    .filter(|(a, b)| a == b)
                    .count();
                println!(
                    "pjrt encode:   {} points in {pjrt_secs:.3}s  (codes agree: {agree}/{})",
                    pjrt.len(),
                    native.len()
                );
            }
            Err(e) => println!("pjrt encoder unavailable: {e:#}"),
        },
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    Ok(())
}
