//! LBH-Hash training (§4 of the paper).
//!
//! Learns k bilinear hash functions `h_j(z) = sgn(u_jᵀ z zᵀ v_j)` so that
//! `(1/k)·B·Bᵀ ≈ S`, where `S` encodes the saturated pairwise similarity
//! `2|cos θ| − 1` of a training subsample (eq. 12) and `B` is the ±1 code
//! matrix. The solve is the paper's greedy per-bit scheme:
//!
//! 1. residue `R₀ = k·S`; for each bit j minimize
//!    `g(u_j, v_j) = −b_jᵀ R_{j−1} b_j` (eq. 15);
//! 2. replace sgn with the sigmoid `φ(x) = 2/(1+e^{−x}) − 1` giving the
//!    smooth surrogate `g̃ = −b̃ᵀR b̃` (eq. 16–17) with analytic gradient
//!    `∇g̃ = −[X Σ Xᵀv; X Σ Xᵀu]`, `Σ = diag((R b̃) ⊙ (1 − b̃⊙b̃))` (eq. 18);
//! 3. Nesterov-accelerated gradient descent from the *random projection*
//!    warm start (the same draw the randomized BH-Hash would use);
//! 4. `R_j = R_{j−1} − b_j b_jᵀ` and continue.
//!
//! The native Rust implementation below is the reference path; the PJRT
//! artifact `lbh_step` (see `python/compile/model.py` and
//! `crate::runtime`) executes the same step as a fused XLA computation and
//! is parity-tested against this module.

use crate::data::FeatureStore;
use crate::hash::{LbhHash, ProjectionPairs};
use crate::linalg::{dot, Mat};
use crate::rng::Rng;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct LbhTrainConfig {
    /// code length k
    pub bits: usize,
    /// Nesterov iterations per bit
    pub iters_per_bit: usize,
    /// initial learning rate (adapted by backtracking)
    pub lr: f32,
    /// Nesterov momentum
    pub momentum: f32,
    /// similarity saturation thresholds (eq. 12); `None` = the paper's
    /// top/bottom-5% rule computed on the training subsample
    pub t1: Option<f32>,
    pub t2: Option<f32>,
    /// cap on the reference set used by the threshold rule
    pub threshold_ref_cap: usize,
}

impl Default for LbhTrainConfig {
    fn default() -> Self {
        LbhTrainConfig {
            bits: 16,
            iters_per_bit: 300,
            lr: 1e-3,
            momentum: 0.9,
            t1: None,
            t2: None,
            threshold_ref_cap: 4000,
        }
    }
}

/// Diagnostics from a training run.
#[derive(Clone, Debug, Default)]
pub struct LbhTrainStats {
    /// surrogate cost g̃ after optimizing each bit
    pub bit_costs: Vec<f32>,
    /// discrete cost −b_jᵀR b_j after each bit
    pub discrete_costs: Vec<f32>,
    /// ‖R‖_F² before/after all bits (residual energy captured)
    pub residue_before: f64,
    pub residue_after: f64,
    /// thresholds actually used
    pub t1: f32,
    pub t2: f32,
    pub train_secs: f64,
}

/// φ(x) = 2/(1+e^{−x}) − 1 = tanh(x/2) — the paper's smooth sign surrogate.
#[inline]
pub fn sigmoid_pm(x: f32) -> f32 {
    (0.5 * x).tanh()
}

/// The similarity matrix S of eq. (12) over unit-normalized rows `xm`,
/// given thresholds t1 > t2.
pub fn similarity_matrix(xm: &Mat, t1: f32, t2: f32) -> Mat {
    let m = xm.rows;
    let mut s = Mat::zeros(m, m);
    for i in 0..m {
        for ip in i..m {
            let c = dot(xm.row(i), xm.row(ip)).abs().min(1.0);
            let v = if c >= t1 {
                1.0
            } else if c <= t2 {
                -1.0
            } else {
                2.0 * c - 1.0
            };
            s.set(i, ip, v);
            s.set(ip, i, v);
        }
    }
    s
}

/// The paper's threshold rule: compute the absolute cosine matrix between
/// the m samples and a reference set, average the top 5% per row → t1,
/// average the bottom 5% per row → t2.
pub fn threshold_rule(xm: &Mat, reference: &Mat) -> (f32, f32) {
    let m = xm.rows;
    let n = reference.rows;
    assert!(n >= 20, "reference set too small for 5% quantiles");
    let top_k = (n as f64 * 0.05).ceil() as usize;
    let bot_k = top_k;
    let mut t1_acc = 0.0f64;
    let mut t2_acc = 0.0f64;
    let mut row: Vec<f32> = Vec::with_capacity(n);
    for i in 0..m {
        row.clear();
        for j in 0..n {
            row.push(dot(xm.row(i), reference.row(j)).abs().min(1.0));
        }
        row.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let top: f32 = row[n - top_k..].iter().sum::<f32>() / top_k as f32;
        let bot: f32 = row[..bot_k].iter().sum::<f32>() / bot_k as f32;
        t1_acc += top as f64;
        t2_acc += bot as f64;
    }
    let mut t1 = (t1_acc / m as f64) as f32;
    let mut t2 = (t2_acc / m as f64) as f32;
    // keep 0 < t2 < t1 < 1 well-posed even on degenerate data
    t1 = t1.clamp(0.05, 0.999);
    t2 = t2.clamp(1e-4, t1 - 1e-3);
    (t1, t2)
}

/// One bit's state during the Nesterov solve.
struct BitState {
    u: Vec<f32>,
    v: Vec<f32>,
    yu: Vec<f32>,
    yv: Vec<f32>,
}

/// Evaluate b̃ (sigmoid codes) and the surrogate cost −b̃ᵀRb̃ at (u, v).
/// Public so the PJRT `lbh_step` artifact can be parity-tested against it.
pub fn surrogate_eval(xm: &Mat, r: &Mat, u: &[f32], v: &[f32], btil: &mut Vec<f32>) -> f32 {
    let m = xm.rows;
    btil.clear();
    for i in 0..m {
        let xi = xm.row(i);
        btil.push(sigmoid_pm(dot(xi, u) * dot(xi, v)));
    }
    // cost = −b̃ᵀ R b̃
    let mut cost = 0.0f32;
    for i in 0..m {
        cost -= btil[i] * dot(r.row(i), btil);
    }
    cost
}

/// Gradient of the surrogate at (u, v) (eq. 18). Returns (g_u, g_v).
/// Public so the PJRT `lbh_step` artifact can be parity-tested against it.
pub fn surrogate_grad(xm: &Mat, r: &Mat, u: &[f32], v: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let m = xm.rows;
    let d = xm.cols;
    let mut pu = Vec::with_capacity(m); // Xu
    let mut pv = Vec::with_capacity(m); // Xv
    let mut btil = Vec::with_capacity(m);
    for i in 0..m {
        let xi = xm.row(i);
        let a = dot(xi, u);
        let b = dot(xi, v);
        pu.push(a);
        pv.push(b);
        btil.push(sigmoid_pm(a * b));
    }
    // σ_i = (R b̃)_i · (1 − b̃_i²)
    let mut sigma = Vec::with_capacity(m);
    for i in 0..m {
        sigma.push(dot(r.row(i), &btil) * (1.0 - btil[i] * btil[i]));
    }
    // g_u = −Σ_i σ_i (x_i·v) x_i ; g_v = −Σ_i σ_i (x_i·u) x_i
    let mut gu = vec![0.0f32; d];
    let mut gv = vec![0.0f32; d];
    for i in 0..m {
        let xi = xm.row(i);
        crate::linalg::axpy(-sigma[i] * pv[i], xi, &mut gu);
        crate::linalg::axpy(-sigma[i] * pu[i], xi, &mut gv);
    }
    (gu, gv)
}

/// Discrete bit vector b_j = sgn(Xu ⊙ Xv) and discrete cost −bᵀRb.
fn discrete_eval(xm: &Mat, r: &Mat, u: &[f32], v: &[f32]) -> (Vec<f32>, f32) {
    let m = xm.rows;
    let mut b = Vec::with_capacity(m);
    for i in 0..m {
        let xi = xm.row(i);
        b.push(if dot(xi, u) * dot(xi, v) >= 0.0 { 1.0 } else { -1.0 });
    }
    let mut cost = 0.0f32;
    for i in 0..m {
        cost -= b[i] * dot(r.row(i), &b);
    }
    (b, cost)
}

/// The LBH trainer.
pub struct LbhTrainer {
    pub cfg: LbhTrainConfig,
}

impl LbhTrainer {
    pub fn new(cfg: LbhTrainConfig) -> Self {
        LbhTrainer { cfg }
    }

    /// Train on `sample_idx` rows of `feats`. `reference_idx` feeds the
    /// threshold rule (pass the same indices to self-reference, or a wider
    /// sample of the database as the paper does).
    pub fn train(
        &self,
        feats: &FeatureStore,
        sample_idx: &[usize],
        reference_idx: &[usize],
        rng: &mut Rng,
    ) -> (LbhHash, LbhTrainStats) {
        let t0 = std::time::Instant::now();
        let d = feats.dim();
        let m = sample_idx.len();
        assert!(m >= 8, "need at least 8 training samples");
        // densify + unit-normalize the training subsample
        let mut xm = Mat::zeros(m, d);
        for (r, &i) in sample_idx.iter().enumerate() {
            feats.row(i).scatter_into(xm.row_mut(r));
        }
        xm.l2_normalize_rows();

        // thresholds
        let (t1, t2) = match (self.cfg.t1, self.cfg.t2) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                let cap = self.cfg.threshold_ref_cap.min(reference_idx.len()).max(20.min(reference_idx.len()));
                let mut xr = Mat::zeros(cap, d);
                for (r, &i) in reference_idx.iter().take(cap).enumerate() {
                    feats.row(i).scatter_into(xr.row_mut(r));
                }
                xr.l2_normalize_rows();
                threshold_rule(&xm, &xr)
            }
        };
        assert!(t2 < t1, "thresholds must satisfy t2 < t1 (t1={t1}, t2={t2})");

        let s = similarity_matrix(&xm, t1, t2);
        let k = self.cfg.bits;
        // R₀ = k·S
        let mut r = Mat::zeros(m, m);
        for (dst, src) in r.data.iter_mut().zip(s.data.iter()) {
            *dst = k as f32 * src;
        }
        let residue_before = r.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();

        let mut stats = LbhTrainStats {
            t1,
            t2,
            residue_before,
            ..Default::default()
        };
        let mut u_all = Mat::zeros(k, d);
        let mut v_all = Mat::zeros(k, d);
        let mut btil_buf: Vec<f32> = Vec::with_capacity(m);

        for j in 0..k {
            // random-projection warm start (what h_j^B would have used)
            let mut st = BitState {
                u: rng.gauss_vec(d),
                v: rng.gauss_vec(d),
                yu: vec![0.0; d],
                yv: vec![0.0; d],
            };
            st.yu.copy_from_slice(&st.u);
            st.yv.copy_from_slice(&st.v);
            let mut lr = self.cfg.lr;
            let mu = self.cfg.momentum;
            let mut best_cost = surrogate_eval(&xm, &r, &st.u, &st.v, &mut btil_buf);
            let mut best_u = st.u.clone();
            let mut best_v = st.v.clone();
            let mut prev_u = st.u.clone();
            let mut prev_v = st.v.clone();
            for _t in 0..self.cfg.iters_per_bit {
                // Nesterov lookahead: y = x + μ(x − x_prev)
                for i in 0..d {
                    st.yu[i] = st.u[i] + mu * (st.u[i] - prev_u[i]);
                    st.yv[i] = st.v[i] + mu * (st.v[i] - prev_v[i]);
                }
                let (gu, gv) = surrogate_grad(&xm, &r, &st.yu, &st.yv);
                prev_u.copy_from_slice(&st.u);
                prev_v.copy_from_slice(&st.v);
                for i in 0..d {
                    st.u[i] = st.yu[i] - lr * gu[i];
                    st.v[i] = st.yv[i] - lr * gv[i];
                }
                let cost = surrogate_eval(&xm, &r, &st.u, &st.v, &mut btil_buf);
                if cost < best_cost {
                    best_cost = cost;
                    best_u.copy_from_slice(&st.u);
                    best_v.copy_from_slice(&st.v);
                    // mild step growth: self-tunes lr across problem scales
                    lr *= 1.02;
                } else if !cost.is_finite() || cost > best_cost.abs() * 4.0 + best_cost {
                    // diverged: restart from best with smaller step
                    lr *= 0.5;
                    st.u.copy_from_slice(&best_u);
                    st.v.copy_from_slice(&best_v);
                    prev_u.copy_from_slice(&best_u);
                    prev_v.copy_from_slice(&best_v);
                    if lr < 1e-6 {
                        break;
                    }
                }
            }
            let (b, dcost) = discrete_eval(&xm, &r, &best_u, &best_v);
            stats.bit_costs.push(best_cost);
            stats.discrete_costs.push(dcost);
            u_all.row_mut(j).copy_from_slice(&best_u);
            v_all.row_mut(j).copy_from_slice(&best_v);
            // R ← R − b bᵀ
            for i in 0..m {
                let bi = b[i];
                let row = r.row_mut(i);
                for ip in 0..m {
                    row[ip] -= bi * b[ip];
                }
            }
        }
        stats.residue_after = r.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
        stats.train_secs = t0.elapsed().as_secs_f64();
        (LbhHash::from_pairs(ProjectionPairs { u: u_all, v: v_all }), stats)
    }
}

impl LbhTrainer {
    /// PJRT-backed training: identical algorithm to [`Self::train`] but
    /// every Nesterov step executes the fused `lbh_step_<profile>` XLA
    /// artifact (L2 graph + L1 Pallas gradient kernels). The sample is
    /// zero-padded to the artifact's fixed m — padding is gradient-neutral.
    /// Residue updates and the discrete bit extraction stay native.
    pub fn train_pjrt(
        &self,
        stepper: &crate::runtime::LbhStepper<'_>,
        feats: &FeatureStore,
        sample_idx: &[usize],
        reference_idx: &[usize],
        rng: &mut Rng,
    ) -> anyhow::Result<(LbhHash, LbhTrainStats)> {
        let t0 = std::time::Instant::now();
        let d = feats.dim();
        anyhow::ensure!(d == stepper.dim, "dim {} != artifact {}", d, stepper.dim);
        let ms = sample_idx.len().min(stepper.m);
        anyhow::ensure!(ms >= 8, "need at least 8 training samples");
        let m_art = stepper.m;
        // padded sample matrix
        let mut xm = Mat::zeros(m_art, d);
        for (row, &i) in sample_idx.iter().take(ms).enumerate() {
            feats.row(i).scatter_into(xm.row_mut(row));
        }
        xm.l2_normalize_rows();
        // thresholds + S on the real (unpadded) sample
        let mut xs = Mat::zeros(ms, d);
        xs.data.copy_from_slice(&xm.data[..ms * d]);
        let (t1, t2) = match (self.cfg.t1, self.cfg.t2) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                let cap = self.cfg.threshold_ref_cap.min(reference_idx.len()).max(20.min(reference_idx.len()));
                let mut xr = Mat::zeros(cap, d);
                for (row, &i) in reference_idx.iter().take(cap).enumerate() {
                    feats.row(i).scatter_into(xr.row_mut(row));
                }
                xr.l2_normalize_rows();
                threshold_rule(&xs, &xr)
            }
        };
        let s = similarity_matrix(&xs, t1, t2);
        let k = self.cfg.bits;
        // residue on the real sample; padded copy refreshed per bit
        let mut r_small = Mat::zeros(ms, ms);
        for (dst, src) in r_small.data.iter_mut().zip(s.data.iter()) {
            *dst = k as f32 * src;
        }
        let residue_before =
            r_small.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
        let mut stats =
            LbhTrainStats { t1, t2, residue_before, ..Default::default() };
        let mut u_all = Mat::zeros(k, d);
        let mut v_all = Mat::zeros(k, d);
        let mut r_pad = Mat::zeros(m_art, m_art);
        for j in 0..k {
            // refresh padded residue
            for row in 0..m_art {
                let dst = r_pad.row_mut(row);
                if row < ms {
                    dst[..ms].copy_from_slice(r_small.row(row));
                    for x in dst[ms..].iter_mut() {
                        *x = 0.0;
                    }
                } else {
                    for x in dst.iter_mut() {
                        *x = 0.0;
                    }
                }
            }
            let mut u = rng.gauss_vec(d);
            let mut v = rng.gauss_vec(d);
            let mut u_prev = u.clone();
            let mut v_prev = v.clone();
            let mut lr = self.cfg.lr;
            let mu = self.cfg.momentum;
            let mut best_cost = f32::INFINITY;
            let mut best_u = u.clone();
            let mut best_v = v.clone();
            for _t in 0..self.cfg.iters_per_bit {
                let (u_new, v_new, cost) =
                    stepper.step(&xm, &r_pad, &u, &v, &u_prev, &v_prev, lr, mu)?;
                u_prev = std::mem::replace(&mut u, u_new);
                v_prev = std::mem::replace(&mut v, v_new);
                if cost < best_cost {
                    best_cost = cost;
                    best_u.copy_from_slice(&u);
                    best_v.copy_from_slice(&v);
                    lr *= 1.02;
                } else if !cost.is_finite() || cost > best_cost.abs() * 4.0 + best_cost {
                    lr *= 0.5;
                    u.copy_from_slice(&best_u);
                    v.copy_from_slice(&best_v);
                    u_prev.copy_from_slice(&best_u);
                    v_prev.copy_from_slice(&best_v);
                    if lr < 1e-6 {
                        break;
                    }
                }
            }
            let (b, dcost) = discrete_eval(&xs, &r_small, &best_u, &best_v);
            stats.bit_costs.push(best_cost);
            stats.discrete_costs.push(dcost);
            u_all.row_mut(j).copy_from_slice(&best_u);
            v_all.row_mut(j).copy_from_slice(&best_v);
            for i in 0..ms {
                let bi = b[i];
                let row = r_small.row_mut(i);
                for ip in 0..ms {
                    row[ip] -= bi * b[ip];
                }
            }
        }
        stats.residue_after =
            r_small.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
        stats.train_secs = t0.elapsed().as_secs_f64();
        Ok((LbhHash::from_pairs(ProjectionPairs { u: u_all, v: v_all }), stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::HashFamily;
    use crate::testing::forall;

    #[test]
    fn sigmoid_matches_definition() {
        for &x in &[-8.0f32, -1.0, 0.0, 0.5, 6.5] {
            let direct = 2.0 / (1.0 + (-x).exp()) - 1.0;
            assert!((sigmoid_pm(x) - direct).abs() < 1e-6, "x={x}");
        }
        // approximates sgn for |x| > 6 (paper's remark)
        assert!(sigmoid_pm(7.0) > 0.95);
        assert!(sigmoid_pm(-7.0) < -0.95);
    }

    #[test]
    fn similarity_matrix_properties() {
        forall("S symmetric, unit diagonal, in [-1,1]", 16, |rng| {
            let m = rng.range(4, 24);
            let d = rng.range(4, 16);
            let mut xm = Mat::from_vec(m, d, rng.gauss_vec(m * d));
            xm.l2_normalize_rows();
            let s = similarity_matrix(&xm, 0.8, 0.2);
            for i in 0..m {
                crate::prop_assert!(s.get(i, i) == 1.0, "diag {i} = {}", s.get(i, i));
                for j in 0..m {
                    let v = s.get(i, j);
                    crate::prop_assert!(v == s.get(j, i), "symmetry");
                    crate::prop_assert!((-1.0..=1.0).contains(&v), "range {v}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn similarity_saturation() {
        // identical rows → 1; orthogonal rows → −1 with t2 above 0
        let xm = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let s = similarity_matrix(&xm, 0.9, 0.1);
        assert_eq!(s.get(0, 1), -1.0);
        let xm2 = Mat::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]);
        let s2 = similarity_matrix(&xm2, 0.9, 0.1);
        assert_eq!(s2.get(0, 1), 1.0);
    }

    #[test]
    fn threshold_rule_ordering() {
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let mut xm = Mat::zeros(50, 16);
        for i in 0..50 {
            ds.features().row(i).scatter_into(xm.row_mut(i));
        }
        let mut xr = Mat::zeros(300, 16);
        for i in 0..300 {
            ds.features().row(i).scatter_into(xr.row_mut(i));
        }
        let (t1, t2) = threshold_rule(&xm, &xr);
        assert!(t2 < t1, "t1={t1} t2={t2}");
        assert!(t1 <= 1.0 && t2 > 0.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::seed_from_u64(4);
        let m = 12;
        let d = 6;
        let mut xm = Mat::from_vec(m, d, rng.gauss_vec(m * d));
        xm.l2_normalize_rows();
        let s = similarity_matrix(&xm, 0.8, 0.2);
        let mut r = s.clone();
        crate::linalg::scal(8.0, &mut r.data);
        let u = rng.gauss_vec(d);
        let v = rng.gauss_vec(d);
        let (gu, gv) = surrogate_grad(&xm, &r, &u, &v);
        let mut buf = Vec::new();
        let eps = 1e-3f32;
        for t in 0..d {
            let mut up = u.clone();
            up[t] += eps;
            let mut um = u.clone();
            um[t] -= eps;
            let fd =
                (surrogate_eval(&xm, &r, &up, &v, &mut buf) - surrogate_eval(&xm, &r, &um, &v, &mut buf))
                    / (2.0 * eps);
            assert!(
                (fd - gu[t]).abs() < 2e-2 * (1.0 + fd.abs().max(gu[t].abs())),
                "du[{t}]: fd {fd} vs analytic {}",
                gu[t]
            );
            let mut vp = v.clone();
            vp[t] += eps;
            let mut vm = v.clone();
            vm[t] -= eps;
            let fdv =
                (surrogate_eval(&xm, &r, &u, &vp, &mut buf) - surrogate_eval(&xm, &r, &u, &vm, &mut buf))
                    / (2.0 * eps);
            assert!(
                (fdv - gv[t]).abs() < 2e-2 * (1.0 + fdv.abs().max(gv[t].abs())),
                "dv[{t}]: fd {fdv} vs analytic {}",
                gv[t]
            );
        }
    }

    #[test]
    fn training_reduces_residue_and_cost() {
        let mut rng = Rng::seed_from_u64(5);
        let ds = test_blobs(200, 24, 4, &mut rng);
        let idx: Vec<usize> = (0..64).collect();
        let refs: Vec<usize> = (0..200).collect();
        let trainer = LbhTrainer::new(LbhTrainConfig { bits: 8, iters_per_bit: 60, ..Default::default() });
        let (_h, stats) = trainer.train(ds.features(), &idx, &refs, &mut rng);
        assert!(
            stats.residue_after < stats.residue_before,
            "residue {} → {}",
            stats.residue_before,
            stats.residue_after
        );
        assert_eq!(stats.bit_costs.len(), 8);
        // discrete cost −bᵀRb is bounded below by −max|R|·m² ≥ −k·m²
        // (|R| entries start at k·|S| ≤ k and shrink as bits are fitted)
        for &c in &stats.discrete_costs {
            assert!(c >= -(8.0 * 64.0f32 * 64.0), "cost {c}");
        }
    }

    #[test]
    fn learned_beats_random_on_similarity_fit() {
        // The defining property: (1/k)BBᵀ should fit S better than random
        // bilinear projections (this is exactly objective Q of the paper).
        let mut rng = Rng::seed_from_u64(6);
        let ds = test_blobs(240, 24, 4, &mut rng);
        let idx: Vec<usize> = (0..80).collect();
        let refs: Vec<usize> = (0..240).collect();
        let k = 12;
        let trainer = LbhTrainer::new(LbhTrainConfig { bits: k, iters_per_bit: 80, ..Default::default() });
        let (lbh, stats) = trainer.train(ds.features(), &idx, &refs, &mut rng);
        // Build Xm and S with the trainer's thresholds.
        let mut xm = Mat::zeros(80, 24);
        for (r, &i) in idx.iter().enumerate() {
            ds.features().row(i).scatter_into(xm.row_mut(r));
        }
        xm.l2_normalize_rows();
        let s = similarity_matrix(&xm, stats.t1, stats.t2);
        let q_of = |fam: &dyn HashFamily| -> f64 {
            let mut q = 0.0f64;
            let codes: Vec<u64> = (0..80).map(|i| fam.encode_point(crate::data::FeatRef::Dense(xm.row(i)))).collect();
            for i in 0..80 {
                for j in 0..80 {
                    let agree = k as i32 - 2 * crate::hash::codes::hamming(codes[i], codes[j], k) as i32;
                    let fit = agree as f64 / k as f64 - s.get(i, j) as f64;
                    q += fit * fit;
                }
            }
            q
        };
        let q_lbh = q_of(&lbh);
        let bh = crate::hash::BhHash::sample(24, k, &mut rng);
        let q_bh = q_of(&bh);
        assert!(
            q_lbh < q_bh,
            "LBH similarity fit {q_lbh} should beat random BH {q_bh}"
        );
    }
}
