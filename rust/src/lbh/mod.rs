//! LBH-Hash training (§4 of the paper).
//!
//! Learns k bilinear hash functions `h_j(z) = sgn(u_jᵀ z zᵀ v_j)` so that
//! `(1/k)·B·Bᵀ ≈ S`, where `S` encodes the saturated pairwise similarity
//! `2|cos θ| − 1` of a training subsample (eq. 12) and `B` is the ±1 code
//! matrix. The solve is the paper's greedy per-bit scheme:
//!
//! 1. residue `R₀ = k·S`; for each bit j minimize
//!    `g(u_j, v_j) = −b_jᵀ R_{j−1} b_j` (eq. 15);
//! 2. replace sgn with the sigmoid `φ(x) = 2/(1+e^{−x}) − 1` giving the
//!    smooth surrogate `g̃ = −b̃ᵀR b̃` (eq. 16–17) with analytic gradient
//!    `∇g̃ = −[X Σ Xᵀv; X Σ Xᵀu]`, `Σ = diag((R b̃) ⊙ (1 − b̃⊙b̃))` (eq. 18);
//! 3. Nesterov-accelerated gradient descent from the *random projection*
//!    warm start (the same draw the randomized BH-Hash would use);
//! 4. `R_j = R_{j−1} − b_j b_jᵀ` and continue.
//!
//! The native Rust implementation below is the reference path; the PJRT
//! artifact `lbh_step` (see `python/compile/model.py` and
//! `crate::runtime`) executes the same step as a fused XLA computation and
//! is parity-tested against this module. Both paths now share one generic
//! stepper loop ([`LbhTrainer::train_core`]) — the native stepper computes
//! the Nesterov step in-process, the PJRT stepper dispatches the fused
//! artifact; residue updates, thresholds and discrete bit extraction are
//! common code.
//!
//! The O(m²) inner products (surrogate cost/gradient, residue update) are
//! data-parallel over a [`crate::par::Pool`] with fixed row chunks, so
//! training output is **bit-identical for every `workers` setting** (see
//! `docs/PARALLEL.md`).

use crate::data::FeatureStore;
use crate::hash::{LbhHash, ProjectionPairs};
use crate::linalg::{axpy, dot, Mat};
use crate::par::Pool;
use crate::rng::Rng;

/// Rows per parallel work unit inside the trainer. Fixed (never derived
/// from the worker count) so float accumulation order is identical for
/// every `workers` setting.
const TRAIN_CHUNK: usize = 64;

/// Below this sample size the trainer's inner loops run serially even
/// when `workers > 1`: the pool spawns scoped threads per call, and for
/// small m the spawn cost rivals the chunk work (the paper's news
/// profile, m = 500, is in that regime). The gate depends only on the
/// problem size, so results stay bit-identical either way.
pub const TRAIN_PAR_MIN_M: usize = 1024;

/// Minimum reference rows the paper's 5% threshold rule needs.
pub const MIN_THRESHOLD_REFS: usize = 20;

/// Default thresholds used when the reference set is too small for the
/// 5% quantile rule: saturate |cos| ≥ 0.9 to similar, ≤ 0.1 to dissimilar
/// (the shape the rule converges to on well-spread data).
pub const FALLBACK_T1: f32 = 0.9;
pub const FALLBACK_T2: f32 = 0.1;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct LbhTrainConfig {
    /// code length k
    pub bits: usize,
    /// Nesterov iterations per bit
    pub iters_per_bit: usize,
    /// initial learning rate (adapted by backtracking)
    pub lr: f32,
    /// Nesterov momentum
    pub momentum: f32,
    /// similarity saturation thresholds (eq. 12); `None` = the paper's
    /// top/bottom-5% rule computed on the training subsample, falling
    /// back to [`FALLBACK_T1`]/[`FALLBACK_T2`] when fewer than
    /// [`MIN_THRESHOLD_REFS`] reference rows are available
    pub t1: Option<f32>,
    pub t2: Option<f32>,
    /// cap on the reference set used by the threshold rule
    pub threshold_ref_cap: usize,
    /// data-parallel worker threads for the O(m²)/O(md) training loops
    /// (0 = all cores, 1 = serial); the result is identical either way
    pub workers: usize,
}

impl Default for LbhTrainConfig {
    fn default() -> Self {
        LbhTrainConfig {
            bits: 16,
            iters_per_bit: 300,
            lr: 1e-3,
            momentum: 0.9,
            t1: None,
            t2: None,
            threshold_ref_cap: 4000,
            workers: 0,
        }
    }
}

/// Diagnostics from a training run.
#[derive(Clone, Debug, Default)]
pub struct LbhTrainStats {
    /// surrogate cost g̃ after optimizing each bit
    pub bit_costs: Vec<f32>,
    /// discrete cost −b_jᵀR b_j after each bit
    pub discrete_costs: Vec<f32>,
    /// ‖R‖_F² before/after all bits (residual energy captured)
    pub residue_before: f64,
    pub residue_after: f64,
    /// thresholds actually used
    pub t1: f32,
    pub t2: f32,
    /// whether the documented fallback thresholds were used because the
    /// reference set was smaller than [`MIN_THRESHOLD_REFS`]
    pub fallback_thresholds: bool,
    pub train_secs: f64,
}

/// φ(x) = 2/(1+e^{−x}) − 1 = tanh(x/2) — the paper's smooth sign surrogate.
#[inline]
pub fn sigmoid_pm(x: f32) -> f32 {
    (0.5 * x).tanh()
}

/// The similarity matrix S of eq. (12) over unit-normalized rows `xm`,
/// given thresholds t1 > t2.
pub fn similarity_matrix(xm: &Mat, t1: f32, t2: f32) -> Mat {
    let m = xm.rows;
    let mut s = Mat::zeros(m, m);
    for i in 0..m {
        for ip in i..m {
            let c = dot(xm.row(i), xm.row(ip)).abs().min(1.0);
            let v = if c >= t1 {
                1.0
            } else if c <= t2 {
                -1.0
            } else {
                2.0 * c - 1.0
            };
            s.set(i, ip, v);
            s.set(ip, i, v);
        }
    }
    s
}

/// The paper's threshold rule: compute the absolute cosine matrix between
/// the m samples and a reference set, average the top 5% per row → t1,
/// average the bottom 5% per row → t2.
pub fn threshold_rule(xm: &Mat, reference: &Mat) -> (f32, f32) {
    let m = xm.rows;
    let n = reference.rows;
    assert!(
        n >= MIN_THRESHOLD_REFS,
        "reference set too small for 5% quantiles (use the trainer's fallback)"
    );
    let top_k = (n as f64 * 0.05).ceil() as usize;
    let bot_k = top_k;
    let mut t1_acc = 0.0f64;
    let mut t2_acc = 0.0f64;
    let mut row: Vec<f32> = Vec::with_capacity(n);
    for i in 0..m {
        row.clear();
        for j in 0..n {
            row.push(dot(xm.row(i), reference.row(j)).abs().min(1.0));
        }
        row.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let top: f32 = row[n - top_k..].iter().sum::<f32>() / top_k as f32;
        let bot: f32 = row[..bot_k].iter().sum::<f32>() / bot_k as f32;
        t1_acc += top as f64;
        t2_acc += bot as f64;
    }
    let mut t1 = (t1_acc / m as f64) as f32;
    let mut t2 = (t2_acc / m as f64) as f32;
    // keep 0 < t2 < t1 < 1 well-posed even on degenerate data
    t1 = t1.clamp(0.05, 0.999);
    t2 = t2.clamp(1e-4, t1 - 1e-3);
    (t1, t2)
}

/// Evaluate b̃ (sigmoid codes) and the surrogate cost −b̃ᵀRb̃ at (u, v).
/// Public so the PJRT `lbh_step` artifact can be parity-tested against it.
pub fn surrogate_eval(xm: &Mat, r: &Mat, u: &[f32], v: &[f32], btil: &mut Vec<f32>) -> f32 {
    surrogate_eval_pool(xm, r, u, v, btil, &Pool::serial())
}

/// [`surrogate_eval`] with the per-row work fanned out over `pool`.
/// Cost partials accumulate per [`TRAIN_CHUNK`] and fold in chunk order,
/// so the result is bit-identical for any worker count.
pub fn surrogate_eval_pool(
    xm: &Mat,
    r: &Mat,
    u: &[f32],
    v: &[f32],
    btil: &mut Vec<f32>,
    pool: &Pool,
) -> f32 {
    let m = xm.rows;
    btil.clear();
    btil.resize(m, 0.0);
    pool.for_each_mut(btil.as_mut_slice(), TRAIN_CHUNK, |c, part| {
        let row0 = c * TRAIN_CHUNK;
        for (off, b) in part.iter_mut().enumerate() {
            let xi = xm.row(row0 + off);
            *b = sigmoid_pm(dot(xi, u) * dot(xi, v));
        }
    });
    // cost = −b̃ᵀ R b̃
    let b = &*btil;
    pool.map_reduce(
        m,
        TRAIN_CHUNK,
        |range| {
            let mut part = 0.0f32;
            for i in range {
                part -= b[i] * dot(r.row(i), b);
            }
            part
        },
        |a, c| a + c,
    )
    .unwrap_or(0.0)
}

/// Gradient of the surrogate at (u, v) (eq. 18). Returns (g_u, g_v).
/// Public so the PJRT `lbh_step` artifact can be parity-tested against it.
pub fn surrogate_grad(xm: &Mat, r: &Mat, u: &[f32], v: &[f32]) -> (Vec<f32>, Vec<f32>) {
    surrogate_grad_pool(xm, r, u, v, &Pool::serial())
}

/// [`surrogate_grad`] with the O(m·d) projection pass, the O(m²) Σ pass
/// and the gradient accumulation fanned out over `pool`. Per-chunk
/// gradient partials fold in chunk order — bit-identical for any worker
/// count.
pub fn surrogate_grad_pool(
    xm: &Mat,
    r: &Mat,
    u: &[f32],
    v: &[f32],
    pool: &Pool,
) -> (Vec<f32>, Vec<f32>) {
    let m = xm.rows;
    let d = xm.cols;
    // pass 1: per-row projections (x_i·u, x_i·v) and sigmoid code b̃_i
    let proj: Vec<(f32, f32, f32)> = pool
        .map(m, TRAIN_CHUNK, |range| {
            range
                .map(|i| {
                    let xi = xm.row(i);
                    let a = dot(xi, u);
                    let b = dot(xi, v);
                    (a, b, sigmoid_pm(a * b))
                })
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    let btil: Vec<f32> = proj.iter().map(|p| p.2).collect();
    // pass 2: σ_i = (R b̃)_i · (1 − b̃_i²)
    let sigma: Vec<f32> = pool
        .map(m, TRAIN_CHUNK, |range| {
            range
                .map(|i| dot(r.row(i), &btil) * (1.0 - btil[i] * btil[i]))
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    // pass 3: g_u = −Σ_i σ_i (x_i·v) x_i ; g_v = −Σ_i σ_i (x_i·u) x_i,
    // accumulated per chunk and folded in chunk order
    let parts: Vec<(Vec<f32>, Vec<f32>)> = pool.map(m, TRAIN_CHUNK, |range| {
        let mut gu = vec![0.0f32; d];
        let mut gv = vec![0.0f32; d];
        for i in range {
            let xi = xm.row(i);
            let (pu_i, pv_i, _) = proj[i];
            axpy(-sigma[i] * pv_i, xi, &mut gu);
            axpy(-sigma[i] * pu_i, xi, &mut gv);
        }
        (gu, gv)
    });
    let mut gu = vec![0.0f32; d];
    let mut gv = vec![0.0f32; d];
    for (cu, cv) in parts {
        axpy(1.0, &cu, &mut gu);
        axpy(1.0, &cv, &mut gv);
    }
    (gu, gv)
}

/// Discrete bit vector b_j = sgn(Xu ⊙ Xv) and discrete cost −bᵀRb.
fn discrete_eval(xm: &Mat, r: &Mat, u: &[f32], v: &[f32], pool: &Pool) -> (Vec<f32>, f32) {
    let m = xm.rows;
    let b: Vec<f32> = pool
        .map(m, TRAIN_CHUNK, |range| {
            range
                .map(|i| {
                    let xi = xm.row(i);
                    if dot(xi, u) * dot(xi, v) >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                })
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    let cost = pool
        .map_reduce(
            m,
            TRAIN_CHUNK,
            |range| {
                let mut part = 0.0f32;
                for i in range {
                    part -= b[i] * dot(r.row(i), &b);
                }
                part
            },
            |a, c| a + c,
        )
        .unwrap_or(0.0);
    (b, cost)
}

/// R ← R − b bᵀ, row-chunked over the pool (each element is written by
/// exactly one chunk, so the update is trivially deterministic).
fn residue_update(r: &mut Mat, b: &[f32], pool: &Pool) {
    let m = r.cols;
    pool.for_each_mut(&mut r.data, TRAIN_CHUNK * m, |c, part| {
        let row0 = c * TRAIN_CHUNK;
        for (local, row) in part.chunks_mut(m).enumerate() {
            let bi = b[row0 + local];
            for (x, &bj) in row.iter_mut().zip(b) {
                *x -= bi * bj;
            }
        }
    });
}

/// The LBH trainer.
pub struct LbhTrainer {
    pub cfg: LbhTrainConfig,
}

impl LbhTrainer {
    pub fn new(cfg: LbhTrainConfig) -> Self {
        LbhTrainer { cfg }
    }

    /// Train on `sample_idx` rows of `feats`. `reference_idx` feeds the
    /// threshold rule (pass the same indices to self-reference, or a wider
    /// sample of the database as the paper does). Runs the native stepper;
    /// `cfg.workers` controls data parallelism (same result either way).
    pub fn train(
        &self,
        feats: &FeatureStore,
        sample_idx: &[usize],
        reference_idx: &[usize],
        rng: &mut Rng,
    ) -> (LbhHash, LbhTrainStats) {
        // gate BEFORE building the step closure so the per-iteration
        // surrogate calls (the dominant cost) honor the small-sample rule
        let pool = if sample_idx.len() < TRAIN_PAR_MIN_M {
            Pool::serial()
        } else {
            Pool::new(self.cfg.workers)
        };
        let mut step_buf: Vec<f32> = Vec::new();
        let step = |xm: &Mat,
                    r: &Mat,
                    u: &[f32],
                    v: &[f32],
                    u_prev: &[f32],
                    v_prev: &[f32],
                    lr: f32,
                    mu: f32| {
            // Nesterov lookahead y = x + μ(x − x_prev), gradient step from y
            let yu: Vec<f32> = u.iter().zip(u_prev).map(|(x, p)| x + mu * (x - p)).collect();
            let yv: Vec<f32> = v.iter().zip(v_prev).map(|(x, p)| x + mu * (x - p)).collect();
            let (gu, gv) = surrogate_grad_pool(xm, r, &yu, &yv, &pool);
            let u_new: Vec<f32> = yu.iter().zip(&gu).map(|(y, g)| y - lr * g).collect();
            let v_new: Vec<f32> = yv.iter().zip(&gv).map(|(y, g)| y - lr * g).collect();
            let cost = surrogate_eval_pool(xm, r, &u_new, &v_new, &mut step_buf, &pool);
            Ok::<_, anyhow::Error>((u_new, v_new, cost))
        };
        self.train_core(feats, sample_idx, reference_idx, rng, sample_idx.len(), step, true, &pool)
            .unwrap_or_else(|e| panic!("native LBH training failed: {e:#}"))
    }

    /// PJRT-backed training: identical algorithm to [`Self::train`] but
    /// every Nesterov step executes the fused `lbh_step_<profile>` XLA
    /// artifact (L2 graph + L1 Pallas gradient kernels). The sample is
    /// zero-padded to the artifact's fixed m — padding is gradient- and
    /// cost-neutral. Residue updates, thresholds and the discrete bit
    /// extraction run on the shared native path.
    pub fn train_pjrt(
        &self,
        stepper: &crate::runtime::LbhStepper<'_>,
        feats: &FeatureStore,
        sample_idx: &[usize],
        reference_idx: &[usize],
        rng: &mut Rng,
    ) -> anyhow::Result<(LbhHash, LbhTrainStats)> {
        anyhow::ensure!(
            feats.dim() == stepper.dim,
            "dim {} != artifact {}",
            feats.dim(),
            stepper.dim
        );
        let pool = Pool::new(self.cfg.workers);
        // warm_start_eval = false: the stepper's XLA-computed costs are
        // the only costs comparable to each other (native vs XLA float
        // paths differ at the ~1e-2 level), so the best-so-far baseline
        // must come from the same engine
        self.train_core(
            feats,
            sample_idx,
            reference_idx,
            rng,
            stepper.m,
            |xm, r, u, v, u_prev, v_prev, lr, mu| stepper.step(xm, r, u, v, u_prev, v_prev, lr, mu),
            false,
            &pool,
        )
    }

    /// The shared per-bit solve both entry points drive: build the
    /// (possibly padded) sample matrix, pick thresholds, then for each bit
    /// run `step` under the adaptive-lr Nesterov loop, extract the
    /// discrete bit and downdate the residue. `pad_to` is the stepper's
    /// fixed row count (`sample_idx.len()` when no padding is needed);
    /// rows `ms..pad_to` stay zero and are gradient- and cost-neutral.
    /// `warm_start_eval` seeds best-so-far from a native surrogate eval of
    /// the warm start — pass false when the stepper's costs come from a
    /// different float engine (PJRT) and are not comparable to it.
    #[allow(clippy::too_many_arguments)]
    fn train_core<S>(
        &self,
        feats: &FeatureStore,
        sample_idx: &[usize],
        reference_idx: &[usize],
        rng: &mut Rng,
        pad_to: usize,
        mut step: S,
        warm_start_eval: bool,
        pool: &Pool,
    ) -> anyhow::Result<(LbhHash, LbhTrainStats)>
    where
        S: FnMut(
            &Mat,
            &Mat,
            &[f32],
            &[f32],
            &[f32],
            &[f32],
            f32,
            f32,
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, f32)>,
    {
        let t0 = std::time::Instant::now();
        let d = feats.dim();
        let ms = sample_idx.len().min(pad_to);
        anyhow::ensure!(ms >= 8, "need at least 8 training samples");
        let m_art = pad_to.max(ms);
        // small samples: per-call thread-spawn cost rivals the chunk work,
        // so drop to the serial twin (identical result, see TRAIN_PAR_MIN_M)
        let serial = Pool::serial();
        let pool = if ms < TRAIN_PAR_MIN_M { &serial } else { pool };
        // densify + unit-normalize the training subsample (padded rows
        // stay zero)
        let mut xm = Mat::zeros(m_art, d);
        for (row, &i) in sample_idx.iter().take(ms).enumerate() {
            feats.row(i).scatter_into(xm.row_mut(row));
        }
        xm.l2_normalize_rows();
        // the real (unpadded) sample for thresholds, S and discrete bits;
        // without padding that is xm itself — no copy
        let xs_pad: Option<Mat> = if m_art > ms {
            let mut xs = Mat::zeros(ms, d);
            xs.data.copy_from_slice(&xm.data[..ms * d]);
            Some(xs)
        } else {
            None
        };
        let xs: &Mat = xs_pad.as_ref().unwrap_or(&xm);

        // thresholds
        let mut fallback = false;
        let (t1, t2) = match (self.cfg.t1, self.cfg.t2) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                // clamp the configured cap up to the rule's minimum, then
                // down to what is actually available — only a genuinely
                // small reference set (never a small configured cap)
                // triggers the fallback
                let cap =
                    self.cfg.threshold_ref_cap.max(MIN_THRESHOLD_REFS).min(reference_idx.len());
                if cap < MIN_THRESHOLD_REFS {
                    // too few reference rows for the 5% quantile rule:
                    // fall back to the documented defaults instead of
                    // crashing deep inside threshold_rule
                    fallback = true;
                    eprintln!(
                        "lbh: only {} reference rows (< {MIN_THRESHOLD_REFS} needed for \
                         the 5% threshold rule); using default thresholds \
                         t1={FALLBACK_T1}, t2={FALLBACK_T2}",
                        reference_idx.len()
                    );
                    (FALLBACK_T1, FALLBACK_T2)
                } else {
                    let mut xr = Mat::zeros(cap, d);
                    for (row, &i) in reference_idx.iter().take(cap).enumerate() {
                        feats.row(i).scatter_into(xr.row_mut(row));
                    }
                    xr.l2_normalize_rows();
                    threshold_rule(xs, &xr)
                }
            }
        };
        anyhow::ensure!(t2 < t1, "thresholds must satisfy t2 < t1 (t1={t1}, t2={t2})");

        let s = similarity_matrix(xs, t1, t2);
        let k = self.cfg.bits;
        // R₀ = k·S on the real sample; the padded copy handed to the
        // stepper is refreshed per bit
        let mut r_small = Mat::zeros(ms, ms);
        for (dst, src) in r_small.data.iter_mut().zip(s.data.iter()) {
            *dst = k as f32 * src;
        }
        let residue_before =
            r_small.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
        let mut stats = LbhTrainStats {
            t1,
            t2,
            residue_before,
            fallback_thresholds: fallback,
            ..Default::default()
        };
        let mut u_all = Mat::zeros(k, d);
        let mut v_all = Mat::zeros(k, d);
        let mut btil_buf: Vec<f32> = Vec::with_capacity(m_art);
        // the padded residue is only materialized when padding is real —
        // the native path steps directly on r_small
        let mut r_pad: Option<Mat> = if m_art > ms { Some(Mat::zeros(m_art, m_art)) } else { None };

        for j in 0..k {
            // refresh the stepper's residue from the live one
            let r_step: &Mat = match r_pad.as_mut() {
                Some(rp) => {
                    for row in 0..m_art {
                        let dst = rp.row_mut(row);
                        if row < ms {
                            dst[..ms].copy_from_slice(r_small.row(row));
                            for x in dst[ms..].iter_mut() {
                                *x = 0.0;
                            }
                        } else {
                            for x in dst.iter_mut() {
                                *x = 0.0;
                            }
                        }
                    }
                    rp
                }
                None => &r_small,
            };
            // random-projection warm start (what h_j^B would have used)
            let mut u = rng.gauss_vec(d);
            let mut v = rng.gauss_vec(d);
            let mut u_prev = u.clone();
            let mut v_prev = v.clone();
            let mut lr = self.cfg.lr;
            let mu = self.cfg.momentum;
            let mut best_cost = if warm_start_eval {
                surrogate_eval_pool(&xm, r_step, &u, &v, &mut btil_buf, pool)
            } else {
                f32::INFINITY
            };
            let mut best_u = u.clone();
            let mut best_v = v.clone();
            for _t in 0..self.cfg.iters_per_bit {
                let (u_new, v_new, cost) = step(&xm, r_step, &u, &v, &u_prev, &v_prev, lr, mu)?;
                u_prev = std::mem::replace(&mut u, u_new);
                v_prev = std::mem::replace(&mut v, v_new);
                if cost < best_cost {
                    best_cost = cost;
                    best_u.copy_from_slice(&u);
                    best_v.copy_from_slice(&v);
                    // mild step growth: self-tunes lr across problem scales
                    lr *= 1.02;
                } else if !cost.is_finite() || cost > best_cost.abs() * 4.0 + best_cost {
                    // diverged: restart from best with smaller step
                    lr *= 0.5;
                    u.copy_from_slice(&best_u);
                    v.copy_from_slice(&best_v);
                    u_prev.copy_from_slice(&best_u);
                    v_prev.copy_from_slice(&best_v);
                    if lr < 1e-6 {
                        break;
                    }
                }
            }
            let (b, dcost) = discrete_eval(xs, &r_small, &best_u, &best_v, pool);
            stats.bit_costs.push(best_cost);
            stats.discrete_costs.push(dcost);
            u_all.row_mut(j).copy_from_slice(&best_u);
            v_all.row_mut(j).copy_from_slice(&best_v);
            // R ← R − b bᵀ
            residue_update(&mut r_small, &b, pool);
        }
        stats.residue_after =
            r_small.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
        stats.train_secs = t0.elapsed().as_secs_f64();
        Ok((LbhHash::from_pairs(ProjectionPairs { u: u_all, v: v_all }), stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::HashFamily;
    use crate::testing::forall;

    #[test]
    fn sigmoid_matches_definition() {
        for &x in &[-8.0f32, -1.0, 0.0, 0.5, 6.5] {
            let direct = 2.0 / (1.0 + (-x).exp()) - 1.0;
            assert!((sigmoid_pm(x) - direct).abs() < 1e-6, "x={x}");
        }
        // approximates sgn for |x| > 6 (paper's remark)
        assert!(sigmoid_pm(7.0) > 0.95);
        assert!(sigmoid_pm(-7.0) < -0.95);
    }

    #[test]
    fn similarity_matrix_properties() {
        forall("S symmetric, unit diagonal, in [-1,1]", 16, |rng| {
            let m = rng.range(4, 24);
            let d = rng.range(4, 16);
            let mut xm = Mat::from_vec(m, d, rng.gauss_vec(m * d));
            xm.l2_normalize_rows();
            let s = similarity_matrix(&xm, 0.8, 0.2);
            for i in 0..m {
                crate::prop_assert!(s.get(i, i) == 1.0, "diag {i} = {}", s.get(i, i));
                for j in 0..m {
                    let v = s.get(i, j);
                    crate::prop_assert!(v == s.get(j, i), "symmetry");
                    crate::prop_assert!((-1.0..=1.0).contains(&v), "range {v}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn similarity_saturation() {
        // identical rows → 1; orthogonal rows → −1 with t2 above 0
        let xm = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let s = similarity_matrix(&xm, 0.9, 0.1);
        assert_eq!(s.get(0, 1), -1.0);
        let xm2 = Mat::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]);
        let s2 = similarity_matrix(&xm2, 0.9, 0.1);
        assert_eq!(s2.get(0, 1), 1.0);
    }

    #[test]
    fn threshold_rule_ordering() {
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let mut xm = Mat::zeros(50, 16);
        for i in 0..50 {
            ds.features().row(i).scatter_into(xm.row_mut(i));
        }
        let mut xr = Mat::zeros(300, 16);
        for i in 0..300 {
            ds.features().row(i).scatter_into(xr.row_mut(i));
        }
        let (t1, t2) = threshold_rule(&xm, &xr);
        assert!(t2 < t1, "t1={t1} t2={t2}");
        assert!(t1 <= 1.0 && t2 > 0.0);
    }

    #[test]
    fn small_reference_set_falls_back_instead_of_panicking() {
        // regression: reference_idx.len() < 20 used to reach
        // threshold_rule's n >= 20 assert and crash deep in training
        let mut rng = Rng::seed_from_u64(31);
        let ds = test_blobs(100, 12, 2, &mut rng);
        let sample: Vec<usize> = (0..32).collect();
        let tiny_refs: Vec<usize> = (0..10).collect();
        let trainer = LbhTrainer::new(LbhTrainConfig {
            bits: 4,
            iters_per_bit: 10,
            ..Default::default()
        });
        let (_h, stats) = trainer.train(ds.features(), &sample, &tiny_refs, &mut rng);
        assert!(stats.fallback_thresholds);
        assert_eq!(stats.t1, FALLBACK_T1);
        assert_eq!(stats.t2, FALLBACK_T2);
        // a healthy reference set keeps the quantile rule
        let refs: Vec<usize> = (0..100).collect();
        let (_h2, stats2) = trainer.train(ds.features(), &sample, &refs, &mut rng);
        assert!(!stats2.fallback_thresholds);
        // a small *configured cap* with plenty of references is clamped
        // up to the rule's minimum, not silently degraded to the fallback
        let capped = LbhTrainer::new(LbhTrainConfig {
            bits: 4,
            iters_per_bit: 10,
            threshold_ref_cap: 10,
            ..Default::default()
        });
        let (_h3, stats3) = capped.train(ds.features(), &sample, &refs, &mut rng);
        assert!(!stats3.fallback_thresholds);
    }

    // full-trainer parity across worker counts (above TRAIN_PAR_MIN_M) is
    // covered by the integration suite in rust/tests/batch_parallel.rs.

    #[test]
    fn surrogate_pool_parity() {
        let mut rng = Rng::seed_from_u64(41);
        let m = 200; // > TRAIN_CHUNK so chunking actually happens
        let d = 12;
        let mut xm = Mat::from_vec(m, d, rng.gauss_vec(m * d));
        xm.l2_normalize_rows();
        let s = similarity_matrix(&xm, 0.8, 0.2);
        let u = rng.gauss_vec(d);
        let v = rng.gauss_vec(d);
        let mut b1 = Vec::new();
        let mut b4 = Vec::new();
        let c1 = surrogate_eval(&xm, &s, &u, &v, &mut b1);
        let c4 = surrogate_eval_pool(&xm, &s, &u, &v, &mut b4, &Pool::new(4));
        assert_eq!(c1.to_bits(), c4.to_bits());
        assert_eq!(b1, b4);
        let (gu1, gv1) = surrogate_grad(&xm, &s, &u, &v);
        let (gu4, gv4) = surrogate_grad_pool(&xm, &s, &u, &v, &Pool::new(4));
        assert_eq!(gu1, gu4);
        assert_eq!(gv1, gv4);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::seed_from_u64(4);
        let m = 12;
        let d = 6;
        let mut xm = Mat::from_vec(m, d, rng.gauss_vec(m * d));
        xm.l2_normalize_rows();
        let s = similarity_matrix(&xm, 0.8, 0.2);
        let mut r = s.clone();
        crate::linalg::scal(8.0, &mut r.data);
        let u = rng.gauss_vec(d);
        let v = rng.gauss_vec(d);
        let (gu, gv) = surrogate_grad(&xm, &r, &u, &v);
        let mut buf = Vec::new();
        let eps = 1e-3f32;
        for t in 0..d {
            let mut up = u.clone();
            up[t] += eps;
            let mut um = u.clone();
            um[t] -= eps;
            let fd =
                (surrogate_eval(&xm, &r, &up, &v, &mut buf) - surrogate_eval(&xm, &r, &um, &v, &mut buf))
                    / (2.0 * eps);
            assert!(
                (fd - gu[t]).abs() < 2e-2 * (1.0 + fd.abs().max(gu[t].abs())),
                "du[{t}]: fd {fd} vs analytic {}",
                gu[t]
            );
            let mut vp = v.clone();
            vp[t] += eps;
            let mut vm = v.clone();
            vm[t] -= eps;
            let fdv =
                (surrogate_eval(&xm, &r, &u, &vp, &mut buf) - surrogate_eval(&xm, &r, &u, &vm, &mut buf))
                    / (2.0 * eps);
            assert!(
                (fdv - gv[t]).abs() < 2e-2 * (1.0 + fdv.abs().max(gv[t].abs())),
                "dv[{t}]: fd {fdv} vs analytic {}",
                gv[t]
            );
        }
    }

    #[test]
    fn training_reduces_residue_and_cost() {
        let mut rng = Rng::seed_from_u64(5);
        let ds = test_blobs(200, 24, 4, &mut rng);
        let idx: Vec<usize> = (0..64).collect();
        let refs: Vec<usize> = (0..200).collect();
        let trainer = LbhTrainer::new(LbhTrainConfig { bits: 8, iters_per_bit: 60, ..Default::default() });
        let (_h, stats) = trainer.train(ds.features(), &idx, &refs, &mut rng);
        assert!(
            stats.residue_after < stats.residue_before,
            "residue {} → {}",
            stats.residue_before,
            stats.residue_after
        );
        assert_eq!(stats.bit_costs.len(), 8);
        // discrete cost −bᵀRb is bounded below by −max|R|·m² ≥ −k·m²
        // (|R| entries start at k·|S| ≤ k and shrink as bits are fitted)
        for &c in &stats.discrete_costs {
            assert!(c >= -(8.0 * 64.0f32 * 64.0), "cost {c}");
        }
    }

    #[test]
    fn learned_beats_random_on_similarity_fit() {
        // The defining property: (1/k)BBᵀ should fit S better than random
        // bilinear projections (this is exactly objective Q of the paper).
        let mut rng = Rng::seed_from_u64(6);
        let ds = test_blobs(240, 24, 4, &mut rng);
        let idx: Vec<usize> = (0..80).collect();
        let refs: Vec<usize> = (0..240).collect();
        let k = 12;
        let trainer = LbhTrainer::new(LbhTrainConfig { bits: k, iters_per_bit: 80, ..Default::default() });
        let (lbh, stats) = trainer.train(ds.features(), &idx, &refs, &mut rng);
        // Build Xm and S with the trainer's thresholds.
        let mut xm = Mat::zeros(80, 24);
        for (r, &i) in idx.iter().enumerate() {
            ds.features().row(i).scatter_into(xm.row_mut(r));
        }
        xm.l2_normalize_rows();
        let s = similarity_matrix(&xm, stats.t1, stats.t2);
        let q_of = |fam: &dyn HashFamily| -> f64 {
            let mut q = 0.0f64;
            let codes: Vec<u64> = (0..80).map(|i| fam.encode_point(crate::data::FeatRef::Dense(xm.row(i)))).collect();
            for i in 0..80 {
                for j in 0..80 {
                    let agree = k as i32 - 2 * crate::hash::codes::hamming(codes[i], codes[j], k) as i32;
                    let fit = agree as f64 / k as f64 - s.get(i, j) as f64;
                    q += fit * fit;
                }
            }
            q
        };
        let q_lbh = q_of(&lbh);
        let bh = crate::hash::BhHash::sample(24, k, &mut rng);
        let q_bh = q_of(&bh);
        assert!(
            q_lbh < q_bh,
            "LBH similarity fit {q_lbh} should beat random BH {q_bh}"
        );
    }
}
