//! Injectable fault plans for the WAL's disk I/O — the error layer the
//! replication fault tests drive.
//!
//! A [`FaultPlan`] wraps the two syscalls the durability contract rests
//! on — the segment `write_all` and the `fsync` — with a counter and a
//! trigger point. Once the trigger fires the plan keeps failing (a dead
//! disk does not come back), which exercises exactly the sticky-error
//! fail-stop path of [`super::log::Wal`]: the op that hit the fault is
//! refused to its caller (never acknowledged), every later op is refused
//! with the same message, and — because the durable watermark only
//! advances after a successful fsync — the replication stream never
//! ships the un-fsynced suffix to a replica.
//!
//! Plans are plain shared state (`Arc<FaultPlan>` in
//! [`super::WalConfig::faults`]), so a test can arm the next fsync while
//! the writer thread is live:
//!
//! ```
//! use chh::wal::FaultPlan;
//! let plan = FaultPlan::new();
//! plan.fail_fsync_at(plan.fsyncs_seen() + 1); // the very next fsync dies
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Counting fault injector for WAL writes and fsyncs. All counters are
/// 1-based: `fail_write_at(n)` makes the n-th (and every later) write
/// fail; 0 disables the trigger.
#[derive(Debug, Default)]
pub struct FaultPlan {
    writes_seen: AtomicU64,
    fsyncs_seen: AtomicU64,
    fail_write_at: AtomicU64,
    fail_fsync_at: AtomicU64,
}

fn injected(what: &str) -> std::io::Error {
    std::io::Error::other(format!("injected wal {what} fault"))
}

impl FaultPlan {
    /// A disarmed plan (counts, never fails) behind the `Arc` the config
    /// wants.
    pub fn new() -> Arc<FaultPlan> {
        Arc::new(FaultPlan::default())
    }

    /// Fail the `n`-th write (1-based) and every write after it; 0 disarms.
    pub fn fail_write_at(&self, n: u64) {
        self.fail_write_at.store(n, Ordering::SeqCst);
    }

    /// Fail the `n`-th fsync (1-based) and every fsync after it; 0 disarms.
    pub fn fail_fsync_at(&self, n: u64) {
        self.fail_fsync_at.store(n, Ordering::SeqCst);
    }

    /// Writes observed so far (whether or not they were failed).
    pub fn writes_seen(&self) -> u64 {
        self.writes_seen.load(Ordering::SeqCst)
    }

    /// Fsyncs observed so far (whether or not they were failed).
    pub fn fsyncs_seen(&self) -> u64 {
        self.fsyncs_seen.load(Ordering::SeqCst)
    }

    /// Called by the writer before each segment write.
    pub(crate) fn on_write(&self) -> std::io::Result<()> {
        let n = self.writes_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let at = self.fail_write_at.load(Ordering::SeqCst);
        if at != 0 && n >= at {
            return Err(injected("write"));
        }
        Ok(())
    }

    /// Called by the writer before each fsync.
    pub(crate) fn on_fsync(&self) -> std::io::Result<()> {
        let n = self.fsyncs_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let at = self.fail_fsync_at.load(Ordering::SeqCst);
        if at != 0 && n >= at {
            return Err(injected("fsync"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_plan_only_counts() {
        let p = FaultPlan::new();
        for _ in 0..5 {
            p.on_write().unwrap();
            p.on_fsync().unwrap();
        }
        assert_eq!(p.writes_seen(), 5);
        assert_eq!(p.fsyncs_seen(), 5);
    }

    #[test]
    fn trigger_is_sticky() {
        let p = FaultPlan::new();
        p.fail_write_at(3);
        assert!(p.on_write().is_ok());
        assert!(p.on_write().is_ok());
        assert!(p.on_write().is_err(), "third write fails");
        assert!(p.on_write().is_err(), "and stays failed");
        // fsyncs are independent
        assert!(p.on_fsync().is_ok());
        p.fail_fsync_at(p.fsyncs_seen() + 1);
        assert!(p.on_fsync().is_err());
    }
}
