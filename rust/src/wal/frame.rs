//! WAL record framing: CRC32-guarded frames with torn-tail tolerance.
//!
//! Each record is one frame on disk:
//!
//! ```text
//! | payload_len u32 | crc32(payload) u32 | payload |
//! ```
//!
//! All integers little-endian (matching the persist container). The
//! payload's first byte is the op tag; every op has a fixed payload
//! length, so any bit damage is caught twice — by the CRC and by the
//! exact-length decode. Readers treat the first bad frame as the end of
//! the segment ([`read_segment_bytes`]): a crash mid-append leaves a
//! torn tail, and the longest valid prefix is exactly the set of writes
//! that were fully on disk.

/// One durable operation against the online index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Record {
    /// Insert (or upsert) `id` with hash `code`.
    Insert { id: u32, code: u64 },
    /// Remove `id` (idempotent on replay).
    Remove { id: u32 },
    /// A snapshot with this generation covers every preceding record.
    /// Purely a marker for diagnostics/tooling — the manifest is the
    /// authority on which snapshot recovery starts from.
    Checkpoint { gen: u64 },
}

const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;
const OP_CHECKPOINT: u8 = 3;

/// Frame header: payload length + CRC.
pub const FRAME_HEADER: usize = 8;
/// Sanity bound on the length field — real payloads are ≤ 13 bytes, but
/// the reader stays tolerant of future (larger) record kinds up to this.
/// Public so the replication stream can size read windows that always
/// hold at least one whole frame.
pub const MAX_PAYLOAD: usize = 1 << 16;

// ───────────────────────── crc32 (IEEE) ─────────────────────────

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let t = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ───────────────────────── encode ─────────────────────────

fn payload(rec: &Record) -> Vec<u8> {
    match *rec {
        Record::Insert { id, code } => {
            let mut p = Vec::with_capacity(13);
            p.push(OP_INSERT);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&code.to_le_bytes());
            p
        }
        Record::Remove { id } => {
            let mut p = Vec::with_capacity(5);
            p.push(OP_REMOVE);
            p.extend_from_slice(&id.to_le_bytes());
            p
        }
        Record::Checkpoint { gen } => {
            let mut p = Vec::with_capacity(9);
            p.push(OP_CHECKPOINT);
            p.extend_from_slice(&gen.to_le_bytes());
            p
        }
    }
}

/// Append `rec` as one frame to `buf`.
pub fn encode_into(rec: &Record, buf: &mut Vec<u8>) {
    let p = payload(rec);
    buf.extend_from_slice(&(p.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(&p).to_le_bytes());
    buf.extend_from_slice(&p);
}

/// On-disk size of one record's frame.
pub fn frame_len(rec: &Record) -> usize {
    FRAME_HEADER
        + match rec {
            Record::Insert { .. } => 13,
            Record::Remove { .. } => 5,
            Record::Checkpoint { .. } => 9,
        }
}

// ───────────────────────── decode ─────────────────────────

fn decode_payload(p: &[u8]) -> Option<Record> {
    match (p.first().copied()?, p.len()) {
        (OP_INSERT, 13) => Some(Record::Insert {
            id: u32::from_le_bytes(p[1..5].try_into().unwrap()),
            code: u64::from_le_bytes(p[5..13].try_into().unwrap()),
        }),
        (OP_REMOVE, 5) => Some(Record::Remove {
            id: u32::from_le_bytes(p[1..5].try_into().unwrap()),
        }),
        (OP_CHECKPOINT, 9) => Some(Record::Checkpoint {
            gen: u64::from_le_bytes(p[1..9].try_into().unwrap()),
        }),
        _ => None,
    }
}

/// Result of scanning one segment's bytes.
#[derive(Debug)]
pub struct SegmentRead {
    /// the valid record prefix, in append order
    pub records: Vec<Record>,
    /// bytes consumed by that prefix (the logical truncation point)
    pub valid_bytes: usize,
    /// whether bytes past the prefix exist (torn tail or corruption)
    pub torn: bool,
}

/// Decode frames until the first bad one (short header, absurd length,
/// CRC mismatch, or unknown op) and stop there. Never errors: a damaged
/// or truncated segment yields its longest valid prefix.
pub fn read_segment_bytes(data: &[u8]) -> SegmentRead {
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        if pos == data.len() {
            return SegmentRead { records, valid_bytes: pos, torn: false };
        }
        if pos + FRAME_HEADER > data.len() {
            break;
        }
        let len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap());
        if len == 0 || len > MAX_PAYLOAD || pos + FRAME_HEADER + len > data.len() {
            break;
        }
        let p = &data[pos + FRAME_HEADER..pos + FRAME_HEADER + len];
        if crc32(p) != crc {
            break;
        }
        let Some(rec) = decode_payload(p) else { break };
        records.push(rec);
        pos += FRAME_HEADER + len;
    }
    SegmentRead { records, valid_bytes: pos, torn: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<Record> {
        vec![
            Record::Insert { id: 7, code: 0xDEAD_BEEF },
            Record::Remove { id: 7 },
            Record::Insert { id: u32::MAX, code: u64::MAX },
            Record::Checkpoint { gen: 42 },
            Record::Insert { id: 0, code: 0 },
        ]
    }

    #[test]
    fn crc32_known_vectors() {
        // standard test vector for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_all_record_kinds() {
        let recs = sample_records();
        let mut buf = Vec::new();
        for r in &recs {
            let before = buf.len();
            encode_into(r, &mut buf);
            assert_eq!(buf.len() - before, frame_len(r), "frame_len matches encoding");
        }
        let read = read_segment_bytes(&buf);
        assert_eq!(read.records, recs);
        assert_eq!(read.valid_bytes, buf.len());
        assert!(!read.torn);
    }

    #[test]
    fn truncation_at_every_byte_yields_a_frame_prefix() {
        let recs = sample_records();
        let mut buf = Vec::new();
        let mut boundaries = vec![0usize];
        for r in &recs {
            encode_into(r, &mut buf);
            boundaries.push(buf.len());
        }
        for cut in 0..=buf.len() {
            let read = read_segment_bytes(&buf[..cut]);
            // the number of whole frames below the cut
            let want = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
            assert_eq!(read.records.len(), want, "cut at byte {cut}");
            assert_eq!(read.records[..], recs[..want]);
            assert_eq!(read.valid_bytes, boundaries[want]);
            assert_eq!(read.torn, cut != boundaries[want]);
        }
    }

    #[test]
    fn corruption_stops_at_the_damaged_frame() {
        let recs = sample_records();
        let mut buf = Vec::new();
        let mut boundaries = vec![0usize];
        for r in &recs {
            encode_into(r, &mut buf);
            boundaries.push(buf.len());
        }
        for pos in 0..buf.len() {
            let mut bad = buf.clone();
            bad[pos] ^= 0x5A;
            let read = read_segment_bytes(&bad);
            // the frame containing the flipped byte is the first loss
            let frame = boundaries.iter().filter(|&&b| b <= pos).count() - 1;
            assert!(
                read.records.len() <= frame,
                "flip at {pos}: got {} records, damage was in frame {frame}",
                read.records.len()
            );
            assert_eq!(read.records[..], recs[..read.records.len()]);
            assert!(read.torn);
        }
    }

    #[test]
    fn garbage_is_empty_prefix() {
        let read = read_segment_bytes(b"not a wal segment, definitely");
        assert!(read.records.is_empty());
        assert_eq!(read.valid_bytes, 0);
        assert!(read.torn);
        let empty = read_segment_bytes(b"");
        assert!(empty.records.is_empty() && !empty.torn);
    }
}
