//! Durability for the online index: write-ahead log, background
//! snapshots, and crash recovery.
//!
//! The online serving stack ([`crate::online`], [`crate::server`])
//! accepts `/insert` and `/remove` into RAM; this module makes those
//! mutations survive a crash or redeploy. Three pieces:
//!
//! * **WAL** ([`log`], [`frame`]) — an append-only segmented log of
//!   CRC32-framed records, written by one dedicated thread doing group
//!   commit under a configurable [`FsyncPolicy`]. A torn tail (crash
//!   mid-append) is tolerated on read: the longest valid frame prefix
//!   is the recovered history.
//! * **Snapshots** ([`snapshot`]) — a background (or on-demand)
//!   checkpoint writes the full index via
//!   [`crate::persist::save_sharded`] to a generation-numbered file
//!   (temp + fsync + atomic rename), flips the manifest, and deletes
//!   the WAL segments the snapshot covers.
//! * **Recovery** ([`recover`]) — load the newest valid snapshot, then
//!   idempotently replay the WAL suffix. The recovered index answers
//!   queries bit-identically to the pre-crash index over every
//!   acknowledged operation (`rust/tests/wal_recovery.rs` asserts
//!   exactly this).
//!
//! [`DurableIndex`] is the glue: it journals each mutation *before*
//! applying it to the wrapped [`ShardedIndex`], holding a tiny order
//! lock across enqueue+apply so WAL order always equals apply order —
//! that identity is what makes replay reproduce the live state exactly.
//! The ack (and hence the client's 200) waits on the group-commit
//! ticket, so under `--fsync always` an acknowledged op is never lost.
//!
//! `chh serve-http --wal-dir` wires this under the HTTP front-end;
//! `chh recover` replays a directory standalone. Formats, fsync-policy
//! trade-offs and the operational runbook live in `docs/DURABILITY.md`.

pub mod fault;
pub mod frame;
pub mod log;
pub mod snapshot;

pub use fault::FaultPlan;
pub use frame::Record;
pub use log::{AppendTicket, FsyncPolicy, Wal, WalStats};
pub use snapshot::{is_wal_dir, recover, Manifest, RecoveryReport};

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::data::FeatRef;
use crate::hash::HashFamily;
use crate::jsonio::{obj, Json};
use crate::online::ShardedIndex;

/// Durability knobs.
#[derive(Clone, Debug)]
pub struct WalConfig {
    /// the durable directory (manifest + snapshots + segments)
    pub dir: PathBuf,
    /// when acknowledged appends are crash-durable
    pub fsync: FsyncPolicy,
    /// roll to a new segment past this many bytes
    pub segment_bytes: u64,
    /// injectable write/fsync failures on the WAL path (fault tests
    /// only; `None` in production)
    pub faults: Option<Arc<FaultPlan>>,
}

impl WalConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        WalConfig {
            dir: dir.into(),
            fsync: FsyncPolicy::Always,
            segment_bytes: 64 << 20,
            faults: None,
        }
    }
}

/// A [`ShardedIndex`] whose mutations are journaled before they are
/// applied, with generation-numbered snapshots bounding replay.
///
/// **Failure contract**: a mutation is applied to the in-memory index
/// before its durability ticket resolves (that ordering is what makes
/// replay exact). If the journal write itself fails (disk full, dead
/// device), the caller gets the error — but the op may remain visible
/// in the served index until restart, and every subsequent mutation is
/// refused with the same sticky error (rolling back is not possible in
/// general: a failed upsert's previous code is unknown). Treat a
/// journal failure as fail-stop: the server keeps answering reads, and
/// the operator restarts onto a healthy disk.
pub struct DurableIndex {
    index: Arc<ShardedIndex>,
    wal: Wal,
    dir: PathBuf,
    /// advisory exclusive lock on the directory, held for this value's
    /// lifetime (the OS releases it if the process dies)
    _lock: std::fs::File,
    /// held across journal-enqueue + apply, so WAL order == apply order
    /// (never across the fsync wait — group commit stays shared)
    order: Mutex<()>,
    /// one checkpoint at a time
    snap_lock: Mutex<()>,
    snapshot_gen: AtomicU64,
    ops_since_snapshot: AtomicU64,
}

/// Take the directory's advisory lock (`LOCK` file, `flock`-style).
/// Exactly one live `DurableIndex` may own a directory: without this, a
/// second process (or a `chh recover` against a live server's dir)
/// would checkpoint and GC segments the live writer is still
/// appending acknowledged records to. The lock dies with the process,
/// so a SIGKILL'd server never blocks its own recovery.
fn acquire_dir_lock(dir: &std::path::Path) -> Result<std::fs::File> {
    let path = dir.join("LOCK");
    let f = std::fs::OpenOptions::new()
        .create(true)
        .write(true)
        .truncate(false)
        .open(&path)
        .with_context(|| format!("opening {}", path.display()))?;
    if f.try_lock().is_err() {
        bail!(
            "{} is in use by another process (LOCK held); stop the server using it \
             first, or point --wal-dir elsewhere",
            dir.display()
        );
    }
    Ok(f)
}

impl DurableIndex {
    /// Start a durability directory from scratch around `index`: write
    /// the base snapshot (generation 0) of its current contents, the
    /// manifest, and open segment 1 for appends. Fails if `dir` already
    /// holds a manifest — use [`Self::open`] to resume one.
    pub fn create(index: Arc<ShardedIndex>, cfg: &WalConfig) -> Result<Self> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating {}", cfg.dir.display()))?;
        let lock = acquire_dir_lock(&cfg.dir)?;
        if is_wal_dir(&cfg.dir) {
            bail!(
                "{} already holds a durable index (use DurableIndex::open / chh recover)",
                cfg.dir.display()
            );
        }
        // no manifest ⇒ not a durable dir: stale segment/snapshot debris
        // (an interrupted create, a hand-cleaned dir) must not survive
        // into the fresh history or recovery would replay garbage
        snapshot::gc(&cfg.dir, u64::MAX, u64::MAX);
        crate::persist::save_sharded(&snapshot::snapshot_path(&cfg.dir, 0), &index)?;
        snapshot::write_manifest(
            &cfg.dir,
            &snapshot::Manifest { snapshot_gen: 0, replay_from_seq: 1 },
        )?;
        let wal =
            Wal::open_with_faults(&cfg.dir, cfg.fsync, cfg.segment_bytes, 1, cfg.faults.clone())?;
        Ok(DurableIndex {
            index,
            wal,
            dir: cfg.dir.clone(),
            _lock: lock,
            order: Mutex::new(()),
            snap_lock: Mutex::new(()),
            snapshot_gen: AtomicU64::new(0),
            ops_since_snapshot: AtomicU64::new(0),
        })
    }

    /// Resume an existing durability directory: recover (snapshot +
    /// replay), reopen the log on a fresh segment, and immediately
    /// checkpoint so the replayed suffix is folded into a new snapshot
    /// and old segments are collected. The report describes what
    /// recovery found *before* that checkpoint.
    ///
    /// Refuses a **lossy** recovery (mid-log corruption, or a snapshot
    /// fallback that may skip collected segments): checkpointing one
    /// would GC the damaged segments — the only copy of whatever could
    /// not be applied. Inspect with `chh recover --inspect`, then
    /// accept the loss explicitly via [`Self::open_forced`]
    /// (`chh recover --force`).
    pub fn open(cfg: &WalConfig) -> Result<(Self, RecoveryReport)> {
        Self::open_with(cfg, false)
    }

    /// [`Self::open`], but permits checkpointing past a lossy recovery,
    /// discarding whatever could not be applied.
    pub fn open_forced(cfg: &WalConfig) -> Result<(Self, RecoveryReport)> {
        Self::open_with(cfg, true)
    }

    fn open_with(cfg: &WalConfig, allow_lossy: bool) -> Result<(Self, RecoveryReport)> {
        // lock before reading anything: recovering a directory a live
        // server still appends to must fail, not GC its segments
        let lock = acquire_dir_lock(&cfg.dir)?;
        let (index, report) = recover(&cfg.dir)?;
        if report.lossy() && !allow_lossy {
            bail!(
                "lossy recovery of {} ({}); refusing to checkpoint — that would \
                 delete the damaged segments. Inspect with `chh recover --inspect`, \
                 then accept the loss with `chh recover --force`",
                cfg.dir.display(),
                report.summary()
            );
        }
        // never append to an existing segment: a torn tail would strand
        // every frame written after it
        let next_seq = log::list_segments(&cfg.dir)?
            .last()
            .map(|&(seq, _)| seq + 1)
            .unwrap_or(1);
        let wal = Wal::open_with_faults(
            &cfg.dir,
            cfg.fsync,
            cfg.segment_bytes,
            next_seq,
            cfg.faults.clone(),
        )?;
        let durable = DurableIndex {
            index: Arc::new(index),
            wal,
            dir: cfg.dir.clone(),
            _lock: lock,
            order: Mutex::new(()),
            snap_lock: Mutex::new(()),
            snapshot_gen: AtomicU64::new(report.snapshot_gen),
            ops_since_snapshot: AtomicU64::new(0),
        };
        durable.checkpoint().context("post-recovery checkpoint")?;
        Ok((durable, report))
    }

    /// The wrapped index (share this `Arc` with routers/servers — reads
    /// need no journaling).
    pub fn index(&self) -> &Arc<ShardedIndex> {
        &self.index
    }

    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    pub fn wal_stats(&self) -> &Arc<WalStats> {
        self.wal.stats()
    }

    /// The fsynced `(segment, offset)` frontier — the farthest point the
    /// replication stream ([`crate::replicate`]) is allowed to serve.
    pub fn durable_watermark(&self) -> (u64, u64) {
        self.wal.stats().durable_watermark()
    }

    pub fn snapshot_gen(&self) -> u64 {
        self.snapshot_gen.load(Ordering::Relaxed)
    }

    /// Mutations journaled since the last completed checkpoint — what a
    /// `--snapshot-every` trigger compares against.
    pub fn ops_since_snapshot(&self) -> u64 {
        self.ops_since_snapshot.load(Ordering::Relaxed)
    }

    /// Journal, apply, then wait for the durability ack. Returns once
    /// the record is durable per the fsync policy.
    pub fn insert(&self, id: u32, code: u64) -> Result<()> {
        let ticket = {
            let _g = self.order.lock().unwrap();
            let t = self.wal.append(&Record::Insert { id, code });
            self.index.insert(id, code);
            t
        };
        self.ops_since_snapshot.fetch_add(1, Ordering::Relaxed);
        ticket.wait()
    }

    /// Encode a feature row with `family` and durably insert it.
    pub fn insert_point(
        &self,
        family: &dyn HashFamily,
        id: u32,
        x: FeatRef<'_>,
    ) -> Result<()> {
        self.insert(id, family.encode_point(x))
    }

    /// Journal and apply a removal; `Ok(was_live)` once durable. The
    /// record is journaled even for an absent id — replay is idempotent
    /// and the log stays a faithful op history.
    pub fn remove(&self, id: u32) -> Result<bool> {
        let (ticket, removed) = {
            let _g = self.order.lock().unwrap();
            let t = self.wal.append(&Record::Remove { id });
            let removed = self.index.remove(id);
            (t, removed)
        };
        self.ops_since_snapshot.fetch_add(1, Ordering::Relaxed);
        ticket.wait()?;
        Ok(removed)
    }

    /// Write a new snapshot generation and collect the segments it
    /// covers. Safe under concurrent mutations: the order lock is taken
    /// only for the segment rotation, which guarantees every record in
    /// the collected segments is already applied (and thus in the
    /// snapshot); records racing into the fresh segment may also land in
    /// the snapshot, and replaying them is idempotent.
    pub fn checkpoint(&self) -> Result<u64> {
        let _s = self.snap_lock.lock().unwrap();
        let new_seq = {
            let _g = self.order.lock().unwrap();
            self.wal.rotate()?
        };
        let ops0 = self.ops_since_snapshot.load(Ordering::Relaxed);
        let gen = self.snapshot_gen.load(Ordering::Relaxed) + 1;
        crate::persist::save_sharded(&snapshot::snapshot_path(&self.dir, gen), &self.index)?;
        snapshot::write_manifest(
            &self.dir,
            &snapshot::Manifest { snapshot_gen: gen, replay_from_seq: new_seq },
        )?;
        // marker in the fresh segment; diagnostics only, no ack needed
        let _ = self.wal.append(&Record::Checkpoint { gen });
        snapshot::gc(&self.dir, gen, new_seq);
        self.snapshot_gen.store(gen, Ordering::Relaxed);
        self.ops_since_snapshot.fetch_sub(ops0, Ordering::Relaxed);
        Ok(gen)
    }

    /// Force-fsync the log without snapshotting.
    pub fn flush(&self) -> Result<()> {
        self.wal.flush()
    }

    /// Final checkpoint + writer join. After a clean close, recovery
    /// replays zero records.
    pub fn close(self) -> Result<()> {
        self.checkpoint()?;
        drop(self.wal);
        Ok(())
    }

    /// Durability counters for `/stats`.
    pub fn stats_json(&self) -> Json {
        let ws = self.wal.stats();
        let (bmean, bp95, bmax, bcount) = ws.batch_stats();
        let segments = log::list_segments(&self.dir).map(|s| s.len()).unwrap_or(0);
        obj(vec![
            ("wal_records", Json::from(ws.records.load(Ordering::Relaxed) as usize)),
            ("wal_bytes", Json::from(ws.bytes.load(Ordering::Relaxed) as usize)),
            ("wal_segments", Json::from(segments)),
            ("fsyncs", Json::from(ws.fsyncs.load(Ordering::Relaxed) as usize)),
            ("rotations", Json::from(ws.rotations.load(Ordering::Relaxed) as usize)),
            ("last_snapshot_gen", Json::from(self.snapshot_gen() as usize)),
            ("ops_since_snapshot", Json::from(self.ops_since_snapshot() as usize)),
            (
                "group_commit",
                obj(vec![
                    ("mean_batch", Json::Num(bmean)),
                    ("p95_batch", Json::Num(bp95)),
                    ("max_batch", Json::Num(bmax)),
                    ("batches", Json::from(bcount)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::online::QueryBudget;

    fn tmpdir(name: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("chh_wal_mod_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn cfg(dir: &PathBuf) -> WalConfig {
        WalConfig {
            dir: dir.clone(),
            fsync: FsyncPolicy::Always,
            segment_bytes: 1 << 20,
            faults: None,
        }
    }

    #[test]
    fn journal_apply_recover_cycle() {
        let dir = tmpdir("cycle");
        let index = Arc::new(ShardedIndex::new(10, 2, 3));
        let d = DurableIndex::create(index.clone(), &cfg(&dir)).unwrap();
        for id in 0..60u32 {
            d.insert(id, (id % 11) as u64).unwrap();
        }
        for id in (0..60u32).step_by(5) {
            assert!(d.remove(id).unwrap());
        }
        assert!(!d.remove(999).unwrap(), "absent id reports not-live");
        assert_eq!(index.len(), 48);
        // crash-style end: drop without checkpoint
        drop(d);
        let (back, report) = recover(&dir).unwrap();
        assert_eq!(report.snapshot_gen, 0);
        assert_eq!(report.inserts, 60);
        assert_eq!(report.removes, 13);
        assert_eq!(report.live, 48);
        assert!(!report.snapshot_fallback);
        assert_eq!(back.len(), index.len());
        assert_eq!(back.bits(), 10);
        assert_eq!(back.radius(), 2);
        for (a, b) in index.shards().iter().zip(back.shards()) {
            let (mut ea, mut eb) = (a.live_entries(), b.live_entries());
            ea.sort_unstable();
            eb.sort_unstable();
            assert_eq!(ea, eb);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clean_close_leaves_nothing_to_replay() {
        let dir = tmpdir("clean");
        let index = Arc::new(ShardedIndex::new(8, 2, 2));
        let d = DurableIndex::create(index, &cfg(&dir)).unwrap();
        for id in 0..30u32 {
            d.insert(id, id as u64 & 0x3F).unwrap();
        }
        d.close().unwrap();
        let (back, report) = recover(&dir).unwrap();
        assert_eq!(report.replayed, 0, "clean shutdown must need no replay");
        assert_eq!(report.snapshot_gen, 1);
        assert_eq!(back.len(), 30);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_rotates_and_collects_segments() {
        let dir = tmpdir("ckpt");
        let index = Arc::new(ShardedIndex::new(8, 2, 2));
        let d = DurableIndex::create(index, &cfg(&dir)).unwrap();
        for id in 0..20u32 {
            d.insert(id, 1).unwrap();
        }
        assert_eq!(d.ops_since_snapshot(), 20);
        let gen = d.checkpoint().unwrap();
        assert_eq!(gen, 1);
        assert_eq!(d.ops_since_snapshot(), 0);
        // old snapshot + covered segment are gone; one fresh segment left
        let snaps = snapshot::list_snapshots(&dir).unwrap();
        assert_eq!(snaps.iter().map(|&(g, _)| g).collect::<Vec<_>>(), vec![1]);
        let segs = log::list_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1);
        assert!(segs[0].0 >= 2);
        // more ops after the checkpoint land in the new segment
        d.insert(100, 2).unwrap();
        drop(d);
        let (back, report) = recover(&dir).unwrap();
        assert_eq!(report.snapshot_gen, 1);
        assert_eq!(report.inserts, 1);
        assert_eq!(back.len(), 21);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_resumes_and_folds_the_suffix() {
        let dir = tmpdir("open");
        {
            let index = Arc::new(ShardedIndex::new(8, 2, 2));
            let d = DurableIndex::create(index, &cfg(&dir)).unwrap();
            for id in 0..25u32 {
                d.insert(id, id as u64 % 7).unwrap();
            }
            drop(d); // no checkpoint: suffix lives in the WAL
        }
        let (d, report) = DurableIndex::open(&cfg(&dir)).unwrap();
        assert_eq!(report.replayed, 25);
        assert_eq!(d.index().len(), 25);
        // open() checkpointed: a second recover needs nothing
        let (_, r2) = recover(&dir).unwrap();
        assert_eq!(r2.replayed, 0);
        assert!(r2.snapshot_gen > report.snapshot_gen);
        // and create() refuses to clobber the directory
        assert!(DurableIndex::create(d.index().clone(), &cfg(&dir)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dir_lock_excludes_concurrent_owners() {
        let dir = tmpdir("lock");
        let index = Arc::new(ShardedIndex::new(8, 2, 2));
        let d = DurableIndex::create(index, &cfg(&dir)).unwrap();
        d.insert(1, 2).unwrap();
        // a second owner (open or create) must be refused while d lives
        assert!(DurableIndex::open(&cfg(&dir)).is_err(), "live dir must stay locked");
        assert!(DurableIndex::create(d.index().clone(), &cfg(&dir)).is_err());
        drop(d);
        // the lock dies with its owner; the dir opens normally afterward
        let (d2, report) = DurableIndex::open(&cfg(&dir)).unwrap();
        assert_eq!(report.replayed, 1);
        assert_eq!(d2.index().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_carries_operational_config() {
        let dir = tmpdir("opcfg");
        let mut raw = ShardedIndex::new(9, 2, 2);
        raw.set_compact_threshold(777);
        raw.set_default_budget(QueryBudget::new(123, 45));
        let d = DurableIndex::create(Arc::new(raw), &cfg(&dir)).unwrap();
        d.insert(1, 3).unwrap();
        drop(d);
        let (back, _) = recover(&dir).unwrap();
        assert_eq!(back.compact_threshold(), 777);
        assert_eq!(back.default_budget().probes, 123);
        assert_eq!(back.default_budget().top, 45);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
