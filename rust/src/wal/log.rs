//! The append-only segmented log: one dedicated writer thread doing
//! group commit under a configurable fsync policy.
//!
//! ```text
//!  mutator threads ── append(rec) ──▶ bounded channel ──▶ writer thread
//!        ▲                                                 │  coalesce burst
//!        └────────────── ack (ticket) ◀────────────────────┘  write_all + fsync
//! ```
//!
//! Appenders enqueue an encoded frame and receive an [`AppendTicket`];
//! the writer drains whatever is queued (one `write_all` for the whole
//! burst — *group commit*), applies the fsync policy, then acks every
//! ticket in the burst. Under [`FsyncPolicy::Always`] a ticket resolves
//! only after the data is fsynced, so N concurrent mutators share one
//! fsync instead of paying one each; under `EveryN`/`IntervalMs` tickets
//! resolve after the buffered write and the fsync runs on its cadence
//! (a crash can lose the still-unsynced suffix — the documented
//! trade-off, see `docs/DURABILITY.md`).
//!
//! Segments are `wal-{seq:016}.log`; the writer rolls to `seq+1` once a
//! segment passes `segment_bytes`. A reopened log always starts a fresh
//! segment — appending after a torn tail would strand every later frame
//! behind the bad one, since readers stop at the first damaged frame.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::frame::{encode_into, Record};
use crate::metrics::Histogram;

/// When acknowledged appends become crash-durable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every group commit before acking it — an acked op is never
    /// lost (the default)
    Always,
    /// fsync once this many records have accumulated since the last sync
    EveryN(u64),
    /// fsync on a timer; the writer wakes itself if appends go quiet
    IntervalMs(u64),
}

impl std::str::FromStr for FsyncPolicy {
    type Err = anyhow::Error;

    /// `always` | `every:<n>` | `interval:<ms>`
    fn from_str(s: &str) -> Result<Self> {
        if s == "always" {
            return Ok(FsyncPolicy::Always);
        }
        if let Some(n) = s.strip_prefix("every:") {
            let n: u64 = n.parse().context("--fsync every:<n> needs an integer")?;
            return Ok(FsyncPolicy::EveryN(n.max(1)));
        }
        if let Some(ms) = s.strip_prefix("interval:") {
            let ms: u64 = ms.parse().context("--fsync interval:<ms> needs an integer")?;
            return Ok(FsyncPolicy::IntervalMs(ms.max(1)));
        }
        bail!("unknown fsync policy '{s}' (always | every:<n> | interval:<ms>)")
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsyncPolicy::Always => write!(f, "always"),
            FsyncPolicy::EveryN(n) => write!(f, "every:{n}"),
            FsyncPolicy::IntervalMs(ms) => write!(f, "interval:{ms}"),
        }
    }
}

/// Writer-side counters, shared with `/stats`.
pub struct WalStats {
    /// records durably appended (written, per the policy)
    pub records: AtomicU64,
    /// frame bytes written across all segments
    pub bytes: AtomicU64,
    /// fsync calls issued
    pub fsyncs: AtomicU64,
    /// segment rolls (size-triggered plus explicit rotations)
    pub rotations: AtomicU64,
    /// group-commit burst sizes (bounded reservoir)
    batches: Mutex<Histogram>,
    /// fsync wall-clock latency (lock-free fixed buckets, nanoseconds) —
    /// exported on `/metrics` as `chh_wal_fsync_seconds`
    pub fsync_hist: Arc<crate::obs::Hist>,
    /// group-commit burst sizes (lock-free fixed buckets) — exported on
    /// `/metrics` as `chh_wal_commit_batch_size`
    pub commit_batch: Arc<crate::obs::Hist>,
    /// `(segment seq, byte offset)` up to which every frame is fsynced.
    /// This is the watermark the replication stream may serve: bytes
    /// past it exist in the page cache but could vanish in a crash, so
    /// shipping them would let a replica apply an op the primary can
    /// lose. One mutex (not two atomics) so the pair is never torn.
    durable: Mutex<(u64, u64)>,
}

impl Default for WalStats {
    fn default() -> Self {
        WalStats {
            records: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            fsyncs: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
            batches: Mutex::new(Histogram::with_capacity(crate::metrics::SERVING_RESERVOIR)),
            fsync_hist: Arc::new(crate::obs::Hist::latency()),
            commit_batch: Arc::new(crate::obs::Hist::sizes()),
            durable: Mutex::new((0, 0)),
        }
    }
}

impl WalStats {
    fn record_batch(&self, n: usize) {
        self.batches.lock().unwrap().record(n as f64);
    }

    /// The fsynced frontier `(segment seq, byte offset within it)` —
    /// everything at or before it survives a crash; nothing after it may
    /// be replicated.
    pub fn durable_watermark(&self) -> (u64, u64) {
        *self.durable.lock().unwrap()
    }

    fn set_durable(&self, seg: u64, off: u64) {
        *self.durable.lock().unwrap() = (seg, off);
    }

    /// (mean, p95, max, count) of recent group-commit burst sizes.
    pub fn batch_stats(&self) -> (f64, f64, f64, usize) {
        let h = self.batches.lock().unwrap();
        if h.is_empty() {
            return (0.0, 0.0, 0.0, 0);
        }
        (h.mean(), h.percentile(95.0), h.max(), h.len())
    }
}

/// Resolves when the writer has made an append durable per the policy.
pub struct AppendTicket {
    rx: Receiver<Result<(), String>>,
}

impl AppendTicket {
    /// Block until the writer acks (or reports a write error).
    pub fn wait(self) -> Result<()> {
        match self.rx.recv() {
            Ok(Ok(())) => Ok(()),
            Ok(Err(e)) => Err(anyhow!("wal write failed: {e}")),
            Err(_) => Err(anyhow!("wal writer gone before ack")),
        }
    }
}

enum Cmd {
    Append(Vec<u8>, Sender<Result<(), String>>),
    /// fsync + close the current segment, open the next; replies with
    /// the new segment's seq
    Rotate(Sender<Result<u64, String>>),
    /// fsync now regardless of policy
    Flush(Sender<Result<(), String>>),
}

/// Handle to the segmented log; all I/O happens on the writer thread.
pub struct Wal {
    tx: Option<SyncSender<Cmd>>,
    writer: Option<std::thread::JoinHandle<()>>,
    stats: Arc<WalStats>,
}

/// `dir/wal-{seq:016}.log`
pub fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:016}.log"))
}

/// Parse a segment file name back to its seq.
pub fn segment_seq(name: &str) -> Option<u64> {
    name.strip_prefix("wal-")?.strip_suffix(".log")?.parse().ok()
}

/// Existing segments in `dir`, ascending by seq.
pub fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
    {
        let entry = entry?;
        if let Some(seq) = entry.file_name().to_str().and_then(segment_seq) {
            out.push((seq, entry.path()));
        }
    }
    out.sort_by_key(|&(seq, _)| seq);
    Ok(out)
}

/// Bound on commands drained per group commit (keeps a single burst's
/// buffer, and the ack latency of its first op, bounded).
const MAX_BURST: usize = 4096;
/// Appender channel bound — backpressure rather than unbounded memory if
/// mutators outrun the disk.
const QUEUE_CAP: usize = 8192;

impl Wal {
    /// Open the log for writing, starting a fresh segment at `start_seq`.
    pub fn open(
        dir: &Path,
        policy: FsyncPolicy,
        segment_bytes: u64,
        start_seq: u64,
    ) -> Result<Wal> {
        Self::open_with_faults(dir, policy, segment_bytes, start_seq, None)
    }

    /// [`Self::open`] with an injectable fault plan on the write/fsync
    /// path (testing only — pass `None` in production wiring).
    pub fn open_with_faults(
        dir: &Path,
        policy: FsyncPolicy,
        segment_bytes: u64,
        start_seq: u64,
        faults: Option<Arc<super::fault::FaultPlan>>,
    ) -> Result<Wal> {
        let file = File::create(segment_path(dir, start_seq))
            .with_context(|| format!("creating wal segment {start_seq} in {}", dir.display()))?;
        let stats = Arc::new(WalStats::default());
        // nothing is durable yet in the fresh segment
        stats.set_durable(start_seq, 0);
        let (tx, rx) = sync_channel::<Cmd>(QUEUE_CAP);
        let wstats = stats.clone();
        let wdir = dir.to_path_buf();
        let writer = std::thread::Builder::new()
            .name("chh-wal-writer".to_string())
            .spawn(move || {
                writer_loop(rx, wdir, policy, segment_bytes.max(1), start_seq, file, wstats, faults)
            })
            .context("spawning wal writer thread")?;
        Ok(Wal { tx: Some(tx), writer: Some(writer), stats })
    }

    pub fn stats(&self) -> &Arc<WalStats> {
        &self.stats
    }

    /// Enqueue one record; the returned ticket resolves when it is
    /// durable per the fsync policy. The enqueue order is the replay
    /// order — callers serialize enqueue-then-apply (see
    /// [`super::DurableIndex`]).
    pub fn append(&self, rec: &Record) -> AppendTicket {
        let (ack, rx) = std::sync::mpsc::channel();
        let mut frame = Vec::with_capacity(super::frame::frame_len(rec));
        encode_into(rec, &mut frame);
        match self.tx.as_ref() {
            Some(tx) => {
                if let Err(e) = tx.send(Cmd::Append(frame, ack.clone())) {
                    let _ = ack.send(Err(format!("wal writer stopped: {e}")));
                }
            }
            None => {
                let _ = ack.send(Err("wal closed".to_string()));
            }
        }
        AppendTicket { rx }
    }

    /// fsync + close the current segment and open the next; everything
    /// appended before this call is durable once it returns. Returns the
    /// new (empty) segment's seq.
    pub fn rotate(&self) -> Result<u64> {
        let (ack, rx) = std::sync::mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("wal closed"))?
            .send(Cmd::Rotate(ack))
            .map_err(|_| anyhow!("wal writer stopped"))?;
        match rx.recv() {
            Ok(Ok(seq)) => Ok(seq),
            Ok(Err(e)) => Err(anyhow!("wal rotate failed: {e}")),
            Err(_) => Err(anyhow!("wal writer gone during rotate")),
        }
    }

    /// Force an fsync now (used by graceful shutdown).
    pub fn flush(&self) -> Result<()> {
        let (ack, rx) = std::sync::mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("wal closed"))?
            .send(Cmd::Flush(ack))
            .map_err(|_| anyhow!("wal writer stopped"))?;
        match rx.recv() {
            Ok(Ok(())) => Ok(()),
            Ok(Err(e)) => Err(anyhow!("wal flush failed: {e}")),
            Err(_) => Err(anyhow!("wal writer gone during flush")),
        }
    }
}

impl Drop for Wal {
    fn drop(&mut self) {
        // disconnect; the writer drains the queue, fsyncs, and exits
        self.tx.take();
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
    }
}

struct WriterState {
    dir: PathBuf,
    policy: FsyncPolicy,
    segment_bytes: u64,
    seq: u64,
    file: File,
    in_segment: u64,
    unsynced: u64,
    last_sync: Instant,
    stats: Arc<WalStats>,
    /// injectable write/fsync failures (tests); None in production
    faults: Option<Arc<super::fault::FaultPlan>>,
    /// sticky I/O error: once the disk fails, every later op is refused
    /// with this message instead of silently acking lost writes
    fail: Option<String>,
}

impl WriterState {
    fn sync_file(&mut self) -> std::io::Result<()> {
        if let Some(f) = &self.faults {
            f.on_fsync()?;
        }
        let t0 = Instant::now();
        self.file.sync_all()?;
        self.stats.fsync_hist.observe_duration(t0.elapsed());
        self.stats.fsyncs.fetch_add(1, Ordering::Relaxed);
        // only now are the written bytes crash-durable — advance the
        // watermark the replication stream is allowed to serve
        self.stats.set_durable(self.seq, self.in_segment);
        Ok(())
    }

    fn fsync(&mut self) -> std::io::Result<()> {
        if self.unsynced > 0 || matches!(self.policy, FsyncPolicy::Always) {
            self.sync_file()?;
        }
        self.unsynced = 0;
        self.last_sync = Instant::now();
        Ok(())
    }

    fn roll(&mut self) -> std::io::Result<u64> {
        self.sync_file()?;
        self.unsynced = 0;
        self.last_sync = Instant::now();
        self.seq += 1;
        self.file = File::create(segment_path(&self.dir, self.seq))?;
        self.in_segment = 0;
        // the fresh (empty) segment is trivially durable up to byte 0
        self.stats.set_durable(self.seq, 0);
        self.stats.rotations.fetch_add(1, Ordering::Relaxed);
        Ok(self.seq)
    }

    /// Write one coalesced burst, apply the policy's fsync, ack tickets.
    fn commit(&mut self, buf: &[u8], acks: Vec<Sender<Result<(), String>>>) {
        if acks.is_empty() {
            return;
        }
        if let Some(msg) = &self.fail {
            let msg = msg.clone();
            for a in acks {
                let _ = a.send(Err(msg.clone()));
            }
            return;
        }
        let res = self.try_commit(buf, acks.len() as u64);
        match res {
            Ok(()) => {
                for a in acks {
                    let _ = a.send(Ok(()));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                self.fail = Some(msg.clone());
                for a in acks {
                    let _ = a.send(Err(msg.clone()));
                }
            }
        }
    }

    fn try_commit(&mut self, buf: &[u8], n: u64) -> std::io::Result<()> {
        if let Some(f) = &self.faults {
            f.on_write()?;
        }
        self.file.write_all(buf)?;
        self.in_segment += buf.len() as u64;
        self.unsynced += n;
        self.stats.records.fetch_add(n, Ordering::Relaxed);
        self.stats.bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.stats.record_batch(n as usize);
        self.stats.commit_batch.record(n);
        let due = match self.policy {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryN(k) => self.unsynced >= k,
            FsyncPolicy::IntervalMs(ms) => {
                self.last_sync.elapsed() >= Duration::from_millis(ms)
            }
        };
        if due {
            self.fsync()?;
        }
        if self.in_segment >= self.segment_bytes {
            self.roll()?;
        }
        Ok(())
    }

    fn control(&mut self, cmd: Cmd) {
        match cmd {
            Cmd::Append(..) => unreachable!("appends are batched by the caller"),
            Cmd::Rotate(ack) => {
                if let Some(msg) = &self.fail {
                    let _ = ack.send(Err(msg.clone()));
                    return;
                }
                match self.roll() {
                    Ok(seq) => {
                        let _ = ack.send(Ok(seq));
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        self.fail = Some(msg.clone());
                        let _ = ack.send(Err(msg));
                    }
                }
            }
            Cmd::Flush(ack) => {
                if let Some(msg) = &self.fail {
                    let _ = ack.send(Err(msg.clone()));
                    return;
                }
                match self.fsync() {
                    Ok(()) => {
                        let _ = ack.send(Ok(()));
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        self.fail = Some(msg.clone());
                        let _ = ack.send(Err(msg));
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn writer_loop(
    rx: Receiver<Cmd>,
    dir: PathBuf,
    policy: FsyncPolicy,
    segment_bytes: u64,
    start_seq: u64,
    file: File,
    stats: Arc<WalStats>,
    faults: Option<Arc<super::fault::FaultPlan>>,
) {
    let mut st = WriterState {
        dir,
        policy,
        segment_bytes,
        seq: start_seq,
        file,
        in_segment: 0,
        unsynced: 0,
        last_sync: Instant::now(),
        stats,
        faults,
        fail: None,
    };
    loop {
        // wait for work; under an interval policy with dirty bytes, wake
        // ourselves at the deadline so quiet periods still get synced
        let first = match st.policy {
            FsyncPolicy::IntervalMs(ms) if st.unsynced > 0 => {
                let deadline = st.last_sync + Duration::from_millis(ms);
                let now = Instant::now();
                if now >= deadline {
                    if let Err(e) = st.fsync() {
                        st.fail = Some(e.to_string());
                    }
                    continue;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(cmd) => cmd,
                    Err(RecvTimeoutError::Timeout) => {
                        if let Err(e) = st.fsync() {
                            st.fail = Some(e.to_string());
                        }
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            _ => match rx.recv() {
                Ok(cmd) => cmd,
                Err(_) => break,
            },
        };
        let mut cmds = vec![first];
        while cmds.len() < MAX_BURST {
            match rx.try_recv() {
                Ok(cmd) => cmds.push(cmd),
                Err(_) => break,
            }
        }
        // coalesce contiguous appends into one write; controls are rare
        // and act as commit barriers within the burst
        let mut buf: Vec<u8> = Vec::new();
        let mut acks: Vec<Sender<Result<(), String>>> = Vec::new();
        for cmd in cmds {
            match cmd {
                Cmd::Append(frame, ack) => {
                    buf.extend_from_slice(&frame);
                    acks.push(ack);
                }
                ctrl => {
                    st.commit(&buf, std::mem::take(&mut acks));
                    buf.clear();
                    st.control(ctrl);
                }
            }
        }
        st.commit(&buf, acks);
    }
    // channel closed: everything queued is written; leave the tail synced
    if st.fail.is_none() && st.file.sync_all().is_ok() {
        st.stats.set_durable(st.seq, st.in_segment);
    }
}

#[cfg(test)]
mod tests {
    use super::super::frame::read_segment_bytes;
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("chh_wal_log_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_rotate_list_roundtrip() {
        let dir = tmpdir("basic");
        let wal = Wal::open(&dir, FsyncPolicy::Always, 1 << 20, 1).unwrap();
        for id in 0..10u32 {
            wal.append(&Record::Insert { id, code: id as u64 * 3 }).wait().unwrap();
        }
        let new_seq = wal.rotate().unwrap();
        assert_eq!(new_seq, 2);
        wal.append(&Record::Remove { id: 4 }).wait().unwrap();
        assert_eq!(wal.stats().records.load(Ordering::Relaxed), 11);
        drop(wal);
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1, 2]);
        let first = read_segment_bytes(&std::fs::read(&segs[0].1).unwrap());
        assert_eq!(first.records.len(), 10);
        assert!(!first.torn);
        let second = read_segment_bytes(&std::fs::read(&segs[1].1).unwrap());
        assert_eq!(second.records, vec![Record::Remove { id: 4 }]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn size_triggered_roll_keeps_every_record() {
        let dir = tmpdir("roll");
        // tiny cap: every few appends roll a segment
        let wal = Wal::open(&dir, FsyncPolicy::EveryN(100), 64, 1).unwrap();
        for id in 0..40u32 {
            wal.append(&Record::Insert { id, code: 1 }).wait().unwrap();
        }
        drop(wal);
        let segs = list_segments(&dir).unwrap();
        assert!(segs.len() > 1, "size cap must roll segments, got {}", segs.len());
        let mut all = Vec::new();
        for (_, p) in &segs {
            let read = read_segment_bytes(&std::fs::read(p).unwrap());
            assert!(!read.torn);
            all.extend(read.records);
        }
        let want: Vec<Record> =
            (0..40u32).map(|id| Record::Insert { id, code: 1 }).collect();
        assert_eq!(all, want, "records in order across rolled segments");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_appenders_all_acked_and_logged() {
        let dir = tmpdir("conc");
        let wal = Arc::new(Wal::open(&dir, FsyncPolicy::Always, 1 << 20, 1).unwrap());
        let threads = 4;
        let per = 50;
        let mut joins = Vec::new();
        for t in 0..threads {
            let wal = wal.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..per {
                    let id = (t * 1000 + i) as u32;
                    wal.append(&Record::Insert { id, code: id as u64 }).wait().unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(
            wal.stats().records.load(Ordering::Relaxed),
            (threads * per) as u64
        );
        let (_, _, max_batch, batches) = wal.stats().batch_stats();
        assert!(batches > 0 && max_batch >= 1.0);
        // the lock-free exposition histograms see the same traffic
        assert!(wal.stats().fsync_hist.count() > 0, "fsyncs must be timed");
        assert_eq!(
            wal.stats().commit_batch.sum_raw(),
            (threads * per) as u64,
            "commit-batch sizes must sum to the record count"
        );
        drop(wal);
        let segs = list_segments(&dir).unwrap();
        let read = read_segment_bytes(&std::fs::read(&segs[0].1).unwrap());
        assert_eq!(read.records.len(), threads * per);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsync_policy_parsing() {
        assert_eq!("always".parse::<FsyncPolicy>().unwrap(), FsyncPolicy::Always);
        assert_eq!("every:8".parse::<FsyncPolicy>().unwrap(), FsyncPolicy::EveryN(8));
        assert_eq!(
            "interval:25".parse::<FsyncPolicy>().unwrap(),
            FsyncPolicy::IntervalMs(25)
        );
        assert!("sometimes".parse::<FsyncPolicy>().is_err());
        assert!("every:x".parse::<FsyncPolicy>().is_err());
        assert_eq!(FsyncPolicy::EveryN(8).to_string(), "every:8");
    }

    #[test]
    fn durable_watermark_tracks_fsyncs_and_rolls() {
        let dir = tmpdir("watermark");
        let wal = Wal::open(&dir, FsyncPolicy::Always, 1 << 20, 1).unwrap();
        assert_eq!(wal.stats().durable_watermark(), (1, 0));
        let rec = Record::Insert { id: 1, code: 2 };
        wal.append(&rec).wait().unwrap();
        let fl = super::super::frame::frame_len(&rec) as u64;
        // fsync: always ⇒ by ack time the frame is durable
        assert_eq!(wal.stats().durable_watermark(), (1, fl));
        let new_seq = wal.rotate().unwrap();
        assert_eq!(wal.stats().durable_watermark(), (new_seq, 0));
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lazy_policy_watermark_lags_written_bytes() {
        let dir = tmpdir("lazy_watermark");
        // huge EveryN: acks resolve after the buffered write, before any
        // fsync — the watermark must NOT cover those bytes
        let wal = Wal::open(&dir, FsyncPolicy::EveryN(1_000_000), 1 << 20, 1).unwrap();
        for id in 0..10u32 {
            wal.append(&Record::Insert { id, code: 3 }).wait().unwrap();
        }
        assert_eq!(
            wal.stats().durable_watermark(),
            (1, 0),
            "unsynced bytes are not durable"
        );
        wal.flush().unwrap();
        let (seg, off) = wal.stats().durable_watermark();
        assert_eq!(seg, 1);
        assert_eq!(off, wal.stats().bytes.load(Ordering::Relaxed));
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_fsync_fault_is_sticky_and_freezes_the_watermark() {
        let dir = tmpdir("fault");
        let plan = super::super::fault::FaultPlan::new();
        let wal = Wal::open_with_faults(
            &dir,
            FsyncPolicy::Always,
            1 << 20,
            1,
            Some(plan.clone()),
        )
        .unwrap();
        for id in 0..5u32 {
            wal.append(&Record::Insert { id, code: 1 }).wait().unwrap();
        }
        let before = wal.stats().durable_watermark();
        plan.fail_fsync_at(plan.fsyncs_seen() + 1);
        let err = wal.append(&Record::Insert { id: 99, code: 1 }).wait();
        assert!(err.is_err(), "faulted op must not be acknowledged");
        // sticky fail-stop: later ops refused, watermark frozen
        assert!(wal.append(&Record::Insert { id: 100, code: 1 }).wait().is_err());
        assert_eq!(
            wal.stats().durable_watermark(),
            before,
            "un-fsynced bytes never become durable"
        );
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interval_policy_syncs_a_quiet_log() {
        let dir = tmpdir("interval");
        let wal = Wal::open(&dir, FsyncPolicy::IntervalMs(10), 1 << 20, 1).unwrap();
        wal.append(&Record::Insert { id: 1, code: 2 }).wait().unwrap();
        // no further appends: the self-wakeup must fsync within ~interval
        let t0 = Instant::now();
        while wal.stats().fsyncs.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "interval fsync never fired");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
