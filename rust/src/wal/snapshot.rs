//! Snapshots, the manifest, recovery, and segment GC.
//!
//! A durable directory holds three kinds of files:
//!
//! ```text
//! MANIFEST.json               which snapshot is current + where replay starts
//! snapshot-{gen:016}.chh      full index state (persist::save_sharded format)
//! wal-{seq:016}.log           record segments after that snapshot
//! ```
//!
//! Every writer is atomic (temp file + fsync + rename via
//! [`crate::persist::atomic_write`]), and the manifest is only updated
//! *after* its snapshot is fully durable — so at any crash point the
//! directory names one complete, loadable snapshot. Recovery loads it
//! and replays the WAL suffix in seq order; inserts are upserts and
//! removes are idempotent, so records that are both in the snapshot and
//! in the suffix (taken while mutators were live) replay harmlessly.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::frame::{read_segment_bytes, Record};
use super::log::list_segments;
use crate::jsonio::{obj, Json};
use crate::online::ShardedIndex;

pub(crate) const MANIFEST: &str = "MANIFEST.json";
const MANIFEST_VERSION: usize = 1;

/// `dir/snapshot-{gen:016}.chh`
pub fn snapshot_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("snapshot-{gen:016}.chh"))
}

fn snapshot_gen_of(name: &str) -> Option<u64> {
    name.strip_prefix("snapshot-")?.strip_suffix(".chh")?.parse().ok()
}

/// Existing snapshots in `dir`, ascending by generation. `.tmp` leftovers
/// from an interrupted atomic write never match the suffix, so they are
/// invisible here by construction.
pub fn list_snapshots(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
    {
        let entry = entry?;
        if let Some(gen) = entry.file_name().to_str().and_then(snapshot_gen_of) {
            out.push((gen, entry.path()));
        }
    }
    out.sort_by_key(|&(gen, _)| gen);
    Ok(out)
}

/// The durable directory's root pointer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// generation of the covering snapshot
    pub snapshot_gen: u64,
    /// first WAL segment NOT covered by that snapshot
    pub replay_from_seq: u64,
}

pub(crate) fn write_manifest(dir: &Path, m: &Manifest) -> Result<()> {
    let doc = obj(vec![
        ("version", Json::from(MANIFEST_VERSION)),
        ("snapshot_gen", Json::from(m.snapshot_gen as usize)),
        ("replay_from_seq", Json::from(m.replay_from_seq as usize)),
    ]);
    crate::persist::atomic_write(&dir.join(MANIFEST), doc.to_string_pretty().as_bytes())
}

/// Read the directory's root pointer (`None` when no manifest exists).
/// Public for the replication bootstrap handler, which must pair a
/// snapshot generation with its replay start atomically.
pub fn read_manifest(dir: &Path) -> Result<Option<Manifest>> {
    let path = dir.join(MANIFEST);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let v = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    let field = |k: &str| {
        v.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing field {k}"))
    };
    Ok(Some(Manifest {
        snapshot_gen: field("snapshot_gen")? as u64,
        replay_from_seq: field("replay_from_seq")? as u64,
    }))
}

/// Whether `dir` looks like a durable index directory.
pub fn is_wal_dir(dir: &Path) -> bool {
    dir.join(MANIFEST).is_file()
}

/// What [`recover`] found and did.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// generation of the snapshot recovery started from
    pub snapshot_gen: u64,
    /// live entries in that snapshot
    pub snapshot_entries: usize,
    /// whether the manifest's snapshot was unreadable and an older
    /// generation had to be used (entails replaying every segment)
    pub snapshot_fallback: bool,
    /// WAL segments scanned
    pub segments: usize,
    /// insert/remove records applied on top of the snapshot
    pub replayed: usize,
    pub inserts: usize,
    pub removes: usize,
    /// checkpoint markers seen (not applied)
    pub checkpoints: usize,
    /// a final segment ended in a torn tail; this many trailing bytes
    /// were ignored
    pub torn_bytes: u64,
    /// a NON-final segment had a bad frame: replay stopped there and
    /// this many later segments were not applied (data past the damage
    /// is unrecoverable in order, so it is not applied at all)
    pub segments_skipped: usize,
    /// live points after replay + compaction
    pub live: usize,
    /// WAL position `(segment seq, byte offset)` replay stopped at — the
    /// "last applied seq" a replication test (or a resuming tailer)
    /// compares convergence points against. When no segment was scanned
    /// this is `(replay start, 0)`.
    pub end_seg: u64,
    pub end_off: u64,
}

impl RecoveryReport {
    /// Whether part of the durable history could NOT be applied: a bad
    /// frame before the final segment, or a fallback to an older
    /// snapshot whose covering segments may already be collected (the
    /// replayed suffix then lands on a state with a gap). A lossy
    /// recovery still yields the longest applicable prefix, but
    /// checkpointing it destroys the unapplied remainder — callers must
    /// opt in ([`super::DurableIndex::open_forced`]).
    pub fn lossy(&self) -> bool {
        self.segments_skipped > 0 || self.snapshot_fallback
    }

    /// One-line human summary for logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "snapshot gen {} ({} entries){} + {} segments: replayed {} records \
             ({} inserts, {} removes) -> {} live",
            self.snapshot_gen,
            self.snapshot_entries,
            if self.snapshot_fallback { " [fallback]" } else { "" },
            self.segments,
            self.replayed,
            self.inserts,
            self.removes,
            self.live
        );
        if self.torn_bytes > 0 {
            s.push_str(&format!(", torn tail ({} bytes ignored)", self.torn_bytes));
        }
        if self.segments_skipped > 0 {
            s.push_str(&format!(
                ", CORRUPT mid-log: {} later segments not applied",
                self.segments_skipped
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("snapshot_gen", Json::from(self.snapshot_gen as usize)),
            ("snapshot_entries", Json::from(self.snapshot_entries)),
            ("snapshot_fallback", Json::from(self.snapshot_fallback)),
            ("segments", Json::from(self.segments)),
            ("replayed", Json::from(self.replayed)),
            ("inserts", Json::from(self.inserts)),
            ("removes", Json::from(self.removes)),
            ("checkpoints", Json::from(self.checkpoints)),
            ("torn_bytes", Json::from(self.torn_bytes as usize)),
            ("segments_skipped", Json::from(self.segments_skipped)),
            ("live", Json::from(self.live)),
            ("end_seg", Json::from(self.end_seg as usize)),
            ("end_off", Json::from(self.end_off as usize)),
        ])
    }
}

/// Rebuild the index from `dir`: newest valid snapshot + idempotent WAL
/// replay. Read-only — the directory is not modified (use
/// [`super::DurableIndex::open`] to also checkpoint and resume logging).
///
/// Damage tolerance:
/// * a torn tail in the final segment (crash mid-append) is expected —
///   replay keeps the longest valid frame prefix and reports the bytes
///   ignored;
/// * a bad frame in an earlier segment stops replay at that point (the
///   longest valid prefix of the whole log) rather than erroring;
/// * if the manifest's snapshot is unreadable, older generations are
///   tried, and the full log is replayed over the one that loads.
pub fn recover(dir: &Path) -> Result<(ShardedIndex, RecoveryReport)> {
    let manifest = read_manifest(dir)?;
    let snapshots = list_snapshots(dir)?;
    if manifest.is_none() && snapshots.is_empty() {
        bail!("{} is not a durable index directory (no manifest, no snapshots)", dir.display());
    }
    let mut report = RecoveryReport::default();

    // pick the snapshot: the manifest's, else newest-loadable fallback
    let mut index: Option<ShardedIndex> = None;
    if let Some(m) = manifest {
        if let Some((_, path)) = snapshots.iter().find(|&&(g, _)| g == m.snapshot_gen) {
            match crate::persist::load_sharded(path) {
                Ok(idx) => {
                    report.snapshot_gen = m.snapshot_gen;
                    index = Some(idx);
                }
                Err(e) => {
                    eprintln!(
                        "wal recover: manifest snapshot gen {} unreadable ({e:#}), \
                         trying older generations",
                        m.snapshot_gen
                    );
                }
            }
        }
    }
    if index.is_none() {
        for (gen, path) in snapshots.iter().rev() {
            match crate::persist::load_sharded(path) {
                Ok(idx) => {
                    report.snapshot_gen = *gen;
                    report.snapshot_fallback = true;
                    index = Some(idx);
                    break;
                }
                Err(_) => continue,
            }
        }
    }
    let Some(index) = index else {
        bail!("no loadable snapshot in {}", dir.display());
    };
    report.snapshot_entries = index.len();

    // replay the suffix: from the manifest pointer, or — on fallback —
    // everything still on disk (older segments may already be GC'd; the
    // replayed prefix is still the longest recoverable one)
    let replay_from = match (manifest, report.snapshot_fallback) {
        (Some(m), false) => m.replay_from_seq,
        _ => 0,
    };
    let segments: Vec<(u64, PathBuf)> = list_segments(dir)?
        .into_iter()
        .filter(|&(seq, _)| seq >= replay_from)
        .collect();
    report.end_seg = replay_from;
    report.end_off = 0;
    let last = segments.len().saturating_sub(1);
    // over-k codes are a hard replay error (mirrors the snapshot loader's
    // mask gate): a CRC-valid frame carrying one means the log was written
    // by a mismatched index — replaying it would silently skew every
    // masked scan. Hoisted: one mask for the whole replay.
    let code_mask = crate::hash::codes::mask(index.bits());
    for (i, (seq, path)) in segments.iter().enumerate() {
        let data =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let read = read_segment_bytes(&data);
        report.segments += 1;
        report.end_seg = *seq;
        report.end_off = read.valid_bytes as u64;
        for rec in &read.records {
            match *rec {
                Record::Insert { id, code } => {
                    if code & !code_mask != 0 {
                        bail!(
                            "segment {seq}: insert {id} carries code {code:#x} \
                             exceeding {} bits",
                            index.bits()
                        );
                    }
                    index.insert(id, code);
                    report.inserts += 1;
                    report.replayed += 1;
                }
                Record::Remove { id } => {
                    index.remove(id);
                    report.removes += 1;
                    report.replayed += 1;
                }
                Record::Checkpoint { .. } => report.checkpoints += 1,
            }
        }
        if read.torn {
            report.torn_bytes = (data.len() - read.valid_bytes) as u64;
            if i != last {
                // damage mid-log: later segments are after the break in
                // the op order — applying them would reorder history
                report.segments_skipped = last - i;
                eprintln!(
                    "wal recover: bad frame in segment {seq} (not the last); \
                     stopping replay at the valid prefix"
                );
            }
            break;
        }
    }
    index.compact();
    report.live = index.len();
    Ok((index, report))
}

/// Delete snapshots older than `keep_gen` and segments before
/// `keep_seq_from`. Called only after the manifest naming `keep_gen` /
/// `keep_seq_from` is durable. Best-effort: a file that refuses to die
/// wastes disk but never correctness.
pub(crate) fn gc(dir: &Path, keep_gen: u64, keep_seq_from: u64) {
    if let Ok(snaps) = list_snapshots(dir) {
        for (gen, path) in snaps {
            if gen < keep_gen {
                let _ = std::fs::remove_file(path);
            }
        }
    }
    if let Ok(segs) = list_segments(dir) {
        for (seq, path) in segs {
            if seq < keep_seq_from {
                let _ = std::fs::remove_file(path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("chh_wal_snap_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn manifest_roundtrip_and_missing() {
        let dir = tmpdir("manifest");
        assert!(read_manifest(&dir).unwrap().is_none());
        assert!(!is_wal_dir(&dir));
        let m = Manifest { snapshot_gen: 3, replay_from_seq: 17 };
        write_manifest(&dir, &m).unwrap();
        assert_eq!(read_manifest(&dir).unwrap(), Some(m));
        assert!(is_wal_dir(&dir));
        // a stale atomic-write temp file is invisible to the reader
        std::fs::write(dir.join("MANIFEST.json.tmp"), b"{gar").unwrap();
        assert_eq!(read_manifest(&dir).unwrap(), Some(m));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_listing_skips_tmp_leftovers() {
        let dir = tmpdir("listing");
        std::fs::write(snapshot_path(&dir, 2), b"x").unwrap();
        std::fs::write(snapshot_path(&dir, 10), b"x").unwrap();
        std::fs::write(dir.join("snapshot-0000000000000011.chh.tmp"), b"trunc").unwrap();
        std::fs::write(dir.join("unrelated.txt"), b"x").unwrap();
        let gens: Vec<u64> =
            list_snapshots(&dir).unwrap().into_iter().map(|(g, _)| g).collect();
        assert_eq!(gens, vec![2, 10]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_rejects_a_plain_directory() {
        let dir = tmpdir("empty");
        assert!(recover(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
