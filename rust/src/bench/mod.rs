//! In-crate benchmark harness (criterion is not in the vendored registry).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that calls
//! [`Bench::run`] for its measurements and the `report` module for the
//! paper-style tables. The harness does warmup, adaptive iteration count to
//! hit a target measurement window, and robust summary stats.
//!
//! Machine-readable output: every bench that builds a [`JsonReport`] also
//! honors `--json <path>` on its command line
//! (`cargo bench --bench online_churn -- --json BENCH_online_churn.json`),
//! writing its records as one JSON document so CI and trend tooling can
//! diff runs without scraping tables.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::jsonio::{obj, Json};

/// Summary statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Throughput given a per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_secs().max(1e-12)
    }

    /// Machine-readable record for a [`JsonReport`].
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("iters", Json::from(self.iters as usize)),
            ("mean_s", Json::Num(self.mean.as_secs_f64())),
            ("p50_s", Json::Num(self.p50.as_secs_f64())),
            ("p95_s", Json::Num(self.p95.as_secs_f64())),
            ("min_s", Json::Num(self.min.as_secs_f64())),
        ])
    }
}

/// Path given via `--json <path>` on this process's command line, if any.
/// Unknown other arguments (e.g. the `--bench` cargo appends to
/// `harness = false` targets) are ignored.
pub fn json_path_from_args() -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from)
}

/// Collector for a bench's machine-readable results.
///
/// Records accumulate unconditionally (they're cheap); [`Self::finish`]
/// writes them only when the process was invoked with `--json <path>`,
/// and returns the path written so the bench can announce it.
pub struct JsonReport {
    bench: String,
    records: Vec<Json>,
}

impl JsonReport {
    pub fn new(bench: &str) -> Self {
        JsonReport { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one named record of key/value fields.
    pub fn push(&mut self, kind: &str, fields: Vec<(&str, Json)>) {
        let mut all = vec![("kind", Json::from(kind))];
        all.extend(fields);
        self.records.push(obj(all));
    }

    /// Append one measured case.
    pub fn push_stats(&mut self, s: &BenchStats) {
        self.records.push(s.to_json());
    }

    /// Write the document if `--json <path>` was given.
    pub fn finish(&self) -> anyhow::Result<Option<PathBuf>> {
        let Some(path) = json_path_from_args() else {
            return Ok(None);
        };
        let doc = obj(vec![
            ("bench", Json::from(self.bench.as_str())),
            ("full_scale", Json::from(full_scale())),
            ("records", Json::Arr(self.records.clone())),
        ]);
        std::fs::write(&path, doc.to_string_pretty())?;
        Ok(Some(path))
    }
}

/// Benchmark runner configuration.
pub struct Bench {
    /// target cumulative measurement time per case
    pub budget: Duration,
    /// warmup time before measuring
    pub warmup: Duration,
    /// hard cap on sample count
    pub max_samples: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(800),
            warmup: Duration::from_millis(100),
            max_samples: 1000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(200),
            warmup: Duration::from_millis(20),
            max_samples: 200,
        }
    }

    /// Measure `f` repeatedly; `f` should perform one unit of work and is
    /// responsible for consuming its result (use `std::hint::black_box`).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && (samples.len() as u64) < self.max_samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let mut sorted = samples.clone();
        sorted.sort();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| -> Duration {
            let r = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
            sorted[r.min(sorted.len() - 1)]
        };
        BenchStats {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean: total / samples.len() as u32,
            p50: pct(50.0),
            p95: pct(95.0),
            min: sorted[0],
        }
    }

    /// Time a single execution of a long-running workload (AL experiments).
    pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchStats) {
        let t0 = Instant::now();
        let out = f();
        let d = t0.elapsed();
        (
            out,
            BenchStats {
                name: name.to_string(),
                iters: 1,
                mean: d,
                p50: d,
                p95: d,
                min: d,
            },
        )
    }
}

/// Render a stats table to stdout.
pub fn print_table(title: &str, rows: &[BenchStats]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "case", "iters", "mean", "p50", "p95", "min"
    );
    for r in rows {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_dur(r.mean),
            fmt_dur(r.p50),
            fmt_dur(r.p95),
            fmt_dur(r.min)
        );
    }
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// True when the `CHH_BENCH_FULL` env var requests paper-scale runs;
/// default bench invocations use reduced scales so `cargo bench` finishes
/// on a laptop-class machine.
pub fn full_scale() -> bool {
    std::env::var("CHH_BENCH_FULL").map(|v| v == "1" || v == "true").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_samples() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.iters >= 1);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn once_returns_value() {
        let (v, s) = Bench::once("x", || 42);
        assert_eq!(v, 42);
        assert_eq!(s.iters, 1);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }

    #[test]
    fn json_report_records_are_well_formed() {
        let mut rep = JsonReport::new("t");
        let (_, s) = Bench::once("case", || 1 + 1);
        rep.push_stats(&s);
        rep.push("load", vec![("ops", Json::from(7usize))]);
        // the records are valid Json values regardless of --json
        assert_eq!(rep.records.len(), 2);
        assert_eq!(rep.records[0].get("name").unwrap().as_str(), Some("case"));
        assert_eq!(rep.records[1].get("kind").unwrap().as_str(), Some("load"));
        assert_eq!(rep.records[1].get("ops").unwrap().as_usize(), Some(7));
        // no --json flag in the test harness argv ⇒ nothing written
        assert!(rep.finish().unwrap().is_none());
    }
}
