//! Evaluation metrics and timing utilities.
//!
//! * `average_precision` — the paper's Fig 3(a)/4(a) metric: rank the
//!   unlabeled pool by the current SVM score and compute AP against the
//!   binary relevance labels; MAP averages over classes and runs.
//! * `Stopwatch` / `Histogram` — wall-clock instrumentation for the
//!   efficiency tables (supplementary Tables 1–3) and the §Perf pass.

use std::time::{Duration, Instant};

/// Average precision of a ranking. `scores` and `relevant` are parallel;
/// ties are broken by original index (stable), matching a deterministic
/// sort so results are reproducible.
pub fn average_precision(scores: &[f32], relevant: &[bool]) -> f64 {
    assert_eq!(scores.len(), relevant.len());
    let n_rel = relevant.iter().filter(|&&r| r).count();
    if n_rel == 0 {
        return 0.0;
    }
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut hits = 0usize;
    let mut ap = 0.0f64;
    for (rank, &i) in idx.iter().enumerate() {
        if relevant[i as usize] {
            hits += 1;
            ap += hits as f64 / (rank + 1) as f64;
        }
    }
    ap / n_rel as f64
}

/// Precision at k of a ranking.
pub fn precision_at_k(scores: &[f32], relevant: &[bool], k: usize) -> f64 {
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let k = k.min(idx.len());
    if k == 0 {
        return 0.0;
    }
    idx[..k].iter().filter(|&&i| relevant[i as usize]).count() as f64 / k as f64
}

/// Simple named stopwatch accumulating multiple segments.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    count: u64,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
            self.count += 1;
        }
    }

    /// Time a closure, accumulating.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    pub fn total_secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_secs() / self.count as f64
        }
    }
}

/// A lock-free integer gauge for serving-path counters and positions
/// (replication lag, stream offsets, reconnect counts). Thin wrapper
/// over an atomic so readers (`/stats`) never contend with the writer;
/// a pair of gauges updated together is *not* read atomically — guard
/// with a lock where torn pairs matter (cf.
/// [`crate::wal::WalStats::durable_watermark`]).
#[derive(Debug, Default)]
pub struct Gauge(std::sync::atomic::AtomicU64);

impl Gauge {
    pub fn new(v: u64) -> Self {
        Gauge(std::sync::atomic::AtomicU64::new(v))
    }

    pub fn get(&self) -> u64 {
        self.0.load(std::sync::atomic::Ordering::Acquire)
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, std::sync::atomic::Ordering::Release)
    }

    /// Add `d` and return the new value.
    pub fn add(&self, d: u64) -> u64 {
        self.0.fetch_add(d, std::sync::atomic::Ordering::AcqRel) + d
    }
}

/// Sample reservoir with percentile queries. [`Self::new`] keeps every
/// sample (bench/eval uses, where run length is known and bounded);
/// [`Self::with_capacity`] keeps a ring of the most recent `cap`
/// samples — the right mode for long-lived servers, where an unbounded
/// per-request `Vec` would grow forever and percentile sorts over the
/// full history would stall the recording hot path.
#[derive(Debug, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    /// ring bound; `None` keeps everything
    cap: Option<usize>,
    /// next slot to overwrite once the ring is full
    next: usize,
}

/// Default ring size for serving-path histograms: big enough for stable
/// tail percentiles, small enough that a locked percentile sort is µs.
pub const SERVING_RESERVOIR: usize = 4096;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { samples: Vec::new(), cap: None, next: 0 }
    }

    /// Keep only the most recent `cap` samples (ring buffer).
    pub fn with_capacity(cap: usize) -> Self {
        Histogram { samples: Vec::new(), cap: Some(cap.max(1)), next: 0 }
    }

    pub fn record(&mut self, v: f64) {
        match self.cap {
            Some(cap) if self.samples.len() >= cap => {
                self.samples[self.next] = v;
                self.next = (self.next + 1) % cap;
            }
            _ => self.samples.push(v),
        }
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    /// Fold another histogram's samples into this one (e.g. merging the
    /// per-thread latency reservoirs of a load generator). Respects this
    /// histogram's own ring bound.
    pub fn merge(&mut self, other: &Histogram) {
        if self.cap.is_none() {
            self.samples.extend_from_slice(&other.samples);
        } else {
            for &v in &other.samples {
                self.record(v);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Percentile in [0, 100] by nearest-rank on a sorted copy.
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// Several percentiles from one sorted pass — use this (not repeated
    /// [`Self::percentile`] calls, each of which clones and sorts) when
    /// reading p50/p95/p99 together, especially under a lock the
    /// recording hot path contends on.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter()
            .map(|&p| {
                let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
                s[rank.min(s.len() - 1)]
            })
            .collect()
    }

    /// Smallest recorded sample; 0.0 when empty — consistent with
    /// [`Self::mean`]/[`Self::percentile`], and finite so `/stats` JSON
    /// never renders an idle reservoir as `null` (jsonio serializes
    /// non-finite numbers as `null`).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest recorded sample; 0.0 when empty (see [`Self::min`]).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ap_perfect_ranking() {
        let scores = vec![0.9, 0.8, 0.1, 0.05];
        let rel = vec![true, true, false, false];
        assert!((average_precision(&scores, &rel) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ap_worst_ranking() {
        let scores = vec![0.9, 0.8, 0.1, 0.05];
        let rel = vec![false, false, true, true];
        // hits at ranks 3,4: AP = (1/3 + 2/4)/2
        let expect = (1.0 / 3.0 + 2.0 / 4.0) / 2.0;
        assert!((average_precision(&scores, &rel) - expect).abs() < 1e-12);
    }

    #[test]
    fn ap_no_relevant_is_zero() {
        assert_eq!(average_precision(&[1.0, 2.0], &[false, false]), 0.0);
    }

    #[test]
    fn ap_interleaved() {
        let scores = vec![4.0, 3.0, 2.0, 1.0];
        let rel = vec![true, false, true, false];
        let expect = (1.0 / 1.0 + 2.0 / 3.0) / 2.0;
        assert!((average_precision(&scores, &rel) - expect).abs() < 1e-12);
    }

    #[test]
    fn p_at_k() {
        let scores = vec![4.0, 3.0, 2.0, 1.0];
        let rel = vec![true, false, true, false];
        assert_eq!(precision_at_k(&scores, &rel, 1), 1.0);
        assert_eq!(precision_at_k(&scores, &rel, 2), 0.5);
        assert_eq!(precision_at_k(&scores, &rel, 4), 0.5);
    }

    #[test]
    fn gauge_set_get_add() {
        let g = Gauge::new(5);
        assert_eq!(g.get(), 5);
        g.set(11);
        assert_eq!(g.get(), 11);
        assert_eq!(g.add(3), 14);
        assert_eq!(g.get(), 14);
        assert_eq!(Gauge::default().get(), 0);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert_eq!(sw.count(), 2);
        assert!(sw.total_secs() >= 0.009);
    }

    #[test]
    fn empty_histogram_min_max_are_finite_zero() {
        let h = Histogram::new();
        assert_eq!(h.min(), 0.0, "empty min must be 0.0, not inf");
        assert_eq!(h.max(), 0.0, "empty max must be 0.0, not -inf");
        // and they serialize as numbers, not null
        let v = crate::jsonio::obj(vec![
            ("min", crate::jsonio::Json::Num(h.min())),
            ("max", crate::jsonio::Json::Num(h.max())),
        ]);
        let s = v.to_string_compact();
        assert!(!s.contains("null"), "idle stats must not render null: {s}");
        // non-empty behavior unchanged
        let mut h = Histogram::new();
        h.record(3.0);
        h.record(-1.0);
        assert_eq!(h.min(), -1.0);
        assert_eq!(h.max(), 3.0);
    }

    #[test]
    fn histogram_ring_keeps_most_recent() {
        let mut h = Histogram::with_capacity(10);
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.len(), 10, "ring bound holds");
        // only the most recent samples (91..=100) survive
        assert_eq!(h.min(), 91.0);
        assert_eq!(h.max(), 100.0);
        assert!((h.mean() - 95.5).abs() < 1e-9);
        // merging into a ring respects the bound too
        let mut other = Histogram::new();
        for i in 0..50 {
            other.record(i as f64);
        }
        h.merge(&other);
        assert_eq!(h.len(), 10);
    }

    #[test]
    fn histogram_merge_combines_samples() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=50 {
            a.record(i as f64);
        }
        for i in 51..=100 {
            b.record(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.len(), 100);
        assert_eq!(a.percentile(100.0), 100.0);
        assert_eq!(a.min(), 1.0);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        // batched read agrees with the one-at-a-time path
        let batch = h.percentiles(&[0.0, 50.0, 100.0]);
        assert_eq!(batch, vec![h.percentile(0.0), h.percentile(50.0), h.percentile(100.0)]);
        assert_eq!(Histogram::new().percentiles(&[50.0, 99.0]), vec![0.0, 0.0]);
    }
}
