//! Binary wire bodies of the replication protocol.
//!
//! Replication moves raw WAL frames and snapshot bytes, so — unlike the
//! query protocol in [`crate::server::protocol`] — its bodies are binary,
//! not JSON: a fixed little-endian header followed by an opaque payload.
//! Both messages are *total* to decode: any truncation, bad magic, wrong
//! version or length mismatch is a clean error, never a panic — these
//! bytes cross the network.
//!
//! ```text
//! stream chunk    "CHWS" | u32 ver | u32 flags | seg off next_seg next_off
//!                 durable_seg durable_off (u64 each) | u64 len | frames
//! bootstrap chunk "CHWB" | u32 ver | gen replay_seg total_len off
//!                 (u64 each) | u64 len | snapshot bytes
//! ```
//!
//! The `frames` payload of a stream chunk is a whole-frame prefix in the
//! on-disk WAL format ([`crate::wal::frame`]) — the replica re-decodes it
//! with the same torn-tail-tolerant reader the recovery path uses, and
//! treats a partial frame as a protocol violation (the primary never
//! sends one).

use anyhow::{bail, Result};

/// Stream chunk magic.
pub const STREAM_MAGIC: &[u8; 4] = b"CHWS";
/// Bootstrap chunk magic.
pub const BOOTSTRAP_MAGIC: &[u8; 4] = b"CHWB";
/// Wire version both messages carry.
pub const WIRE_VERSION: u32 = 1;
/// `gen` request value meaning "whatever snapshot is current".
pub const GEN_CURRENT: u64 = u64::MAX;

const FLAG_BOOTSTRAP_REQUIRED: u32 = 1;

/// One `/wal/stream` response: whole WAL frames from `(seg, off)`, the
/// position to fetch next, and the primary's durable watermark (for lag
/// accounting). `bootstrap_required` means the requested segment was
/// already garbage-collected — the replica must re-bootstrap from a
/// snapshot before tailing again.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamChunk {
    pub seg: u64,
    pub off: u64,
    pub next_seg: u64,
    pub next_off: u64,
    pub durable_seg: u64,
    pub durable_off: u64,
    pub bootstrap_required: bool,
    pub frames: Vec<u8>,
}

/// One `/wal/bootstrap` response: a window of the snapshot file for
/// generation `gen`, whose WAL replay starts at segment `replay_seg`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BootstrapChunk {
    pub gen: u64,
    pub replay_seg: u64,
    pub total_len: u64,
    pub off: u64,
    pub data: Vec<u8>,
}

// ───────────────────────── encode ─────────────────────────

pub fn encode_stream_chunk(c: &StreamChunk) -> Vec<u8> {
    let mut b = Vec::with_capacity(68 + c.frames.len());
    b.extend_from_slice(STREAM_MAGIC);
    b.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    let flags = if c.bootstrap_required { FLAG_BOOTSTRAP_REQUIRED } else { 0 };
    b.extend_from_slice(&flags.to_le_bytes());
    for v in [c.seg, c.off, c.next_seg, c.next_off, c.durable_seg, c.durable_off] {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b.extend_from_slice(&(c.frames.len() as u64).to_le_bytes());
    b.extend_from_slice(&c.frames);
    b
}

pub fn encode_bootstrap_chunk(c: &BootstrapChunk) -> Vec<u8> {
    let mut b = Vec::with_capacity(48 + c.data.len());
    b.extend_from_slice(BOOTSTRAP_MAGIC);
    b.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    for v in [c.gen, c.replay_seg, c.total_len, c.off] {
        b.extend_from_slice(&v.to_le_bytes());
    }
    b.extend_from_slice(&(c.data.len() as u64).to_le_bytes());
    b.extend_from_slice(&c.data);
    b
}

// ───────────────────────── decode ─────────────────────────

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked: a hostile length field near usize::MAX must error,
        // not wrap past the bounds check into a slice panic
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                anyhow::anyhow!("truncated replication message at byte {}", self.pos)
            })?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn header<'a>(b: &'a [u8], magic: &[u8; 4], what: &str) -> Result<Cursor<'a>> {
    let mut c = Cursor { b, pos: 0 };
    if c.take(4)? != magic {
        bail!("bad magic — not a {what} message");
    }
    let ver = c.u32()?;
    if ver != WIRE_VERSION {
        bail!("unsupported {what} wire version {ver}");
    }
    Ok(c)
}

pub fn decode_stream_chunk(b: &[u8]) -> Result<StreamChunk> {
    let mut c = header(b, STREAM_MAGIC, "stream")?;
    let flags = c.u32()?;
    let (seg, off) = (c.u64()?, c.u64()?);
    let (next_seg, next_off) = (c.u64()?, c.u64()?);
    let (durable_seg, durable_off) = (c.u64()?, c.u64()?);
    let len = c.u64()? as usize;
    let frames = c.take(len)?.to_vec();
    if c.pos != b.len() {
        bail!("stream message has {} trailing bytes", b.len() - c.pos);
    }
    Ok(StreamChunk {
        seg,
        off,
        next_seg,
        next_off,
        durable_seg,
        durable_off,
        bootstrap_required: flags & FLAG_BOOTSTRAP_REQUIRED != 0,
        frames,
    })
}

pub fn decode_bootstrap_chunk(b: &[u8]) -> Result<BootstrapChunk> {
    let mut c = header(b, BOOTSTRAP_MAGIC, "bootstrap")?;
    let (gen, replay_seg) = (c.u64()?, c.u64()?);
    let (total_len, off) = (c.u64()?, c.u64()?);
    let len = c.u64()? as usize;
    let data = c.take(len)?.to_vec();
    if c.pos != b.len() {
        bail!("bootstrap message has {} trailing bytes", b.len() - c.pos);
    }
    let end = off
        .checked_add(len as u64)
        .ok_or_else(|| anyhow::anyhow!("bootstrap window offset overflow"))?;
    if end > total_len {
        bail!("bootstrap window [{off}, {end}) exceeds total {total_len}");
    }
    Ok(BootstrapChunk { gen, replay_seg, total_len, off, data })
}

// ───────────────────────── query params ─────────────────────────

/// Extract `key=<u64>` from an HTTP query string (`a=1&b=2`). Returns
/// `None` for a missing key or an unparsable value.
pub fn param_u64(query: &str, key: &str) -> Option<u64> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        if k == key {
            v.parse().ok()
        } else {
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stream() -> StreamChunk {
        StreamChunk {
            seg: 7,
            off: 1234,
            next_seg: 8,
            next_off: 0,
            durable_seg: 9,
            durable_off: 555,
            bootstrap_required: false,
            frames: vec![1, 2, 3, 4, 5, 0xFF],
        }
    }

    fn sample_bootstrap() -> BootstrapChunk {
        BootstrapChunk {
            gen: 3,
            replay_seg: 12,
            total_len: 100,
            off: 40,
            data: (0..60u8).collect(),
        }
    }

    #[test]
    fn stream_roundtrip() {
        let c = sample_stream();
        assert_eq!(decode_stream_chunk(&encode_stream_chunk(&c)).unwrap(), c);
        let mut flagged = c.clone();
        flagged.bootstrap_required = true;
        flagged.frames.clear();
        assert_eq!(
            decode_stream_chunk(&encode_stream_chunk(&flagged)).unwrap(),
            flagged
        );
    }

    #[test]
    fn bootstrap_roundtrip() {
        let c = sample_bootstrap();
        assert_eq!(decode_bootstrap_chunk(&encode_bootstrap_chunk(&c)).unwrap(), c);
    }

    #[test]
    fn truncation_at_every_byte_is_a_clean_error() {
        let s = encode_stream_chunk(&sample_stream());
        for cut in 0..s.len() {
            assert!(
                decode_stream_chunk(&s[..cut]).is_err(),
                "stream cut at {cut} must error"
            );
        }
        let b = encode_bootstrap_chunk(&sample_bootstrap());
        for cut in 0..b.len() {
            assert!(
                decode_bootstrap_chunk(&b[..cut]).is_err(),
                "bootstrap cut at {cut} must error"
            );
        }
    }

    #[test]
    fn malformed_messages_rejected() {
        // wrong magic, cross-decoding, bad version, trailing junk,
        // window past total — all errors, no panics
        assert!(decode_stream_chunk(b"nope").is_err());
        assert!(decode_stream_chunk(&encode_bootstrap_chunk(&sample_bootstrap())).is_err());
        assert!(decode_bootstrap_chunk(&encode_stream_chunk(&sample_stream())).is_err());
        let mut bad_ver = encode_stream_chunk(&sample_stream());
        bad_ver[4] = 99;
        assert!(decode_stream_chunk(&bad_ver).is_err());
        let mut trailing = encode_stream_chunk(&sample_stream());
        trailing.push(0);
        assert!(decode_stream_chunk(&trailing).is_err());
        // hostile length field: u64::MAX must be a clean error, not an
        // overflow panic (frames_len lives at bytes 60..68)
        let mut huge_len = encode_stream_chunk(&sample_stream());
        huge_len[60..68].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_stream_chunk(&huge_len).is_err());
        let mut past_total = sample_bootstrap();
        past_total.total_len = 10;
        assert!(decode_bootstrap_chunk(&encode_bootstrap_chunk(&past_total)).is_err());
    }

    #[test]
    fn query_param_parsing() {
        assert_eq!(param_u64("seg=3&off=128", "seg"), Some(3));
        assert_eq!(param_u64("seg=3&off=128", "off"), Some(128));
        assert_eq!(param_u64("seg=3&off=128", "max"), None);
        assert_eq!(param_u64("", "seg"), None);
        assert_eq!(param_u64("seg=abc", "seg"), None);
        assert_eq!(param_u64("seg", "seg"), None);
        assert_eq!(param_u64("off=1&off=2", "off"), Some(1), "first occurrence wins");
    }
}
