//! Primary-side replication: serve the WAL stream and snapshot bootstrap
//! off a live [`DurableIndex`]'s directory.
//!
//! Both handlers are pure reads over the durable directory — they take
//! no locks against the WAL writer or the snapshotter. Safety comes from
//! two invariants the durability subsystem already maintains:
//!
//! * **The durable watermark** ([`crate::wal::WalStats::durable_watermark`])
//!   bounds what the stream serves. Bytes past the last fsync exist in
//!   the page cache but can vanish in a crash; shipping them would let a
//!   replica apply an operation the primary is allowed to lose. The
//!   stream therefore caps every read at the watermark — a replica's
//!   state is always a prefix of the *durable* history.
//! * **Snapshot files are immutable** once their atomic rename lands, so
//!   a windowed bootstrap transfer pinned to a generation is internally
//!   consistent; if a checkpoint supersedes (and GCs) that generation
//!   mid-transfer, the next window gets `409 Conflict` and the replica
//!   restarts the transfer against the new generation.
//!
//! A replica that asks for a segment the checkpointer already collected
//! gets a chunk with `bootstrap_required` set instead of an error — the
//! signal to fall back from tailing to a fresh snapshot transfer.

use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use crate::server::protocol::ProtoError;
use crate::wal::{frame, log, snapshot, DurableIndex};

use super::wire::{self, BootstrapChunk, StreamChunk};

/// Per-response cap on streamed frame bytes (also the cap on the `max`
/// query parameter). Well under the HTTP body limit.
pub const MAX_STREAM_BYTES: usize = 1 << 20;
/// Per-response cap on bootstrap snapshot bytes.
pub const MAX_BOOTSTRAP_BYTES: usize = 4 << 20;

fn internal(msg: String) -> ProtoError {
    ProtoError { status: 500, msg }
}

/// Answer `GET /wal/stream?seg=<n>&off=<n>[&max=<bytes>]`.
pub fn handle_stream(d: &DurableIndex, query: &str) -> Result<StreamChunk, ProtoError> {
    let seg = wire::param_u64(query, "seg")
        .ok_or_else(|| ProtoError::bad("missing seg parameter"))?;
    let off = wire::param_u64(query, "off").unwrap_or(0);
    let max = wire::param_u64(query, "max")
        .unwrap_or(MAX_STREAM_BYTES as u64)
        .clamp(1, MAX_STREAM_BYTES as u64) as usize;
    let (durable_seg, durable_off) = d.durable_watermark();
    stream_from_dir(d.dir(), seg, off, max, durable_seg, durable_off)
}

/// The stream read itself, parameterized on the directory and watermark
/// (separable for tests).
pub fn stream_from_dir(
    dir: &Path,
    seg: u64,
    off: u64,
    max: usize,
    durable_seg: u64,
    durable_off: u64,
) -> Result<StreamChunk, ProtoError> {
    let mut chunk = StreamChunk {
        seg,
        off,
        next_seg: seg,
        next_off: off,
        durable_seg,
        durable_off,
        bootstrap_required: false,
        frames: Vec::new(),
    };
    if seg > durable_seg || (seg == durable_seg && off > durable_off) {
        // A correct replica can never be ahead of the watermark: every
        // position it holds came from one of our own next-pointers,
        // which stop at the fsynced frontier, and the frontier is
        // monotone across restarts of the same directory. Being ahead
        // means the history itself regressed (the WAL dir was wiped or
        // replaced) — tell the replica to re-bootstrap onto the new
        // history instead of letting it poll empty chunks forever.
        chunk.bootstrap_required = true;
        return Ok(chunk);
    }
    if seg == durable_seg && off == durable_off {
        // caught-up idle poll — the common steady state: answer without
        // touching the filesystem at all
        return Ok(chunk);
    }
    let mut file = match std::fs::File::open(log::segment_path(dir, seg)) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            // a checkpoint collected this segment: the replica is too
            // far behind to tail — it must re-bootstrap
            chunk.bootstrap_required = true;
            return Ok(chunk);
        }
        Err(e) => return Err(internal(format!("opening wal segment {seg}: {e}"))),
    };
    let file_len = file
        .metadata()
        .map_err(|e| internal(format!("stat wal segment {seg}: {e}")))?
        .len();
    // never serve past the fsynced frontier; segments before the one the
    // writer holds open are complete and fully durable
    let cap = if seg < durable_seg { file_len } else { file_len.min(durable_off) };
    if off < cap {
        // windowed read, not the whole (up to segment_bytes) file: max
        // budget plus one max-size frame, so the at-least-one-frame rule
        // holds even when the first frame exceeds `max`
        let window =
            (cap - off).min((max + frame::FRAME_HEADER + frame::MAX_PAYLOAD) as u64) as usize;
        let mut avail = vec![0u8; window];
        // safe against concurrent appends: the file only ever grows and
        // [off, off+window) lies below `cap`, which was on disk already
        file.seek(SeekFrom::Start(off))
            .and_then(|_| file.read_exact(&mut avail))
            .map_err(|e| internal(format!("reading wal segment {seg}: {e}")))?;
        let read = frame::read_segment_bytes(&avail);
        // largest whole-frame prefix within `max`, but always at least
        // one frame so a tiny `max` (frame-granular tests) still moves
        let mut serve = 0usize;
        for rec in &read.records {
            let flen = frame::frame_len(rec);
            if serve > 0 && serve + flen > max {
                break;
            }
            serve += flen;
        }
        avail.truncate(serve);
        chunk.frames = avail;
    }
    let end = off + chunk.frames.len() as u64;
    if seg < durable_seg && end == file_len {
        // completed segment fully consumed: hop to the next one
        chunk.next_seg = seg + 1;
        chunk.next_off = 0;
    } else {
        chunk.next_seg = seg;
        chunk.next_off = end;
    }
    Ok(chunk)
}

/// Answer `GET /wal/bootstrap?gen=<g>&off=<n>`: one window of the pinned
/// snapshot generation (`gen = u64::MAX` pins whatever is current). A
/// superseded generation returns `409` — restart the transfer.
pub fn handle_bootstrap(d: &DurableIndex, query: &str) -> Result<BootstrapChunk, ProtoError> {
    let want_gen = wire::param_u64(query, "gen").unwrap_or(wire::GEN_CURRENT);
    let off = wire::param_u64(query, "off").unwrap_or(0) as usize;
    let manifest = snapshot::read_manifest(d.dir())
        .map_err(|e| internal(format!("reading manifest: {e:#}")))?
        .ok_or_else(|| internal("durable directory has no manifest".to_string()))?;
    if want_gen != wire::GEN_CURRENT && want_gen != manifest.snapshot_gen {
        return Err(ProtoError {
            status: 409,
            msg: format!(
                "snapshot gen {want_gen} superseded by {} — restart the bootstrap",
                manifest.snapshot_gen
            ),
        });
    }
    let path = snapshot::snapshot_path(d.dir(), manifest.snapshot_gen);
    let mut file = match std::fs::File::open(&path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Err(ProtoError {
                status: 409,
                msg: "snapshot superseded during transfer — restart the bootstrap"
                    .to_string(),
            });
        }
        Err(e) => return Err(internal(format!("opening {}: {e}", path.display()))),
    };
    let total_len = file
        .metadata()
        .map_err(|e| internal(format!("stat {}: {e}", path.display())))?
        .len();
    if off as u64 > total_len {
        return Err(ProtoError::bad(format!(
            "bootstrap offset {off} beyond snapshot ({total_len} bytes)"
        )));
    }
    // one window per request, seeked — not an O(file) read per window
    // (snapshot files are immutable once renamed in, so this is stable)
    let window = (total_len - off as u64).min(MAX_BOOTSTRAP_BYTES as u64) as usize;
    let mut data = vec![0u8; window];
    file.seek(SeekFrom::Start(off as u64))
        .and_then(|_| file.read_exact(&mut data))
        .map_err(|e| internal(format!("reading {}: {e}", path.display())))?;
    Ok(BootstrapChunk {
        gen: manifest.snapshot_gen,
        replay_seg: manifest.replay_from_seq,
        total_len,
        off: off as u64,
        data,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::online::ShardedIndex;
    use crate::wal::{frame::Record, WalConfig};
    use std::path::PathBuf;
    use std::sync::Arc;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("chh_repl_primary_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn frames_of(chunk: &StreamChunk) -> Vec<Record> {
        let read = frame::read_segment_bytes(&chunk.frames);
        assert!(!read.torn, "stream chunks hold whole frames only");
        read.records
    }

    #[test]
    fn stream_serves_acked_prefix_and_advances() {
        let dir = tmpdir("serve");
        let d = DurableIndex::create(
            Arc::new(ShardedIndex::new(10, 2, 2)),
            &WalConfig::new(&dir),
        )
        .unwrap();
        for id in 0..6u32 {
            d.insert(id, id as u64).unwrap();
        }
        // frame-at-a-time (max=1 still serves one whole frame)
        let (mut seg, mut off) = (1u64, 0u64);
        let mut got = Vec::new();
        loop {
            let c =
                handle_stream(&d, &format!("seg={seg}&off={off}&max=1")).unwrap();
            assert!(!c.bootstrap_required);
            let recs = frames_of(&c);
            assert!(recs.len() <= 1);
            if recs.is_empty() && (c.next_seg, c.next_off) == (seg, off) {
                break; // caught up with the watermark
            }
            got.extend(recs);
            seg = c.next_seg;
            off = c.next_off;
        }
        let want: Vec<Record> =
            (0..6u32).map(|id| Record::Insert { id, code: id as u64 }).collect();
        assert_eq!(got, want);
        // the final position equals the durable watermark
        assert_eq!((seg, off), d.durable_watermark());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gcd_segment_demands_bootstrap_and_bootstrap_windows_assemble() {
        let dir = tmpdir("gc");
        let d = DurableIndex::create(
            Arc::new(ShardedIndex::new(10, 2, 2)),
            &WalConfig::new(&dir),
        )
        .unwrap();
        for id in 0..10u32 {
            d.insert(id, 3).unwrap();
        }
        d.checkpoint().unwrap(); // collects segment 1
        let c = handle_stream(&d, "seg=1&off=0").unwrap();
        assert!(c.bootstrap_required, "GC'd segment must demand a bootstrap");
        // windowed transfer pinned to the current generation
        let first = handle_bootstrap(&d, "").unwrap();
        assert_eq!(first.off, 0);
        let mut buf = first.data.clone();
        while (buf.len() as u64) < first.total_len {
            let c = handle_bootstrap(
                &d,
                &format!("gen={}&off={}", first.gen, buf.len()),
            )
            .unwrap();
            assert!(!c.data.is_empty());
            buf.extend_from_slice(&c.data);
        }
        let snap = crate::persist::load_sharded_bytes(&buf).unwrap();
        assert_eq!(snap.len(), 10);
        // a stale pinned generation is refused with 409
        let err = handle_bootstrap(&d, &format!("gen={}&off=0", first.gen + 7))
            .unwrap_err();
        assert_eq!(err.status, 409);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stream_respects_the_durable_watermark() {
        let dir = tmpdir("watermark");
        // lazy fsync: acked-but-unsynced bytes must not be streamed
        let mut cfg = WalConfig::new(&dir);
        cfg.fsync = crate::wal::FsyncPolicy::EveryN(1_000_000);
        let d =
            DurableIndex::create(Arc::new(ShardedIndex::new(10, 2, 2)), &cfg).unwrap();
        for id in 0..4u32 {
            d.insert(id, 1).unwrap();
        }
        let c = handle_stream(&d, "seg=1&off=0").unwrap();
        assert!(
            frames_of(&c).is_empty(),
            "unsynced frames are on disk but must not be served"
        );
        d.flush().unwrap();
        let c = handle_stream(&d, "seg=1&off=0").unwrap();
        assert_eq!(frames_of(&c).len(), 4, "flush makes them durable and servable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replica_ahead_of_the_watermark_is_told_to_rebootstrap() {
        let dir = tmpdir("ahead");
        let d = DurableIndex::create(
            Arc::new(ShardedIndex::new(10, 2, 2)),
            &WalConfig::new(&dir),
        )
        .unwrap();
        d.insert(1, 1).unwrap();
        let (dseg, doff) = d.durable_watermark();
        // beyond the open segment, and beyond the offset within it:
        // both mean the history this position came from no longer
        // exists (wiped/replaced WAL dir) — resync, don't stall
        let c = handle_stream(&d, &format!("seg={}&off=0", dseg + 5)).unwrap();
        assert!(c.bootstrap_required);
        let c = handle_stream(&d, &format!("seg={dseg}&off={}", doff + 999)).unwrap();
        assert!(c.bootstrap_required);
        // exactly at the watermark is the normal caught-up poll
        let c = handle_stream(&d, &format!("seg={dseg}&off={doff}")).unwrap();
        assert!(!c.bootstrap_required && c.frames.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
