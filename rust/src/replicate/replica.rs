//! Replica-side replication: bootstrap from the primary's snapshot, then
//! tail its WAL stream and apply records in journal order.
//!
//! A [`ReplicaIndex`] wraps a [`ShardedIndex`] exactly the way
//! [`crate::wal::DurableIndex`] does on the primary, with one apply path
//! serialized under an order lock — so for any position `(seg, off)` in
//! the durable history, the replica's live set is byte-for-byte the same
//! set the primary's recovery would produce at that position, and its
//! query answers (ids, margin bits, scanned/probed counters) are
//! bit-identical to the primary's over that prefix.
//!
//! The [`Tailer`] is the background driver: fetch a chunk, apply it,
//! poll again. It survives primary restarts (reconnect with backoff) and
//! falling behind a checkpoint's segment GC (`bootstrap_required` →
//! [`ReplicaIndex::resync`], a diff-apply of a fresh snapshot). During a
//! resync the replica keeps answering reads — stale, and flagged
//! `resyncing` in `/stats` — but it only ever holds entries that came
//! from fsynced primary state, so an unacknowledged op is never served.
//!
//! Transport: the stream and snapshot payloads are already binary
//! ([`super::wire`] CHWS/CHWB frames over plain HTTP bodies) — the
//! query-path binary protocol in [`crate::server::binproto`] follows the
//! same length-prefixed total-decoding idiom. The tailer's `HttpClient`
//! carries read *and* write socket timeouts (`set_timeout`), so a hung
//! primary surfaces as a reconnect, never a parked tailer thread.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{obj, Json};
use crate::metrics::Gauge;
use crate::online::ShardedIndex;
use crate::server::HttpClient;
use crate::wal::frame::{read_segment_bytes, Record};

use super::wire::{self, StreamChunk};

/// How a replica reaches (and paces against) its primary.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// primary address (`host:port` of a `serve-http --wal-dir` server)
    pub primary: String,
    /// idle sleep once caught up with the durable watermark
    pub poll: Duration,
    /// sleep before reconnecting after a transport error
    pub backoff: Duration,
    /// per-fetch cap on streamed frame bytes
    pub max_bytes: usize,
    /// HTTP connect/read timeout
    pub timeout: Duration,
}

impl ReplicaConfig {
    pub fn new(primary: impl Into<String>) -> Self {
        ReplicaConfig {
            primary: primary.into(),
            poll: Duration::from_millis(20),
            backoff: Duration::from_millis(200),
            max_bytes: super::primary::MAX_STREAM_BYTES,
            timeout: Duration::from_secs(5),
        }
    }
}

/// A read-only index kept in sync by WAL shipping. See the module docs
/// for the consistency contract.
pub struct ReplicaIndex {
    index: Arc<ShardedIndex>,
    /// serializes apply (and resync) so stream order == apply order —
    /// the replica-side twin of the primary's order lock
    order: Mutex<()>,
    /// applied stream position `(seg, off)` — one mutex per pair (like
    /// [`crate::wal::WalStats::durable_watermark`]) so a concurrent
    /// `/stats` or convergence poll never observes a torn pair
    applied: Mutex<(u64, u64)>,
    /// primary durable watermark as last observed on the stream
    primary_wm: Mutex<(u64, u64)>,
    applied_records: Gauge,
    checkpoints_seen: Gauge,
    bootstraps: Gauge,
    reconnects: Gauge,
    /// full resynchronizations after falling behind a segment GC
    /// (bootstraps = 1 initial + resyncs)
    resyncs: Gauge,
    /// when the last chunk was applied — drives the lag-age gauge on
    /// `/metrics` (how stale are reads, in wall-clock terms)
    last_apply: Mutex<Option<Instant>>,
    resyncing: AtomicBool,
}

impl ReplicaIndex {
    /// Wrap an index whose contents are a snapshot covering everything
    /// before `(start_seg, 0)` — the constructor [`Self::bootstrap`] and
    /// the tests share.
    pub fn from_snapshot(index: ShardedIndex, start_seg: u64) -> Arc<ReplicaIndex> {
        Arc::new(ReplicaIndex {
            index: Arc::new(index),
            order: Mutex::new(()),
            applied: Mutex::new((start_seg, 0)),
            primary_wm: Mutex::new((0, 0)),
            applied_records: Gauge::new(0),
            checkpoints_seen: Gauge::new(0),
            bootstraps: Gauge::new(1),
            reconnects: Gauge::new(0),
            resyncs: Gauge::new(0),
            last_apply: Mutex::new(None),
            resyncing: AtomicBool::new(false),
        })
    }

    /// Connect to the primary, transfer its current snapshot, and return
    /// a replica positioned at that snapshot's replay start.
    pub fn bootstrap(cfg: &ReplicaConfig) -> Result<Arc<ReplicaIndex>> {
        let mut client = HttpClient::connect_retry(&cfg.primary, cfg.timeout)
            .with_context(|| format!("connecting to primary {}", cfg.primary))?;
        client.set_timeout(cfg.timeout)?;
        let (_gen, replay_seg, bytes) = fetch_snapshot(&mut client)?;
        let index = crate::persist::load_sharded_bytes(&bytes)
            .context("parsing bootstrap snapshot")?;
        Ok(Self::from_snapshot(index, replay_seg))
    }

    /// The served index (share this `Arc` with a router — reads need no
    /// coordination with the tailer beyond the index's own epochs).
    pub fn index(&self) -> &Arc<ShardedIndex> {
        &self.index
    }

    /// Position `(segment, offset)` up to which the stream is applied.
    pub fn position(&self) -> (u64, u64) {
        *self.applied.lock().unwrap()
    }

    /// Insert/remove records applied since process start (checkpoint
    /// markers are counted separately).
    pub fn applied_records(&self) -> u64 {
        self.applied_records.get()
    }

    /// Bootstrap transfers performed (1 = just the initial one).
    pub fn bootstraps(&self) -> u64 {
        self.bootstraps.get()
    }

    pub fn reconnects(&self) -> u64 {
        self.reconnects.get()
    }

    /// Full resyncs performed after falling behind a segment GC.
    pub fn resyncs(&self) -> u64 {
        self.resyncs.get()
    }

    /// Seconds since the last applied chunk (`None` before the first).
    pub fn applied_age_secs(&self) -> Option<f64> {
        self.last_apply.lock().unwrap().map(|t| t.elapsed().as_secs_f64())
    }

    pub(crate) fn note_reconnect(&self) {
        self.reconnects.add(1);
    }

    /// The primary durable watermark as last observed on the stream
    /// (`(0, 0)` before the first chunk).
    pub fn observed_watermark(&self) -> (u64, u64) {
        *self.primary_wm.lock().unwrap()
    }

    /// Whether the replica has applied everything the primary reported
    /// durable (false until the first chunk has been observed).
    pub fn caught_up(&self) -> bool {
        let wm = self.observed_watermark();
        wm.0 != 0 && self.position() == wm
    }

    /// `(lag_segments, lag_bytes)` against the last observed primary
    /// watermark; `lag_bytes` is exact only while the replica is inside
    /// the primary's current segment (`None` otherwise, and before the
    /// first chunk).
    pub fn lag(&self) -> (u64, Option<u64>) {
        let (pseg, poff) = self.observed_watermark();
        if pseg == 0 {
            return (0, None);
        }
        let (aseg, aoff) = self.position();
        let segs = pseg.saturating_sub(aseg);
        let bytes = if segs == 0 { Some(poff.saturating_sub(aoff)) } else { None };
        (segs, bytes)
    }

    /// Apply one stream chunk: whole frames, in order, under the order
    /// lock; then advance the position to the chunk's `next` pointer.
    /// Returns the number of insert/remove records applied.
    pub fn apply_chunk(&self, chunk: &StreamChunk) -> Result<usize> {
        *self.primary_wm.lock().unwrap() = (chunk.durable_seg, chunk.durable_off);
        if chunk.bootstrap_required {
            bail!("chunk demands a bootstrap — call resync() instead");
        }
        let (aseg, aoff) = self.position();
        if (chunk.seg, chunk.off) != (aseg, aoff) {
            bail!(
                "chunk starts at ({}, {}) but replica is at ({aseg}, {aoff})",
                chunk.seg,
                chunk.off
            );
        }
        let read = read_segment_bytes(&chunk.frames);
        if read.torn {
            bail!("stream chunk contains a partial frame (protocol violation)");
        }
        let _g = self.order.lock().unwrap();
        let mut applied = 0u64;
        for rec in &read.records {
            match *rec {
                Record::Insert { id, code } => {
                    self.index.insert(id, code);
                    applied += 1;
                }
                Record::Remove { id } => {
                    self.index.remove(id);
                    applied += 1;
                }
                Record::Checkpoint { .. } => {
                    self.checkpoints_seen.add(1);
                }
            }
        }
        self.applied_records.add(applied);
        *self.applied.lock().unwrap() = (chunk.next_seg, chunk.next_off);
        *self.last_apply.lock().unwrap() = Some(Instant::now());
        Ok(applied as usize)
    }

    /// Full resynchronization after falling behind a segment GC: fetch a
    /// fresh snapshot and diff-apply it (remove what the snapshot lost,
    /// upsert what it holds, in the snapshot's deterministic order),
    /// then resume tailing at its replay start. Reads keep flowing
    /// meanwhile — stale, flagged `resyncing`, and still built only from
    /// durable primary state.
    ///
    /// Caveat shared with crash recovery: the replica's within-bucket
    /// scan order can differ from the live primary's (compaction
    /// histories diverge), so two candidates with *exactly* equal f32
    /// margins may tie-break differently on `/query` (first-encountered
    /// wins); `/query_topk` orders ties by id and is unaffected.
    pub fn resync(&self, client: &mut HttpClient) -> Result<()> {
        self.resyncing.store(true, Ordering::SeqCst);
        self.resyncs.add(1);
        let out = self.resync_inner(client);
        self.resyncing.store(false, Ordering::SeqCst);
        out
    }

    fn resync_inner(&self, client: &mut HttpClient) -> Result<()> {
        let (_gen, replay_seg, bytes) = fetch_snapshot(client)?;
        let snap =
            crate::persist::load_sharded_bytes(&bytes).context("parsing resync snapshot")?;
        if snap.bits() != self.index.bits()
            || snap.radius() != self.index.radius()
            || snap.shard_count() != self.index.shard_count()
        {
            bail!(
                "primary snapshot layout changed (k={} r={} shards={} vs local k={} r={} \
                 shards={}) — restart the replica",
                snap.bits(),
                snap.radius(),
                snap.shard_count(),
                self.index.bits(),
                self.index.radius(),
                self.index.shard_count()
            );
        }
        let _g = self.order.lock().unwrap();
        let mut have: std::collections::HashMap<u32, u64> = std::collections::HashMap::new();
        for s in self.index.shards() {
            for (id, code) in s.live_entries() {
                have.insert(id, code);
            }
        }
        // apply the snapshot in its own (deterministic) order: entries
        // already correct stay in place, everything else upserts —
        // never in HashMap iteration order, which is randomized
        for s in snap.shards() {
            for (id, code) in s.live_entries() {
                match have.remove(&id) {
                    Some(c) if c == code => {}
                    _ => self.index.insert(id, code),
                }
            }
        }
        // whatever is left was dropped by the snapshot's history
        for (id, _) in have {
            self.index.remove(id);
        }
        *self.applied.lock().unwrap() = (replay_seg, 0);
        self.bootstraps.add(1);
        Ok(())
    }

    /// Whether a resync transfer is in flight right now.
    pub fn resyncing(&self) -> bool {
        self.resyncing.load(Ordering::SeqCst)
    }

    /// The `/stats` replication section.
    pub fn stats_json(&self, primary_addr: &str) -> Json {
        let (lag_segments, lag_bytes) = self.lag();
        let (aseg, aoff) = self.position();
        let (pseg, poff) = self.observed_watermark();
        obj(vec![
            ("primary", Json::from(primary_addr)),
            ("applied_seg", Json::from(aseg as usize)),
            ("applied_off", Json::from(aoff as usize)),
            ("applied_records", Json::from(self.applied_records.get() as usize)),
            ("checkpoints_seen", Json::from(self.checkpoints_seen.get() as usize)),
            ("primary_durable_seg", Json::from(pseg as usize)),
            ("primary_durable_off", Json::from(poff as usize)),
            ("lag_segments", Json::from(lag_segments as usize)),
            (
                "lag_bytes",
                match lag_bytes {
                    Some(b) => Json::from(b as usize),
                    None => Json::Null,
                },
            ),
            ("caught_up", Json::from(self.caught_up())),
            ("resyncing", Json::from(self.resyncing())),
            ("bootstraps", Json::from(self.bootstraps.get() as usize)),
            ("reconnects", Json::from(self.reconnects.get() as usize)),
            ("resyncs", Json::from(self.resyncs.get() as usize)),
            (
                "applied_age_secs",
                match self.applied_age_secs() {
                    Some(a) => Json::Num(a),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Windowed snapshot transfer: pin the first window's generation, fetch
/// until `total_len`, restart (bounded) when a checkpoint supersedes the
/// pinned generation mid-transfer.
fn fetch_snapshot(client: &mut HttpClient) -> Result<(u64, u64, Vec<u8>)> {
    const MAX_RESTARTS: usize = 16;
    for _ in 0..MAX_RESTARTS {
        let mut gen = wire::GEN_CURRENT;
        let mut replay_seg = 0u64;
        let mut buf: Vec<u8> = Vec::new();
        let mut superseded = false;
        loop {
            let path = format!("/wal/bootstrap?gen={gen}&off={}", buf.len());
            let resp = client
                .get(&path)
                .map_err(|e| anyhow!("GET {path}: {e}"))?;
            if resp.status == 409 {
                superseded = true;
                break;
            }
            if resp.status != 200 {
                bail!(
                    "bootstrap returned {}: {}",
                    resp.status,
                    String::from_utf8_lossy(&resp.body)
                );
            }
            let chunk = wire::decode_bootstrap_chunk(&resp.body)?;
            if chunk.off as usize != buf.len() {
                bail!("bootstrap window at {} but expected {}", chunk.off, buf.len());
            }
            gen = chunk.gen;
            replay_seg = chunk.replay_seg;
            if chunk.data.is_empty() && (buf.len() as u64) < chunk.total_len {
                bail!("empty bootstrap window before total_len");
            }
            buf.extend_from_slice(&chunk.data);
            if buf.len() as u64 >= chunk.total_len {
                return Ok((gen, replay_seg, buf));
            }
        }
        if !superseded {
            break;
        }
        // superseded: loop around and pin the new current generation
    }
    bail!("bootstrap kept getting superseded — primary checkpointing too fast")
}

/// Handle to the background tail thread; joins on [`Self::stop`] or drop.
pub struct Tailer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Tailer {
    /// Signal the loop to stop and join it.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Tailer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Spawn the tail loop for `replica` against `cfg.primary`.
pub fn spawn_tailer(replica: Arc<ReplicaIndex>, cfg: ReplicaConfig) -> Tailer {
    let stop = Arc::new(AtomicBool::new(false));
    let tstop = stop.clone();
    let handle = std::thread::Builder::new()
        .name("chh-replica-tail".to_string())
        .spawn(move || tail_loop(&replica, &cfg, &tstop))
        .expect("spawn replica tailer");
    Tailer { stop, handle: Some(handle) }
}

fn tail_loop(replica: &ReplicaIndex, cfg: &ReplicaConfig, stop: &AtomicBool) {
    let mut client: Option<HttpClient> = None;
    // one correlation id per primary connection, sent as
    // `x-chh-request-id` on every poll: the primary's access metrics and
    // slow-query log carry the same id, so a replication stall can be
    // followed primary → WAL → replica from either side's logs
    let mut conn_id = crate::obs::gen_request_id();
    while !stop.load(Ordering::SeqCst) {
        if client.is_none() {
            match HttpClient::connect_with_timeout(&cfg.primary, cfg.timeout) {
                Ok(c) => {
                    let _ = c.set_timeout(cfg.timeout);
                    conn_id = crate::obs::gen_request_id();
                    client = Some(c);
                }
                Err(_) => {
                    replica.note_reconnect();
                    std::thread::sleep(cfg.backoff);
                    continue;
                }
            }
        }
        let c = client.as_mut().expect("client just ensured");
        let (seg, off) = replica.position();
        let path = format!("/wal/stream?seg={seg}&off={off}&max={}", cfg.max_bytes);
        let step = (|| -> Result<bool> {
            let resp = c.get_with_id(&path, &conn_id).map_err(|e| anyhow!("GET {path}: {e}"))?;
            if resp.status != 200 {
                bail!(
                    "stream returned {}: {}",
                    resp.status,
                    String::from_utf8_lossy(&resp.body)
                );
            }
            let chunk = wire::decode_stream_chunk(&resp.body)?;
            if chunk.bootstrap_required {
                replica.resync(c).context("resync after segment GC")?;
                return Ok(true);
            }
            let n = replica.apply_chunk(&chunk)?;
            Ok(n > 0 || (chunk.next_seg, chunk.next_off) != (seg, off))
        })();
        match step {
            Ok(true) => {} // progressed: fetch again immediately
            Ok(false) => std::thread::sleep(cfg.poll),
            Err(e) => {
                eprintln!("replica tailer: {e:#} (request_id={conn_id}); reconnecting");
                client = None;
                replica.note_reconnect();
                std::thread::sleep(cfg.backoff);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wal::frame::encode_into;

    fn chunk_of(records: &[Record], seg: u64, off: u64) -> StreamChunk {
        let mut frames = Vec::new();
        for r in records {
            encode_into(r, &mut frames);
        }
        let next_off = off + frames.len() as u64;
        StreamChunk {
            seg,
            off,
            next_seg: seg,
            next_off,
            durable_seg: seg,
            durable_off: next_off,
            bootstrap_required: false,
            frames,
        }
    }

    #[test]
    fn apply_chunk_advances_position_and_state() {
        let r = ReplicaIndex::from_snapshot(ShardedIndex::new(8, 2, 2), 1);
        assert_eq!(r.position(), (1, 0));
        assert!(!r.caught_up(), "no watermark observed yet");
        let c = chunk_of(
            &[
                Record::Insert { id: 1, code: 3 },
                Record::Insert { id: 2, code: 5 },
                Record::Checkpoint { gen: 1 },
                Record::Remove { id: 1 },
            ],
            1,
            0,
        );
        assert_eq!(r.apply_chunk(&c).unwrap(), 3, "checkpoint markers not counted");
        assert_eq!(r.index().len(), 1);
        assert!(r.index().contains(2) && !r.index().contains(1));
        assert_eq!(r.position(), (c.next_seg, c.next_off));
        assert!(r.caught_up());
        assert_eq!(r.lag(), (0, Some(0)));
    }

    #[test]
    fn apply_chunk_rejects_position_mismatch_and_torn_frames() {
        let r = ReplicaIndex::from_snapshot(ShardedIndex::new(8, 2, 2), 1);
        let misplaced = chunk_of(&[Record::Insert { id: 1, code: 1 }], 1, 999);
        assert!(r.apply_chunk(&misplaced).is_err());
        let mut torn = chunk_of(&[Record::Insert { id: 1, code: 1 }], 1, 0);
        torn.frames.pop();
        assert!(r.apply_chunk(&torn).is_err());
        assert_eq!(r.position(), (1, 0), "failed chunks must not move the position");
        assert_eq!(r.index().len(), 0);
    }

    #[test]
    fn lag_accounting_across_segments() {
        let r = ReplicaIndex::from_snapshot(ShardedIndex::new(8, 2, 2), 1);
        let mut c = chunk_of(&[Record::Insert { id: 1, code: 1 }], 1, 0);
        c.durable_seg = 3;
        c.durable_off = 40;
        r.apply_chunk(&c).unwrap();
        let (segs, bytes) = r.lag();
        assert_eq!(segs, 2);
        assert_eq!(bytes, None, "cross-segment byte lag is unknowable");
        assert!(!r.caught_up());
    }
}
