//! Replicated serving: WAL shipping from a durable primary to N read
//! replicas — the horizontal read-scaling layer over [`crate::wal`] and
//! [`crate::server`].
//!
//! ```text
//!              writes                        GET /wal/stream?seg&off
//!  clients ──▶ primary (serve-http --wal-dir) ◀──────────────┐
//!              │ WAL: journal → fsync → durable watermark    │ tail + apply
//!              ▼                                             │ (order lock)
//!           snapshots ── GET /wal/bootstrap ──▶ replica (serve-http --replica-of)
//!  clients ──▶ reads (round-robin) ──▶ replicas: /query /query_topk /stats
//!                                      mutations → 421 + primary address
//! ```
//!
//! Three pieces:
//!
//! * **Wire** ([`wire`]) — binary chunk formats for the two transfer
//!   endpoints; total decoding, clean errors on any damage.
//! * **Primary** ([`primary`]) — lock-free handlers over the durable
//!   directory: the stream serves whole WAL frames **capped at the
//!   fsynced watermark** (an op a crash could lose is never shipped),
//!   and the bootstrap serves windowed snapshot bytes pinned to a
//!   generation (superseded mid-transfer → `409`, restart).
//! * **Replica** ([`replica`]) — [`ReplicaIndex`] applies records in
//!   journal order under an order lock, exactly like the primary's
//!   [`crate::wal::DurableIndex`], so replica query answers are
//!   **bit-identical** to the primary's for every durable prefix
//!   (`rust/tests/replication_faults.rs` asserts this at every frame
//!   boundary); the [`Tailer`] drives it, reconnecting through primary
//!   restarts and re-bootstrapping when it falls behind a segment GC.
//!
//! `chh serve-http --replica-of <addr>` runs the replica; `chh loadgen
//! --replicas <addrs>` fans reads out across the fleet. Protocol, lag
//! semantics and the failover runbook live in `docs/REPLICATION.md`.

pub mod primary;
pub mod replica;
pub mod wire;

pub use replica::{spawn_tailer, ReplicaConfig, ReplicaIndex, Tailer};
pub use wire::{BootstrapChunk, StreamChunk};

use crate::hash::HashFamily;

/// Content fingerprint of a hash family: an FNV-1a fold of the codes it
/// assigns to a small deterministic probe set. Two families sampled with
/// different seeds (same dim/bits/kind) fingerprint differently with
/// overwhelming probability, so a replica can verify it holds the
/// primary's *actual* hyperplanes — `bits`+`family` name alone cannot
/// catch a `--seed` mismatch, which would silently break answer parity.
/// Served in `/stats` as `family_check`; 32-bit so it survives the JSON
/// f64 number path exactly.
pub fn family_fingerprint(family: &dyn HashFamily, dim: usize) -> u32 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for i in 0..4usize {
        let w: Vec<f32> = (0..dim)
            .map(|j| ((i * 31 + j * 17) % 23) as f32 / 7.0 - 1.5)
            .collect();
        for b in family.encode_query(&w).to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    (h ^ (h >> 32)) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::BhHash;
    use crate::rng::Rng;

    #[test]
    fn family_fingerprint_is_deterministic_and_seed_sensitive() {
        let a = BhHash::sample(16, 10, &mut Rng::seed_from_u64(1));
        let b = BhHash::sample(16, 10, &mut Rng::seed_from_u64(2));
        assert_eq!(family_fingerprint(&a, 16), family_fingerprint(&a, 16));
        assert_ne!(
            family_fingerprint(&a, 16),
            family_fingerprint(&b, 16),
            "different seeds must fingerprint differently"
        );
    }
}
