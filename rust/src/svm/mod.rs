//! Linear SVM via dual coordinate descent — the LIBLINEAR stand-in.
//!
//! The paper drives active learning with LIBLINEAR; this module implements
//! the same algorithm family (Hsieh et al., ICML 2008: "A Dual Coordinate
//! Descent Method for Large-scale Linear SVM") for L1-loss and L2-loss
//! L2-regularized SVC:
//!
//! ```text
//! min_w  ½‖w‖² + C Σ_i max(0, 1 − y_i wᵀx_i)^p        p ∈ {1, 2}
//! ```
//!
//! solved in the dual over α ∈ [0, U]ⁿ with `w = Σ α_i y_i x_i` maintained
//! incrementally. Warm starting from the previous iteration's α is what
//! makes 300 AL retrains cheap: adding one labeled point changes the
//! optimum only locally.

use crate::data::{FeatRef, FeatureStore};
use crate::rng::Rng;

/// Loss variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// hinge (U = C)
    L1,
    /// squared hinge (U = ∞, diagonal shift 1/(2C))
    L2,
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SvmConfig {
    pub c: f32,
    pub loss: Loss,
    /// stop when the maximal projected gradient violation < tol
    pub tol: f32,
    /// hard cap on epochs over the data
    pub max_epochs: usize,
    pub seed: u64,
    /// multiplier on C for positive examples (LIBLINEAR's `-w1`); the AL
    /// engine sets this to n_neg/n_pos so the accumulating near-boundary
    /// negatives of margin-based selection don't drown the positives
    pub pos_weight: f32,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig { c: 1.0, loss: Loss::L1, tol: 1e-3, max_epochs: 60, seed: 1, pos_weight: 1.0 }
    }
}

/// A trained (or warm-startable) linear model for one binary problem.
#[derive(Clone, Debug)]
pub struct LinearSvm {
    /// primal weights (dim = feature dim)
    pub w: Vec<f32>,
    /// dual variables, parallel to the training index list
    pub alpha: Vec<f32>,
    /// epochs used by the last `train` call
    pub epochs_run: usize,
}

impl LinearSvm {
    pub fn new(dim: usize) -> Self {
        LinearSvm { w: vec![0.0; dim], alpha: Vec::new(), epochs_run: 0 }
    }

    /// Decision value wᵀx.
    #[inline]
    pub fn score(&self, x: FeatRef<'_>) -> f32 {
        x.dot(&self.w)
    }

    /// Extend the dual with zeros for newly added training points
    /// (w is unchanged — α=0 contributes nothing).
    pub fn grow_to(&mut self, n: usize) {
        if self.alpha.len() < n {
            self.alpha.resize(n, 0.0);
        }
    }

    /// Train with dual coordinate descent on `idx`/`y` (y_i ∈ {−1, +1}).
    /// Existing `self.alpha`/`self.w` are used as a warm start; call
    /// [`Self::grow_to`] first when the training set grew.
    pub fn train(&mut self, feats: &FeatureStore, idx: &[usize], y: &[f32], cfg: &SvmConfig) {
        assert_eq!(idx.len(), y.len());
        let n = idx.len();
        self.grow_to(n);
        assert!(self.alpha.len() >= n);
        let (u_pos, u_neg, diag_pos, diag_neg) = match cfg.loss {
            Loss::L1 => (cfg.c * cfg.pos_weight, cfg.c, 0.0f32, 0.0f32),
            Loss::L2 => (
                f32::INFINITY,
                f32::INFINITY,
                0.5 / (cfg.c * cfg.pos_weight),
                0.5 / cfg.c,
            ),
        };
        // Per-point squared norms (Q_ii = x_iᵀx_i + diag).
        let qii: Vec<f32> = idx
            .iter()
            .enumerate()
            .map(|(t, &i)| {
                feats.row(i).sq_norm() + if y[t] > 0.0 { diag_pos } else { diag_neg }
            })
            .collect();
        // Rebuild w from alpha to stay consistent under warm starts where
        // the caller may have mutated labels (cheap: labeled sets are small).
        for v in self.w.iter_mut() {
            *v = 0.0;
        }
        for t in 0..n {
            let a = self.alpha[t];
            if a != 0.0 {
                feats.row(idx[t]).axpy_into(a * y[t], &mut self.w);
            }
        }
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from_u64(cfg.seed);
        self.epochs_run = 0;
        for epoch in 0..cfg.max_epochs {
            rng.shuffle(&mut order);
            let mut max_violation = 0.0f32;
            for &t in &order {
                let i = idx[t];
                if qii[t] <= 0.0 {
                    continue;
                }
                let xi = feats.row(i);
                let (u_bound, diag) =
                    if y[t] > 0.0 { (u_pos, diag_pos) } else { (u_neg, diag_neg) };
                let g = y[t] * xi.dot(&self.w) - 1.0 + diag * self.alpha[t];
                let a = self.alpha[t];
                // projected gradient
                let pg = if a <= 0.0 {
                    g.min(0.0)
                } else if a >= u_bound {
                    g.max(0.0)
                } else {
                    g
                };
                if pg.abs() > max_violation {
                    max_violation = pg.abs();
                }
                if pg.abs() > 1e-12 {
                    let a_new = (a - g / qii[t]).clamp(0.0, u_bound);
                    let delta = a_new - a;
                    if delta != 0.0 {
                        self.alpha[t] = a_new;
                        xi.axpy_into(delta * y[t], &mut self.w);
                    }
                }
            }
            self.epochs_run = epoch + 1;
            if max_violation < cfg.tol {
                break;
            }
        }
    }

    /// Primal objective ½‖w‖² + C Σ loss (for convergence tests).
    pub fn primal_objective(
        &self,
        feats: &FeatureStore,
        idx: &[usize],
        y: &[f32],
        cfg: &SvmConfig,
    ) -> f64 {
        let mut obj = 0.5 * crate::linalg::dot(&self.w, &self.w) as f64;
        for (t, &i) in idx.iter().enumerate() {
            let margin = 1.0 - y[t] * self.score(feats.row(i));
            let ci = if y[t] > 0.0 { cfg.c * cfg.pos_weight } else { cfg.c };
            if margin > 0.0 {
                obj += ci as f64
                    * match cfg.loss {
                        Loss::L1 => margin as f64,
                        Loss::L2 => (margin * margin) as f64,
                    };
            }
        }
        obj
    }

    /// Training accuracy (sanity checks).
    pub fn accuracy(&self, feats: &FeatureStore, idx: &[usize], y: &[f32]) -> f64 {
        let correct = idx
            .iter()
            .enumerate()
            .filter(|(t, &i)| self.score(feats.row(i)) * y[*t] > 0.0)
            .count();
        correct as f64 / idx.len().max(1) as f64
    }
}

/// One-vs-all multiclass wrapper (the paper's experimental protocol).
pub struct OneVsAll {
    pub models: Vec<LinearSvm>,
}

impl OneVsAll {
    /// Train `classes` binary models over the same labeled index set.
    pub fn train(
        feats: &FeatureStore,
        idx: &[usize],
        labels: &[u16],
        classes: usize,
        cfg: &SvmConfig,
    ) -> Self {
        let models = (0..classes)
            .map(|c| {
                let y: Vec<f32> =
                    idx.iter().map(|&i| if labels[i] == c as u16 { 1.0 } else { -1.0 }).collect();
                let mut m = LinearSvm::new(feats.dim());
                m.train(feats, idx, &y, cfg);
                m
            })
            .collect();
        OneVsAll { models }
    }

    /// argmax_c w_cᵀx.
    pub fn predict(&self, x: FeatRef<'_>) -> usize {
        let mut best = (0usize, f32::NEG_INFINITY);
        for (c, m) in self.models.iter().enumerate() {
            let s = m.score(x);
            if s > best.1 {
                best = (c, s);
            }
        }
        best.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{test_blobs, FeatureStore};
    use crate::linalg::Mat;
    use crate::testing::forall;

    /// trivially separable 1-D-ish problem
    fn toy() -> (FeatureStore, Vec<usize>, Vec<f32>) {
        let m = Mat::from_vec(
            4,
            2,
            vec![
                1.0, 0.1, //
                0.9, -0.2, //
                -1.0, 0.3, //
                -1.1, -0.1,
            ],
        );
        (FeatureStore::Dense(m), vec![0, 1, 2, 3], vec![1.0, 1.0, -1.0, -1.0])
    }

    #[test]
    fn separable_is_perfectly_classified() {
        let (f, idx, y) = toy();
        let mut svm = LinearSvm::new(2);
        svm.train(&f, &idx, &y, &SvmConfig::default());
        assert_eq!(svm.accuracy(&f, &idx, &y), 1.0);
        assert!(svm.w[0] > 0.0, "w = {:?}", svm.w);
    }

    #[test]
    fn dual_feasible_l1() {
        let (f, idx, y) = toy();
        let cfg = SvmConfig { c: 0.7, ..Default::default() };
        let mut svm = LinearSvm::new(2);
        svm.train(&f, &idx, &y, &cfg);
        for (t, &a) in svm.alpha.iter().enumerate() {
            let u = if y[t] > 0.0 { cfg.c * cfg.pos_weight } else { cfg.c };
            assert!((0.0..=u + 1e-6).contains(&a), "alpha {a} outside box");
        }
        // w must equal Σ α y x (representation invariant)
        let mut w = vec![0.0f32; 2];
        for (t, &i) in idx.iter().enumerate() {
            f.row(i).axpy_into(svm.alpha[t] * y[t], &mut w);
        }
        for (a, b) in w.iter().zip(svm.w.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn l2_loss_converges_too() {
        let (f, idx, y) = toy();
        let cfg = SvmConfig { loss: Loss::L2, ..Default::default() };
        let mut svm = LinearSvm::new(2);
        svm.train(&f, &idx, &y, &cfg);
        assert_eq!(svm.accuracy(&f, &idx, &y), 1.0);
    }

    #[test]
    fn near_optimal_primal_objective() {
        // DCD should approach the optimum: compare against a long run.
        let mut rng = Rng::seed_from_u64(2);
        let ds = test_blobs(200, 8, 2, &mut rng);
        let idx: Vec<usize> = (0..200).collect();
        let y: Vec<f32> = ds.labels().iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        let cfg = SvmConfig { tol: 1e-4, max_epochs: 300, ..Default::default() };
        let mut svm = LinearSvm::new(8);
        svm.train(ds.features(), &idx, &y, &cfg);
        let obj = svm.primal_objective(ds.features(), &idx, &y, &cfg);
        let cfg_long = SvmConfig { tol: 1e-7, max_epochs: 3000, ..cfg.clone() };
        let mut svm_long = LinearSvm::new(8);
        svm_long.train(ds.features(), &idx, &y, &cfg_long);
        let obj_long = svm_long.primal_objective(ds.features(), &idx, &y, &cfg_long);
        assert!(obj >= obj_long - 1e-6, "primal must upper-bound optimum");
        assert!(
            (obj - obj_long) / obj_long.max(1e-9) < 0.01,
            "obj {obj} should be within 1% of {obj_long}"
        );
    }

    #[test]
    fn warm_start_fewer_epochs() {
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(400, 16, 2, &mut rng);
        let idx: Vec<usize> = (0..399).collect();
        let y: Vec<f32> = idx
            .iter()
            .map(|&i| if ds.labels()[i] == 0 { 1.0 } else { -1.0 })
            .collect();
        let cfg = SvmConfig { tol: 1e-4, max_epochs: 500, ..Default::default() };
        let mut warm = LinearSvm::new(16);
        warm.train(ds.features(), &idx, &y, &cfg);
        let cold_epochs = {
            let mut cold = LinearSvm::new(16);
            let mut idx2 = idx.clone();
            idx2.push(399);
            let mut y2 = y.clone();
            y2.push(if ds.labels()[399] == 0 { 1.0 } else { -1.0 });
            cold.train(ds.features(), &idx2, &y2, &cfg);
            cold.epochs_run
        };
        let warm_epochs = {
            let mut idx2 = idx.clone();
            idx2.push(399);
            let mut y2 = y.clone();
            y2.push(if ds.labels()[399] == 0 { 1.0 } else { -1.0 });
            warm.grow_to(idx2.len());
            warm.train(ds.features(), &idx2, &y2, &cfg);
            warm.epochs_run
        };
        assert!(
            warm_epochs <= cold_epochs,
            "warm {warm_epochs} should not exceed cold {cold_epochs}"
        );
    }

    #[test]
    fn kkt_residual_small_after_convergence() {
        forall("KKT violations below tol", 8, |rng| {
            let n = rng.range(30, 120);
            let ds = test_blobs(n, 8, 2, rng);
            let idx: Vec<usize> = (0..n).collect();
            let y: Vec<f32> =
                ds.labels().iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
            let cfg = SvmConfig { tol: 1e-4, max_epochs: 2000, ..Default::default() };
            let mut svm = LinearSvm::new(8);
            svm.train(ds.features(), &idx, &y, &cfg);
            for (t, &i) in idx.iter().enumerate() {
                let g = y[t] * svm.score(ds.features().row(i)) - 1.0;
                let a = svm.alpha[t];
                let pg = if a <= 1e-9 {
                    g.min(0.0)
                } else if a >= cfg.c - 1e-9 {
                    g.max(0.0)
                } else {
                    g
                };
                crate::prop_assert!(pg.abs() < 5e-3, "KKT violation {pg} at point {t}");
            }
            Ok(())
        });
    }

    #[test]
    fn one_vs_all_predicts_majority_correctly() {
        let mut rng = Rng::seed_from_u64(4);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let idx: Vec<usize> = (0..300).collect();
        let ova = OneVsAll::train(ds.features(), &idx, ds.labels(), 3, &SvmConfig::default());
        let correct = (0..300)
            .filter(|&i| ova.predict(ds.features().row(i)) == ds.labels()[i] as usize)
            .count();
        assert!(correct > 280, "correct {correct}/300");
    }

    #[test]
    fn sparse_training_works() {
        let mut rng = Rng::seed_from_u64(5);
        let cfg = crate::data::NewsConfig { n: 200, vocab: 256, classes: 2, ..Default::default() };
        let ds = crate::data::newsgroups_like(&cfg, &mut rng);
        let idx: Vec<usize> = (0..200).collect();
        let y: Vec<f32> =
            ds.labels().iter().map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        let mut svm = LinearSvm::new(256);
        svm.train(ds.features(), &idx, &y, &SvmConfig::default());
        assert!(svm.accuracy(ds.features(), &idx, &y) > 0.9);
    }
}
