//! Hand-rolled command-line parsing (no clap in the vendored registry).
//!
//! Grammar: `chh <subcommand> [--flag] [--key value]...`. Flags are
//! registered with a description so `--help` is generated, and unknown
//! arguments are hard errors — silent typos in experiment parameters are
//! how reproductions go wrong.

use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown argument '{0}' (see --help)")]
    Unknown(String),
    #[error("missing value for '--{0}'")]
    MissingValue(String),
    #[error("invalid value for '--{key}': {msg}")]
    Invalid { key: String, msg: String },
}

/// Declarative option set with parsed values.
pub struct Args {
    name: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

struct Spec {
    key: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

impl Args {
    pub fn new(name: &str, about: &str) -> Self {
        Args {
            name: name.to_string(),
            about: about.to_string(),
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
        }
    }

    /// Register a `--key <value>` option with a default.
    pub fn opt(mut self, key: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            key: key.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Register a boolean `--key` flag.
    pub fn flag(mut self, key: &str, help: &str) -> Self {
        self.specs.push(Spec { key: key.to_string(), help: help.to_string(), default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for spec in &self.specs {
            if spec.is_flag {
                s.push_str(&format!("  --{:<18} {}\n", spec.key, spec.help));
            } else {
                s.push_str(&format!(
                    "  --{:<18} {} [default: {}]\n",
                    format!("{} <v>", spec.key),
                    spec.help,
                    spec.default.as_deref().unwrap_or("-")
                ));
            }
        }
        s
    }

    /// Parse a raw token list. Returns Err(help text) on --help.
    pub fn parse(mut self, tokens: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if t == "--help" || t == "-h" {
                return Err(self.usage());
            }
            let Some(key) = t.strip_prefix("--") else {
                return Err(format!("unexpected positional argument '{t}'\n\n{}", self.usage()));
            };
            let Some(spec) = self.specs.iter().find(|s| s.key == key) else {
                return Err(format!("unknown option '--{key}'\n\n{}", self.usage()));
            };
            if spec.is_flag {
                self.flags.insert(key.to_string(), true);
                i += 1;
            } else {
                if i + 1 >= tokens.len() {
                    return Err(format!("missing value for '--{key}'"));
                }
                self.values.insert(key.to_string(), tokens[i + 1].clone());
                i += 2;
            }
        }
        // fill defaults
        for spec in &self.specs {
            if spec.is_flag {
                self.flags.entry(spec.key.clone()).or_insert(false);
            } else if let Some(d) = &spec.default {
                self.values.entry(spec.key.clone()).or_insert_with(|| d.clone());
            }
        }
        Ok(Parsed { values: self.values, flags: self.flags })
    }
}

/// The result of parsing: typed getters.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Parsed {
    pub fn str(&self, key: &str) -> &str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or_else(|| panic!("option --{key} not registered"))
    }

    pub fn flag(&self, key: &str) -> bool {
        *self.flags.get(key).unwrap_or_else(|| panic!("flag --{key} not registered"))
    }

    pub fn usize(&self, key: &str) -> Result<usize, CliError> {
        // Accept 100_000 / 100k / 1m spellings for scale parameters.
        let raw = self.str(key).replace('_', "");
        let (num, mult) = if let Some(p) = raw.strip_suffix(['k', 'K']) {
            (p.to_string(), 1_000usize)
        } else if let Some(p) = raw.strip_suffix(['m', 'M']) {
            (p.to_string(), 1_000_000usize)
        } else {
            (raw, 1)
        };
        num.parse::<usize>()
            .map(|v| v * mult)
            .map_err(|e| CliError::Invalid { key: key.to_string(), msg: e.to_string() })
    }

    pub fn f64(&self, key: &str) -> Result<f64, CliError> {
        self.str(key)
            .parse::<f64>()
            .map_err(|e| CliError::Invalid { key: key.to_string(), msg: e.to_string() })
    }

    pub fn u64(&self, key: &str) -> Result<u64, CliError> {
        self.str(key)
            .parse::<u64>()
            .map_err(|e| CliError::Invalid { key: key.to_string(), msg: e.to_string() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("t", "test")
            .opt("n", "100", "count")
            .opt("seed", "7", "seed")
            .opt("rate", "0.5", "rate")
            .flag("verbose", "talk")
    }

    #[test]
    fn defaults_applied() {
        let p = spec().parse(&toks(&[])).unwrap();
        assert_eq!(p.usize("n").unwrap(), 100);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let p = spec().parse(&toks(&["--n", "50k", "--verbose", "--rate", "0.25"])).unwrap();
        assert_eq!(p.usize("n").unwrap(), 50_000);
        assert!(p.flag("verbose"));
        assert_eq!(p.f64("rate").unwrap(), 0.25);
    }

    #[test]
    fn scale_suffixes() {
        let p = spec().parse(&toks(&["--n", "1m"])).unwrap();
        assert_eq!(p.usize("n").unwrap(), 1_000_000);
        let p = spec().parse(&toks(&["--n", "100_000"])).unwrap();
        assert_eq!(p.usize("n").unwrap(), 100_000);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&toks(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse(&toks(&["--n"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = spec().parse(&toks(&["--help"])).unwrap_err();
        assert!(err.contains("--n"));
        assert!(err.contains("--verbose"));
    }

    #[test]
    fn bad_number_is_invalid() {
        let p = spec().parse(&toks(&["--n", "abc"])).unwrap();
        assert!(p.usize("n").is_err());
    }
}
