//! CSR sparse matrices for the text-corpus (tf-idf) feature store.
//!
//! 20-Newsgroups-style documents are extremely sparse (a few hundred
//! non-zeros out of tens of thousands of dimensions); the SVM solver, the
//! margin scans, and the hash encoders all consume rows through this module
//! so the AL loop never materializes dense document vectors except inside
//! fixed-shape PJRT tiles.

use crate::linalg::Mat;

/// Compressed sparse row matrix, f32 values, u32 column indices.
#[derive(Clone, Debug, Default)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

/// A single sparse row view.
#[derive(Clone, Copy, Debug)]
pub struct SparseRow<'a> {
    pub indices: &'a [u32],
    pub values: &'a [f32],
}

impl<'a> SparseRow<'a> {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Dot with a dense vector.
    #[inline]
    pub fn dot_dense(&self, w: &[f32]) -> f32 {
        let mut s = 0.0f32;
        for (&j, &v) in self.indices.iter().zip(self.values.iter()) {
            s += v * w[j as usize];
        }
        s
    }

    /// w += alpha * row (scatter-axpy).
    #[inline]
    pub fn axpy_into(&self, alpha: f32, w: &mut [f32]) {
        for (&j, &v) in self.indices.iter().zip(self.values.iter()) {
            w[j as usize] += alpha * v;
        }
    }

    #[inline]
    pub fn sq_norm(&self) -> f32 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Scatter into a dense buffer (buffer is NOT cleared first).
    pub fn scatter_into(&self, out: &mut [f32]) {
        for (&j, &v) in self.indices.iter().zip(self.values.iter()) {
            out[j as usize] = v;
        }
    }
}

/// Incremental CSR builder.
#[derive(Default)]
pub struct CsrBuilder {
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrBuilder {
    pub fn new(cols: usize) -> Self {
        CsrBuilder { cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Push a row given (col, value) pairs; pairs need not be sorted and
    /// duplicate columns are summed.
    pub fn push_row(&mut self, entries: &mut Vec<(u32, f32)>) {
        entries.sort_unstable_by_key(|e| e.0);
        let mut i = 0;
        while i < entries.len() {
            let col = entries[i].0;
            debug_assert!((col as usize) < self.cols, "column out of range");
            let mut v = entries[i].1;
            let mut j = i + 1;
            while j < entries.len() && entries[j].0 == col {
                v += entries[j].1;
                j += 1;
            }
            if v != 0.0 {
                self.indices.push(col);
                self.values.push(v);
            }
            i = j;
        }
        self.indptr.push(self.indices.len());
    }

    pub fn finish(self) -> Csr {
        Csr {
            rows: self.indptr.len() - 1,
            cols: self.cols,
            indptr: self.indptr,
            indices: self.indices,
            values: self.values,
        }
    }
}

impl Csr {
    #[inline]
    pub fn row(&self, i: usize) -> SparseRow<'_> {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        SparseRow { indices: &self.indices[a..b], values: &self.values[a..b] }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// ℓ2-normalize each row in place.
    pub fn l2_normalize_rows(&mut self) {
        for i in 0..self.rows {
            let (a, b) = (self.indptr[i], self.indptr[i + 1]);
            let n: f32 = self.values[a..b].iter().map(|v| v * v).sum::<f32>().sqrt();
            if n > 0.0 {
                let inv = 1.0 / n;
                for v in &mut self.values[a..b] {
                    *v *= inv;
                }
            }
        }
    }

    /// Apply idf weights column-wise: v_ij *= idf[j].
    pub fn scale_columns(&mut self, scale: &[f32]) {
        assert_eq!(scale.len(), self.cols);
        for (idx, v) in self.indices.iter().zip(self.values.iter_mut()) {
            *v *= scale[*idx as usize];
        }
    }

    /// Document frequency per column (number of rows with a non-zero).
    pub fn column_doc_freq(&self) -> Vec<u32> {
        let mut df = vec![0u32; self.cols];
        for &j in &self.indices {
            df[j as usize] += 1;
        }
        df
    }

    /// Densify a contiguous row block [row0, row0+n) into a `Mat`
    /// (rows past the end are zero-padded) — PJRT tile staging.
    pub fn dense_block(&self, row0: usize, n: usize) -> Mat {
        let mut m = Mat::zeros(n, self.cols);
        for r in 0..n {
            let i = row0 + r;
            if i >= self.rows {
                break;
            }
            self.row(i).scatter_into(m.row_mut(r));
        }
        m
    }

    /// Full densification (tests / small data only).
    pub fn to_dense(&self) -> Mat {
        self.dense_block(0, self.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        let mut b = CsrBuilder::new(5);
        b.push_row(&mut vec![(0, 1.0), (3, 2.0)]);
        b.push_row(&mut vec![(4, -1.0)]);
        b.push_row(&mut vec![]);
        b.push_row(&mut vec![(1, 0.5), (1, 0.5), (2, 3.0)]); // dup col summed
        b.finish()
    }

    #[test]
    fn builder_shapes() {
        let m = sample();
        assert_eq!(m.rows, 4);
        assert_eq!(m.cols, 5);
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(3).values, &[1.0, 3.0]);
    }

    #[test]
    fn dup_columns_summed_and_sorted() {
        let m = sample();
        assert_eq!(m.row(3).indices, &[1, 2]);
    }

    #[test]
    fn zero_rows_ok() {
        let m = sample();
        assert_eq!(m.row(2).nnz(), 0);
        assert_eq!(m.row(2).dot_dense(&[1.; 5]), 0.0);
    }

    #[test]
    fn dot_and_axpy_match_dense() {
        let m = sample();
        let w = vec![0.1, 0.2, 0.3, 0.4, 0.5];
        let d = m.to_dense();
        for i in 0..m.rows {
            let sd = m.row(i).dot_dense(&w);
            let dd = crate::linalg::dot(d.row(i), &w);
            assert!((sd - dd).abs() < 1e-6);
        }
        let mut acc_s = vec![0.0f32; 5];
        let mut acc_d = vec![0.0f32; 5];
        m.row(0).axpy_into(2.0, &mut acc_s);
        crate::linalg::axpy(2.0, d.row(0), &mut acc_d);
        assert_eq!(acc_s, acc_d);
    }

    #[test]
    fn normalize_rows_unit() {
        let mut m = sample();
        m.l2_normalize_rows();
        for i in [0usize, 1, 3] {
            let n = m.row(i).sq_norm().sqrt();
            assert!((n - 1.0).abs() < 1e-6, "row {i} norm {n}");
        }
    }

    #[test]
    fn doc_freq_counts() {
        let m = sample();
        assert_eq!(m.column_doc_freq(), vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn dense_block_padding() {
        let m = sample();
        let blk = m.dense_block(3, 3);
        assert_eq!(blk.rows, 3);
        assert_eq!(blk.get(0, 2), 3.0);
        assert!(blk.row(1).iter().all(|&v| v == 0.0));
        assert!(blk.row(2).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn scale_columns_idf() {
        let mut m = sample();
        m.scale_columns(&[2.0, 2.0, 2.0, 2.0, 2.0]);
        assert_eq!(m.row(0).values, &[2.0, 4.0]);
    }
}
