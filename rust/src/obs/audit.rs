//! Sampling search-quality auditor: live recall / margin-ratio /
//! collision-model telemetry for a serving index.
//!
//! The paper's value proposition is a *quality* claim — compact bilinear
//! codes keep collision probability (Lemma 1) and recall high at low
//! probe budgets — but a production server only observes latency. This
//! module closes that gap: for a configurable fraction of served
//! `/query` requests (`--audit-frac`, default 0) the server clones the
//! query off the request path and a background thread **re-answers** it
//! against a reference:
//!
//! * small indexes — an exhaustive margin scan over every eligible
//!   point (the same ground truth as [`crate::eval`]);
//! * large online indexes — a full-Hamming-ball probe
//!   ([`crate::online::QueryBudget::unlimited`]), the best answer the
//!   hash arrangement can possibly give.
//!
//! Published live on the server's `/metrics` registry:
//!
//! * `chh_audit_recall` — fraction of audited queries whose served best
//!   matched the reference best (id match, or an exactly equal margin —
//!   duplicate-point ties are not misses);
//! * `chh_audit_margin_ratio` — mean finite served/true margin ratio,
//!   [`crate::eval::QueryEval`] semantics (1.0 = perfect);
//! * `chh_audit_rank_of_best` — mean 1-based rank of the served best in
//!   the true margin order (exhaustive mode only);
//! * `chh_probe_model_calibration{bucket_rank,kind}` — the Lemma-1
//!   modeled collision mass of each of the first probed buckets
//!   (`kind="modeled"`, normalized over the ball) next to the observed
//!   fraction of audited queries whose true best point actually lay in
//!   that bucket (`kind="observed"`) — a live calibration check of the
//!   [`crate::online::ProbePlanner`]'s collision model;
//! * `chh_audit_queries_total` / `chh_audit_dropped_total` — audited
//!   and queue-overflow counts.
//!
//! The auditor is strictly off the request path: sampling is a counter
//! decision plus one clone, the queue is bounded (overflow increments a
//! counter and drops the sample — auditing never applies backpressure),
//! and wire answers are bit-identical with auditing on (pinned by the
//! server tests).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};

use crate::data::FeatureStore;
use crate::hash::HashFamily;
use crate::linalg::{margin_feat, nrm2};
use crate::online::{QueryBudget, ShardedIndex};

use super::{Counter, Registry};

/// Bound on queued audit samples; overflow drops (never blocks serving).
const QUEUE_CAP: usize = 1024;

/// Re-answer by exhaustive scan up to this many indexed points; larger
/// indexes fall back to the full-Hamming-ball probe.
const EXHAUSTIVE_MAX: usize = 50_000;

/// Probe-plan ranks tracked by `chh_probe_model_calibration` (series
/// count is 2× this, bounded regardless of probe budget).
const CALIB_BUCKETS: usize = 8;

/// Cap on the masks enumerated when normalizing modeled mass over the
/// ball — large-`k` balls are truncated to their best-first prefix.
const CALIB_MASS_CAP: usize = 65_536;

/// What the auditor re-answers against.
pub enum AuditTarget {
    /// A prebuilt static index: reference is always the exhaustive scan.
    Static { family: Arc<dyn HashFamily>, feats: Arc<FeatureStore> },
    /// A dynamic sharded index: eligibility tracks live ids at audit
    /// time, and the probed buckets of the *serving* budget are compared
    /// against the planner's modeled collision mass.
    Online {
        family: Arc<dyn HashFamily>,
        feats: Arc<FeatureStore>,
        index: Arc<ShardedIndex>,
        /// the serving budget (per-shard probes define the audited buckets)
        budget: QueryBudget,
    },
}

/// One cloned query plus what the server actually answered.
struct Sample {
    w: Vec<f32>,
    exclude: Option<Arc<HashSet<usize>>>,
    served: Option<(usize, f32)>,
}

/// Aggregated audit state read by the registry's gauge closures.
struct Agg {
    audited: u64,
    matched: u64,
    ratio_sum: f64,
    ratio_n: u64,
    rank_sum: f64,
    rank_n: u64,
    calib_modeled: Vec<f64>,
    calib_observed: Vec<u64>,
    calib_n: u64,
}

impl Agg {
    fn new() -> Self {
        Agg {
            audited: 0,
            matched: 0,
            ratio_sum: 0.0,
            ratio_n: 0,
            rank_sum: 0.0,
            rank_n: 0,
            calib_modeled: vec![0.0; CALIB_BUCKETS],
            calib_observed: vec![0; CALIB_BUCKETS],
            calib_n: 0,
        }
    }
}

/// The sampling auditor: owns the bounded queue and the background
/// audit thread; joined on drop.
pub struct Auditor {
    frac: f64,
    seen: AtomicU64,
    tx: Option<SyncSender<Sample>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    audited_total: Arc<Counter>,
    dropped_total: Arc<Counter>,
}

impl Auditor {
    /// Spawn the audit thread and register the audit metric families on
    /// `reg`. `frac` is clamped to [0, 1]; the deterministic sampler
    /// audits the `k`-th served query iff `⌊k·frac⌋ > ⌊(k−1)·frac⌋`, so
    /// `frac = 1` audits every query and `frac = 0.1` exactly every
    /// tenth — no RNG, reproducible under test.
    pub fn spawn(target: AuditTarget, frac: f64, reg: &Registry) -> Arc<Auditor> {
        let frac = if frac.is_finite() { frac.clamp(0.0, 1.0) } else { 0.0 };
        let agg = Arc::new(Mutex::new(Agg::new()));
        let audited_total = reg.counter(
            "chh_audit_queries_total",
            "served queries re-answered by the sampling auditor",
            vec![],
        );
        let dropped_total = reg.counter(
            "chh_audit_dropped_total",
            "audit samples dropped because the audit queue was full",
            vec![],
        );
        let a = agg.clone();
        reg.gauge_fn(
            "chh_audit_recall",
            "fraction of audited queries whose served best matched the reference answer",
            vec![],
            move || {
                let g = a.lock().unwrap();
                if g.audited == 0 {
                    0.0
                } else {
                    g.matched as f64 / g.audited as f64
                }
            },
        );
        let a = agg.clone();
        reg.gauge_fn(
            "chh_audit_margin_ratio",
            "mean finite served/true margin ratio over audited queries (1 = perfect)",
            vec![],
            move || {
                let g = a.lock().unwrap();
                if g.ratio_n == 0 {
                    0.0
                } else {
                    g.ratio_sum / g.ratio_n as f64
                }
            },
        );
        let a = agg.clone();
        reg.gauge_fn(
            "chh_audit_rank_of_best",
            "mean 1-based rank of the served best in the true margin order",
            vec![],
            move || {
                let g = a.lock().unwrap();
                if g.rank_n == 0 {
                    0.0
                } else {
                    g.rank_sum / g.rank_n as f64
                }
            },
        );
        if matches!(target, AuditTarget::Online { .. }) {
            for j in 0..CALIB_BUCKETS {
                let a = agg.clone();
                reg.gauge_fn(
                    "chh_probe_model_calibration",
                    "modeled (Lemma-1, ball-normalized) vs observed probability that the \
                     true best point lies in the j-th probed bucket",
                    vec![("bucket_rank", j.to_string()), ("kind", "modeled".to_string())],
                    move || {
                        let g = a.lock().unwrap();
                        if g.calib_n == 0 {
                            0.0
                        } else {
                            g.calib_modeled[j] / g.calib_n as f64
                        }
                    },
                );
                let a = agg.clone();
                reg.gauge_fn(
                    "chh_probe_model_calibration",
                    "modeled (Lemma-1, ball-normalized) vs observed probability that the \
                     true best point lies in the j-th probed bucket",
                    vec![("bucket_rank", j.to_string()), ("kind", "observed".to_string())],
                    move || {
                        let g = a.lock().unwrap();
                        if g.calib_n == 0 {
                            0.0
                        } else {
                            g.calib_observed[j] as f64 / g.calib_n as f64
                        }
                    },
                );
            }
        }
        let (tx, rx) = sync_channel::<Sample>(QUEUE_CAP);
        let audited = audited_total.clone();
        let handle = std::thread::Builder::new()
            .name("chh-audit".to_string())
            .spawn(move || audit_loop(rx, target, agg, audited))
            .expect("spawn audit thread");
        Arc::new(Auditor {
            frac,
            seen: AtomicU64::new(0),
            tx: Some(tx),
            handle: Mutex::new(Some(handle)),
            audited_total,
            dropped_total,
        })
    }

    /// Deterministic sampling decision for the next served query.
    fn sample(&self) -> bool {
        if self.frac <= 0.0 {
            return false;
        }
        let k = self.seen.fetch_add(1, Ordering::Relaxed) + 1;
        (k as f64 * self.frac).floor() > ((k - 1) as f64 * self.frac).floor()
    }

    /// Offer one served query to the auditor. Decides sampling first so
    /// the non-sampled path costs one atomic increment and no clones;
    /// a full queue drops the sample and counts it.
    pub fn offer(
        &self,
        w: &[f32],
        exclude: &Option<Arc<HashSet<usize>>>,
        served: Option<(usize, f32)>,
    ) {
        if !self.sample() {
            return;
        }
        let s = Sample { w: w.to_vec(), exclude: exclude.clone(), served };
        match self.tx.as_ref().expect("auditor queue open").try_send(s) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => self.dropped_total.inc(),
            Err(TrySendError::Disconnected(_)) => {}
        }
    }

    /// Completed audits (tests poll this to rendezvous with the thread).
    pub fn audited(&self) -> u64 {
        self.audited_total.get()
    }

    /// Samples dropped on queue overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped_total.get()
    }

    /// The configured sampling fraction.
    pub fn frac(&self) -> f64 {
        self.frac
    }
}

impl Drop for Auditor {
    fn drop(&mut self) {
        // close the queue, then join — the thread drains what's left
        self.tx = None;
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn audit_loop(
    rx: Receiver<Sample>,
    target: AuditTarget,
    agg: Arc<Mutex<Agg>>,
    audited_total: Arc<Counter>,
) {
    while let Ok(s) = rx.recv() {
        audit_one(&target, &s, &agg);
        audited_total.inc();
    }
}

/// Exhaustive reference: the minimum-margin eligible point, plus the
/// 1-based rank of the served answer in the true `(margin, id)` order.
fn scan_truth(
    feats: &FeatureStore,
    w: &[f32],
    eligible: impl Fn(usize) -> bool,
    served: Option<(usize, f32)>,
) -> (Option<(usize, f32)>, Option<u64>) {
    let wn = nrm2(w);
    let mut best: Option<(usize, f32)> = None;
    let mut before = 0u64;
    for i in 0..feats.len() {
        if !eligible(i) {
            continue;
        }
        let m = margin_feat(feats.row(i), w, wn);
        if best.map_or(true, |(_, bm)| m < bm) {
            best = Some((i, m));
        }
        if let Some((sid, sm)) = served {
            if m < sm || (m == sm && i < sid) {
                before += 1;
            }
        }
    }
    (best, served.map(|_| before + 1))
}

/// Fold one reference answer into the aggregate. Margin-ratio follows
/// [`crate::eval::QueryEval`]: 1.0 on an exact margin match (including
/// a retrieved zero-margin point), +Inf on a genuine miss of a
/// zero-margin point or an empty served answer, served/true otherwise;
/// only finite ratios enter the mean.
fn fold(
    agg: &Mutex<Agg>,
    served: Option<(usize, f32)>,
    truth: Option<(usize, f32)>,
    rank: Option<u64>,
) {
    let matched = match (served, truth) {
        (None, None) => true,
        (Some((sid, sm)), Some((tid, tm))) => sid == tid || sm == tm,
        _ => false,
    };
    let ratio = match (served, truth) {
        (Some((_, sm)), Some((_, tm))) => {
            if sm == tm {
                1.0
            } else if tm <= 0.0 {
                f64::INFINITY
            } else {
                (sm / tm) as f64
            }
        }
        (None, Some(_)) => f64::INFINITY,
        // nothing eligible to retrieve: the empty answer is correct
        _ => 1.0,
    };
    let mut g = agg.lock().unwrap();
    g.audited += 1;
    if matched {
        g.matched += 1;
    }
    if ratio.is_finite() {
        g.ratio_sum += ratio;
        g.ratio_n += 1;
    }
    if let (Some(_), Some(r)) = (served, rank) {
        g.rank_sum += r as f64;
        g.rank_n += 1;
    }
}

fn audit_one(target: &AuditTarget, s: &Sample, agg: &Arc<Mutex<Agg>>) {
    let not_excluded =
        |i: usize| s.exclude.as_ref().map_or(true, |ex| !ex.contains(&i));
    match target {
        AuditTarget::Static { feats, .. } => {
            let (truth, rank) = scan_truth(feats, &s.w, not_excluded, s.served);
            fold(agg, s.served, truth, rank);
        }
        AuditTarget::Online { family, feats, index, budget } => {
            let eligible = |i: usize| index.contains(i as u32) && not_excluded(i);
            let (truth, rank) = if index.len() <= EXHAUSTIVE_MAX {
                scan_truth(feats, &s.w, eligible, s.served)
            } else {
                // full-ball probe: the best answer the arrangement can give
                let hit = index.query(
                    family.as_ref(),
                    &s.w,
                    feats,
                    QueryBudget::unlimited(),
                    eligible,
                );
                (hit.best, None)
            };
            fold(agg, s.served, truth, rank);
            // probe-model calibration against the serving budget's buckets
            let lookup = family.encode_query(&s.w);
            let scores = family.query_bit_scores(&s.w);
            let masks = index.plan_masks(scores.as_deref(), budget.probes);
            let planner = match scores.as_deref() {
                Some(sc) => index.planner().query_scaled(sc),
                None => index.planner().clone(),
            };
            let total: f64 =
                planner.planned_masses(CALIB_MASS_CAP).iter().map(|&(_, m)| m).sum();
            let mut g = agg.lock().unwrap();
            if total > 0.0 {
                for (j, &mask) in masks.iter().take(CALIB_BUCKETS).enumerate() {
                    g.calib_modeled[j] += planner.mass(mask) / total;
                }
            }
            if let Some((tid, _)) = truth {
                let flip = family.encode_point(feats.row(tid)) ^ lookup;
                if let Some(j) =
                    masks.iter().take(CALIB_BUCKETS).position(|&m| m == flip)
                {
                    g.calib_observed[j] += 1;
                }
            }
            g.calib_n += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;
    use crate::obs::{parse_scrape, series_value};
    use crate::rng::Rng;
    use crate::testing::unit_vec;
    use std::time::{Duration, Instant};

    fn wait_audited(a: &Auditor, n: u64) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while a.audited() + a.dropped() < n {
            assert!(Instant::now() < deadline, "auditor stalled at {}", a.audited());
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn deterministic_sampler_hits_exact_fractions() {
        let mut rng = Rng::seed_from_u64(31);
        let ds = test_blobs(20, 8, 2, &mut rng);
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(8, 6, &mut rng));
        let feats = Arc::new(ds.features().clone());
        let reg = Registry::new();
        let a = Auditor::spawn(AuditTarget::Static { family: fam, feats }, 0.25, &reg);
        assert_eq!((0..100).filter(|_| a.sample()).count(), 25, "frac 0.25 → every 4th");
        assert_eq!(a.frac(), 0.25);
        let reg2 = Registry::new();
        let mut rng2 = Rng::seed_from_u64(32);
        let ds2 = test_blobs(10, 8, 2, &mut rng2);
        let fam2: Arc<dyn HashFamily> = Arc::new(BhHash::sample(8, 6, &mut rng2));
        let z = Auditor::spawn(
            AuditTarget::Static { family: fam2, feats: Arc::new(ds2.features().clone()) },
            0.0,
            &reg2,
        );
        assert_eq!((0..100).filter(|_| z.sample()).count(), 0, "frac 0 audits nothing");
    }

    #[test]
    fn online_full_ball_audit_reports_perfect_quality() {
        let mut rng = Rng::seed_from_u64(41);
        let ds = test_blobs(200, 16, 3, &mut rng);
        let fam_raw = BhHash::sample(16, 8, &mut rng);
        let codes = fam_raw.encode_all(ds.features());
        let index = Arc::new(ShardedIndex::from_codes(&codes, 8, 8)); // radius = bits
        let fam: Arc<dyn HashFamily> = Arc::new(fam_raw);
        let feats = Arc::new(ds.features().clone());
        let budget = QueryBudget::unlimited();
        let reg = Registry::new();
        let a = Auditor::spawn(
            AuditTarget::Online {
                family: fam.clone(),
                feats: feats.clone(),
                index: index.clone(),
                budget,
            },
            1.0,
            &reg,
        );
        let n = 20;
        for _ in 0..n {
            let w = unit_vec(&mut rng, 16);
            // serve with the same full-ball budget the auditor checks
            let hit = index.query(fam.as_ref(), &w, &feats, budget, |_| true);
            a.offer(&w, &None, hit.best);
        }
        wait_audited(&a, n);
        assert_eq!(a.dropped(), 0);
        let scrape = parse_scrape(&reg.render());
        assert_eq!(series_value(&scrape, "chh_audit_recall", ""), Some(1.0));
        assert_eq!(series_value(&scrape, "chh_audit_margin_ratio", ""), Some(1.0));
        assert_eq!(series_value(&scrape, "chh_audit_rank_of_best", ""), Some(1.0));
        assert_eq!(series_value(&scrape, "chh_audit_queries_total", ""), Some(n as f64));
        // calibration: both kinds render for every tracked rank, values
        // are probabilities, and the exact bucket carries the most
        // modeled mass (plans are best-first)
        let get = |rank: usize, kind: &str| -> f64 {
            scrape
                .iter()
                .find(|(k, _)| {
                    k.starts_with("chh_probe_model_calibration{")
                        && k.contains(&format!(r#"bucket_rank="{rank}""#))
                        && k.contains(&format!(r#"kind="{kind}""#))
                })
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("missing calibration series rank={rank} kind={kind}"))
        };
        let (mut modeled_sum, mut observed_sum) = (0.0, 0.0);
        for j in 0..CALIB_BUCKETS {
            let m = get(j, "modeled");
            let o = get(j, "observed");
            assert!((0.0..=1.0).contains(&m), "modeled[{j}] = {m}");
            assert!((0.0..=1.0).contains(&o), "observed[{j}] = {o}");
            modeled_sum += m;
            observed_sum += o;
        }
        assert!(modeled_sum <= 1.0 + 1e-9, "normalized masses sum ≤ 1: {modeled_sum}");
        assert!(observed_sum <= 1.0 + 1e-9, "bucket events are disjoint: {observed_sum}");
        assert!(get(0, "modeled") >= get(1, "modeled"), "best-first: rank 0 dominates");
    }

    #[test]
    fn wrong_served_answer_drops_recall_and_raises_rank() {
        let mut rng = Rng::seed_from_u64(51);
        let ds = test_blobs(100, 8, 2, &mut rng);
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(8, 6, &mut rng));
        let feats = Arc::new(ds.features().clone());
        let reg = Registry::new();
        let a = Auditor::spawn(
            AuditTarget::Static { family: fam, feats: feats.clone() },
            1.0,
            &reg,
        );
        // claim the served best was the *worst* point
        let w = unit_vec(&mut rng, 8);
        let wn = nrm2(&w);
        let worst = (0..feats.len())
            .max_by(|&x, &y| {
                margin_feat(feats.row(x), &w, wn)
                    .partial_cmp(&margin_feat(feats.row(y), &w, wn))
                    .unwrap()
            })
            .unwrap();
        let wm = margin_feat(feats.row(worst), &w, wn);
        a.offer(&w, &None, Some((worst, wm)));
        wait_audited(&a, 1);
        let scrape = parse_scrape(&reg.render());
        assert_eq!(series_value(&scrape, "chh_audit_recall", ""), Some(0.0));
        assert_eq!(
            series_value(&scrape, "chh_audit_rank_of_best", ""),
            Some(feats.len() as f64),
            "the worst point ranks last"
        );
        let ratio = series_value(&scrape, "chh_audit_margin_ratio", "").unwrap();
        assert!(ratio > 1.0, "served margin is worse than true: {ratio}");
    }

    #[test]
    fn exclude_sets_shrink_the_reference() {
        let mut rng = Rng::seed_from_u64(61);
        let ds = test_blobs(50, 8, 2, &mut rng);
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(8, 6, &mut rng));
        let feats = Arc::new(ds.features().clone());
        let w = unit_vec(&mut rng, 8);
        let (truth_all, _) = scan_truth(&feats, &w, |_| true, None);
        let best = truth_all.unwrap().0;
        // excluding the true best: the reference becomes the runner-up,
        // so serving the runner-up is a perfect answer
        let ex: Arc<HashSet<usize>> = Arc::new([best].into_iter().collect());
        let (truth_ex, _) = scan_truth(&feats, &w, |i| i != best, None);
        let runner = truth_ex.unwrap();
        let reg = Registry::new();
        let a = Auditor::spawn(
            AuditTarget::Static { family: fam, feats: feats.clone() },
            1.0,
            &reg,
        );
        a.offer(&w, &Some(ex), Some(runner));
        wait_audited(&a, 1);
        let scrape = parse_scrape(&reg.render());
        assert_eq!(series_value(&scrape, "chh_audit_recall", ""), Some(1.0));
    }
}
