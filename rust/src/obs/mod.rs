//! Unified observability: metrics registry, Prometheus text exposition,
//! per-request tracing, and the slow-query log.
//!
//! The serving stack's only runtime window used to be the hand-assembled
//! `/stats` JSON; this module adds the pieces fleet tooling expects:
//!
//! * [`Counter`] / [`Gauge`] / [`Hist`] — atomic metric primitives. The
//!   histogram uses **fixed log-spaced buckets** recorded with three
//!   relaxed `fetch_add`s, so hot paths (per-batch stage timings, WAL
//!   fsyncs) never take the reservoir mutex that
//!   [`crate::metrics::Histogram`] needs.
//! * [`Registry`] — a global-free, label-aware collection of named
//!   metrics, rendered as Prometheus text exposition (`GET /metrics`).
//!   Callback metrics let already-existing atomics (router counters, WAL
//!   gauges, replication watermarks) appear in the scrape without being
//!   rewritten.
//! * [`Trace`] / [`gen_request_id`] — a per-request stage breakdown plus
//!   the `x-chh-request-id` correlation id the HTTP layer propagates
//!   (generated when absent, echoed in responses, logged by the replica
//!   tailer).
//! * [`SlowLog`] — JSON-lines of requests over a `--slow-ms` threshold,
//!   rotated by size.
//!
//! Everything here is `std`-only and crash-tolerant: metric recording
//! never blocks, and slow-log I/O failures are swallowed — observability
//! must not take down serving.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::jsonio::{obj, Json};

pub mod audit;

// ───────────────────────────── primitives ─────────────────────────────

/// Monotonically increasing atomic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An f64 gauge (value stored as bits in an atomic).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log-spaced latency bucket upper bounds, in **nanoseconds**: a 1-2.5-5
/// decade ladder from 1µs to 10s. Render with `scale = 1e9` so `le`
/// values come out in seconds, per Prometheus convention.
pub const LATENCY_BOUNDS_NS: &[u64] = &[
    1_000,
    2_500,
    5_000,
    10_000,
    25_000,
    50_000,
    100_000,
    250_000,
    500_000,
    1_000_000,
    2_500_000,
    5_000_000,
    10_000_000,
    25_000_000,
    50_000_000,
    100_000_000,
    250_000_000,
    500_000_000,
    1_000_000_000,
    2_500_000_000,
    5_000_000_000,
    10_000_000_000,
];

/// Power-of-two size bucket upper bounds (group-commit batch sizes and
/// similar counts). Render with `scale = 1.0`.
pub const SIZE_BOUNDS: &[u64] =
    &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192];

/// Fixed-bucket histogram: recording is three relaxed `fetch_add`s, so
/// it is safe on paths where a mutex would serialize workers (stage
/// timings inside the batch flush, the WAL writer's fsync loop).
///
/// Raw values are `u64` in whatever unit the bounds are in (ns for
/// [`LATENCY_BOUNDS_NS`], plain counts for [`SIZE_BOUNDS`]); rendering
/// divides by a scale so the exposition shows seconds.
pub struct Hist {
    bounds: &'static [u64],
    /// one slot per bound plus the +Inf overflow slot
    buckets: Box<[AtomicU64]>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Hist {
    pub fn new(bounds: &'static [u64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must be sorted");
        let buckets = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Hist { bounds, buckets, sum: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    pub fn latency() -> Self {
        Hist::new(LATENCY_BOUNDS_NS)
    }

    pub fn sizes() -> Self {
        Hist::new(SIZE_BOUNDS)
    }

    /// Record one observation (raw units).
    pub fn record(&self, raw: u64) {
        let i = self.bounds.partition_point(|&b| b < raw);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(raw, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds (use with [`LATENCY_BOUNDS_NS`]).
    pub fn observe_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_raw(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn bounds(&self) -> &'static [u64] {
        self.bounds
    }

    /// Per-bucket counts (non-cumulative), overflow slot last.
    fn snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Percentile estimate from the bucket counts (raw units): linear
    /// interpolation inside the landing bucket; observations past the
    /// last bound report the last bound (the estimate saturates).
    pub fn approx_percentile(&self, p: f64) -> f64 {
        let counts = self.snapshot();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &n) in counts.iter().enumerate() {
            if cum + n >= target {
                if i >= self.bounds.len() {
                    return *self.bounds.last().unwrap_or(&0) as f64;
                }
                let lo = if i == 0 { 0 } else { self.bounds[i - 1] } as f64;
                let hi = self.bounds[i] as f64;
                let frac = (target - cum) as f64 / n as f64;
                return lo + (hi - lo) * frac;
            }
            cum += n;
        }
        *self.bounds.last().unwrap_or(&0) as f64
    }

    /// Summary document for JSON reports (`chh recover --json`, the
    /// `wal_append` bench): raw values divided by `scale`.
    pub fn summary_json(&self, scale: f64) -> Json {
        let count = self.count();
        let sum = self.sum_raw() as f64 / scale;
        let mean = if count == 0 { 0.0 } else { sum / count as f64 };
        obj(vec![
            ("count", Json::from(count as usize)),
            ("sum", Json::Num(sum)),
            ("mean", Json::Num(mean)),
            ("p50", Json::Num(self.approx_percentile(50.0) / scale)),
            ("p95", Json::Num(self.approx_percentile(95.0) / scale)),
            ("p99", Json::Num(self.approx_percentile(99.0) / scale)),
        ])
    }
}

// ───────────────────────────── registry ─────────────────────────────

type Callback = Box<dyn Fn() -> f64 + Send + Sync>;

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    /// computed at scrape time (wraps already-existing atomics)
    Func(Callback),
    Hist {
        h: Arc<Hist>,
        scale: f64,
    },
}

struct Series {
    labels: Vec<(String, String)>,
    metric: Metric,
}

struct Family {
    help: String,
    kind: &'static str,
    series: Vec<Series>,
}

/// A named collection of metrics with label support, rendered as
/// Prometheus text exposition. Global-free: the server owns one, tests
/// build as many as they want. The internal mutex is taken only at
/// registration and render time — recording goes through the `Arc`ed
/// primitives and never touches it.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

/// `(key, value)` label pairs at registration. Values are escaped at
/// render time, so any string is safe.
pub type Labels = Vec<(&'static str, String)>;

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    fn register(&self, name: &str, help: &str, kind: &'static str, labels: Labels, m: Metric) {
        let mut fams = self.families.lock().unwrap();
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            series: Vec::new(),
        });
        debug_assert_eq!(fam.kind, kind, "metric {name} registered with two kinds");
        fam.series.push(Series {
            labels: labels.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            metric: m,
        });
    }

    /// Register and return a counter (name should end in `_total`).
    pub fn counter(&self, name: &str, help: &str, labels: Labels) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.register(name, help, "counter", labels, Metric::Counter(c.clone()));
        c
    }

    /// Register and return a gauge.
    pub fn gauge(&self, name: &str, help: &str, labels: Labels) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.register(name, help, "gauge", labels, Metric::Gauge(g.clone()));
        g
    }

    /// Register a gauge computed at scrape time.
    pub fn gauge_fn(
        &self,
        name: &str,
        help: &str,
        labels: Labels,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.register(name, help, "gauge", labels, Metric::Func(Box::new(f)));
    }

    /// Register a counter whose value lives in an existing atomic,
    /// read at scrape time.
    pub fn counter_fn(
        &self,
        name: &str,
        help: &str,
        labels: Labels,
        f: impl Fn() -> f64 + Send + Sync + 'static,
    ) {
        self.register(name, help, "counter", labels, Metric::Func(Box::new(f)));
    }

    /// Register and return a histogram with the given bucket bounds;
    /// `scale` divides raw values for rendering (1e9 turns ns into s).
    pub fn hist(
        &self,
        name: &str,
        help: &str,
        labels: Labels,
        bounds: &'static [u64],
        scale: f64,
    ) -> Arc<Hist> {
        let h = Arc::new(Hist::new(bounds));
        self.register_hist(name, help, labels, h.clone(), scale);
        h
    }

    /// Register an externally-owned histogram (e.g. the WAL's fsync
    /// timings, which live in [`crate::wal::WalStats`]).
    pub fn register_hist(&self, name: &str, help: &str, labels: Labels, h: Arc<Hist>, scale: f64) {
        self.register(name, help, "histogram", labels, Metric::Hist { h, scale });
    }

    /// Render the whole registry as Prometheus text exposition
    /// (`text/plain; version=0.0.4`).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(4096);
        let fams = self.families.lock().unwrap();
        for (name, fam) in fams.iter() {
            let _ = writeln!(out, "# HELP {name} {}", escape_help(&fam.help));
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind);
            for s in &fam.series {
                match &s.metric {
                    Metric::Counter(c) => {
                        let _ = writeln!(out, "{name}{} {}", labels_str(&s.labels, None), c.get());
                    }
                    Metric::Gauge(g) => {
                        let _ = writeln!(
                            out,
                            "{name}{} {}",
                            labels_str(&s.labels, None),
                            fmt_f64(g.get())
                        );
                    }
                    Metric::Func(f) => {
                        let _ = writeln!(
                            out,
                            "{name}{} {}",
                            labels_str(&s.labels, None),
                            fmt_f64(f())
                        );
                    }
                    Metric::Hist { h, scale } => {
                        let counts = h.snapshot();
                        let mut cum = 0u64;
                        for (i, &n) in counts.iter().enumerate() {
                            cum += n;
                            let le = if i < h.bounds.len() {
                                fmt_f64(h.bounds[i] as f64 / scale)
                            } else {
                                "+Inf".to_string()
                            };
                            let _ = writeln!(
                                out,
                                "{name}_bucket{} {cum}",
                                labels_str(&s.labels, Some(&le))
                            );
                        }
                        let _ = writeln!(
                            out,
                            "{name}_sum{} {}",
                            labels_str(&s.labels, None),
                            fmt_f64(h.sum_raw() as f64 / scale)
                        );
                        let _ = writeln!(
                            out,
                            "{name}_count{} {}",
                            labels_str(&s.labels, None),
                            h.count()
                        );
                    }
                }
            }
        }
        out
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else {
        format!("{v}")
    }
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// `{k="v",...}` (with the `le` bucket label appended when given), or
/// the empty string for an unlabeled series.
fn labels_str(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

// ─────────────────────── scrape parsing (client) ───────────────────────

/// Parse an exposition body into `(series, value)` pairs — the client
/// half `loadgen` and the CI smoke use to diff two scrapes. Comment and
/// blank lines are skipped; a malformed sample line yields `None` from
/// the value parse and is dropped.
pub fn parse_scrape(text: &str) -> Vec<(String, f64)> {
    text.lines()
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (k, v) = l.rsplit_once(' ')?;
            let val = match v {
                "+Inf" => f64::INFINITY,
                "-Inf" => f64::NEG_INFINITY,
                _ => v.parse().ok()?,
            };
            Some((k.to_string(), val))
        })
        .collect()
}

/// Look up one series by family name and (optionally) a `key="value"`
/// label pair that must appear among its labels.
pub fn series_value(scrape: &[(String, f64)], name: &str, label: &str) -> Option<f64> {
    scrape
        .iter()
        .find(|(k, _)| match k.split_once('{') {
            Some((n, rest)) => {
                n == name
                    && (label.is_empty()
                        || rest.trim_end_matches('}').split(',').any(|kv| kv == label))
            }
            None => *k == name && label.is_empty(),
        })
        .map(|(_, v)| *v)
}

// ───────────────────────────── tracing ─────────────────────────────

/// Per-stage durations of one batch flush, accumulated inside the query
/// path (coordinator + online index) and recorded into stage-labeled
/// histograms by the server. Plain data — carrying it through the
/// pipeline never changes what is computed, only what is measured.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    /// hyperplane encoding (`encode_query` + per-bit scores)
    pub encode: Duration,
    /// probe planning (`plan_masks`)
    pub probe: Duration,
    /// shard scans (table probes + margin re-ranking)
    pub scan: Duration,
    /// cross-shard partial-hit merge
    pub merge: Duration,
}

impl StageTimes {
    pub fn add(&mut self, o: &StageTimes) {
        self.encode += o.encode;
        self.probe += o.probe;
        self.scan += o.scan;
        self.merge += o.merge;
    }
}

/// Encode a stage breakdown for the `x-chh-stages` response header:
/// `name=micros;name=micros` in recording order. Compact and allocation-
/// light — one small string per traced response.
pub fn encode_stages(stages: &[(&'static str, Duration)]) -> String {
    let mut out = String::with_capacity(stages.len() * 16);
    for (n, d) in stages {
        if !out.is_empty() {
            out.push(';');
        }
        out.push_str(n);
        out.push('=');
        out.push_str(&(d.as_micros() as u64).to_string());
    }
    out
}

/// Decode an `x-chh-stages` header value back into `(stage, micros)`
/// pairs. Total: malformed segments are skipped, never an error — the
/// header is diagnostics from another process, not protocol.
pub fn decode_stages(v: &str) -> Vec<(String, u64)> {
    v.split(';')
        .filter_map(|seg| {
            let (n, us) = seg.split_once('=')?;
            if n.is_empty() {
                return None;
            }
            Some((n.to_string(), us.parse::<u64>().ok()?))
        })
        .collect()
}

/// One partition's contribution to a routed request: which partition,
/// how long the router waited for its answer, and the per-stage
/// breakdown the partition echoed in its `x-chh-stages` header (empty
/// when the partition predates the header or the answer failed).
#[derive(Clone, Debug)]
pub struct PartitionSpan {
    pub partition: usize,
    /// router-side wall time waiting for this partition's answer
    pub wait: Duration,
    /// `(stage, micros)` pairs echoed by the partition
    pub stages: Vec<(String, u64)>,
}

/// One request's trace: the correlation id plus named stage durations,
/// carried from accept to response. Rendered into the slow-query log
/// when the request exceeds the threshold. Router-tier requests also
/// carry one [`PartitionSpan`] per partition contacted, so a single
/// slow-log line shows the full cross-tier breakdown.
pub struct Trace {
    pub id: String,
    stages: Vec<(&'static str, Duration)>,
    partitions: Vec<PartitionSpan>,
}

impl Trace {
    pub fn new(id: String) -> Self {
        Trace { id, stages: Vec::new(), partitions: Vec::new() }
    }

    pub fn stage(&mut self, name: &'static str, d: Duration) {
        self.stages.push((name, d));
    }

    pub fn stages(&self) -> &[(&'static str, Duration)] {
        &self.stages
    }

    /// Attach one partition's span (router tier only).
    pub fn partition(&mut self, span: PartitionSpan) {
        self.partitions.push(span);
    }

    pub fn partition_spans(&self) -> &[PartitionSpan] {
        &self.partitions
    }

    /// The slow-log JSON line (compact, no trailing newline).
    pub fn slow_line(&self, route: &str, status: u16, total: Duration) -> String {
        let stages = Json::Obj(
            self.stages
                .iter()
                .map(|&(n, d)| (n.to_string(), Json::Num(d.as_secs_f64() * 1e6)))
                .collect(),
        );
        let mut fields = vec![
            ("request_id", Json::from(self.id.as_str())),
            ("route", Json::from(route)),
            ("status", Json::from(status as usize)),
            ("total_us", Json::Num(total.as_secs_f64() * 1e6)),
            ("stages_us", stages),
        ];
        if !self.partitions.is_empty() {
            let spans = Json::Arr(
                self.partitions
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("partition", Json::from(s.partition)),
                            ("wait_us", Json::Num(s.wait.as_secs_f64() * 1e6)),
                            (
                                "stages_us",
                                Json::Obj(
                                    s.stages
                                        .iter()
                                        .map(|(n, us)| (n.clone(), Json::Num(*us as f64)))
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            );
            fields.push(("partitions", spans));
        }
        obj(fields).to_string_compact()
    }
}

/// Generate a request id: 16 hex chars mixing wall-clock nanos, the pid
/// and a process-wide counter — unique enough to correlate a request
/// across primary logs, the slow log and replica tailer output without
/// coordination.
pub fn gen_request_id() -> String {
    static CTR: AtomicU64 = AtomicU64::new(0);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let c = CTR.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let pid = std::process::id() as u64;
    format!("{:016x}", t ^ (pid << 48) ^ c)
}

// ───────────────────────────── slow log ─────────────────────────────

/// Append-only JSON-lines log of slow requests, rotated by size: when
/// the active file would exceed `max_bytes` it is renamed to
/// `<path>.1` (replacing any previous rotation) and a fresh file is
/// started. Write errors are swallowed — the log is diagnostics, not
/// durability.
pub struct SlowLog {
    path: PathBuf,
    max_bytes: u64,
    inner: Mutex<SlowInner>,
}

struct SlowInner {
    file: Option<std::fs::File>,
    written: u64,
}

impl SlowLog {
    pub fn create(path: impl Into<PathBuf>, max_bytes: u64) -> Self {
        SlowLog {
            path: path.into(),
            max_bytes: max_bytes.max(1024),
            inner: Mutex::new(SlowInner { file: None, written: 0 }),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn open(path: &Path) -> Option<(std::fs::File, u64)> {
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path).ok()?;
        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
        Some((f, len))
    }

    /// Append one line (a newline is added).
    pub fn append(&self, line: &str) {
        let mut g = self.inner.lock().unwrap();
        if g.file.is_none() {
            if let Some((f, len)) = Self::open(&self.path) {
                g.file = Some(f);
                g.written = len;
            } else {
                return;
            }
        }
        let add = line.len() as u64 + 1;
        if g.written > 0 && g.written + add > self.max_bytes {
            g.file = None;
            let mut rotated = self.path.as_os_str().to_owned();
            rotated.push(".1");
            let _ = std::fs::rename(&self.path, PathBuf::from(rotated));
            match Self::open(&self.path) {
                Some((f, len)) => {
                    g.file = Some(f);
                    g.written = len;
                }
                None => return,
            }
        }
        if let Some(f) = g.file.as_mut() {
            if f.write_all(line.as_bytes()).and_then(|_| f.write_all(b"\n")).is_ok() {
                g.written += add;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_percentiles() {
        let h = Hist::new(&[10, 100, 1000]);
        for v in [1, 5, 10, 50, 99, 500, 5000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum_raw(), 1 + 5 + 10 + 50 + 99 + 500 + 5000);
        // bucket placement: le=10 gets {1,5,10}, le=100 gets {50,99},
        // le=1000 gets {500}, +Inf gets {5000}
        assert_eq!(h.snapshot(), vec![3, 2, 1, 1]);
        let p50 = h.approx_percentile(50.0);
        assert!(p50 > 0.0 && p50 <= 100.0, "p50={p50}");
        // p100 lands in the overflow bucket and saturates at the last bound
        assert_eq!(h.approx_percentile(100.0), 1000.0);
        // empty histogram reports zeros
        let empty = Hist::latency();
        assert_eq!(empty.approx_percentile(50.0), 0.0);
        assert_eq!(empty.count(), 0);
    }

    #[test]
    fn hist_summary_json_scales() {
        let h = Hist::latency();
        h.observe_duration(Duration::from_micros(100));
        h.observe_duration(Duration::from_micros(300));
        let s = h.summary_json(1e3); // ns → µs
        assert_eq!(s.get("count").and_then(|v| v.as_usize()), Some(2));
        let sum = s.get("sum").and_then(|v| v.as_f64()).unwrap();
        assert!((sum - 400.0).abs() < 1.0, "sum µs = {sum}");
        assert!(s.get("p95").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }

    #[test]
    fn registry_renders_valid_exposition() {
        let reg = Registry::new();
        let c = reg.counter("chh_test_total", "test counter", vec![("route", "/q".into())]);
        c.add(3);
        let g = reg.gauge("chh_test_gauge", "a gauge", vec![]);
        g.set(2.5);
        reg.gauge_fn("chh_test_fn", "computed", vec![], || 7.0);
        let h = reg.hist("chh_test_seconds", "latency", vec![("stage", "scan".into())],
            LATENCY_BOUNDS_NS, 1e9);
        h.observe_duration(Duration::from_micros(80));
        h.observe_duration(Duration::from_millis(3));
        let text = reg.render();
        // HELP/TYPE lines present for every family
        for fam in ["chh_test_total", "chh_test_gauge", "chh_test_fn", "chh_test_seconds"] {
            assert!(text.contains(&format!("# HELP {fam} ")), "missing HELP for {fam}");
            assert!(text.contains(&format!("# TYPE {fam} ")), "missing TYPE for {fam}");
        }
        let scrape = parse_scrape(&text);
        assert_eq!(series_value(&scrape, "chh_test_total", r#"route="/q""#), Some(3.0));
        assert_eq!(series_value(&scrape, "chh_test_gauge", ""), Some(2.5));
        assert_eq!(series_value(&scrape, "chh_test_fn", ""), Some(7.0));
        assert_eq!(series_value(&scrape, "chh_test_seconds_count", r#"stage="scan""#), Some(2.0));
        // bucket counts are cumulative and end at +Inf == _count
        let mut last = 0.0;
        let mut inf = None;
        for (k, v) in &scrape {
            if k.starts_with("chh_test_seconds_bucket") {
                assert!(*v >= last, "bucket counts must be monotone: {k} {v}");
                last = *v;
                if k.contains("le=\"+Inf\"") {
                    inf = Some(*v);
                }
            }
        }
        assert_eq!(inf, Some(2.0));
    }

    #[test]
    fn labels_are_escaped() {
        let reg = Registry::new();
        let c = reg.counter("chh_esc_total", "esc", vec![("k", "a\"b\\c\nd".into())]);
        c.inc();
        let text = reg.render();
        assert!(text.contains(r#"k="a\"b\\c\nd""#), "escaped label missing: {text}");
        // the sample line still parses
        let scrape = parse_scrape(&text);
        assert!(scrape.iter().any(|(k, v)| k.starts_with("chh_esc_total") && *v == 1.0));
    }

    #[test]
    fn request_ids_are_unique_and_hex() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let id = gen_request_id();
            assert_eq!(id.len(), 16);
            assert!(id.chars().all(|c| c.is_ascii_hexdigit()));
            assert!(seen.insert(id), "duplicate request id");
        }
    }

    #[test]
    fn trace_slow_line_is_valid_json() {
        let mut t = Trace::new("abc123".into());
        t.stage("batch_wait", Duration::from_micros(120));
        t.stage("encode", Duration::from_micros(30));
        let line = t.slow_line("/query", 200, Duration::from_millis(12));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("request_id").and_then(|x| x.as_str()), Some("abc123"));
        assert_eq!(v.get("route").and_then(|x| x.as_str()), Some("/query"));
        assert_eq!(v.get("status").and_then(|x| x.as_usize()), Some(200));
        let stages = v.get("stages_us").unwrap();
        assert!(stages.get("batch_wait").and_then(|x| x.as_f64()).unwrap() > 100.0);
    }

    #[test]
    fn stage_codec_roundtrips_and_tolerates_junk() {
        let stages: Vec<(&'static str, Duration)> = vec![
            ("batch_wait", Duration::from_micros(120)),
            ("encode", Duration::from_micros(30)),
            ("scan", Duration::from_micros(4567)),
        ];
        let enc = encode_stages(&stages);
        assert_eq!(enc, "batch_wait=120;encode=30;scan=4567");
        let dec = decode_stages(&enc);
        assert_eq!(
            dec,
            vec![
                ("batch_wait".to_string(), 120),
                ("encode".to_string(), 30),
                ("scan".to_string(), 4567)
            ]
        );
        assert!(encode_stages(&[]).is_empty());
        // malformed segments are skipped, valid ones survive
        assert_eq!(decode_stages("a=1;;junk;=5;b=x;c=7"), vec![
            ("a".to_string(), 1),
            ("c".to_string(), 7)
        ]);
        assert!(decode_stages("").is_empty());
    }

    #[test]
    fn trace_partitions_render_in_slow_line() {
        let mut t = Trace::new("rid42".into());
        t.stage("route_fanout", Duration::from_micros(900));
        t.stage("merge", Duration::from_micros(15));
        t.partition(PartitionSpan {
            partition: 0,
            wait: Duration::from_micros(850),
            stages: vec![("encode".to_string(), 12), ("scan".to_string(), 700)],
        });
        t.partition(PartitionSpan {
            partition: 1,
            wait: Duration::from_micros(400),
            stages: vec![],
        });
        assert_eq!(t.partition_spans().len(), 2);
        let line = t.slow_line("/query", 200, Duration::from_millis(1));
        let v = Json::parse(&line).unwrap();
        let parts = v.get("partitions").and_then(|p| p.as_arr()).expect("partitions array");
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].get("partition").and_then(|x| x.as_usize()), Some(0));
        assert!(parts[0].get("wait_us").and_then(|x| x.as_f64()).unwrap() > 800.0);
        let st = parts[0].get("stages_us").unwrap();
        assert_eq!(st.get("scan").and_then(|x| x.as_f64()), Some(700.0));
        // a partition with no echoed stages still appears with its wait
        assert_eq!(parts[1].get("partition").and_then(|x| x.as_usize()), Some(1));
        // a trace without partition spans renders no "partitions" key
        let plain = Trace::new("x".into()).slow_line("/q", 200, Duration::from_micros(1));
        assert!(Json::parse(&plain).unwrap().get("partitions").is_none());
    }

    #[test]
    fn slow_log_exact_fit_line_does_not_rotate() {
        // a line landing exactly at the byte threshold stays in the
        // active file — rotation is strictly "would exceed"
        let dir = std::env::temp_dir().join(format!("chh_obs_fit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("slow.log");
        let log = SlowLog::create(&path, 2048);
        let first = "a".repeat(1023); // +1 newline = 1024 written
        log.append(&first);
        let second = "b".repeat(1023); // lands exactly at 2048
        log.append(&second);
        let active = std::fs::read_to_string(&path).unwrap();
        assert_eq!(active.len(), 2048, "both lines in the active file");
        let lines: Vec<&str> = active.lines().collect();
        assert_eq!(lines.len(), 2, "no truncation, no duplication");
        assert_eq!(lines[0], first);
        assert_eq!(lines[1], second);
        let mut rotated = path.as_os_str().to_owned();
        rotated.push(".1");
        assert!(
            std::fs::metadata(PathBuf::from(rotated.clone())).is_err(),
            "exact fit must not rotate"
        );
        // the NEXT append crosses the threshold: the full file rotates
        // to .1 intact and the new line starts a fresh active file
        let third = "c".repeat(10);
        log.append(&third);
        let moved = std::fs::read_to_string(PathBuf::from(rotated)).unwrap();
        assert_eq!(moved.len(), 2048, "rotated file holds the exact-fit content");
        assert_eq!(moved.lines().count(), 2);
        let active = std::fs::read_to_string(&path).unwrap();
        assert_eq!(active, format!("{third}\n"), "fresh file holds only the new line");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slow_log_rotation_overwrites_previous_dot1() {
        // the .1 file is replaced wholesale on each rotation, never
        // appended to
        let dir = std::env::temp_dir().join(format!("chh_obs_rot1_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("slow.log");
        let log = SlowLog::create(&path, 1024);
        let gen1 = "g1-".to_string() + &"x".repeat(1020); // 1024 with newline
        log.append(&gen1);
        log.append("tiny"); // rotates gen1 out
        let mut rotated = path.as_os_str().to_owned();
        rotated.push(".1");
        let r1 = std::fs::read_to_string(PathBuf::from(rotated.clone())).unwrap();
        assert!(r1.starts_with("g1-"), "first rotation holds gen1");
        // fill the fresh file and rotate again: .1 must now hold the
        // second generation only
        let gen2 = "g2-".to_string() + &"y".repeat(1015); // fills to the cap
        log.append(&gen2);
        log.append("tick"); // crosses the cap → second rotation
        let r2 = std::fs::read_to_string(PathBuf::from(rotated)).unwrap();
        assert!(
            !r2.contains("g1-"),
            "second rotation must overwrite .1, not append: {r2:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slow_log_rotates_by_size() {
        let dir = std::env::temp_dir().join(format!("chh_obs_slow_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("slow.log");
        let log = SlowLog::create(&path, 1024);
        let line = "x".repeat(100);
        for _ in 0..30 {
            log.append(&line);
        }
        let active = std::fs::metadata(&path).unwrap().len();
        assert!(active <= 1024, "active file exceeds cap: {active}");
        let mut rotated = path.as_os_str().to_owned();
        rotated.push(".1");
        assert!(
            std::fs::metadata(PathBuf::from(rotated)).is_ok(),
            "rotation file missing"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scrape_parser_skips_comments_and_junk() {
        let text = "# HELP a b\n# TYPE a counter\na 1\n\nbad-line-no-value\nb{x=\"y\"} 2.5\n";
        let s = parse_scrape(text);
        assert_eq!(s.len(), 2);
        assert_eq!(series_value(&s, "a", ""), Some(1.0));
        assert_eq!(series_value(&s, "b", r#"x="y""#), Some(2.5));
        assert_eq!(series_value(&s, "b", r#"x="z""#), None);
    }

    #[test]
    fn stage_times_accumulate() {
        let mut a = StageTimes::default();
        let b = StageTimes {
            encode: Duration::from_micros(1),
            probe: Duration::from_micros(2),
            scan: Duration::from_micros(3),
            merge: Duration::from_micros(4),
        };
        a.add(&b);
        a.add(&b);
        assert_eq!(a.encode, Duration::from_micros(2));
        assert_eq!(a.merge, Duration::from_micros(8));
    }
}
