//! Model and index persistence.
//!
//! A deployment trains LBH projections once (minutes at paper scale) and
//! serves them forever; this module gives every trained object a stable
//! on-disk form. The format is a small hand-rolled binary container
//! (magic + version + sections), since the vendored registry has no serde:
//!
//! ```text
//! "CHH1" | u32 version | u32 section_count |
//!   per section: u32 tag | u64 byte_len | payload
//! ```
//!
//! All integers little-endian. f32 payloads are raw LE bytes. Codes are
//! stored as u64 words. Round-trip property tests live at the bottom.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::hash::codes::CodeArray;
use crate::hash::{AhHash, BhHash, LbhHash, ProjectionPairs};
use crate::linalg::Mat;

const MAGIC: &[u8; 4] = b"CHH1";
const VERSION: u32 = 1;

/// Section tags.
mod tag {
    pub const META: u32 = 1; // [kind u32, k u32, dim u32]
    pub const U_MAT: u32 = 2;
    pub const V_MAT: u32 = 3;
    pub const CODES: u32 = 4; // [k u32, n u64, words...]
    pub const SHARDS_META: u32 = 5; // [k u32, radius u32, n_shards u32, n_live u64]
    pub const SHARD: u32 = 6; // [shard u32, epoch u64, n u64, n × (id u32, code u64)]
    pub const SHARDS_CONFIG: u32 = 7; // [compact_threshold u64, probes u64, top u64]
}

/// `usize::MAX` (an unlimited budget) encodes as `u64::MAX` so the value
/// survives a 32-bit ↔ 64-bit round trip unambiguously.
fn budget_word(v: usize) -> u64 {
    if v == usize::MAX {
        u64::MAX
    } else {
        v as u64
    }
}

fn budget_from_word(w: u64) -> usize {
    if w == u64::MAX {
        usize::MAX
    } else {
        w as usize
    }
}

/// Hash-family kind discriminator for META.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FamilyKind {
    Bh = 1,
    Lbh = 2,
    Ah = 3,
}

impl FamilyKind {
    fn from_u32(v: u32) -> Result<Self> {
        Ok(match v {
            1 => FamilyKind::Bh,
            2 => FamilyKind::Lbh,
            3 => FamilyKind::Ah,
            other => bail!("unknown family kind {other}"),
        })
    }
}

/// A deserialized bilinear model file.
#[derive(Debug)]
pub struct ModelFile {
    pub kind: FamilyKind,
    pub pairs: ProjectionPairs,
}

impl ModelFile {
    pub fn into_lbh(self) -> Result<LbhHash> {
        if self.kind != FamilyKind::Lbh {
            bail!("model file holds {:?}, not LBH", self.kind);
        }
        Ok(LbhHash::from_pairs(self.pairs))
    }

    pub fn into_bh(self) -> Result<BhHash> {
        if self.kind != FamilyKind::Bh {
            bail!("model file holds {:?}, not BH", self.kind);
        }
        Ok(BhHash::from_pairs(self.pairs))
    }

    pub fn into_ah(self) -> Result<AhHash> {
        if self.kind != FamilyKind::Ah {
            bail!("model file holds {:?}, not AH", self.kind);
        }
        Ok(AhHash::from_pairs(self.pairs))
    }
}

// ───────────────────────── writer ─────────────────────────

struct SectionWriter {
    buf: Vec<u8>,
    sections: u32,
}

impl SectionWriter {
    fn new() -> Self {
        SectionWriter { buf: Vec::new(), sections: 0 }
    }

    fn section(&mut self, tag: u32, payload: &[u8]) {
        self.buf.extend_from_slice(&tag.to_le_bytes());
        self.buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(payload);
        self.sections += 1;
    }

    fn finish(self, path: &Path) -> Result<()> {
        let mut out = Vec::with_capacity(12 + self.buf.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.sections.to_le_bytes());
        out.extend_from_slice(&self.buf);
        atomic_write(path, &out)
    }
}

/// Crash-safe file replacement: write `<path>.tmp`, fsync it, then
/// rename over `path` (and best-effort fsync the directory so the
/// rename itself is durable). Dying at any point leaves either the old
/// complete file or the new complete file — never a truncated hybrid —
/// plus at worst a stale `.tmp` that every loader ignores. All persist
/// writers ([`SectionWriter`]) and the WAL manifest/snapshot writers go
/// through this.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow!("atomic_write: {} has no file name", path.display()))?;
    let tmp = path.with_file_name(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()
            .with_context(|| format!("fsyncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
    // durable rename: fsync the parent directory where the platform
    // allows opening one (Unix); elsewhere the rename is still atomic
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(parent) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

fn mat_payload(m: &Mat) -> Vec<u8> {
    let mut p = Vec::with_capacity(16 + m.data.len() * 4);
    p.extend_from_slice(&(m.rows as u64).to_le_bytes());
    p.extend_from_slice(&(m.cols as u64).to_le_bytes());
    for v in &m.data {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

/// Save a bilinear family (BH / LBH / AH share the parameterization).
pub fn save_model(path: &Path, kind: FamilyKind, pairs: &ProjectionPairs) -> Result<()> {
    let mut w = SectionWriter::new();
    let mut meta = Vec::new();
    meta.extend_from_slice(&(kind as u32).to_le_bytes());
    meta.extend_from_slice(&(pairs.k() as u32).to_le_bytes());
    meta.extend_from_slice(&(pairs.dim() as u32).to_le_bytes());
    w.section(tag::META, &meta);
    w.section(tag::U_MAT, &mat_payload(&pairs.u));
    w.section(tag::V_MAT, &mat_payload(&pairs.v));
    w.finish(path)
}

/// Save a code array (the preprocessed database codes).
pub fn save_codes(path: &Path, codes: &CodeArray) -> Result<()> {
    let mut w = SectionWriter::new();
    let mut p = Vec::with_capacity(12 + codes.codes.len() * 8);
    p.extend_from_slice(&(codes.k as u32).to_le_bytes());
    p.extend_from_slice(&(codes.codes.len() as u64).to_le_bytes());
    for &c in &codes.codes {
        p.extend_from_slice(&c.to_le_bytes());
    }
    w.section(tag::CODES, &p);
    w.finish(path)
}

/// Save an online [`crate::online::ShardedIndex`] snapshot: shard layout
/// plus every shard's live (id, code) entries, merged across its frozen
/// generation and delta at call time. Epochs are recorded for diagnostics;
/// they restart at zero in a fresh process.
///
/// Operational config rides along in an optional section: the
/// compaction threshold and the index's default [`crate::online::QueryBudget`],
/// restored by [`load_sharded`] (snapshots written before this section
/// existed load with current defaults). A custom
/// [`crate::online::ProbePlanner`] (e.g. from `with_planner` with
/// hand-tuned costs) is still NOT stored; [`load_sharded`] rebuilds with
/// the default collision-model planner — reapply a non-default planner
/// after loading.
pub fn save_sharded(path: &Path, index: &crate::online::ShardedIndex) -> Result<()> {
    // Collect every shard's entries BEFORE writing the meta count: each
    // live_entries() call is an atomic per-shard snapshot, so the file's
    // total always matches its sections even if writers churn the index
    // between shard reads (the load-side count check would otherwise
    // reject a backup taken under load).
    let snapshots: Vec<(u64, Vec<(u32, u64)>)> = index
        .shards()
        .iter()
        .map(|s| (s.epoch(), s.live_entries()))
        .collect();
    let total: u64 = snapshots.iter().map(|(_, e)| e.len() as u64).sum();
    let mut w = SectionWriter::new();
    let mut meta = Vec::new();
    meta.extend_from_slice(&(index.bits() as u32).to_le_bytes());
    meta.extend_from_slice(&(index.radius() as u32).to_le_bytes());
    meta.extend_from_slice(&(index.shard_count() as u32).to_le_bytes());
    meta.extend_from_slice(&total.to_le_bytes());
    w.section(tag::SHARDS_META, &meta);
    let budget = index.default_budget();
    let mut cfg = Vec::with_capacity(24);
    cfg.extend_from_slice(&(index.compact_threshold() as u64).to_le_bytes());
    cfg.extend_from_slice(&budget_word(budget.probes).to_le_bytes());
    cfg.extend_from_slice(&budget_word(budget.top).to_le_bytes());
    w.section(tag::SHARDS_CONFIG, &cfg);
    for (i, (epoch, entries)) in snapshots.into_iter().enumerate() {
        let mut p = Vec::with_capacity(20 + entries.len() * 12);
        p.extend_from_slice(&(i as u32).to_le_bytes());
        p.extend_from_slice(&epoch.to_le_bytes());
        p.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for (id, code) in entries {
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&code.to_le_bytes());
        }
        w.section(tag::SHARD, &p);
    }
    w.finish(path)
}

// ───────────────────────── reader ─────────────────────────

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated file at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn read_sections(data: &[u8]) -> Result<Vec<(u32, &[u8])>> {
    let mut c = Cursor { b: data, pos: 0 };
    if c.take(4)? != MAGIC {
        bail!("bad magic — not a chh file");
    }
    let version = c.u32()?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let count = c.u32()?;
    let mut out = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let tag = c.u32()?;
        let len = c.u64()? as usize;
        out.push((tag, c.take(len)?));
    }
    Ok(out)
}

fn parse_mat(payload: &[u8]) -> Result<Mat> {
    let mut c = Cursor { b: payload, pos: 0 };
    let rows = c.u64()? as usize;
    let cols = c.u64()? as usize;
    let need = rows
        .checked_mul(cols)
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| anyhow!("matrix size overflow"))?;
    let raw = c.take(need)?;
    let data: Vec<f32> = raw
        .chunks_exact(4)
        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    Ok(Mat::from_vec(rows, cols, data))
}

/// Load a bilinear model file.
pub fn load_model(path: &Path) -> Result<ModelFile> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut data)?;
    let sections = read_sections(&data)?;
    let mut kind = None;
    let mut u = None;
    let mut v = None;
    for (t, payload) in sections {
        match t {
            tag::META => {
                let mut c = Cursor { b: payload, pos: 0 };
                kind = Some(FamilyKind::from_u32(c.u32()?)?);
                let _k = c.u32()?;
                let _dim = c.u32()?;
            }
            tag::U_MAT => u = Some(parse_mat(payload)?),
            tag::V_MAT => v = Some(parse_mat(payload)?),
            _ => {} // forward compat: unknown sections skipped
        }
    }
    let kind = kind.ok_or_else(|| anyhow!("missing META section"))?;
    let u = u.ok_or_else(|| anyhow!("missing U section"))?;
    let v = v.ok_or_else(|| anyhow!("missing V section"))?;
    if u.rows != v.rows || u.cols != v.cols {
        bail!("U/V shape mismatch");
    }
    Ok(ModelFile { kind, pairs: ProjectionPairs { u, v } })
}

/// Load a [`crate::online::ShardedIndex`] snapshot saved by
/// [`save_sharded`]. The id→shard routing is deterministic (`id % shards`),
/// so entries reload onto the same shard they were saved from; every shard
/// is compacted after loading so serving starts from frozen generations.
/// The probe policy is rebuilt from the default collision model — a
/// custom planner is not part of the snapshot (see [`save_sharded`]).
pub fn load_sharded(path: &Path) -> Result<crate::online::ShardedIndex> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut data)?;
    load_sharded_bytes(&data).with_context(|| format!("parsing {}", path.display()))
}

/// [`load_sharded`] over bytes already in memory — the replication
/// bootstrap hands the snapshot over the wire instead of a path
/// ([`crate::replicate::ReplicaIndex::bootstrap`]).
pub fn load_sharded_bytes(data: &[u8]) -> Result<crate::online::ShardedIndex> {
    let sections = read_sections(data)?;
    let mut index: Option<crate::online::ShardedIndex> = None;
    let mut config: Option<(u64, u64, u64)> = None;
    let mut loaded = 0u64;
    let mut expect = 0u64;
    for (t, payload) in sections {
        match t {
            tag::SHARDS_CONFIG => {
                let mut c = Cursor { b: payload, pos: 0 };
                config = Some((c.u64()?, c.u64()?, c.u64()?));
            }
            tag::SHARDS_META => {
                let mut c = Cursor { b: payload, pos: 0 };
                let k = c.u32()? as usize;
                let radius = c.u32()? as usize;
                let n_shards = c.u32()? as usize;
                expect = c.u64()?;
                if !(1..=64).contains(&k) || n_shards == 0 {
                    bail!("bad shard snapshot meta: k={k}, shards={n_shards}");
                }
                index = Some(crate::online::ShardedIndex::new(k, radius, n_shards));
            }
            tag::SHARD => {
                let idx = index
                    .as_ref()
                    .ok_or_else(|| anyhow!("SHARD section before SHARDS_META"))?;
                let mut c = Cursor { b: payload, pos: 0 };
                let shard = c.u32()? as usize;
                let _epoch = c.u64()?;
                let n = c.u64()? as usize;
                if shard >= idx.shard_count() {
                    bail!("shard index {shard} out of range");
                }
                let code_mask = crate::hash::codes::mask(idx.bits());
                for _ in 0..n {
                    let id = u32::from_le_bytes(c.take(4)?.try_into().unwrap());
                    let code = c.u64()?;
                    if idx.shard_of(id) != shard {
                        bail!("entry {id} misrouted to shard {shard}");
                    }
                    if code & !code_mask != 0 {
                        bail!("entry {id}: code {code:#x} exceeds {} bits", idx.bits());
                    }
                    idx.insert(id, code);
                    loaded += 1;
                }
            }
            _ => {}
        }
    }
    let mut index = index.ok_or_else(|| anyhow!("missing SHARDS_META section"))?;
    if loaded != expect {
        bail!("shard snapshot holds {loaded} entries, meta says {expect}");
    }
    if let Some((threshold, probes, top)) = config {
        // snapshots predating the config section simply keep the
        // defaults the index was constructed with
        index.set_compact_threshold(budget_from_word(threshold));
        index.set_default_budget(crate::online::QueryBudget::new(
            budget_from_word(probes),
            budget_from_word(top),
        ));
    }
    index.compact();
    Ok(index)
}

/// Load a code array file.
pub fn load_codes(path: &Path) -> Result<CodeArray> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut data)?;
    let sections = read_sections(&data)?;
    for (t, payload) in sections {
        if t == tag::CODES {
            let mut c = Cursor { b: payload, pos: 0 };
            let k = c.u32()? as usize;
            let n = c.u64()? as usize;
            if !(1..=64).contains(&k) {
                bail!("bad CODES section: k={k} out of range");
            }
            // Over-k words are a hard load error, mirroring the shard
            // snapshot gate above: a corrupt code would silently skew
            // every masked scan it later participates in.
            let code_mask = crate::hash::codes::mask(k);
            let raw = c.take(n * 8)?;
            let mut arr = CodeArray::with_capacity(k, n);
            for (i, ch) in raw.chunks_exact(8).enumerate() {
                let code = u64::from_le_bytes(ch.try_into().unwrap());
                if code & !code_mask != 0 {
                    bail!("code {i}: word {code:#x} exceeds {k} bits");
                }
                arr.push(code);
            }
            return Ok(arr);
        }
    }
    bail!("no CODES section in {}", path.display())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::forall;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("chh_persist_{}_{name}", std::process::id()))
    }

    #[test]
    fn model_roundtrip_exact() {
        forall("model save/load roundtrip", 12, |rng| {
            let k = rng.range(1, 33);
            let d = rng.range(2, 128);
            let pairs = ProjectionPairs::sample(d, k, rng);
            let path = tmp("model");
            save_model(&path, FamilyKind::Lbh, &pairs).map_err(|e| e.to_string())?;
            let back = load_model(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            crate::prop_assert!(back.kind == FamilyKind::Lbh, "kind");
            crate::prop_assert!(back.pairs.u == pairs.u, "u matrix");
            crate::prop_assert!(back.pairs.v == pairs.v, "v matrix");
            Ok(())
        });
    }

    #[test]
    fn codes_roundtrip_exact() {
        forall("codes save/load roundtrip", 12, |rng| {
            let k = rng.range(1, 65);
            let n = rng.range(0, 500);
            let mut codes = CodeArray::new(k);
            for _ in 0..n {
                codes.push(rng.next_u64() & crate::hash::codes::mask(k));
            }
            let path = tmp("codes");
            save_codes(&path, &codes).map_err(|e| e.to_string())?;
            let back = load_codes(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            crate::prop_assert!(back.k == k, "k");
            crate::prop_assert!(back.codes == codes.codes, "codes");
            Ok(())
        });
    }

    #[test]
    fn over_k_code_rejected_at_load() {
        // masked-scan regression: a stored word with bits above k must be
        // a hard load error, not a silent scan-skewing payload
        let mut codes = CodeArray::new(8);
        codes.push(0x11);
        let path = tmp("overk");
        save_codes(&path, &codes).unwrap();
        let mut data = std::fs::read(&path).unwrap();
        // the single code word 0x11 is the only 0x11 byte in the file;
        // set the top byte of its LE u64 to put a bit above k=8
        let pos = data.iter().position(|&b| b == 0x11).unwrap();
        data[pos + 7] = 0x80;
        std::fs::write(&path, &data).unwrap();
        let err = load_codes(&path).unwrap_err().to_string();
        let _ = std::fs::remove_file(&path);
        assert!(err.contains("exceeds 8 bits"), "got: {err}");
    }

    #[test]
    fn out_of_range_k_rejected_at_load() {
        let mut codes = CodeArray::new(8);
        codes.push(0x22);
        let path = tmp("badk");
        save_codes(&path, &codes).unwrap();
        let mut data = std::fs::read(&path).unwrap();
        // header (magic+version+sections = 12 B) + tag u32 + len u64 put
        // the CODES k field at byte 24 (format doc at the top of file)
        assert_eq!(data[24], 8, "layout drifted; adjust offset");
        data[24] = 65;
        std::fs::write(&path, &data).unwrap();
        let err = load_codes(&path).unwrap_err().to_string();
        let _ = std::fs::remove_file(&path);
        assert!(err.contains("k=65 out of range"), "got: {err}");
    }

    #[test]
    fn loaded_model_encodes_identically() {
        let mut rng = Rng::seed_from_u64(5);
        let bh = BhHash::sample(32, 12, &mut rng);
        let path = tmp("encode");
        save_model(&path, FamilyKind::Bh, &bh.pairs).unwrap();
        let back = load_model(&path).unwrap().into_bh().unwrap();
        let _ = std::fs::remove_file(&path);
        use crate::hash::HashFamily;
        for _ in 0..50 {
            let x = rng.gauss_vec(32);
            let r = crate::data::FeatRef::Dense(&x);
            assert_eq!(bh.encode_point(r), back.encode_point(r));
        }
    }

    #[test]
    fn sharded_snapshot_roundtrip() {
        let mut rng = Rng::seed_from_u64(9);
        let idx = crate::online::ShardedIndex::new(12, 3, 4);
        for id in 0..500u32 {
            idx.insert(id, rng.next_u64() & crate::hash::codes::mask(12));
        }
        for id in (0..500u32).step_by(7) {
            idx.remove(id);
        }
        // deliberately leave an uncompacted delta: save must merge it
        let path = tmp("sharded");
        save_sharded(&path, &idx).unwrap();
        let back = load_sharded(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.shard_count(), 4);
        assert_eq!(back.bits(), 12);
        assert_eq!(back.radius(), 3);
        assert_eq!(back.len(), idx.len());
        for (a, b) in idx.shards().iter().zip(back.shards()) {
            let mut ea = a.live_entries();
            ea.sort_unstable();
            let mut eb = b.live_entries();
            eb.sort_unstable();
            assert_eq!(ea, eb, "per-shard live entries survive the roundtrip");
        }
    }

    #[test]
    fn sharded_config_roundtrip() {
        let mut idx = crate::online::ShardedIndex::new(10, 2, 3);
        idx.set_compact_threshold(1234);
        idx.set_default_budget(crate::online::QueryBudget::new(77, 9));
        idx.insert(5, 0b11);
        let path = tmp("sharded_cfg");
        save_sharded(&path, &idx).unwrap();
        let back = load_sharded(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.compact_threshold(), 1234);
        assert_eq!(back.default_budget().probes, 77);
        assert_eq!(back.default_budget().top, 9);
        // unlimited budgets survive too (usize::MAX ↔ u64::MAX)
        let unl = crate::online::ShardedIndex::new(10, 2, 3);
        unl.set_default_budget(crate::online::QueryBudget::unlimited());
        save_sharded(&path, &unl).unwrap();
        let back = load_sharded(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.default_budget().probes, usize::MAX);
        assert_eq!(back.default_budget().top, usize::MAX);
    }

    #[test]
    fn sharded_snapshot_without_config_section_gets_defaults() {
        // hand-build an old-format file: SHARDS_META only, no
        // SHARDS_CONFIG — loaders must fall back to current defaults
        let mut data = Vec::new();
        data.extend_from_slice(MAGIC);
        data.extend_from_slice(&VERSION.to_le_bytes());
        data.extend_from_slice(&1u32.to_le_bytes()); // one section
        let mut meta = Vec::new();
        meta.extend_from_slice(&12u32.to_le_bytes()); // k
        meta.extend_from_slice(&3u32.to_le_bytes()); // radius
        meta.extend_from_slice(&2u32.to_le_bytes()); // shards
        meta.extend_from_slice(&0u64.to_le_bytes()); // no entries
        data.extend_from_slice(&tag::SHARDS_META.to_le_bytes());
        data.extend_from_slice(&(meta.len() as u64).to_le_bytes());
        data.extend_from_slice(&meta);
        let path = tmp("sharded_oldfmt");
        std::fs::write(&path, &data).unwrap();
        let back = load_sharded(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let defaults = crate::online::ShardedIndex::new(12, 3, 2);
        assert_eq!(back.compact_threshold(), defaults.compact_threshold());
        assert_eq!(back.default_budget().probes, defaults.default_budget().probes);
        assert_eq!(back.default_budget().top, defaults.default_budget().top);
    }

    #[test]
    fn truncated_tmp_leftover_is_ignored_by_loaders() {
        // simulate a crash mid-atomic-write: a good file plus a
        // truncated `<path>.tmp` next to it — loading the real path must
        // succeed untouched by the leftover
        let mut rng = Rng::seed_from_u64(11);
        let pairs = ProjectionPairs::sample(8, 4, &mut rng);
        let path = tmp("tmp_leftover");
        save_model(&path, FamilyKind::Bh, &pairs).unwrap();
        let good = std::fs::read(&path).unwrap();
        let tmp_path = path.with_file_name(format!(
            "{}.tmp",
            path.file_name().unwrap().to_str().unwrap()
        ));
        std::fs::write(&tmp_path, &good[..good.len() / 3]).unwrap();
        let back = load_model(&path).unwrap();
        assert_eq!(back.pairs.u, pairs.u);
        // and the next atomic write simply replaces the stale tmp
        save_model(&path, FamilyKind::Bh, &pairs).unwrap();
        assert!(load_model(&path).is_ok());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&tmp_path);
    }

    #[test]
    fn atomic_write_replaces_whole_file() {
        let path = tmp("atomic");
        atomic_write(&path, b"first version, longer than the second").unwrap();
        atomic_write(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        assert!(
            !path.with_file_name(format!(
                "{}.tmp",
                path.file_name().unwrap().to_str().unwrap()
            ))
            .exists(),
            "no tmp debris after a successful write"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sharded_loader_rejects_model_files() {
        let mut rng = Rng::seed_from_u64(10);
        let pairs = ProjectionPairs::sample(8, 4, &mut rng);
        let path = tmp("not_sharded");
        save_model(&path, FamilyKind::Bh, &pairs).unwrap();
        assert!(load_sharded(&path).is_err(), "no SHARDS_META section");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn kind_mismatch_rejected() {
        let mut rng = Rng::seed_from_u64(6);
        let pairs = ProjectionPairs::sample(8, 4, &mut rng);
        let path = tmp("kind");
        save_model(&path, FamilyKind::Bh, &pairs).unwrap();
        let m = load_model(&path).unwrap();
        assert!(m.into_lbh().is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn garbage_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a chh file at all").unwrap();
        assert!(load_model(&path).is_err());
        assert!(load_codes(&path).is_err());
        std::fs::write(&path, b"CH").unwrap();
        assert!(load_model(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_rejected() {
        let mut rng = Rng::seed_from_u64(7);
        let pairs = ProjectionPairs::sample(16, 8, &mut rng);
        let path = tmp("trunc");
        save_model(&path, FamilyKind::Bh, &pairs).unwrap();
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() / 2]).unwrap();
        assert!(load_model(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
