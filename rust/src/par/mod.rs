//! Data-parallel execution for the batch hot paths.
//!
//! The paper's headline application — active learning over a million
//! samples — is dominated by embarrassingly parallel batch work: encode
//! the whole database, answer a batch of hyperplane queries per AL round,
//! accumulate LBH gradients over an m-row training sample. This module
//! provides the one primitive all of those share: a [`Pool`] that splits
//! an index range into fixed-size chunks and runs them on scoped OS
//! threads (std-only — the vendored registry has no rayon).
//!
//! ## Determinism contract
//!
//! Every parallel path in the crate is **bit-identical to its serial
//! twin** (`workers = 1`), for any worker count. Two rules make that
//! hold, and new call sites must follow them (see `docs/PARALLEL.md`):
//!
//! 1. **Chunk boundaries are fixed by the caller**, never derived from
//!    the worker count. A chunk is the unit of float accumulation, so
//!    identical chunking ⇒ identical per-chunk rounding.
//! 2. **Results are combined in chunk order.** [`Pool::map`] returns
//!    chunk results in index order regardless of which worker finished
//!    first, and [`Pool::map_reduce`] folds them left to right.
//!
//! Work is still *scheduled* dynamically (an atomic chunk cursor), so
//! stragglers balance across workers without affecting the result.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a `workers` knob: 0 means "all available cores".
pub fn effective(workers: usize) -> usize {
    if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        workers
    }
}

/// A chunked fork-join pool over scoped threads.
///
/// `Pool` is a policy object (just a worker count) — threads live only
/// for the duration of one `map`/`for_each` call, so it is `Copy`-cheap
/// to construct, needs no shutdown, and nests safely (an inner call from
/// a worker simply runs with its own scope).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// Pool with `workers` threads; 0 resolves to all available cores.
    pub fn new(workers: usize) -> Self {
        Pool { workers: effective(workers) }
    }

    /// The serial special case — every parallel path's reference twin.
    pub fn serial() -> Self {
        Pool { workers: 1 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn is_serial(&self) -> bool {
        self.workers == 1
    }

    /// Split `0..n` into `chunk`-sized ranges, apply `f` to each, and
    /// return the results **in chunk order** (independent of scheduling).
    pub fn map<T, F>(&self, n: usize, chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        let chunk = chunk.max(1);
        if n == 0 {
            return Vec::new();
        }
        let n_chunks = n.div_ceil(chunk);
        let bounds = |c: usize| c * chunk..((c + 1) * chunk).min(n);
        let w = self.workers.min(n_chunks);
        if w <= 1 {
            return (0..n_chunks).map(|c| f(bounds(c))).collect();
        }
        let next = AtomicUsize::new(0);
        let per_worker: Vec<Vec<(usize, T)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..w)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            let c = next.fetch_add(1, Ordering::Relaxed);
                            if c >= n_chunks {
                                break;
                            }
                            local.push((c, f(bounds(c))));
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("par: worker panicked")).collect()
        });
        let mut slots: Vec<Option<T>> = (0..n_chunks).map(|_| None).collect();
        for (c, t) in per_worker.into_iter().flatten() {
            slots[c] = Some(t);
        }
        slots.into_iter().map(|t| t.expect("par: chunk never ran")).collect()
    }

    /// Side-effect-only variant of [`Self::map`].
    pub fn for_each<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.map(n, chunk, f);
    }

    /// Map chunks, then fold the per-chunk results **left to right in
    /// chunk order** — the deterministic reduction used for float
    /// accumulators (gradient partials, cost sums).
    pub fn map_reduce<T, F, R>(&self, n: usize, chunk: usize, map: F, reduce: R) -> Option<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
        R: Fn(T, T) -> T,
    {
        self.map(n, chunk, map).into_iter().reduce(reduce)
    }

    /// Run `f` over disjoint `chunk_len`-sized mutable sub-slices of
    /// `data`. `f` receives the chunk index (chunk `c` starts at element
    /// `c * chunk_len`). Safe because the chunks never alias; results are
    /// deterministic because every element is written by exactly one
    /// chunk.
    pub fn for_each_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        if data.is_empty() {
            return;
        }
        let n_chunks = data.len().div_ceil(chunk_len);
        let w = self.workers.min(n_chunks);
        if w <= 1 {
            for (c, part) in data.chunks_mut(chunk_len).enumerate() {
                f(c, part);
            }
            return;
        }
        let queue = Mutex::new(data.chunks_mut(chunk_len).enumerate());
        std::thread::scope(|s| {
            for _ in 0..w {
                s.spawn(|| loop {
                    // take the next chunk while holding the lock, run it after
                    let item = queue.lock().expect("par: queue poisoned").next();
                    match item {
                        Some((c, part)) => f(c, part),
                        None => break,
                    }
                });
            }
        });
    }
}

impl Default for Pool {
    /// All available cores.
    fn default() -> Self {
        Pool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_resolves_zero_to_cores() {
        assert!(effective(0) >= 1);
        assert_eq!(effective(3), 3);
        assert_eq!(Pool::new(0).workers(), effective(0));
        assert!(Pool::serial().is_serial());
    }

    #[test]
    fn map_preserves_chunk_order() {
        for workers in [1, 2, 4, 7] {
            let pool = Pool::new(workers);
            let got = pool.map(103, 10, |r| (r.start, r.end));
            assert_eq!(got.len(), 11);
            for (c, &(lo, hi)) in got.iter().enumerate() {
                assert_eq!(lo, c * 10);
                assert_eq!(hi, (c * 10 + 10).min(103));
            }
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let pool = Pool::new(4);
        assert!(pool.map(0, 8, |r| r.len()).is_empty());
        assert_eq!(pool.map(3, 8, |r| r.len()), vec![3]);
    }

    #[test]
    fn map_reduce_is_left_fold_in_chunk_order() {
        // string concatenation is order-sensitive: any scheduling
        // nondeterminism would scramble the result
        let serial = Pool::serial()
            .map_reduce(57, 5, |r| format!("[{}..{})", r.start, r.end), |a, b| a + &b)
            .unwrap();
        for workers in [2, 4, 8] {
            let par = Pool::new(workers)
                .map_reduce(57, 5, |r| format!("[{}..{})", r.start, r.end), |a, b| a + &b)
                .unwrap();
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn for_each_mut_writes_every_element_once() {
        for workers in [1, 3, 8] {
            let pool = Pool::new(workers);
            let mut data = vec![0u32; 1000];
            pool.for_each_mut(&mut data, 64, |c, part| {
                for (off, x) in part.iter_mut().enumerate() {
                    *x += (c * 64 + off) as u32 + 1;
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i as u32 + 1, "workers={workers} element {i}");
            }
        }
    }

    #[test]
    fn float_accumulation_parity_across_worker_counts() {
        // the contract the batch paths rely on: fixed chunks + ordered
        // fold ⇒ bit-identical sums for every worker count
        let xs: Vec<f32> = (0..10_000).map(|i| ((i * 2654435761_usize) as f32).sin()).collect();
        let sum_with = |workers: usize| -> f32 {
            Pool::new(workers)
                .map_reduce(
                    xs.len(),
                    256,
                    |r| r.map(|i| xs[i]).fold(0.0f32, |a, v| a + v),
                    |a, b| a + b,
                )
                .unwrap()
        };
        let serial = sum_with(1);
        for workers in [2, 3, 4, 8] {
            let par = sum_with(workers);
            assert_eq!(par.to_bits(), serial.to_bits(), "workers={workers}");
        }
    }

    #[test]
    fn nested_pools_do_not_deadlock() {
        let outer = Pool::new(4);
        let inner = Pool::new(2);
        let got = outer.map(8, 1, |r| {
            inner.map(4, 1, |q| q.start + r.start).into_iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..8).map(|i| (0..4).map(|q| q + i).sum()).collect();
        assert_eq!(got, want);
    }
}
