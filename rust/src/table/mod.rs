//! Hash-table search structures.
//!
//! * [`HyperplaneIndex`] — the paper's §4 compact protocol: ONE table over
//!   k-bit codes; a query encodes the hyperplane normal, flips per family
//!   rules (done inside `HashFamily::encode_query`), enumerates the Hamming
//!   ball of radius r around the lookup code, and re-ranks the bucket
//!   candidates by true margin `|wᵀx|/‖w‖`.
//! * [`LshIndex`] — the randomized multi-table mode of Theorem 2
//!   (`n^ρ` tables, exact-bucket probes), kept as the theory-faithful
//!   baseline the compact scheme is measured against.

use crate::data::FeatureStore;
use crate::hash::codes::{ball_volume, hamming_sweep_into, mask, CodeArray, HammingBall};
use crate::hash::fasthash::CodeMap;
use crate::hash::HashFamily;
use crate::linalg::nrm2;
use crate::par::Pool;

/// Queries per parallel work unit in [`HyperplaneIndex::query_batch`] and
/// the coordinator's pooled batch path; fixed so the split is independent
/// of the worker count.
pub(crate) const QUERY_CHUNK: usize = 4;

/// Reusable per-query scratch: the candidate gather and distance-sweep
/// buffers that used to be allocated fresh on every query. Callers that
/// answer many queries on one thread (router worker loops, benches) own
/// one `QueryScratch` and pass it to the `_with` query variants; the
/// plain variants fall back to a thread-local instance, so every entry
/// point is allocation-free after its first query on a thread either
/// way. Scratch never affects answers — only where the temporaries live.
#[derive(Default)]
pub struct QueryScratch {
    /// candidate ids gathered from the Hamming ball
    pub(crate) cand: Vec<u32>,
    /// full-scan Hamming distances ([`HyperplaneIndex::rank_search`])
    pub(crate) dists: Vec<u32>,
}

impl QueryScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

thread_local! {
    static TL_SCRATCH: std::cell::RefCell<QueryScratch> =
        std::cell::RefCell::new(QueryScratch::new());
}

/// Run `f` with this thread's scratch. Re-entrant calls (an `eligible`
/// closure that queries again) fall back to a fresh scratch instead of
/// panicking on the RefCell.
pub(crate) fn with_scratch<R>(f: impl FnOnce(&mut QueryScratch) -> R) -> R {
    TL_SCRATCH.with(|s| match s.try_borrow_mut() {
        Ok(mut sc) => f(&mut sc),
        Err(_) => f(&mut QueryScratch::new()),
    })
}

/// Result of a point-to-hyperplane query.
#[derive(Clone, Debug, Default)]
pub struct QueryHit {
    /// best candidate (index, margin |wᵀx|/‖w‖); None if ball was empty
    pub best: Option<(usize, f32)>,
    /// candidates scanned during re-ranking
    pub scanned: usize,
    /// hash buckets probed (ball volume actually enumerated)
    pub probed: usize,
    /// whether any non-empty bucket was found (Fig 3(c)/4(c) statistic)
    pub nonempty: bool,
}

/// Single-table compact hyperplane index.
pub struct HyperplaneIndex {
    k: usize,
    radius: usize,
    buckets: CodeMap<Vec<u32>>,
    codes: CodeArray,
}

impl HyperplaneIndex {
    /// Encode every database point with `family` and build the table.
    pub fn build(family: &dyn HashFamily, feats: &FeatureStore, radius: usize) -> Self {
        Self::build_with(family, feats, radius, &Pool::serial())
    }

    /// [`Self::build`] with the batch encode fanned out over `pool`
    /// (identical table for any worker count).
    pub fn build_with(
        family: &dyn HashFamily,
        feats: &FeatureStore,
        radius: usize,
        pool: &Pool,
    ) -> Self {
        Self::from_codes(family.encode_all_pool(feats, pool), radius)
    }

    /// Build from precomputed codes (e.g. the PJRT batch-encode path).
    pub fn from_codes(codes: CodeArray, radius: usize) -> Self {
        let k = codes.k;
        let mut buckets: CodeMap<Vec<u32>> = CodeMap::default();
        for (i, &c) in codes.codes.iter().enumerate() {
            buckets.entry(c).or_default().push(i as u32);
        }
        HyperplaneIndex { k, radius, buckets, codes }
    }

    pub fn bits(&self) -> usize {
        self.k
    }

    pub fn radius(&self) -> usize {
        self.radius
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Memory footprint estimate in bytes: code words plus the bucket
    /// map at allocated capacity
    /// ([`crate::hash::fasthash::bucket_map_bytes`] — the accounting
    /// shared with [`LshIndex`] and the online shards, so cross-index
    /// memory comparisons stay apples-to-apples).
    pub fn memory_bytes(&self) -> usize {
        self.codes.codes.capacity() * 8 + crate::hash::fasthash::bucket_map_bytes(&self.buckets)
    }

    /// Collect candidate ids within the Hamming ball of `lookup_code`,
    /// visiting buckets in increasing Hamming distance. Stops early once
    /// `stop_after` candidates have been gathered AND the current distance
    /// ring is fully enumerated (so ranking by ring is unbiased).
    pub fn candidates_into(&self, lookup_code: u64, stop_after: usize, out: &mut Vec<u32>) -> usize {
        out.clear();
        let mut probed = 0usize;
        let mut cur_weight = 0u32;
        let mut enough_at: Option<u32> = None;
        for mask in HammingBall::new(self.k, self.radius) {
            let w = mask.count_ones();
            if let Some(stop_w) = enough_at {
                if w > stop_w {
                    break;
                }
            }
            probed += 1;
            if let Some(ids) = self.buckets.get(&(lookup_code ^ mask)) {
                out.extend_from_slice(ids);
            }
            if w > cur_weight {
                cur_weight = w;
            }
            if out.len() >= stop_after && enough_at.is_none() {
                enough_at = Some(w);
            }
        }
        probed
    }

    /// Full query: encode `w`, gather ball candidates, re-rank by margin.
    /// `eligible` filters candidates (the AL loop excludes labeled points).
    pub fn query_filtered(
        &self,
        family: &dyn HashFamily,
        w: &[f32],
        feats: &FeatureStore,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        let lookup = family.encode_query(w);
        self.query_code_filtered(lookup, w, feats, eligible)
    }

    /// Query with a precomputed lookup code (thread-local scratch).
    pub fn query_code_filtered(
        &self,
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        with_scratch(|s| self.query_code_filtered_with(lookup, w, feats, eligible, s))
    }

    /// [`Self::query_code_filtered`] with caller-owned scratch — the
    /// allocation-free form for long-lived query loops. Answers are
    /// identical; only the candidate buffer's home differs.
    pub fn query_code_filtered_with(
        &self,
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        eligible: impl Fn(usize) -> bool,
        scratch: &mut QueryScratch,
    ) -> QueryHit {
        let cand = &mut scratch.cand;
        let probed = self.candidates_into(lookup, usize::MAX, cand);
        let w_norm = nrm2(w);
        let mut best: Option<(usize, f32)> = None;
        let mut scanned = 0usize;
        let mut any = false;
        for &id in cand.iter() {
            let id = id as usize;
            any = true;
            if !eligible(id) {
                continue;
            }
            scanned += 1;
            let m = crate::linalg::margin_feat(feats.row(id), w, w_norm);
            if best.map_or(true, |(_, bm)| m < bm) {
                best = Some((id, m));
            }
        }
        QueryHit { best, scanned, probed, nonempty: any }
    }

    /// Unfiltered query.
    pub fn query(&self, family: &dyn HashFamily, w: &[f32], feats: &FeatureStore) -> QueryHit {
        self.query_filtered(family, w, feats, |_| true)
    }

    /// Answer a batch of hyperplane queries (e.g. all one-vs-all SVM
    /// normals of an AL round) with the per-query work fanned out over
    /// `pool`. Queries are independent, so the hits are bit-identical to
    /// calling [`Self::query`] in a loop, in query order.
    pub fn query_batch(
        &self,
        family: &dyn HashFamily,
        queries: &[Vec<f32>],
        feats: &FeatureStore,
        pool: &Pool,
    ) -> Vec<QueryHit> {
        pool.map(queries.len(), QUERY_CHUNK, |range| {
            range.map(|q| self.query(family, &queries[q], feats)).collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect()
    }

    /// Top-T near-to-hyperplane neighbors: the paper's "short list L"
    /// protocol, returning up to T eligible candidates sorted by ascending
    /// true margin. Used for batch labeling and evaluation.
    pub fn query_topk(
        &self,
        family: &dyn HashFamily,
        w: &[f32],
        feats: &FeatureStore,
        t: usize,
        eligible: impl Fn(usize) -> bool,
    ) -> Vec<(usize, f32)> {
        with_scratch(|s| self.query_topk_with(family, w, feats, t, eligible, s))
    }

    /// [`Self::query_topk`] with caller-owned scratch for the candidate
    /// gather; the returned short list is identical.
    pub fn query_topk_with(
        &self,
        family: &dyn HashFamily,
        w: &[f32],
        feats: &FeatureStore,
        t: usize,
        eligible: impl Fn(usize) -> bool,
        scratch: &mut QueryScratch,
    ) -> Vec<(usize, f32)> {
        let lookup = family.encode_query(w);
        let cand = &mut scratch.cand;
        self.candidates_into(lookup, usize::MAX, cand);
        let w_norm = nrm2(w);
        let mut scored: Vec<(usize, f32)> = cand
            .iter()
            .map(|&id| id as usize)
            .filter(|&id| eligible(id))
            .map(|id| (id, crate::linalg::margin_feat(feats.row(id), w, w_norm)))
            .collect();
        // ties broken by id: identical margins (duplicate rows) must
        // order the same here and in the online index's query_topk
        scored.sort_by(|a, b| {
            a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        scored.truncate(t);
        scored
    }

    /// Hamming-ranking fallback: scan ALL codes, return the eligible point
    /// with the smallest Hamming distance to the lookup code, breaking ties
    /// by true margin among the best ring. O(n) but cheap: distances come
    /// from the chunked [`hamming_sweep_into`] popcount kernel (lookup
    /// masked to k bits once, hoisted out of the loop), then the
    /// eligibility/margin pass walks the precomputed distance slice. Uses
    /// thread-local scratch; see [`Self::rank_search_with`].
    pub fn rank_search(
        &self,
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        with_scratch(|s| self.rank_search_with(lookup, w, feats, eligible, s))
    }

    /// [`Self::rank_search`] with caller-owned scratch for the distance
    /// sweep. Best id, margin bits and the scanned counter are identical
    /// to the fused scalar loop: the sweep only hoists the XOR+POPCNT out
    /// of the eligibility walk, which visits ids in the same order.
    pub fn rank_search_with(
        &self,
        lookup: u64,
        w: &[f32],
        feats: &FeatureStore,
        eligible: impl Fn(usize) -> bool,
        scratch: &mut QueryScratch,
    ) -> QueryHit {
        let qm = lookup & mask(self.k);
        hamming_sweep_into(&self.codes.codes, qm, &mut scratch.dists);
        let mut best_d = u32::MAX;
        let mut best: Option<(usize, f32)> = None;
        let w_norm = nrm2(w);
        let mut scanned = 0usize;
        for (i, &d) in scratch.dists.iter().enumerate() {
            if !eligible(i) {
                continue;
            }
            if d > best_d {
                continue;
            }
            scanned += 1;
            let m = crate::linalg::margin_feat(feats.row(i), w, w_norm);
            if d < best_d || best.map_or(true, |(_, bm)| m < bm) {
                best_d = d;
                best = Some((i, m));
            }
        }
        QueryHit { best, scanned, probed: 0, nonempty: best.is_some() }
    }

    /// Number of buckets a radius-r query enumerates: Σ C(k,i).
    pub fn probe_volume(&self) -> u64 {
        ball_volume(self.k, self.radius)
    }
}

// ───────────────────────── multi-table randomized LSH ─────────────────────────

/// Theorem-2-style multi-table index: L independent k-bit tables, each
/// probed at the exact lookup code; the union of bucket members is
/// re-ranked by margin.
pub struct LshIndex<H: HashFamily> {
    tables: Vec<(H, CodeMap<Vec<u32>>)>,
    n: usize,
}

impl<H: HashFamily> LshIndex<H> {
    /// Build L tables using `make(table_idx)` to draw each table's family.
    pub fn build(
        feats: &FeatureStore,
        n_tables: usize,
        make: impl FnMut(usize) -> H,
    ) -> Self {
        Self::build_with(feats, n_tables, make, &Pool::serial())
    }

    /// [`Self::build`] with the per-table encode + bucket work fanned out
    /// over `pool` — the multi-table analogue of
    /// [`HyperplaneIndex::build_with`]. Families are drawn serially in
    /// table order first (`make` may hold a sequential RNG), so the
    /// resulting tables are identical for any worker count.
    pub fn build_with(
        feats: &FeatureStore,
        n_tables: usize,
        make: impl FnMut(usize) -> H,
        pool: &Pool,
    ) -> Self {
        let fams: Vec<H> = (0..n_tables).map(make).collect();
        let bucket_sets: Vec<CodeMap<Vec<u32>>> = pool
            .map(n_tables, 1, |range| {
                range
                    .map(|t| {
                        let codes = fams[t].encode_all(feats);
                        let mut buckets: CodeMap<Vec<u32>> = CodeMap::default();
                        for (i, &c) in codes.codes.iter().enumerate() {
                            buckets.entry(c).or_default().push(i as u32);
                        }
                        buckets
                    })
                    .collect::<Vec<_>>()
            })
            .into_iter()
            .flatten()
            .collect();
        let tables = fams.into_iter().zip(bucket_sets).collect();
        LshIndex { tables, n: feats.len() }
    }

    pub fn n_tables(&self) -> usize {
        self.tables.len()
    }

    /// Memory footprint estimate mirroring
    /// [`HyperplaneIndex::memory_bytes`]'s accounting (the shared
    /// [`crate::hash::fasthash::bucket_map_bytes`] formula), summed over
    /// all L tables. The families' projection parameters are not counted
    /// (the compact table does not count its family either) — this
    /// measures the L× table storage Theorem 2 pays for.
    pub fn memory_bytes(&self) -> usize {
        self.tables
            .iter()
            .map(|(_, buckets)| crate::hash::fasthash::bucket_map_bytes(buckets))
            .sum()
    }

    /// Query all tables; candidates are deduplicated with a visit mark.
    pub fn query_filtered(
        &self,
        w: &[f32],
        feats: &FeatureStore,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        let mut visited = vec![false; self.n];
        let w_norm = nrm2(w);
        let mut best: Option<(usize, f32)> = None;
        let mut scanned = 0usize;
        let mut any = false;
        for (fam, buckets) in &self.tables {
            let code = fam.encode_query(w);
            if let Some(ids) = buckets.get(&code) {
                any = true;
                for &id in ids {
                    let id = id as usize;
                    if visited[id] {
                        continue;
                    }
                    visited[id] = true;
                    if !eligible(id) {
                        continue;
                    }
                    scanned += 1;
                    let m = crate::linalg::margin_feat(feats.row(id), w, w_norm);
                    if best.map_or(true, |(_, bm)| m < bm) {
                        best = Some((id, m));
                    }
                }
            }
        }
        QueryHit { best, scanned, probed: self.tables.len(), nonempty: any }
    }

    /// Answer a batch of hyperplane queries with the per-query work
    /// fanned out over `pool` — the multi-table analogue of
    /// [`HyperplaneIndex::query_batch`]. Queries are independent, so hits
    /// are bit-identical to a serial loop, in query order.
    pub fn query_batch(
        &self,
        queries: &[Vec<f32>],
        feats: &FeatureStore,
        pool: &Pool,
    ) -> Vec<QueryHit> {
        pool.map(queries.len(), QUERY_CHUNK, |range| {
            range
                .map(|q| self.query_filtered(&queries[q], feats, |_| true))
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::codes::hamming;
    use crate::hash::BhHash;
    use crate::rng::Rng;
    use crate::testing::forall;

    #[test]
    fn from_codes_buckets_cover_all_points() {
        forall("buckets partition points", 32, |rng| {
            let k = rng.range(4, 20);
            let n = rng.range(1, 200);
            let mut codes = CodeArray::new(k);
            for _ in 0..n {
                codes.push(rng.next_u64() & crate::hash::codes::mask(k));
            }
            let idx = HyperplaneIndex::from_codes(codes, 2);
            let total: usize = idx.buckets.values().map(|v| v.len()).sum();
            crate::prop_assert!(total == n, "bucket sizes sum {total} != {n}");
            Ok(())
        });
    }

    #[test]
    fn candidates_match_linear_scan() {
        // Ball lookup must return exactly the points within Hamming radius.
        forall("ball lookup == brute force", 24, |rng| {
            let k = rng.range(6, 18);
            let r = rng.range(0, 4);
            let n = rng.range(10, 300);
            let mut codes = CodeArray::new(k);
            for _ in 0..n {
                codes.push(rng.next_u64() & crate::hash::codes::mask(k));
            }
            let all = codes.codes.clone();
            let idx = HyperplaneIndex::from_codes(codes, r);
            let q = rng.next_u64() & crate::hash::codes::mask(k);
            let mut cand = Vec::new();
            idx.candidates_into(q, usize::MAX, &mut cand);
            let mut got: Vec<u32> = cand.clone();
            got.sort_unstable();
            let mut want: Vec<u32> = (0..n as u32)
                .filter(|&i| hamming(all[i as usize], q, k) <= r as u32)
                .collect();
            want.sort_unstable();
            crate::prop_assert!(got == want, "mismatch k={k} r={r}");
            Ok(())
        });
    }

    #[test]
    fn query_returns_minimum_margin_candidate() {
        let mut rng = Rng::seed_from_u64(5);
        let ds = test_blobs(400, 16, 4, &mut rng);
        let fam = BhHash::sample(16, 8, &mut rng);
        let idx = HyperplaneIndex::build(&fam, ds.features(), 8); // full ball: all points
        let w = crate::testing::unit_vec(&mut rng, 16);
        let hit = idx.query(&fam, &w, ds.features());
        assert!(hit.nonempty);
        let (best_i, best_m) = hit.best.unwrap();
        // brute force minimum margin
        let wn = nrm2(&w);
        let mut bf = (0usize, f32::INFINITY);
        for i in 0..ds.len() {
            let m = crate::linalg::margin_feat(ds.features().row(i), &w, wn);
            if m < bf.1 {
                bf = (i, m);
            }
        }
        assert_eq!(best_i, bf.0);
        assert!((best_m - bf.1).abs() < 1e-6);
    }

    #[test]
    fn eligible_filter_respected() {
        let mut rng = Rng::seed_from_u64(6);
        let ds = test_blobs(100, 8, 2, &mut rng);
        let fam = BhHash::sample(8, 6, &mut rng);
        let idx = HyperplaneIndex::build(&fam, ds.features(), 6);
        let w = crate::testing::unit_vec(&mut rng, 8);
        let banned = 37usize;
        // ban everything except one point: query must return it
        let hit = idx.query_filtered(&fam, &w, ds.features(), |i| i == banned);
        assert_eq!(hit.best.unwrap().0, banned);
        assert_eq!(hit.scanned, 1);
    }

    #[test]
    fn empty_ball_reports_empty() {
        let mut codes = CodeArray::new(16);
        codes.push(0xFFFF);
        let idx = HyperplaneIndex::from_codes(codes, 1);
        let hit = idx.query_code_filtered(0, &[1.0; 4], &FeatureStore::Dense(crate::linalg::Mat::zeros(1, 4)), |_| true);
        assert!(!hit.nonempty);
        assert!(hit.best.is_none());
        assert_eq!(hit.probed as u64, ball_volume(16, 1));
    }

    #[test]
    fn rank_search_finds_closest_ring() {
        let mut rng = Rng::seed_from_u64(8);
        let ds = test_blobs(200, 16, 2, &mut rng);
        let fam = BhHash::sample(16, 10, &mut rng);
        let idx = HyperplaneIndex::build(&fam, ds.features(), 0);
        let w = crate::testing::unit_vec(&mut rng, 16);
        let lookup = fam.encode_query(&w);
        let hit = idx.rank_search(lookup, &w, ds.features(), |_| true);
        let (i, _) = hit.best.unwrap();
        let d_best = hamming(idx.codes.get(i), lookup, 10);
        for j in 0..ds.len() {
            assert!(hamming(idx.codes.get(j), lookup, 10) >= d_best);
        }
    }

    #[test]
    fn rank_search_masks_lookup_bits_above_k() {
        // regression (masked-scan bugfix): garbage bits above k in the
        // lookup code must not perturb distances — the sweep masks the
        // lookup once instead of XORing raw words
        let mut rng = Rng::seed_from_u64(18);
        let ds = test_blobs(150, 16, 2, &mut rng);
        let fam = BhHash::sample(16, 10, &mut rng);
        let idx = HyperplaneIndex::build(&fam, ds.features(), 0);
        let w = crate::testing::unit_vec(&mut rng, 16);
        let lookup = fam.encode_query(&w);
        let clean = idx.rank_search(lookup, &w, ds.features(), |_| true);
        let dirty = idx.rank_search(lookup | (0xDEAD << 10), &w, ds.features(), |_| true);
        assert_eq!(dirty.best.map(|(i, m)| (i, m.to_bits())), clean.best.map(|(i, m)| (i, m.to_bits())));
        assert_eq!(dirty.scanned, clean.scanned);
    }

    #[test]
    fn scratch_reuse_is_answer_invariant() {
        // one scratch across many queries == fresh scratch per query
        let mut rng = Rng::seed_from_u64(19);
        let ds = test_blobs(400, 16, 3, &mut rng);
        let fam = BhHash::sample(16, 9, &mut rng);
        let idx = HyperplaneIndex::build(&fam, ds.features(), 2);
        let mut shared = QueryScratch::new();
        for _ in 0..12 {
            let w = crate::testing::unit_vec(&mut rng, 16);
            let lookup = fam.encode_query(&w);
            let a = idx.query_code_filtered_with(lookup, &w, ds.features(), |_| true, &mut shared);
            let b = idx.query_code_filtered_with(
                lookup,
                &w,
                ds.features(),
                |_| true,
                &mut QueryScratch::new(),
            );
            assert_eq!(a.best.map(|(i, m)| (i, m.to_bits())), b.best.map(|(i, m)| (i, m.to_bits())));
            assert_eq!((a.scanned, a.probed, a.nonempty), (b.scanned, b.probed, b.nonempty));
            let ta = idx.query_topk_with(&fam, &w, ds.features(), 5, |_| true, &mut shared);
            let tb = idx.query_topk(&fam, &w, ds.features(), 5, |_| true);
            assert_eq!(ta, tb);
            let ra = idx.rank_search_with(lookup, &w, ds.features(), |_| true, &mut shared);
            let rb = idx.rank_search(lookup, &w, ds.features(), |_| true);
            assert_eq!(ra.best.map(|(i, m)| (i, m.to_bits())), rb.best.map(|(i, m)| (i, m.to_bits())));
            assert_eq!(ra.scanned, rb.scanned);
        }
    }

    #[test]
    fn lsh_union_dedup() {
        let mut rng = Rng::seed_from_u64(9);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let mut seeds: Vec<u64> = (0..8).map(|_| rng.next_u64()).collect();
        let lsh = LshIndex::build(ds.features(), 8, |t| {
            BhHash::sample(16, 6, &mut Rng::seed_from_u64(seeds[t]))
        });
        seeds.clear();
        let w = crate::testing::unit_vec(&mut rng, 16);
        let hit = lsh.query_filtered(&w, ds.features(), |_| true);
        assert!(hit.probed == 8);
        if let Some((i, m)) = hit.best {
            assert!(i < 300);
            assert!(m >= 0.0);
        }
    }

    #[test]
    fn query_topk_sorted_and_filtered() {
        let mut rng = Rng::seed_from_u64(77);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let fam = BhHash::sample(16, 8, &mut rng);
        let idx = HyperplaneIndex::build(&fam, ds.features(), 8); // full ball
        let w = crate::testing::unit_vec(&mut rng, 16);
        let top = idx.query_topk(&fam, &w, ds.features(), 10, |i| i % 2 == 0);
        assert!(top.len() <= 10);
        for pair in top.windows(2) {
            assert!(pair[0].1 <= pair[1].1, "must be margin-sorted");
        }
        assert!(top.iter().all(|&(i, _)| i % 2 == 0), "filter respected");
        // the best entry matches query_filtered's best under same filter
        let single = idx.query_filtered(&fam, &w, ds.features(), |i| i % 2 == 0);
        assert_eq!(top[0].0, single.best.unwrap().0);
    }

    // build_with / query_batch parity across worker counts (for both
    // HyperplaneIndex and LshIndex) is covered by the integration suite
    // in rust/tests/batch_parallel.rs.

    #[test]
    fn lsh_memory_bytes_counts_every_table() {
        let mut rng = Rng::seed_from_u64(43);
        let ds = test_blobs(2000, 16, 3, &mut rng);
        let mut seeds: Vec<u64> = (0..6).map(|_| rng.next_u64()).collect();
        let lsh = LshIndex::build(ds.features(), 6, |t| {
            BhHash::sample(16, 8, &mut Rng::seed_from_u64(seeds[t]))
        });
        seeds.clear();
        // floor: every entry id (4B) appears in every table
        assert!(
            lsh.memory_bytes() >= 6 * 2000 * 4,
            "memory_bytes {} under-reports the L x n entry payload",
            lsh.memory_bytes()
        );
        // the single compact table reports less than L tables over the
        // same points — the Theorem-2 storage argument in numbers
        let fam = BhHash::sample(16, 8, &mut rng);
        let compact = HyperplaneIndex::build(&fam, ds.features(), 2);
        assert!(lsh.memory_bytes() > compact.memory_bytes());
    }

    #[test]
    fn memory_bytes_counts_bucket_payloads() {
        let mut rng = Rng::seed_from_u64(41);
        let k = 16;
        let n = 5000usize;
        let mut codes = CodeArray::with_capacity(k, n);
        for _ in 0..n {
            codes.push(rng.next_u64() & crate::hash::codes::mask(k));
        }
        let idx = HyperplaneIndex::from_codes(codes, 2);
        // lower bound: every entry id (4B) + every code word (8B) must be
        // accounted for, plus per-bucket map overhead
        let floor = n * 4
            + n * 8
            + idx.bucket_count() * (8 + std::mem::size_of::<Vec<u32>>());
        assert!(
            idx.memory_bytes() >= floor,
            "memory_bytes {} under-reports floor {floor}",
            idx.memory_bytes()
        );
    }

    #[test]
    fn probe_volume_formula() {
        let codes = CodeArray::new(20);
        let idx = HyperplaneIndex::from_codes(codes, 4);
        assert_eq!(idx.probe_volume(), 1 + 20 + 190 + 1140 + 4845);
    }

    #[test]
    fn stop_after_early_exit_completes_ring() {
        // with stop_after=1 the search must still finish the distance ring
        // it found candidates in (unbiased ring ranking)
        let mut codes = CodeArray::new(8);
        codes.push(0b0000_0001); // distance 1 from 0
        codes.push(0b0000_0010); // distance 1 from 0
        codes.push(0b0000_0111); // distance 3
        let idx = HyperplaneIndex::from_codes(codes, 3);
        let mut cand = Vec::new();
        idx.candidates_into(0, 1, &mut cand);
        let mut got = cand.clone();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1], "both distance-1 points must be found");
    }
}
