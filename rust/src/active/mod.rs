//! SVM active learning driven by point-to-hyperplane search (§5 protocol).
//!
//! For each class c (one-vs-all) and each run:
//! 1. seed the labeled set with `init_per_class` samples from every class;
//! 2. train a linear SVM on the binary labels of class c;
//! 3. for 300 iterations: ask the selection strategy for the unlabeled
//!    point nearest the current hyperplane, reveal its label, retrain
//!    (warm-started), and record the selected point's true margin;
//! 4. every `eval_every` iterations score the remaining unlabeled pool and
//!    compute average precision.
//!
//! Strategies: random, exhaustive (the two §5.2 baselines) and hash-based
//! (AH / EH / BH / LBH through [`crate::table::HyperplaneIndex`]); empty
//! hash lookups fall back to random selection exactly as the paper does.

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::{Dataset, FeatureStore};
use crate::hash::HashFamily;
use crate::metrics::average_precision;
use crate::rng::Rng;
use crate::svm::{LinearSvm, SvmConfig};
use crate::table::HyperplaneIndex;

/// Which sample-selection strategy an AL run uses.
#[derive(Clone)]
pub enum Strategy {
    Random,
    Exhaustive,
    /// hash family + prebuilt single-table index + Hamming radius
    Hash { family: Arc<dyn HashFamily>, index: Arc<HyperplaneIndex> },
    /// Hamming-ranking mode: linear scan over codes instead of bucket probes
    HashRank { family: Arc<dyn HashFamily>, index: Arc<HyperplaneIndex> },
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Random => "Random".into(),
            Strategy::Exhaustive => "Exhaustive".into(),
            Strategy::Hash { family, .. } => format!("{}-Hash", family.name()),
            Strategy::HashRank { family, .. } => format!("{}-Rank", family.name()),
        }
    }
}

/// Per-iteration bookkeeping of one (class, run) AL trajectory.
#[derive(Clone, Debug, Default)]
pub struct ClassResult {
    /// (iteration, AP) pairs at evaluation points
    pub ap_curve: Vec<(usize, f64)>,
    /// margin |wᵀx|/‖w‖ of the point selected at each iteration
    pub min_margins: Vec<f32>,
    /// queries (out of al_iters) whose hash lookup was nonempty
    pub nonempty_lookups: usize,
    /// total candidates scanned by the selector
    pub scanned_total: usize,
    /// wall-clock spent inside selection only (the hashing speedup metric)
    pub select_secs: f64,
    /// wall-clock spent retraining the SVM
    pub train_secs: f64,
}

/// Aggregated result over classes and runs.
#[derive(Clone, Debug, Default)]
pub struct AlResult {
    pub strategy: String,
    /// mean AP curve: (iteration, MAP)
    pub map_curve: Vec<(usize, f64)>,
    /// mean selected margin per iteration
    pub margin_curve: Vec<f64>,
    /// per-class nonempty lookup counts (averaged over runs)
    pub nonempty_per_class: Vec<f64>,
    pub select_secs: f64,
    pub train_secs: f64,
    pub scanned_total: usize,
}

/// Re-usable configuration of one AL experiment (see [`ExperimentConfig`]).
#[derive(Clone, Debug)]
pub struct AlConfig {
    pub al_iters: usize,
    pub init_per_class: usize,
    pub eval_every: usize,
    pub svm: SvmConfig,
}

impl AlConfig {
    pub fn from_experiment(cfg: &ExperimentConfig) -> Self {
        AlConfig {
            al_iters: cfg.al_iters,
            init_per_class: cfg.profile.init_per_class(),
            eval_every: cfg.eval_every,
            svm: SvmConfig { c: cfg.svm_c, ..Default::default() },
        }
    }
}

/// The engine: borrows a dataset, runs (class × run) trajectories.
pub struct AlEngine<'a> {
    pub data: &'a Dataset,
    pub cfg: AlConfig,
}

impl<'a> AlEngine<'a> {
    pub fn new(data: &'a Dataset, cfg: AlConfig) -> Self {
        AlEngine { data, cfg }
    }

    /// Draw the shared initial labeled set: `init_per_class` per class
    /// (including the "other" class when present, mirroring a realistic
    /// seed pool).
    pub fn initial_labeled(&self, rng: &mut Rng) -> Vec<usize> {
        let mut labeled = Vec::new();
        let n_labels = *self.data.labels().iter().max().unwrap_or(&0) as usize + 1;
        for c in 0..n_labels {
            let members = self.data.class_indices(c as u16);
            if members.is_empty() {
                continue;
            }
            let take = self.cfg.init_per_class.min(members.len());
            for &i in rng.sample_indices(members.len(), take).iter().map(|&p| &members[p]) {
                labeled.push(i);
            }
        }
        labeled
    }

    /// Run one (class, strategy) trajectory from a given initial pool.
    pub fn run_class(
        &self,
        class: u16,
        strategy: &Strategy,
        init_labeled: &[usize],
        rng: &mut Rng,
    ) -> ClassResult {
        let n = self.data.len();
        let feats = self.data.features();
        let relevant: Vec<bool> = self.data.binary_labels(class);
        let mut labeled = vec![false; n];
        let mut idx: Vec<usize> = Vec::with_capacity(init_labeled.len() + self.cfg.al_iters);
        let mut y: Vec<f32> = Vec::with_capacity(idx.capacity());
        for &i in init_labeled {
            if !labeled[i] {
                labeled[i] = true;
                idx.push(i);
                y.push(if relevant[i] { 1.0 } else { -1.0 });
            }
        }
        let mut svm = LinearSvm::new(self.data.dim());
        let mut res = ClassResult::default();
        let mut t_train = crate::metrics::Stopwatch::new();
        let mut t_select = crate::metrics::Stopwatch::new();
        // auto-balance: the one-vs-all problems are heavily skewed and the
        // margin criterion keeps adding near-boundary negatives; weight the
        // positive class like LIBLINEAR's -w1 n_neg/n_pos
        let balanced = |y: &[f32]| -> SvmConfig {
            let pos = y.iter().filter(|&&v| v > 0.0).count().max(1);
            let neg = y.len() - pos;
            SvmConfig {
                pos_weight: (neg as f32 / pos as f32).clamp(1.0, 100.0),
                ..self.cfg.svm.clone()
            }
        };
        t_train.time(|| svm.train(feats, &idx, &y, &balanced(&y)));

        for it in 0..self.cfg.al_iters {
            // ── selection ────────────────────────────────────────────
            let (pick, nonempty, scanned) = t_select.time(|| {
                self.select(strategy, &svm.w, feats, &labeled, rng)
            });
            if nonempty {
                res.nonempty_lookups += 1;
            }
            res.scanned_total += scanned;
            let Some(pick) = pick else {
                // pool exhausted
                break;
            };
            debug_assert!(!labeled[pick]);
            let w_norm = crate::linalg::nrm2(&svm.w);
            res.min_margins
                .push(crate::linalg::margin_feat(feats.row(pick), &svm.w, w_norm));
            // ── label + retrain (warm start) ────────────────────────
            labeled[pick] = true;
            idx.push(pick);
            y.push(if relevant[pick] { 1.0 } else { -1.0 });
            svm.grow_to(idx.len());
            t_train.time(|| svm.train(feats, &idx, &y, &balanced(&y)));
            // ── evaluation ──────────────────────────────────────────
            if (it + 1) % self.cfg.eval_every == 0 || it + 1 == self.cfg.al_iters {
                let mut scores = Vec::with_capacity(n);
                let mut rel = Vec::with_capacity(n);
                for i in 0..n {
                    if labeled[i] {
                        continue;
                    }
                    scores.push(svm.score(feats.row(i)));
                    rel.push(relevant[i]);
                }
                res.ap_curve.push((it + 1, average_precision(&scores, &rel)));
            }
        }
        res.select_secs = t_select.total_secs();
        res.train_secs = t_train.total_secs();
        res
    }

    /// One selection step. Returns (picked index, lookup nonempty, scanned).
    fn select(
        &self,
        strategy: &Strategy,
        w: &[f32],
        feats: &FeatureStore,
        labeled: &[bool],
        rng: &mut Rng,
    ) -> (Option<usize>, bool, usize) {
        match strategy {
            Strategy::Random => (random_unlabeled(labeled, rng), true, 0),
            Strategy::Exhaustive => {
                let w_norm = crate::linalg::nrm2(w);
                let mut best: Option<(usize, f32)> = None;
                for i in 0..feats.len() {
                    if labeled[i] {
                        continue;
                    }
                    let m = crate::linalg::margin_feat(feats.row(i), w, w_norm);
                    if best.map_or(true, |(_, bm)| m < bm) {
                        best = Some((i, m));
                    }
                }
                (best.map(|(i, _)| i), true, feats.len())
            }
            Strategy::Hash { family, index } => {
                let hit = index.query_filtered(family.as_ref(), w, feats, |i| !labeled[i]);
                match hit.best {
                    Some((i, _)) => (Some(i), hit.nonempty, hit.scanned),
                    // paper §5.2: empty lookups fall back to random selection
                    None => (random_unlabeled(labeled, rng), hit.nonempty, hit.scanned),
                }
            }
            Strategy::HashRank { family, index } => {
                let lookup = family.encode_query(w);
                let hit = index.rank_search(lookup, w, feats, |i| !labeled[i]);
                match hit.best {
                    Some((i, _)) => (Some(i), true, hit.scanned),
                    None => (random_unlabeled(labeled, rng), false, hit.scanned),
                }
            }
        }
    }

    /// Full experiment: all classes × `runs`, averaged. `make_strategy` is
    /// called once per run (randomized families redraw projections per run,
    /// matching the paper's 5 random initializations).
    pub fn run_experiment(
        &self,
        runs: usize,
        max_classes: Option<usize>,
        seed: u64,
        mut make_strategy: impl FnMut(&mut Rng) -> Strategy,
    ) -> AlResult {
        let classes = self.data.eval_classes().min(max_classes.unwrap_or(usize::MAX));
        let mut agg: Option<AlResult> = None;
        let mut total_curves = 0usize;
        for run in 0..runs {
            let mut rng = Rng::seed_from_u64(seed ^ (run as u64).wrapping_mul(0x9E37_79B9));
            let strategy = make_strategy(&mut rng);
            let init = self.initial_labeled(&mut rng);
            for c in 0..classes {
                let r = self.run_class(c as u16, &strategy, &init, &mut rng);
                let a = agg.get_or_insert_with(|| AlResult {
                    strategy: strategy.name(),
                    map_curve: r.ap_curve.iter().map(|&(i, _)| (i, 0.0)).collect(),
                    margin_curve: vec![0.0; r.min_margins.len()],
                    nonempty_per_class: vec![0.0; classes],
                    ..Default::default()
                });
                for (slot, &(_, ap)) in a.map_curve.iter_mut().zip(r.ap_curve.iter()) {
                    slot.1 += ap;
                }
                for (slot, &m) in a.margin_curve.iter_mut().zip(r.min_margins.iter()) {
                    *slot += m as f64;
                }
                a.nonempty_per_class[c] += r.nonempty_lookups as f64;
                a.select_secs += r.select_secs;
                a.train_secs += r.train_secs;
                a.scanned_total += r.scanned_total;
                total_curves += 1;
            }
        }
        let mut a = agg.unwrap_or_default();
        if total_curves > 0 {
            for slot in a.map_curve.iter_mut() {
                slot.1 /= total_curves as f64;
            }
            for slot in a.margin_curve.iter_mut() {
                *slot /= total_curves as f64;
            }
            for slot in a.nonempty_per_class.iter_mut() {
                *slot /= runs as f64;
            }
        }
        a
    }
}

fn random_unlabeled(labeled: &[bool], rng: &mut Rng) -> Option<usize> {
    let n = labeled.len();
    let remaining = labeled.iter().filter(|&&l| !l).count();
    if remaining == 0 {
        return None;
    }
    // rejection sampling is fast while the pool is mostly unlabeled
    for _ in 0..64 {
        let i = rng.below(n);
        if !labeled[i] {
            return Some(i);
        }
    }
    let target = rng.below(remaining);
    labeled
        .iter()
        .enumerate()
        .filter(|(_, &l)| !l)
        .nth(target)
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;

    fn small_cfg() -> AlConfig {
        AlConfig {
            al_iters: 20,
            init_per_class: 3,
            eval_every: 5,
            svm: SvmConfig::default(),
        }
    }

    #[test]
    fn random_unlabeled_excludes_labeled() {
        let mut rng = Rng::seed_from_u64(1);
        let mut labeled = vec![false; 10];
        for i in 0..9 {
            labeled[i] = true;
        }
        for _ in 0..20 {
            assert_eq!(random_unlabeled(&labeled, &mut rng), Some(9));
        }
        labeled[9] = true;
        assert_eq!(random_unlabeled(&labeled, &mut rng), None);
    }

    #[test]
    fn exhaustive_picks_global_min_margin() {
        let mut rng = Rng::seed_from_u64(2);
        let ds = test_blobs(120, 8, 2, &mut rng);
        let engine = AlEngine::new(&ds, small_cfg());
        let init = engine.initial_labeled(&mut rng);
        let res = engine.run_class(0, &Strategy::Exhaustive, &init, &mut rng);
        assert_eq!(res.min_margins.len(), 20);
        assert_eq!(res.nonempty_lookups, 20);
        // AP evaluated at 5,10,15,20
        assert_eq!(res.ap_curve.len(), 4);
        for &(_, ap) in &res.ap_curve {
            assert!((0.0..=1.0).contains(&ap));
        }
    }

    #[test]
    fn exhaustive_margins_below_random_margins() {
        // The defining property of margin-based AL: the exhaustive picker
        // selects points much nearer the hyperplane than random picks.
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(400, 16, 2, &mut rng);
        let engine = AlEngine::new(&ds, small_cfg());
        let init = engine.initial_labeled(&mut rng);
        let r_ex = engine.run_class(0, &Strategy::Exhaustive, &init, &mut rng);
        let r_rand = engine.run_class(0, &Strategy::Random, &init, &mut rng);
        let mean = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!(
            mean(&r_ex.min_margins) < 0.5 * mean(&r_rand.min_margins),
            "exhaustive {} vs random {}",
            mean(&r_ex.min_margins),
            mean(&r_rand.min_margins)
        );
    }

    #[test]
    fn hash_strategy_runs_and_tracks_lookups() {
        let mut rng = Rng::seed_from_u64(4);
        let ds = test_blobs(300, 16, 2, &mut rng);
        let fam = Arc::new(BhHash::sample(16, 10, &mut rng));
        let index = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 3));
        let engine = AlEngine::new(&ds, small_cfg());
        let init = engine.initial_labeled(&mut rng);
        let strat = Strategy::Hash { family: fam, index };
        let res = engine.run_class(0, &strat, &init, &mut rng);
        assert_eq!(res.min_margins.len(), 20);
        assert!(res.nonempty_lookups <= 20);
    }

    #[test]
    fn never_selects_labeled_point() {
        let mut rng = Rng::seed_from_u64(5);
        let ds = test_blobs(60, 8, 2, &mut rng);
        let mut cfg = small_cfg();
        cfg.al_iters = 54; // 60 - 6 init: exhausts the pool exactly
        let engine = AlEngine::new(&ds, cfg);
        let init = engine.initial_labeled(&mut rng);
        assert_eq!(init.len(), 6);
        let res = engine.run_class(0, &Strategy::Random, &init, &mut rng);
        assert_eq!(res.min_margins.len(), 54, "every point labeled exactly once");
    }

    #[test]
    fn experiment_aggregates_over_runs_and_classes() {
        let mut rng = Rng::seed_from_u64(6);
        let ds = test_blobs(150, 8, 3, &mut rng);
        let engine = AlEngine::new(&ds, small_cfg());
        let res = engine.run_experiment(2, None, 77, |_| Strategy::Random);
        assert_eq!(res.strategy, "Random");
        assert_eq!(res.nonempty_per_class.len(), 3);
        assert_eq!(res.margin_curve.len(), 20);
        assert!(!res.map_curve.is_empty());
        for &(_, ap) in &res.map_curve {
            assert!((0.0..=1.0).contains(&ap));
        }
    }

    #[test]
    fn learning_improves_ap_over_iterations() {
        // with informative selection on separable blobs, late AP ≥ early AP
        let mut rng = Rng::seed_from_u64(7);
        let ds = test_blobs(300, 16, 2, &mut rng);
        let mut cfg = small_cfg();
        cfg.al_iters = 40;
        cfg.eval_every = 10;
        let engine = AlEngine::new(&ds, cfg);
        let res = engine.run_experiment(3, Some(1), 99, |_| Strategy::Exhaustive);
        let first = res.map_curve.first().unwrap().1;
        let last = res.map_curve.last().unwrap().1;
        assert!(last >= first - 0.05, "AP {first} → {last} should not collapse");
    }
}
