//! Minimal property-based testing framework (proptest is not in the
//! vendored registry).
//!
//! A property is a closure over a seeded [`crate::rng::Rng`]; `forall` runs
//! it for N cases with derived seeds and reports the failing seed so any
//! counter-example can be replayed deterministically:
//!
//! ```
//! use chh::testing::forall;
//! forall("reverse twice is identity", 64, |rng| {
//!     let n = rng.below(100);
//!     let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     if w == v { Ok(()) } else { Err("mismatch".to_string()) }
//! });
//! ```

use crate::rng::Rng;

/// Run `prop` for `cases` seeds; panics with the offending seed on failure.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed fixed for reproducibility; override with CHH_PROP_SEED to
    // replay a reported failure directly.
    let base = std::env::var("CHH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    if let Some(seed) = base {
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at replay seed {seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED_0000_0000_0000u64 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay with CHH_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close_slice(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0 + x.abs().max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Generate a random unit vector of dimension d.
pub fn unit_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v = rng.gauss_vec(d);
    crate::linalg::normalize(&mut v);
    v
}

/// Generate a pair (w, x) of unit vectors with an exact angle θ between
/// them (used to validate collision probabilities at controlled angles).
pub fn pair_with_angle(rng: &mut Rng, d: usize, theta: f32) -> (Vec<f32>, Vec<f32>) {
    assert!(d >= 2);
    let w = unit_vec(rng, d);
    // Gram-Schmidt a random direction against w.
    let mut e = rng.gauss_vec(d);
    let proj = crate::linalg::dot(&e, &w);
    for i in 0..d {
        e[i] -= proj * w[i];
    }
    crate::linalg::normalize(&mut e);
    let x: Vec<f32> = (0..d).map(|i| theta.cos() * w[i] + theta.sin() * e[i]).collect();
    (w, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cosine, nrm2};

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 xor self is zero", 32, |rng| {
            let x = rng.next_u64();
            prop_assert!(x ^ x == 0, "xor");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn unit_vec_is_unit() {
        forall("unit vec norm 1", 32, |rng| {
            let d = rng.range(2, 64);
            let v = unit_vec(rng, d);
            prop_assert!((nrm2(&v) - 1.0).abs() < 1e-4, "norm {}", nrm2(&v));
            Ok(())
        });
    }

    #[test]
    fn pair_with_angle_has_requested_angle() {
        forall("controlled angle", 64, |rng| {
            let d = rng.range(2, 128);
            let theta = (rng.f32() * std::f32::consts::PI).max(1e-3);
            let (w, x) = pair_with_angle(rng, d, theta);
            let got = cosine(&w, &x).acos();
            prop_assert!((got - theta).abs() < 1e-2, "want {theta} got {got}");
            Ok(())
        });
    }

    #[test]
    fn assert_close_slice_detects_mismatch() {
        assert!(assert_close_slice(&[1.0], &[1.0 + 1e-7], 1e-5).is_ok());
        assert!(assert_close_slice(&[1.0], &[1.2], 1e-5).is_err());
        assert!(assert_close_slice(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }
}
