//! JSON wire protocol of the serving front-end.
//!
//! Request bodies are parsed with [`crate::jsonio`] (total parser — no
//! panic on malformed/truncated network payloads) into the coordinator's
//! native types; responses are rendered back to JSON. Keeping both
//! directions here means the CLI load generator, the integration tests
//! and the server agree on one serialization — the parity tests compare
//! responses bit-for-bit against direct router calls, which works
//! because `f32 → f64 → shortest-decimal → f64 → f32` round-trips
//! exactly.
//!
//! Bodies:
//!
//! * `POST /query`      `{"w": [f32...], "exclude": [id...]?}`
//! * `POST /query_topk` `{"w": [f32...], "t": usize, "exclude": [id...]?}`
//! * `POST /insert`     `{"id": u32}`  (re-encode row `id` of the serving
//!   feature store — the store is append-only in a deployment; the index
//!   controls visibility)
//! * `POST /remove`     `{"id": u32}`

use std::collections::HashSet;
use std::sync::Arc;

use crate::coordinator::QueryRequest;
use crate::jsonio::{obj, Json};
use crate::table::QueryHit;

/// A protocol-level rejection: maps to an HTTP status + JSON error body.
#[derive(Debug)]
pub struct ProtoError {
    pub status: u16,
    pub msg: String,
}

impl ProtoError {
    pub fn bad(msg: impl Into<String>) -> Self {
        ProtoError { status: 400, msg: msg.into() }
    }
}

fn parse_body(body: &[u8]) -> Result<Json, ProtoError> {
    Json::parse_bytes(body).map_err(|e| ProtoError::bad(format!("bad json: {e}")))
}

fn parse_w(v: &Json, dim: usize) -> Result<Vec<f32>, ProtoError> {
    let arr = v
        .get("w")
        .and_then(|w| w.as_arr())
        .ok_or_else(|| ProtoError::bad("missing \"w\" array"))?;
    if arr.len() != dim {
        return Err(ProtoError::bad(format!(
            "\"w\" has {} dims, index expects {dim}",
            arr.len()
        )));
    }
    arr.iter()
        .map(|x| {
            // reject what f32 can't represent finitely: a 1e39 entry
            // would cast to inf, poison the margins with NaN, and make
            // the response unserializable
            match x.as_f64() {
                Some(f) if (f as f32).is_finite() => Ok(f as f32),
                Some(_) => Err(ProtoError::bad("\"w\" entries must be finite f32s")),
                None => Err(ProtoError::bad("\"w\" entries must be numbers")),
            }
        })
        .collect()
}

fn parse_exclude(v: &Json) -> Result<Option<Arc<HashSet<usize>>>, ProtoError> {
    let Some(ex) = v.get("exclude") else {
        return Ok(None);
    };
    let arr = ex
        .as_arr()
        .ok_or_else(|| ProtoError::bad("\"exclude\" must be an array of ids"))?;
    let mut set = HashSet::with_capacity(arr.len());
    for x in arr {
        set.insert(
            x.as_usize()
                .ok_or_else(|| ProtoError::bad("\"exclude\" entries must be non-negative ints"))?,
        );
    }
    Ok(Some(Arc::new(set)))
}

/// Parse a `/query` body into a router request.
pub fn parse_query(body: &[u8], dim: usize) -> Result<QueryRequest, ProtoError> {
    let v = parse_body(body)?;
    Ok(QueryRequest { w: parse_w(&v, dim)?, exclude: parse_exclude(&v)? })
}

/// Parse a `/query_topk` body: the request plus the list length `t`.
pub fn parse_topk(body: &[u8], dim: usize) -> Result<(QueryRequest, usize), ProtoError> {
    let v = parse_body(body)?;
    let t = v
        .get("t")
        .and_then(|t| t.as_usize())
        .ok_or_else(|| ProtoError::bad("missing \"t\" (short-list length)"))?;
    if t == 0 {
        return Err(ProtoError::bad("\"t\" must be >= 1"));
    }
    Ok((QueryRequest { w: parse_w(&v, dim)?, exclude: parse_exclude(&v)? }, t))
}

/// Parse an `/insert` or `/remove` body: the point id.
pub fn parse_id(body: &[u8]) -> Result<u32, ProtoError> {
    let v = parse_body(body)?;
    let id = v
        .get("id")
        .and_then(|x| x.as_usize())
        .ok_or_else(|| ProtoError::bad("missing \"id\""))?;
    u32::try_from(id).map_err(|_| ProtoError::bad(format!("id {id} exceeds u32")))
}

/// Serialize a `/query` body (the client half — loadgen and tests).
pub fn query_body(w: &[f32]) -> String {
    obj(vec![("w", Json::Arr(w.iter().map(|&x| Json::Num(x as f64)).collect()))])
        .to_string_compact()
}

/// Serialize a `/query_topk` body.
pub fn topk_body(w: &[f32], t: usize) -> String {
    obj(vec![
        ("w", Json::Arr(w.iter().map(|&x| Json::Num(x as f64)).collect())),
        ("t", Json::from(t)),
    ])
    .to_string_compact()
}

/// Serialize an `/insert` / `/remove` body.
pub fn id_body(id: u32) -> String {
    obj(vec![("id", Json::from(id as usize))]).to_string_compact()
}

/// Render a [`QueryHit`] response.
pub fn hit_json(hit: &QueryHit) -> Json {
    let best = match hit.best {
        Some((id, m)) => obj(vec![("id", Json::from(id)), ("margin", Json::Num(m as f64))]),
        None => Json::Null,
    };
    obj(vec![
        ("best", best),
        ("scanned", Json::from(hit.scanned)),
        ("probed", Json::from(hit.probed)),
        ("nonempty", Json::from(hit.nonempty)),
    ])
}

/// Parse a `/query` response back into a [`QueryHit`] (client half).
pub fn parse_hit(body: &[u8]) -> Result<QueryHit, ProtoError> {
    let v = parse_body(body)?;
    let best = match v.get("best") {
        None | Some(Json::Null) => None,
        Some(b) => {
            let id = b
                .get("id")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| ProtoError::bad("best.id missing"))?;
            let m = b
                .get("margin")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| ProtoError::bad("best.margin missing"))?;
            Some((id, m as f32))
        }
    };
    let field = |k: &str| v.get(k).and_then(|x| x.as_usize());
    Ok(QueryHit {
        best,
        scanned: field("scanned").ok_or_else(|| ProtoError::bad("scanned missing"))?,
        probed: field("probed").ok_or_else(|| ProtoError::bad("probed missing"))?,
        nonempty: v
            .get("nonempty")
            .and_then(|x| x.as_bool())
            .ok_or_else(|| ProtoError::bad("nonempty missing"))?,
    })
}

/// Render a `/query_topk` response.
pub fn topk_json(hits: &[(usize, f32)]) -> Json {
    obj(vec![(
        "hits",
        Json::Arr(
            hits.iter()
                .map(|&(id, m)| obj(vec![("id", Json::from(id)), ("margin", Json::Num(m as f64))]))
                .collect(),
        ),
    )])
}

/// Parse a `/query_topk` response (client half).
pub fn parse_topk_hits(body: &[u8]) -> Result<Vec<(usize, f32)>, ProtoError> {
    let v = parse_body(body)?;
    let arr = v
        .get("hits")
        .and_then(|h| h.as_arr())
        .ok_or_else(|| ProtoError::bad("hits missing"))?;
    arr.iter()
        .map(|h| {
            let id = h
                .get("id")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| ProtoError::bad("hit id missing"))?;
            let m = h
                .get("margin")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| ProtoError::bad("hit margin missing"))?;
            Ok((id, m as f32))
        })
        .collect()
}

/// Render an error body.
pub fn error_json(msg: &str) -> String {
    obj(vec![("error", Json::from(msg))]).to_string_compact()
}

/// Render an error body carrying the request's correlation id, so a
/// client that lost the `x-chh-request-id` response header (proxies,
/// minimal clients) can still quote the id when reporting the failure.
pub fn error_json_id(msg: &str, request_id: &str) -> String {
    obj(vec![("error", Json::from(msg)), ("request_id", Json::from(request_id))])
        .to_string_compact()
}

/// Render the `421 Misdirected Request` body a read replica answers
/// mutations with: the error plus the primary's address, so a client can
/// follow the redirect without a second discovery round trip.
pub fn redirect_json(msg: &str, primary: &str) -> String {
    obj(vec![("error", Json::from(msg)), ("primary", Json::from(primary))])
        .to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_body_roundtrips_bit_exact() {
        // adversarial f32s: subnormals, max, negative zero, odd fractions
        let w = vec![1.0f32, -0.0, f32::MIN_POSITIVE, 3.4e38, -2.718_281_8, 1.0e-8];
        let body = query_body(&w);
        let req = parse_query(body.as_bytes(), w.len()).unwrap();
        for (a, b) in w.iter().zip(req.w.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 roundtrip must be exact");
        }
        assert!(req.exclude.is_none());
    }

    #[test]
    fn dim_mismatch_rejected() {
        let body = query_body(&[1.0, 2.0]);
        let err = parse_query(body.as_bytes(), 3).unwrap_err();
        assert_eq!(err.status, 400);
        assert!(err.msg.contains("dims"));
    }

    #[test]
    fn exclude_parsed() {
        let body = r#"{"w":[1,2],"exclude":[3,5,5]}"#;
        let req = parse_query(body.as_bytes(), 2).unwrap();
        let ex = req.exclude.unwrap();
        assert!(ex.contains(&3) && ex.contains(&5));
        assert_eq!(ex.len(), 2);
    }

    #[test]
    fn malformed_bodies_rejected() {
        for bad in [
            &b"not json"[..],
            br#"{"w": "nope"}"#,
            br#"{"w": [1, "x"]}"#,
            br#"{}"#,
            br#"{"w":[1,2],"exclude":[-1]}"#,
            br#"{"w":[1e39, 0]}"#,
            b"\xff\xfe",
        ] {
            assert!(parse_query(bad, 2).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn topk_body_roundtrip() {
        let body = topk_body(&[0.5, -0.5], 7);
        let (req, t) = parse_topk(body.as_bytes(), 2).unwrap();
        assert_eq!(t, 7);
        assert_eq!(req.w, vec![0.5, -0.5]);
        assert!(parse_topk(br#"{"w":[1,2],"t":0}"#, 2).is_err());
        assert!(parse_topk(br#"{"w":[1,2]}"#, 2).is_err());
    }

    #[test]
    fn id_body_roundtrip() {
        assert_eq!(parse_id(id_body(42).as_bytes()).unwrap(), 42);
        assert!(parse_id(br#"{"id": -3}"#).is_err());
        assert!(parse_id(br#"{"id": 1.5}"#).is_err());
        assert!(parse_id(br#"{"id": 4294967296}"#).is_err());
        assert!(parse_id(br#"{}"#).is_err());
    }

    #[test]
    fn hit_roundtrips_bit_exact() {
        let hit = QueryHit {
            best: Some((123, 0.123_456_79_f32)),
            scanned: 9,
            probed: 4,
            nonempty: true,
        };
        let back = parse_hit(hit_json(&hit).to_string_compact().as_bytes()).unwrap();
        assert_eq!(back.best.unwrap().0, 123);
        assert_eq!(
            back.best.unwrap().1.to_bits(),
            hit.best.unwrap().1.to_bits(),
            "margin must round-trip exactly"
        );
        assert_eq!(back.scanned, 9);
        assert_eq!(back.probed, 4);
        assert!(back.nonempty);
        // empty hit
        let empty = QueryHit::default();
        let back = parse_hit(hit_json(&empty).to_string_compact().as_bytes()).unwrap();
        assert!(back.best.is_none());
        assert!(!back.nonempty);
    }

    #[test]
    fn topk_hits_roundtrip() {
        let hits = vec![(1usize, 0.25f32), (7, 0.5), (2, f32::MIN_POSITIVE)];
        let back =
            parse_topk_hits(topk_json(&hits).to_string_compact().as_bytes()).unwrap();
        assert_eq!(back.len(), 3);
        for ((ia, ma), (ib, mb)) in hits.iter().zip(back.iter()) {
            assert_eq!(ia, ib);
            assert_eq!(ma.to_bits(), mb.to_bits());
        }
    }

    #[test]
    fn error_json_is_valid() {
        let e = error_json("boom \"quoted\"");
        let v = Json::parse(&e).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("boom \"quoted\""));
    }

    #[test]
    fn error_json_id_carries_the_request_id() {
        let e = error_json_id("boom", "deadbeef01234567");
        let v = Json::parse(&e).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("boom"));
        assert_eq!(v.get("request_id").unwrap().as_str(), Some("deadbeef01234567"));
    }

    #[test]
    fn redirect_json_carries_the_primary() {
        let e = redirect_json("read-only replica", "10.0.0.7:8080");
        let v = Json::parse(&e).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("read-only replica"));
        assert_eq!(v.get("primary").unwrap().as_str(), Some("10.0.0.7:8080"));
    }

    /// A finite f32 drawn from raw bit patterns: exercises subnormals,
    /// extreme exponents and odd mantissas — not just "nice" values.
    fn adversarial_f32(rng: &mut crate::rng::Rng) -> f32 {
        loop {
            let v = f32::from_bits(rng.next_u64() as u32);
            if v.is_finite() {
                return v;
            }
        }
    }

    #[test]
    fn query_and_topk_bodies_roundtrip_bit_exact_forall() {
        crate::testing::forall("wire w roundtrip", 64, |rng| {
            let dim = rng.range(1, 33);
            let mut w: Vec<f32> = (0..dim).map(|_| adversarial_f32(rng)).collect();
            // plant the canonical adversaries deterministically
            w[0] = -0.0;
            if dim > 1 {
                w[1] = f32::from_bits(1); // smallest subnormal
            }
            if dim > 2 {
                w[2] = f32::MAX;
            }
            if dim > 3 {
                w[3] = -f32::MAX;
            }
            let req = parse_query(query_body(&w).as_bytes(), dim)
                .map_err(|e| format!("parse_query: {}", e.msg))?;
            for (i, (a, b)) in w.iter().zip(req.w.iter()).enumerate() {
                crate::prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "query w[{i}]: {a:?} != {b:?}"
                );
            }
            let t = rng.range(1, 100);
            let (req2, t2) = parse_topk(topk_body(&w, t).as_bytes(), dim)
                .map_err(|e| format!("parse_topk: {}", e.msg))?;
            crate::prop_assert!(t2 == t, "t roundtrip");
            for (a, b) in w.iter().zip(req2.w.iter()) {
                crate::prop_assert!(a.to_bits() == b.to_bits(), "topk w bits");
            }
            Ok(())
        });
    }

    #[test]
    fn hit_and_topk_responses_roundtrip_bit_exact_forall() {
        crate::testing::forall("wire hit roundtrip", 64, |rng| {
            let hit = QueryHit {
                best: if rng.below(8) == 0 {
                    None
                } else {
                    Some((rng.below(1 << 20), adversarial_f32(rng)))
                },
                scanned: rng.below(10_000),
                probed: rng.below(10_000),
                nonempty: rng.below(2) == 1,
            };
            let back = parse_hit(hit_json(&hit).to_string_compact().as_bytes())
                .map_err(|e| format!("parse_hit: {}", e.msg))?;
            match (hit.best, back.best) {
                (Some((ia, ma)), Some((ib, mb))) => {
                    crate::prop_assert!(ia == ib, "best id");
                    crate::prop_assert!(
                        ma.to_bits() == mb.to_bits(),
                        "margin bits {ma:?} vs {mb:?}"
                    );
                }
                (None, None) => {}
                (a, b) => return Err(format!("best mismatch {a:?} vs {b:?}")),
            }
            crate::prop_assert!(back.scanned == hit.scanned, "scanned");
            crate::prop_assert!(back.probed == hit.probed, "probed");
            crate::prop_assert!(back.nonempty == hit.nonempty, "nonempty");
            let hits: Vec<(usize, f32)> = (0..rng.below(20))
                .map(|_| (rng.below(1 << 20), adversarial_f32(rng)))
                .collect();
            let back =
                parse_topk_hits(topk_json(&hits).to_string_compact().as_bytes())
                    .map_err(|e| format!("parse_topk_hits: {}", e.msg))?;
            crate::prop_assert!(back.len() == hits.len(), "topk len");
            for ((ia, ma), (ib, mb)) in hits.iter().zip(back.iter()) {
                crate::prop_assert!(ia == ib && ma.to_bits() == mb.to_bits(), "topk entry");
            }
            Ok(())
        });
    }
}
