//! The network serving subsystem: a std-only HTTP/1.1 front-end that
//! exposes the routers over the wire, with dynamic micro-batching into
//! the data-parallel engine.
//!
//! ```text
//!                    ┌────────────────────── Server ──────────────────────┐
//!  clients ── TCP ──▶│ poll(2) event loop ──▶ worker pool (conn_workers)  │
//!                    │   /query ───▶ Batcher ──▶ query_batch_pooled ──┐   │
//!                    │   /query_topk /insert /remove /healthz /stats  │   │
//!                    │◀─ JSON / binary responses ◀─── per-query hits ◀┘   │
//!                    └────────────────────────────────────────────────────┘
//! ```
//!
//! * **Transport** ([`event_loop`], unix) — a readiness-polled event
//!   loop (nonblocking sockets + a thin `poll(2)` FFI shim) multiplexes
//!   every connection onto one loop thread; complete requests run on a
//!   bounded worker pool. 10k idle keep-alive clients cost 10k slab
//!   slots and O(`conn_workers`) threads, not 10k threads. Non-unix
//!   targets fall back to the original thread-per-connection acceptor.
//!   Both transports funnel through [`process_request`], so shedding,
//!   graceful shutdown, request-id propagation and the drain-before-
//!   close 4xx/503 paths behave identically.
//! * **Framing** ([`http`]) — hand-rolled HTTP/1.1 with keep-alive and
//!   `Content-Length` bodies; total parsing, hard size limits. The
//!   resumable [`http::FrameParser`] serves both blocking clients and
//!   the nonblocking loop.
//! * **Protocol** ([`protocol`], [`binproto`]) — JSON bodies via
//!   [`crate::jsonio`]; float payloads round-trip bit-exactly, so wire
//!   responses are bit-identical to direct router calls. A request with
//!   `Content-Type: application/x-chh-binary` negotiates the compact
//!   binary codec ([`binproto`]) on the data routes instead — raw
//!   little-endian f32 bit patterns, bit-exact by construction. Errors
//!   are always JSON.
//! * **Micro-batching** ([`batcher`]) — concurrent `/query` requests
//!   coalesce (flush on `max_batch` or `max_wait`) into one
//!   `query_batch_pooled` call; a bounded admission queue rejects
//!   overload with HTTP 503 instead of queueing unboundedly.
//! * **Serving stacks** — [`Stack::Static`] (prebuilt
//!   [`crate::table::HyperplaneIndex`] behind a
//!   [`crate::coordinator::Router`]), [`Stack::Online`] (dynamic
//!   [`crate::online::ShardedIndex`] behind an
//!   [`crate::coordinator::OnlineRouter`], with `/insert` + `/remove`),
//!   or [`Stack::Cluster`] (`chh route` — no local index; the data
//!   routes scatter-gather across partition servers via
//!   [`crate::cluster::ClusterRouter`], with a `/map` endpoint for
//!   atomic partition-map flips and a mandatory `"partial"` flag on
//!   every read answer).
//!
//! * **Durability** (optional) — [`Server::spawn_with_durability`]
//!   routes `/insert`/`/remove` through a [`crate::wal::DurableIndex`]
//!   (journal → apply → ack once durable), runs a background
//!   snapshotter, reports WAL/snapshot counters on `/stats`, and writes
//!   a final checkpoint on graceful shutdown so a clean stop never
//!   needs replay. See `docs/DURABILITY.md`.
//! * **Replication** — a durable server is automatically a replication
//!   *primary*: `GET /wal/stream` serves fsynced WAL frames and
//!   `GET /wal/bootstrap` serves snapshot windows ([`crate::replicate`]).
//!   [`Server::spawn_replica`] runs the read-only *replica* role: reads
//!   as usual, mutations answered `421` with the primary's address, a
//!   `replication` lag section in `/stats`, and the background tailer
//!   joined on shutdown. See `docs/REPLICATION.md`.
//!
//! `chh serve-http` wires a stack to this server; `chh loadgen` drives
//! it. See `docs/SERVING.md` for the protocol and operational notes.

pub mod batcher;
pub mod binproto;
#[cfg(unix)]
mod event_loop;
pub mod http;
pub mod protocol;

pub use batcher::{BatchedReply, Batcher, BatcherConfig, BatcherStats, FlushOutcome, SubmitError};
pub use http::{HttpClient, HttpError};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::{ClusterRouter, PartitionMap};
use crate::coordinator::{OnlineRouter, QueryRequest, Router};
use crate::data::FeatureStore;
use crate::hash::HashFamily;
use crate::jsonio::{obj, Json};
use crate::metrics::Histogram;
use crate::obs::{self, Hist, Registry, SlowLog, Trace};
use crate::replicate::{ReplicaIndex, Tailer};
use crate::table::QueryHit;
use crate::wal::DurableIndex;

/// Durability wiring for an online stack: mutations journal through
/// `durable` (which must wrap the same [`crate::online::ShardedIndex`]
/// the router serves), and a background snapshotter checkpoints every
/// `snapshot_every_ops` journaled mutations (0 = only on shutdown).
pub struct Durability {
    pub durable: Arc<DurableIndex>,
    pub snapshot_every_ops: u64,
}

/// Replica wiring for an online stack: `replica` must wrap the same
/// [`crate::online::ShardedIndex`] the router serves; `tailer` (if
/// given) is stopped and joined on graceful shutdown.
pub struct ReplicaRole {
    pub replica: Arc<ReplicaIndex>,
    pub primary_addr: String,
    pub tailer: Option<Tailer>,
}

/// Which index the server fronts. `Static`/`Online` answer `/query`
/// through the micro-batcher; `Online` additionally accepts `/insert` +
/// `/remove`; `Cluster` holds no index at all — it scatter-gathers the
/// data routes across partition servers ([`crate::cluster`]) and owns
/// the `/map` endpoint.
#[derive(Clone)]
pub enum Stack {
    Static(Arc<Router>),
    Online(Arc<OnlineRouter>),
    Cluster(Arc<ClusterRouter>),
}

impl Stack {
    pub fn mode(&self) -> &'static str {
        match self {
            Stack::Static(_) => "static",
            Stack::Online(_) => "online",
            Stack::Cluster(_) => "cluster",
        }
    }

    fn family(&self) -> &Arc<dyn HashFamily> {
        match self {
            Stack::Static(r) => r.family(),
            Stack::Online(r) => r.family(),
            Stack::Cluster(_) => unreachable!("a router stack holds no local hash family"),
        }
    }

    fn feats(&self) -> &Arc<FeatureStore> {
        match self {
            Stack::Static(r) => r.feats(),
            Stack::Online(r) => r.feats(),
            Stack::Cluster(_) => unreachable!("a router stack holds no local feature store"),
        }
    }

    /// The traced batch path the flush closure uses: answers are
    /// bit-identical to [`crate::coordinator::Router::query_batch_pooled`]
    /// (the untraced entry points delegate here), plus the batch's
    /// per-stage wall-clock breakdown.
    fn query_batch_traced(
        &self,
        reqs: &[QueryRequest],
        pool: &crate::par::Pool,
    ) -> (Vec<QueryHit>, obs::StageTimes) {
        match self {
            Stack::Static(r) => r.query_batch_pooled_traced(reqs, pool),
            Stack::Online(r) => r.query_batch_pooled_traced(reqs, pool),
            Stack::Cluster(_) => unreachable!("cluster stacks do not batch locally"),
        }
    }
}

/// Server configuration (see `docs/SERVING.md` for the knobs).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// listen address; port 0 binds an ephemeral port (tests)
    pub addr: String,
    /// concurrent-connection cap; connections beyond it are shed with an
    /// immediate 503. Idle keep-alive connections are cheap under the
    /// event loop (a slab slot, no thread), so the default is high.
    pub max_conns: usize,
    /// worker threads of the event-loop transport — the number of
    /// requests executing concurrently (connections themselves are
    /// multiplexed on one loop thread). Ignored by the non-unix
    /// thread-per-connection fallback.
    pub conn_workers: usize,
    /// micro-batcher policy
    pub batch: BatcherConfig,
    /// worker threads of the flush pool (0 = all cores,
    /// [`crate::par::effective`])
    pub pool_workers: usize,
    /// reap keep-alive connections idle this long
    pub idle_timeout: Duration,
    /// slow-query threshold in milliseconds. 0 = off — unless
    /// `slow_log` is set, in which case every request is logged (full
    /// request tracing)
    pub slow_ms: u64,
    /// where slow-query JSON lines go (size-rotated); stderr when unset
    pub slow_log: Option<PathBuf>,
    /// fraction of served `/query` requests re-answered by the sampling
    /// auditor ([`crate::obs::audit`]); 0 disables auditing entirely.
    /// Local stacks only — the router tier audits nothing (partitions
    /// audit their own shard of the data)
    pub audit_frac: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 4096,
            conn_workers: 16,
            batch: BatcherConfig::default(),
            pool_workers: 0,
            idle_timeout: Duration::from_secs(5),
            slow_ms: 0,
            slow_log: None,
            audit_frac: 0.0,
        }
    }
}

struct ServerStats {
    started: Instant,
    http_requests: AtomicU64,
    bad_requests: AtomicU64,
    /// buckets probed across all answered queries
    probes_total: AtomicU64,
    /// submit→reply wall time of /query requests
    latency: Mutex<Histogram>,
}

/// Active slow-log cap before rotation to `<path>.1`.
const SLOW_LOG_MAX_BYTES: u64 = 4 << 20;

/// Pipeline stage names in pipeline order — the `stage` label values of
/// `chh_stage_seconds` and the keys of a slow-log line's `stages_us`.
pub const STAGES: &[&str] = &["batch_wait", "encode", "probe", "scan", "merge", "serialize"];

/// Package version baked into `/healthz` and `chh_build_info`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Git hash injected at compile time via the `CHH_GIT_HASH` env var (CI
/// sets it); local builds report `unknown`.
pub fn git_hash() -> &'static str {
    option_env!("CHH_GIT_HASH").unwrap_or("unknown")
}

/// Server-owned observability: the `/metrics` registry, the shared
/// stage histograms the flush closure records into, per-route request
/// accounting, and the slow-query sink. Global-free — every server (and
/// test) owns its own.
struct Telemetry {
    registry: Registry,
    /// batch-level stage latencies (encode/probe/scan/merge are recorded
    /// once per flush; batch_wait/serialize once per request)
    stage_batch_wait: Arc<Hist>,
    stage_encode: Arc<Hist>,
    stage_probe: Arc<Hist>,
    stage_scan: Arc<Hist>,
    stage_merge: Arc<Hist>,
    stage_serialize: Arc<Hist>,
    /// per-route request counter + latency hist; the final entry is the
    /// catch-all `route="other"` series (404s, junk paths)
    routes: Vec<(&'static str, Arc<obs::Counter>, Arc<Hist>)>,
    /// data-route requests by negotiated wire protocol
    proto_json: Arc<obs::Counter>,
    proto_binary: Arc<obs::Counter>,
    slow_threshold: Option<Duration>,
    slow_log: Option<SlowLog>,
}

impl Telemetry {
    fn new(slow_ms: u64, slow_log: Option<PathBuf>) -> Self {
        let registry = Registry::new();
        let stage = |name: &'static str| {
            registry.hist(
                "chh_stage_seconds",
                "query pipeline stage latency (encode/probe/scan/merge are per batch flush, \
                 batch_wait/serialize per request)",
                vec![("stage", name.to_string())],
                obs::LATENCY_BOUNDS_NS,
                1e9,
            )
        };
        let stage_batch_wait = stage("batch_wait");
        let stage_encode = stage("encode");
        let stage_probe = stage("probe");
        let stage_scan = stage("scan");
        let stage_merge = stage("merge");
        let stage_serialize = stage("serialize");
        let mut routes = Vec::new();
        for r in ROUTES.iter().copied().chain(std::iter::once("other")) {
            let c = registry.counter(
                "chh_http_requests_total",
                "HTTP requests answered, by route",
                vec![("route", r.to_string())],
            );
            let h = registry.hist(
                "chh_request_seconds",
                "request wall time from parse to response, by route",
                vec![("route", r.to_string())],
                obs::LATENCY_BOUNDS_NS,
                1e9,
            );
            routes.push((r, c, h));
        }
        let proto = |p: &'static str| {
            registry.counter(
                "chh_requests_by_protocol",
                "data-route requests answered, by negotiated wire protocol",
                vec![("proto", p.to_string())],
            )
        };
        let proto_json = proto("json");
        let proto_binary = proto("binary");
        Telemetry {
            registry,
            stage_batch_wait,
            stage_encode,
            stage_probe,
            stage_scan,
            stage_merge,
            stage_serialize,
            routes,
            proto_json,
            proto_binary,
            // threshold 0 with a sink configured means "log every
            // request" (full request tracing, e.g. the CI cluster
            // smoke); 0 with no sink keeps slow logging off so the
            // default config never floods stderr
            slow_threshold: if slow_ms > 0 {
                Some(Duration::from_millis(slow_ms))
            } else if slow_log.is_some() {
                Some(Duration::ZERO)
            } else {
                None
            },
            slow_log: slow_log.map(|p| SlowLog::create(p, SLOW_LOG_MAX_BYTES)),
        }
    }

    fn route_entry(&self, route: &str) -> &(&'static str, Arc<obs::Counter>, Arc<Hist>) {
        self.routes
            .iter()
            .find(|(r, _, _)| *r == route)
            .unwrap_or_else(|| self.routes.last().expect("catch-all route registered"))
    }

    /// Count one finished request (route counter + latency hist) and run
    /// the slow-query check.
    fn finish_request(&self, trace: &Trace, path: &str, status: u16, total: Duration) {
        let route = path.split('?').next().unwrap_or(path);
        let entry = self.route_entry(route);
        entry.1.inc();
        entry.2.observe_duration(total);
        if let Some(th) = self.slow_threshold {
            if total >= th {
                let line = trace.slow_line(entry.0, status, total);
                match &self.slow_log {
                    Some(log) => log.append(&line),
                    None => eprintln!("slow-query: {line}"),
                }
            }
        }
    }

    /// Count one data-route request against its negotiated wire protocol
    /// (`chh_requests_by_protocol{proto=...}`).
    fn count_proto(&self, binary: bool) {
        if binary {
            self.proto_binary.inc()
        } else {
            self.proto_json.inc()
        }
    }

    /// Record a batch flush's stage breakdown (called once per flush, on
    /// the collector thread — the histograms are lock-free).
    fn record_stages(&self, st: &obs::StageTimes) {
        self.stage_encode.observe_duration(st.encode);
        self.stage_probe.observe_duration(st.probe);
        self.stage_scan.observe_duration(st.scan);
        self.stage_merge.observe_duration(st.merge);
    }
}

/// Wire every non-Telemetry metric family into the registry. Callback
/// metrics read already-existing atomics at scrape time, so hot paths
/// stay untouched; each callback captures its own `Arc` (never
/// [`State`]), so the registry creates no reference cycle.
fn register_metrics(
    tel: &Telemetry,
    stack: &Stack,
    sstats: &Arc<ServerStats>,
    bstats: Option<&Arc<BatcherStats>>,
    conns: &Arc<ConnCounts>,
    durable: Option<&Arc<DurableIndex>>,
    replica: Option<&(Arc<ReplicaIndex>, String)>,
    role: &'static str,
) {
    let reg = &tel.registry;
    reg.gauge_fn(
        "chh_build_info",
        "build and serving metadata (value is always 1)",
        vec![
            ("version", VERSION.to_string()),
            ("git_hash", git_hash().to_string()),
            ("mode", stack.mode().to_string()),
            ("role", role.to_string()),
        ],
        || 1.0,
    );
    let s = sstats.clone();
    reg.gauge_fn("chh_uptime_seconds", "seconds since the server started", vec![], move || {
        s.started.elapsed().as_secs_f64()
    });
    let s = sstats.clone();
    reg.counter_fn(
        "chh_http_bad_requests_total",
        "malformed HTTP requests answered 4xx before routing",
        vec![],
        move || s.bad_requests.load(Ordering::Relaxed) as f64,
    );
    let s = sstats.clone();
    reg.counter_fn(
        "chh_probes_total",
        "hash buckets probed across answered /query requests",
        vec![],
        move || s.probes_total.load(Ordering::Relaxed) as f64,
    );
    if let Some(bstats) = bstats {
        let b = bstats.clone();
        reg.counter_fn(
            "chh_batcher_submitted_total",
            "queries admitted to the micro-batcher",
            vec![],
            move || b.submitted.load(Ordering::Relaxed) as f64,
        );
        let b = bstats.clone();
        reg.counter_fn(
            "chh_batcher_rejected_total",
            "queries refused at admission (answered 503)",
            vec![],
            move || b.rejected.load(Ordering::Relaxed) as f64,
        );
        let b = bstats.clone();
        reg.counter_fn("chh_batcher_batches_total", "batch flushes executed", vec![], move || {
            b.batches.load(Ordering::Relaxed) as f64
        });
        let b = bstats.clone();
        reg.counter_fn(
            "chh_batcher_flushed_total",
            "queries answered through batch flushes",
            vec![],
            move || b.flushed.load(Ordering::Relaxed) as f64,
        );
    }
    let c = conns.clone();
    reg.gauge_fn(
        "chh_open_connections",
        "currently open client connections (shed connections excluded)",
        vec![],
        move || c.open.load(Ordering::SeqCst) as f64,
    );
    let c = conns.clone();
    reg.counter_fn(
        "chh_connections_accepted_total",
        "client connections accepted since the server started",
        vec![],
        move || c.accepted.load(Ordering::Relaxed) as f64,
    );
    if let Stack::Cluster(c) = stack {
        register_cluster_metrics(reg, c);
    } else {
        let router_counter = |name: &'static str,
                              help: &'static str,
                              pick: fn(&crate::coordinator::RouterStats) -> u64| {
            let st = stack.clone();
            reg.counter_fn(name, help, vec![], move || {
                let rs = match &st {
                    Stack::Static(r) => r.stats(),
                    Stack::Online(r) => r.stats(),
                    Stack::Cluster(_) => unreachable!("gated above"),
                };
                pick(rs) as f64
            });
        };
        router_counter("chh_router_submitted_total", "queries submitted to the router", |s| {
            s.submitted.load(Ordering::Relaxed)
        });
        router_counter("chh_router_completed_total", "queries completed by the router", |s| {
            s.completed.load(Ordering::Relaxed)
        });
        router_counter(
            "chh_router_empty_lookups_total",
            "queries whose probe sequence matched no candidates",
            |s| s.empty_lookups.load(Ordering::Relaxed),
        );
        router_counter(
            "chh_router_candidates_scanned_total",
            "candidate points scanned across all queries",
            |s| s.candidates_scanned.load(Ordering::Relaxed),
        );
        let st = stack.clone();
        reg.gauge_fn("chh_index_points", "live points in the serving index", vec![], move || {
            match &st {
                Stack::Static(r) => r.index().len() as f64,
                Stack::Online(r) => r.index().len() as f64,
                Stack::Cluster(_) => unreachable!("gated above"),
            }
        });
    }
    if let Some(d) = durable {
        let ws = d.wal_stats().clone();
        reg.counter_fn("chh_wal_records_total", "records appended to the WAL", vec![], move || {
            ws.records.load(Ordering::Relaxed) as f64
        });
        let ws = d.wal_stats().clone();
        reg.counter_fn("chh_wal_bytes_total", "frame bytes written to the WAL", vec![], move || {
            ws.bytes.load(Ordering::Relaxed) as f64
        });
        let ws = d.wal_stats().clone();
        reg.counter_fn("chh_wal_fsyncs_total", "fsync calls issued by the WAL writer", vec![], move || {
            ws.fsyncs.load(Ordering::Relaxed) as f64
        });
        let ws = d.wal_stats().clone();
        reg.counter_fn("chh_wal_rotations_total", "WAL segment rolls", vec![], move || {
            ws.rotations.load(Ordering::Relaxed) as f64
        });
        let dd = d.clone();
        reg.gauge_fn(
            "chh_wal_durable_segment",
            "segment seq of the fsynced frontier",
            vec![],
            move || dd.durable_watermark().0 as f64,
        );
        let dd = d.clone();
        reg.gauge_fn(
            "chh_wal_durable_offset",
            "byte offset of the fsynced frontier within its segment",
            vec![],
            move || dd.durable_watermark().1 as f64,
        );
        let dd = d.clone();
        reg.gauge_fn(
            "chh_wal_snapshot_generation",
            "generation of the last completed snapshot",
            vec![],
            move || dd.snapshot_gen() as f64,
        );
        let dd = d.clone();
        reg.gauge_fn(
            "chh_wal_ops_since_snapshot",
            "journaled mutations since the last snapshot",
            vec![],
            move || dd.ops_since_snapshot() as f64,
        );
        reg.register_hist(
            "chh_wal_fsync_seconds",
            "WAL fsync wall time",
            vec![],
            d.wal_stats().fsync_hist.clone(),
            1e9,
        );
        reg.register_hist(
            "chh_wal_commit_batch_size",
            "records coalesced per WAL group commit",
            vec![],
            d.wal_stats().commit_batch.clone(),
            1.0,
        );
    }
    if let Some((r, primary)) = replica {
        reg.gauge_fn(
            "chh_replica_primary",
            "the primary this replica tails (value is always 1)",
            vec![("addr", primary.clone())],
            || 1.0,
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_applied_segment",
            "WAL segment the replica has applied through",
            vec![],
            move || rr.position().0 as f64,
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_applied_offset",
            "byte offset the replica has applied through",
            vec![],
            move || rr.position().1 as f64,
        );
        let rr = r.clone();
        reg.counter_fn(
            "chh_replica_applied_records_total",
            "insert/remove records applied from the stream",
            vec![],
            move || rr.applied_records() as f64,
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_lag_segments",
            "whole segments behind the primary's durable watermark",
            vec![],
            move || rr.lag().0 as f64,
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_lag_bytes",
            "byte lag inside the primary's current segment (-1 = unknown / cross-segment)",
            vec![],
            move || rr.lag().1.map_or(-1.0, |b| b as f64),
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_applied_age_seconds",
            "seconds since the last applied stream chunk (-1 before the first)",
            vec![],
            move || rr.applied_age_secs().unwrap_or(-1.0),
        );
        let rr = r.clone();
        reg.counter_fn(
            "chh_replica_bootstraps_total",
            "snapshot transfers (1 initial + resyncs)",
            vec![],
            move || rr.bootstraps() as f64,
        );
        let rr = r.clone();
        reg.counter_fn(
            "chh_replica_reconnects_total",
            "primary reconnect attempts after transport errors",
            vec![],
            move || rr.reconnects() as f64,
        );
        let rr = r.clone();
        reg.counter_fn(
            "chh_replica_resyncs_total",
            "full resyncs after falling behind a segment GC",
            vec![],
            move || rr.resyncs() as f64,
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_caught_up",
            "1 when the replica has applied the observed durable watermark",
            vec![],
            move || if rr.caught_up() { 1.0 } else { 0.0 },
        );
        let rr = r.clone();
        reg.gauge_fn(
            "chh_replica_resyncing",
            "1 while a resync transfer is in flight",
            vec![],
            move || if rr.resyncing() { 1.0 } else { 0.0 },
        );
    }
}

/// The router tier's metric family (`chh route` processes only).
/// Per-partition health gauges are registered for the partitions of the
/// map installed at spawn; after a map flip that changes the partition
/// count, a retired slot reports -1 (see `ClusterRouter::health_at`) and
/// routers are restarted to re-register — they are stateless, so a
/// restart costs one `/stats` probe round.
fn register_cluster_metrics(reg: &Registry, c: &Arc<ClusterRouter>) {
    let counter = |name: &'static str, help: &'static str, pick: fn(&ClusterRouter) -> u64| {
        let cc = c.clone();
        reg.counter_fn(name, help, vec![], move || pick(&cc) as f64);
    };
    counter("chh_router_fanout_reads_total", "scatter-gather reads issued", |c| {
        c.stats().fanout_reads.load(Ordering::Relaxed)
    });
    counter(
        "chh_router_partial_answers_total",
        "reads answered degraded with at least one partition missing",
        |c| c.stats().partial_answers.load(Ordering::Relaxed),
    );
    counter(
        "chh_router_failovers_total",
        "reads answered by a replica because the partition primary was unreachable",
        |c| c.stats().failovers.load(Ordering::Relaxed),
    );
    counter(
        "chh_router_stale_map_retries_total",
        "mutations that hit a 421 and were retried at the advertised primary",
        |c| c.stats().stale_map_retries.load(Ordering::Relaxed),
    );
    counter(
        "chh_router_map_reloads_total",
        "partition-map installs (POST /map or disk reload after a 421)",
        |c| c.stats().map_reloads.load(Ordering::Relaxed),
    );
    counter(
        "chh_router_downstream_errors_total",
        "downstream partition requests that errored (transport or non-2xx)",
        |c| c.stats().downstream_errors.load(Ordering::Relaxed),
    );
    counter("chh_router_mutations_routed_total", "mutations routed by id range", |c| {
        c.stats().mutations_routed.load(Ordering::Relaxed)
    });
    let cc = c.clone();
    reg.gauge_fn(
        "chh_cluster_map_version",
        "version of the installed partition map",
        vec![],
        move || cc.map_version() as f64,
    );
    let cc = c.clone();
    reg.gauge_fn("chh_cluster_partitions", "partitions in the installed map", vec![], move || {
        cc.partition_count() as f64
    });
    let cc = c.clone();
    reg.gauge_fn(
        "chh_cluster_id_space",
        "one past the largest routable id in the installed map",
        vec![],
        move || cc.id_space() as f64,
    );
    for i in 0..c.partition_count() {
        let cc = c.clone();
        reg.gauge_fn(
            "chh_cluster_partition_healthy",
            "1 when the partition answered its last read, 0 when every target failed, \
             -1 when the installed map no longer has this partition index",
            vec![("partition", i.to_string())],
            move || cc.health_at(i),
        );
    }
}

/// Router-tier per-partition read telemetry: one wait histogram and one
/// straggler counter per partition of the map installed at spawn. The
/// wait is the router-side wall time of the partition's read (failover
/// attempts included); the straggler counter ticks when the partition
/// was the slowest contributor to a multi-partition fan-out — a
/// persistently hot straggler is the partition to re-split or re-home.
/// Sized at spawn like the health gauges: after a map flip that grows
/// the partition count, new slots are unobserved until a router restart.
struct ClusterTelemetry {
    partition_wait: Vec<Arc<Hist>>,
    stragglers: Vec<Arc<obs::Counter>>,
}

impl ClusterTelemetry {
    fn new(reg: &Registry, partitions: usize) -> Self {
        let mut partition_wait = Vec::with_capacity(partitions);
        let mut stragglers = Vec::with_capacity(partitions);
        for i in 0..partitions {
            partition_wait.push(reg.hist(
                "chh_partition_seconds",
                "router-side wait for one partition's read (failover attempts included)",
                vec![("partition", i.to_string())],
                obs::LATENCY_BOUNDS_NS,
                1e9,
            ));
            stragglers.push(reg.counter(
                "chh_router_stragglers_total",
                "fan-out reads in which this partition was the slowest contributor",
                vec![("partition", i.to_string())],
            ));
        }
        ClusterTelemetry { partition_wait, stragglers }
    }

    /// Observe one fan-out's spans: every partition's wait lands in its
    /// histogram; the slowest of a multi-partition read is the straggler.
    fn record(&self, spans: &[obs::PartitionSpan]) {
        for s in spans {
            if let Some(h) = self.partition_wait.get(s.partition) {
                h.observe_duration(s.wait);
            }
        }
        if spans.len() > 1 {
            if let Some(worst) = spans.iter().max_by_key(|s| s.wait) {
                if let Some(c) = self.stragglers.get(worst.partition) {
                    c.inc();
                }
            }
        }
    }
}

/// Transport-level connection accounting, shared between the transport
/// (event loop or legacy acceptor) and the `/metrics` scrape callbacks.
#[derive(Default)]
struct ConnCounts {
    /// currently open client connections (shed connections excluded)
    open: AtomicUsize,
    /// connections accepted since start
    accepted: AtomicU64,
}

struct State {
    stack: Stack,
    /// micro-batcher over the local index; `None` for the cluster stack
    /// (routers batch nothing — every request fans out immediately)
    batcher: Option<Batcher>,
    /// metrics registry, stage histograms, slow-query sink
    telemetry: Arc<Telemetry>,
    /// per-partition wait histograms + straggler counters; router tier only
    cluster_tel: Option<ClusterTelemetry>,
    /// sampling search-quality auditor ([`crate::obs::audit`]); local
    /// stacks with `audit_frac > 0` only. Dropped with `State`, which
    /// joins the audit thread after the transport drains.
    auditor: Option<Arc<obs::audit::Auditor>>,
    /// journaling wrapper around the online index, when serving durably
    /// (a durable server doubles as a replication primary)
    durable: Option<Arc<DurableIndex>>,
    /// replica role: the tailed index plus the primary's address
    /// (mutations are answered 421 pointing there)
    replica: Option<(Arc<ReplicaIndex>, String)>,
    /// content fingerprint of the serving hash family, computed once at
    /// spawn (immutable for the server's lifetime; /stats is polled)
    family_check: u32,
    budget_desc: Option<(usize, usize)>,
    shutdown: AtomicBool,
    addr: SocketAddr,
    max_conns: usize,
    /// event-loop worker threads (request-execution concurrency)
    conn_workers: usize,
    /// open/accepted counts (`Arc` so scrape callbacks can read them
    /// without referencing `State`)
    conns: Arc<ConnCounts>,
    /// over-cap connections currently being refused with a courtesy 503
    shedding_conns: AtomicUsize,
    idle_timeout: Duration,
    /// `Arc` so scrape callbacks can read it without referencing `State`
    stats: Arc<ServerStats>,
}

/// Cap on concurrent courtesy-503 shed threads; past this, over-cap
/// connections are dropped outright so the acceptor keeps draining.
const MAX_SHEDDING: usize = 64;

impl State {
    fn dim(&self) -> usize {
        match &self.stack {
            Stack::Cluster(c) => c.dim(),
            _ => self.stack.feats().dim(),
        }
    }

    /// The micro-batcher; only the cluster stack runs without one.
    fn batcher(&self) -> &Batcher {
        self.batcher.as_ref().expect("non-cluster stacks own a batcher")
    }

    /// Serving role for `/healthz` and `/stats`.
    fn role(&self) -> &'static str {
        if matches!(self.stack, Stack::Cluster(_)) {
            "router"
        } else if self.replica.is_some() {
            "replica"
        } else if self.durable.is_some() {
            "primary"
        } else {
            "standalone"
        }
    }
}

/// Handle to trigger shutdown from another thread (timers, signal shims).
#[derive(Clone)]
pub struct Stopper {
    state: Arc<State>,
}

impl Stopper {
    pub fn trigger(&self) {
        trigger_shutdown(&self.state);
    }
}

fn trigger_shutdown(state: &State) {
    if !state.shutdown.swap(true, Ordering::SeqCst) {
        // one poke unblocks the acceptor; connection threads notice the
        // flag at their next request boundary or idle timeout
        let _ = TcpStream::connect(state.addr);
    }
}

/// A running server; join it with [`Self::wait`] or stop it with
/// [`Self::shutdown`].
pub struct ServerHandle {
    state: Arc<State>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    /// background snapshotter (durable serving only): stop flag + thread
    snapshotter: Option<(Arc<AtomicBool>, std::thread::JoinHandle<()>)>,
    /// background WAL tailer (replica serving only), joined on shutdown
    tailer: Option<Tailer>,
}

impl ServerHandle {
    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// A cloneable trigger usable from other threads.
    pub fn stopper(&self) -> Stopper {
        Stopper { state: self.state.clone() }
    }

    /// Block until the server shuts down (a `POST /shutdown`, or any
    /// [`Stopper`]): joins the acceptor, waits for the connection
    /// threads to drain (bounded by `idle_timeout` + in-flight work),
    /// writes a final WAL checkpoint when serving durably (so a clean
    /// stop replays nothing on restart), then drains the batcher.
    pub fn wait(mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // the event loop closes every connection before its thread exits;
        // the non-unix fallback's connection threads drain on their own —
        // either way, wait for the count to hit zero
        while self.state.conns.open.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // connection threads are gone ⇒ no more mutations can arrive;
        // stop the snapshotter first so the final checkpoint below is
        // the last word, then flush + checkpoint the WAL
        if let Some((stop, h)) = self.snapshotter.take() {
            stop.store(true, Ordering::SeqCst);
            let _ = h.join();
        }
        // replica role: stop tailing before the server object unwinds so
        // no apply races the final stats readers
        if let Some(t) = self.tailer.take() {
            t.stop();
        }
        if let Some(d) = &self.state.durable {
            match d.checkpoint() {
                Ok(gen) => eprintln!("serve-http: shutdown checkpoint gen {gen}"),
                Err(e) => eprintln!("serve-http: shutdown checkpoint FAILED: {e:#}"),
            }
        }
        // the batcher (owned by `state`) drains and joins when the last
        // Arc drops — force that here if we hold the last one, so
        // callers observe a fully-stopped server
        drop(self.state);
    }

    /// Trigger shutdown and wait for a clean stop.
    pub fn shutdown(self) {
        trigger_shutdown(&self.state);
        self.wait();
    }
}

/// The HTTP front-end.
pub struct Server;

impl Server {
    /// Bind, spawn the batcher + acceptor, return immediately.
    pub fn spawn(stack: Stack, cfg: ServerConfig) -> anyhow::Result<ServerHandle> {
        Self::spawn_with_durability(stack, cfg, None)
    }

    /// [`Self::spawn`] with WAL-backed durability: `/insert`/`/remove`
    /// journal through `durability.durable` before applying, `/stats`
    /// gains a `durability` section, a background snapshotter
    /// checkpoints on the configured cadence, graceful shutdown writes a
    /// final checkpoint — and the server answers the replication
    /// endpoints (`/wal/stream`, `/wal/bootstrap`) as a primary.
    pub fn spawn_with_durability(
        stack: Stack,
        cfg: ServerConfig,
        durability: Option<Durability>,
    ) -> anyhow::Result<ServerHandle> {
        Self::spawn_inner(stack, cfg, durability, None)
    }

    /// Run the read-replica role: reads as usual off `stack`'s index
    /// (which `role.replica` keeps in sync by tailing the primary),
    /// mutations answered `421` with the primary's address, replication
    /// lag in `/stats`, and the tailer joined on graceful shutdown.
    pub fn spawn_replica(
        stack: Stack,
        cfg: ServerConfig,
        role: ReplicaRole,
    ) -> anyhow::Result<ServerHandle> {
        if !matches!(stack, Stack::Online(_)) {
            anyhow::bail!("the replica role requires the online stack");
        }
        Self::spawn_inner(stack, cfg, None, Some(role))
    }

    /// Run the stateless router tier (`chh route`): scatter-gather the
    /// data routes across the cluster's partitions and serve `/map`.
    pub fn spawn_cluster(
        router: Arc<ClusterRouter>,
        cfg: ServerConfig,
    ) -> anyhow::Result<ServerHandle> {
        Self::spawn_inner(Stack::Cluster(router), cfg, None, None)
    }

    fn spawn_inner(
        stack: Stack,
        cfg: ServerConfig,
        durability: Option<Durability>,
        replica_role: Option<ReplicaRole>,
    ) -> anyhow::Result<ServerHandle> {
        if durability.is_some() && !matches!(stack, Stack::Online(_)) {
            anyhow::bail!("durability requires the online stack");
        }
        if durability.is_some() && replica_role.is_some() {
            anyhow::bail!("a server is a primary or a replica, not both");
        }
        if replica_role.is_some() && matches!(stack, Stack::Cluster(_)) {
            anyhow::bail!("the router tier is stateless; it cannot be a replica");
        }
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let telemetry = Arc::new(Telemetry::new(cfg.slow_ms, cfg.slow_log.clone()));
        // the cluster stack holds no local index: no batcher, no flush
        // pool — every data request fans out to the partitions instead
        let batcher = if matches!(stack, Stack::Cluster(_)) {
            None
        } else {
            let flush_stack = stack.clone();
            let pool = crate::par::Pool::new(cfg.pool_workers);
            let ftel = telemetry.clone();
            Some(Batcher::new(
                cfg.batch,
                Box::new(move |reqs: &[QueryRequest]| {
                    let (hits, stages) = flush_stack.query_batch_traced(reqs, &pool);
                    ftel.record_stages(&stages);
                    FlushOutcome { hits, stages }
                }),
            ))
        };
        let budget_desc = match &stack {
            Stack::Online(r) => {
                let b = r.budget();
                Some((b.probes, b.top))
            }
            Stack::Static(_) | Stack::Cluster(_) => None,
        };
        let (durable, snapshot_every_ops) = match durability {
            Some(d) => (Some(d.durable), d.snapshot_every_ops),
            None => (None, 0),
        };
        let (replica, tailer) = match replica_role {
            Some(r) => (Some((r.replica, r.primary_addr)), r.tailer),
            None => (None, None),
        };
        let family_check = match &stack {
            // the router validated every partition against this at connect
            Stack::Cluster(c) => c.meta().family_check,
            _ => crate::replicate::family_fingerprint(stack.family().as_ref(), stack.feats().dim()),
        };
        let cluster_tel = match &stack {
            Stack::Cluster(c) => {
                Some(ClusterTelemetry::new(&telemetry.registry, c.partition_count()))
            }
            _ => None,
        };
        // the auditor re-answers a sample of served queries off to the
        // side; it never touches the serving path beyond a queue push
        let auditor = if cfg.audit_frac > 0.0 {
            let target = match &stack {
                Stack::Static(r) => Some(obs::audit::AuditTarget::Static {
                    family: r.family().clone(),
                    feats: r.feats().clone(),
                }),
                Stack::Online(r) => Some(obs::audit::AuditTarget::Online {
                    family: r.family().clone(),
                    feats: r.feats().clone(),
                    index: r.index().clone(),
                    budget: r.budget(),
                }),
                Stack::Cluster(_) => None,
            };
            target.map(|t| obs::audit::Auditor::spawn(t, cfg.audit_frac, &telemetry.registry))
        } else {
            None
        };
        let state = Arc::new(State {
            stack,
            batcher,
            telemetry,
            cluster_tel,
            auditor,
            durable,
            replica,
            family_check,
            budget_desc,
            shutdown: AtomicBool::new(false),
            addr,
            max_conns: cfg.max_conns.max(1),
            conn_workers: cfg.conn_workers.max(1),
            conns: Arc::new(ConnCounts::default()),
            shedding_conns: AtomicUsize::new(0),
            idle_timeout: cfg.idle_timeout,
            stats: Arc::new(ServerStats {
                started: Instant::now(),
                http_requests: AtomicU64::new(0),
                bad_requests: AtomicU64::new(0),
                probes_total: AtomicU64::new(0),
                // bounded ring: a long-lived server must not grow memory
                // per request, and /stats sorts this under the same mutex
                // the query path records into
                latency: Mutex::new(Histogram::with_capacity(
                    crate::metrics::SERVING_RESERVOIR,
                )),
            }),
        });
        register_metrics(
            &state.telemetry,
            &state.stack,
            &state.stats,
            state.batcher.as_ref().map(|b| b.stats()),
            &state.conns,
            state.durable.as_ref(),
            state.replica.as_ref(),
            state.role(),
        );
        let astate = state.clone();
        #[cfg(unix)]
        let acceptor = std::thread::Builder::new()
            .name("chh-http-loop".to_string())
            .spawn(move || event_loop::run(listener, &astate))
            .expect("spawn http event loop");
        #[cfg(not(unix))]
        let acceptor = std::thread::Builder::new()
            .name("chh-http-accept".to_string())
            .spawn(move || acceptor_loop(&listener, &astate))
            .expect("spawn http acceptor");
        // background snapshotter: checkpoint once enough mutations have
        // been journaled since the last snapshot; polling (rather than
        // waking per op) keeps the mutation path free of extra signaling
        let snapshotter = match (&state.durable, snapshot_every_ops) {
            (Some(d), every) if every > 0 => {
                let stop = Arc::new(AtomicBool::new(false));
                let (sstop, sd) = (stop.clone(), d.clone());
                let h = std::thread::Builder::new()
                    .name("chh-wal-snapshot".to_string())
                    .spawn(move || {
                        while !sstop.load(Ordering::SeqCst) {
                            std::thread::sleep(Duration::from_millis(200));
                            if sstop.load(Ordering::SeqCst) {
                                break;
                            }
                            if sd.ops_since_snapshot() >= every {
                                if let Err(e) = sd.checkpoint() {
                                    eprintln!("snapshotter: checkpoint failed: {e:#}");
                                }
                            }
                        }
                    })
                    .expect("spawn wal snapshotter");
                Some((stop, h))
            }
            _ => None,
        };
        Ok(ServerHandle { state, acceptor: Some(acceptor), snapshotter, tailer })
    }
}

/// Execute one parsed request end to end — count, trace, dispatch,
/// account, serialize — and return the response bytes plus whether the
/// connection should stay open. Both transports (the unix event loop
/// and the thread-per-connection fallback) funnel through here, so
/// routing, tracing and accounting are transport-independent.
fn process_request(state: &Arc<State>, req: &http::Request) -> (Vec<u8>, bool) {
    state.stats.http_requests.fetch_add(1, Ordering::Relaxed);
    let t0 = Instant::now();
    // propagate the client's correlation id, or mint one — either way it
    // is echoed in the response and carried through the trace /
    // slow-query log
    let rid = req.request_id.clone().unwrap_or_else(obs::gen_request_id);
    let mut trace = Trace::new(rid);
    let reply = dispatch(state, req, &mut trace);
    let total = t0.elapsed();
    state.telemetry.finish_request(&trace, &req.path, reply.status, total);
    let keep = req.keep_alive && !state.shutdown.load(Ordering::SeqCst);
    let mut out = Vec::with_capacity(reply.body.len() + 128);
    // every traced stage rides back in `x-chh-stages`, so an upstream
    // router can fold this server's breakdown into its own span (and a
    // client can see the per-stage cost of exactly its request)
    let stages = obs::encode_stages(trace.stages());
    let _ = http::write_response_traced(
        &mut out,
        reply.status,
        &reply.body,
        keep,
        reply.content_type,
        Some(&trace.id),
        if stages.is_empty() { None } else { Some(&stages) },
    );
    (out, keep)
}

/// Serialized 4xx for a framing error; counts `bad_requests`. The
/// connection must close after flushing — framing is unreliable past a
/// malformed request.
fn bad_request_bytes(state: &Arc<State>, e: &HttpError) -> Vec<u8> {
    state.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
    let status = if matches!(e, HttpError::TooLarge(_)) { 413 } else { 400 };
    let body = protocol::error_json(&e.to_string());
    let mut out = Vec::new();
    let _ = http::write_response(&mut out, status, body.as_bytes(), false);
    out
}

/// Serialized 503 for an over-cap connection, shed at the edge.
#[cfg(unix)]
fn overload_response_bytes() -> Vec<u8> {
    let body = protocol::error_json("overloaded: connection limit reached");
    let mut out = Vec::new();
    let _ = http::write_response(&mut out, 503, body.as_bytes(), false);
    out
}

/// Serialized 503 for a saturated worker queue. The event loop answers
/// this from its own thread so overload can never wedge the transport.
#[cfg(unix)]
fn busy_response_bytes(keep_alive: bool) -> Vec<u8> {
    let body = protocol::error_json("overloaded: request queue full");
    let mut out = Vec::new();
    let _ = http::write_response(&mut out, 503, body.as_bytes(), keep_alive);
    out
}

#[cfg(not(unix))]
fn acceptor_loop(listener: &TcpListener, state: &Arc<State>) {
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return; // the accept was a shutdown poke
                }
                state.conns.accepted.fetch_add(1, Ordering::Relaxed);
                // connection cap: shed load at the edge with a 503
                // instead of growing an unbounded thread count. The
                // courtesy 503 (write + drain) blocks for up to ~400ms
                // on a misbehaving client, so it runs on a short-lived
                // detached thread — the acceptor itself must never
                // stall, least of all under overload. Past MAX_SHEDDING
                // concurrent sheds, degrade to a plain drop.
                if state.conns.open.load(Ordering::SeqCst) >= state.max_conns {
                    if state.shedding_conns.fetch_add(1, Ordering::SeqCst) < MAX_SHEDDING {
                        let sstate = state.clone();
                        let spawned = std::thread::Builder::new()
                            .name("chh-http-shed".to_string())
                            .spawn(move || {
                                shed_connection(&stream);
                                sstate.shedding_conns.fetch_sub(1, Ordering::SeqCst);
                            });
                        if spawned.is_err() {
                            state.shedding_conns.fetch_sub(1, Ordering::SeqCst);
                        }
                    } else {
                        state.shedding_conns.fetch_sub(1, Ordering::SeqCst);
                        // dropped without ceremony: shed capacity is full
                    }
                    continue;
                }
                state.conns.open.fetch_add(1, Ordering::SeqCst);
                let cstate = state.clone();
                let spawned = std::thread::Builder::new()
                    .name("chh-http-conn".to_string())
                    .spawn(move || {
                        let _guard = ConnGuard(&cstate);
                        handle_conn(&cstate, &stream);
                    });
                if spawned.is_err() {
                    // thread spawn failed (resource exhaustion): undo
                    state.conns.open.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(_) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept error (EMFILE, aborted handshake):
                // back off briefly rather than spinning
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Refuse an over-cap connection with a 503 the client can actually
/// read: write the response first, then [`drain_and_close`].
#[cfg(not(unix))]
fn shed_connection(stream: &TcpStream) {
    let body = protocol::error_json("overloaded: connection limit reached");
    let mut out = stream;
    if http::write_response(&mut out, 503, body.as_bytes(), false).is_ok() {
        drain_and_close(stream);
    }
}

/// Bounded drain, then close. Dropping a socket with unread request
/// bytes makes the kernel send RST, which can race ahead of a
/// just-written response and surface client-side as a bare transport
/// error instead of the clean status we sent. Pulling the pending bytes
/// out first lets the FIN (and the response) land. Best-effort and
/// bounded — short timeout, few reads — so a misbehaving or very large
/// sender cannot hold the thread; payloads beyond the drain window may
/// still observe a reset. (The event loop's equivalent is its
/// discard-input linger.)
#[cfg(not(unix))]
fn drain_and_close(stream: &TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 4096];
    let mut reader = stream;
    for _ in 0..8 {
        match reader.read(&mut sink) {
            Ok(0) | Err(_) => break, // client closed (read our reply) or idle
            Ok(_) => {}
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Decrements the live-connection counter even if a handler panics.
#[cfg(not(unix))]
struct ConnGuard<'a>(&'a Arc<State>);

#[cfg(not(unix))]
impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.conns.open.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(not(unix))]
fn handle_conn(state: &Arc<State>, stream: &TcpStream) {
    use std::io::Write;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(state.idle_timeout));
    // a peer that stalls mid-read of a response must not park this
    // thread forever either
    let _ = stream.set_write_timeout(Some(state.idle_timeout));
    let mut reader = http::MessageReader::new(stream);
    loop {
        match reader.request() {
            Ok(req) => {
                let (bytes, keep) = process_request(state, &req);
                let mut out = stream;
                if out.write_all(&bytes).is_err() || !keep {
                    return;
                }
            }
            // clean close / idle reap / transport error: nothing to say
            Err(HttpError::Closed) | Err(HttpError::Timeout) | Err(HttpError::Io(_)) => return,
            Err(e) => {
                // framing is unreliable after a malformed request — answer
                // and close (draining first, so the 4xx isn't destroyed
                // by a reset triggered by unread request bytes)
                let bytes = bad_request_bytes(state, &e);
                let mut out = stream;
                let _ = out.write_all(&bytes);
                drain_and_close(stream);
                return;
            }
        }
    }
}

/// Content type of the Prometheus text exposition.
pub const METRICS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

struct Reply {
    status: u16,
    body: Vec<u8>,
    /// JSON on every route except `/metrics` (Prometheus text) and the
    /// replication transfers (binary, [`crate::replicate::wire`])
    content_type: &'static str,
}

const CT_JSON: &str = "application/json";
const CT_BIN: &str = "application/octet-stream";

fn ok_json(v: Json) -> Reply {
    Reply { status: 200, body: v.to_string_compact().into_bytes(), content_type: CT_JSON }
}

fn err_json(status: u16, msg: &str) -> Reply {
    Reply { status, body: protocol::error_json(msg).into_bytes(), content_type: CT_JSON }
}

/// A binary-codec success reply ([`binproto`]), negotiated by the
/// request's `Content-Type: application/x-chh-binary`. Errors are
/// always JSON regardless of the request's wire protocol.
fn ok_bin(body: Vec<u8>) -> Reply {
    Reply { status: 200, body, content_type: http::CT_CHH_BIN }
}

const ROUTES: &[&str] = &[
    "/healthz",
    "/stats",
    "/metrics",
    "/query",
    "/query_topk",
    "/insert",
    "/remove",
    "/shutdown",
    "/wal/stream",
    "/wal/bootstrap",
    "/map",
];

fn dispatch(state: &Arc<State>, req: &http::Request, trace: &mut Trace) -> Reply {
    // the replication endpoints carry `?seg=...`-style parameters; every
    // other route ignores its query string
    let (route, query) = match req.path.split_once('?') {
        Some((r, q)) => (r, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), route) {
        ("GET", "/healthz") => handle_healthz(state),
        ("GET", "/stats") => handle_stats(state),
        ("GET", "/metrics") => Reply {
            status: 200,
            body: state.telemetry.registry.render().into_bytes(),
            content_type: METRICS_CONTENT_TYPE,
        },
        // the four data routes honor the negotiated wire protocol
        // (`Content-Type: application/x-chh-binary` selects [`binproto`])
        // and attribute themselves to `chh_requests_by_protocol`
        ("POST", "/query") => {
            state.telemetry.count_proto(req.binary);
            match &state.stack {
                Stack::Cluster(c) => handle_cluster_query(state, c, &req.body, req.binary, trace),
                _ => handle_query(state, &req.body, req.binary, trace),
            }
        }
        ("POST", "/query_topk") => {
            state.telemetry.count_proto(req.binary);
            match &state.stack {
                Stack::Cluster(c) => handle_cluster_topk(state, c, &req.body, req.binary, trace),
                _ => handle_topk(state, &req.body, req.binary),
            }
        }
        ("POST", "/insert") => {
            state.telemetry.count_proto(req.binary);
            match &state.stack {
                Stack::Cluster(c) => handle_cluster_mutate(c, &req.body, req.binary, true, trace),
                _ => handle_insert(state, &req.body, req.binary),
            }
        }
        ("POST", "/remove") => {
            state.telemetry.count_proto(req.binary);
            match &state.stack {
                Stack::Cluster(c) => handle_cluster_mutate(c, &req.body, req.binary, false, trace),
                _ => handle_remove(state, &req.body, req.binary),
            }
        }
        ("GET", "/wal/stream") => handle_wal_stream(state, query),
        ("GET", "/wal/bootstrap") => handle_wal_bootstrap(state, query),
        ("GET", "/map") => handle_map_get(state),
        ("POST", "/map") => handle_map_post(state, &req.body),
        ("POST", "/shutdown") => {
            trigger_shutdown(state);
            ok_json(obj(vec![("shutting_down", Json::from(true))]))
        }
        (_, path) if ROUTES.contains(&path) => {
            err_json(405, &format!("wrong method for {path}"))
        }
        (_, path) => err_json(404, &format!("no route {path}")),
    }
}

fn handle_healthz(state: &Arc<State>) -> Reply {
    ok_json(obj(vec![
        ("status", Json::from("ok")),
        ("mode", Json::from(state.stack.mode())),
        ("role", Json::from(state.role())),
        ("version", Json::from(VERSION)),
        ("git_hash", Json::from(git_hash())),
        ("uptime_secs", Json::Num(state.stats.started.elapsed().as_secs_f64())),
    ]))
}

/// Serve fsynced WAL frames to a tailing replica (primaries only).
fn handle_wal_stream(state: &Arc<State>, query: &str) -> Reply {
    let Some(d) = &state.durable else {
        return err_json(400, "not a replication primary (serve with --wal-dir)");
    };
    match crate::replicate::primary::handle_stream(d, query) {
        Ok(chunk) => Reply {
            status: 200,
            body: crate::replicate::wire::encode_stream_chunk(&chunk),
            content_type: CT_BIN,
        },
        Err(e) => err_json(e.status, &e.msg),
    }
}

/// Serve a snapshot window for replica bootstrap (primaries only).
fn handle_wal_bootstrap(state: &Arc<State>, query: &str) -> Reply {
    let Some(d) = &state.durable else {
        return err_json(400, "not a replication primary (serve with --wal-dir)");
    };
    match crate::replicate::primary::handle_bootstrap(d, query) {
        Ok(chunk) => Reply {
            status: 200,
            body: crate::replicate::wire::encode_bootstrap_chunk(&chunk),
            content_type: CT_BIN,
        },
        Err(e) => err_json(e.status, &e.msg),
    }
}

fn handle_query(state: &Arc<State>, body: &[u8], binary: bool, trace: &mut Trace) -> Reply {
    let parsed = if binary {
        binproto::decode_query(body, state.dim())
    } else {
        protocol::parse_query(body, state.dim())
    };
    let req = match parsed {
        Ok(r) => r,
        Err(e) => return err_json(e.status, &e.msg),
    };
    // keep what the auditor needs before the request moves into the
    // batcher (cheap, and only paid when auditing is on)
    let audit_req = state.auditor.as_ref().map(|a| (a, req.w.clone(), req.exclude.clone()));
    let t0 = Instant::now();
    match state.batcher().submit(req) {
        Ok(rx) => match rx.recv() {
            Ok(BatchedReply { hit, wait, stages }) => {
                let tel = &state.telemetry;
                // batch_wait is exact per request; the compute stages are
                // the batch-level breakdown the flush recorded (shared by
                // every request in the batch — context, not attribution)
                tel.stage_batch_wait.observe_duration(wait);
                trace.stage("batch_wait", wait);
                trace.stage("encode", stages.encode);
                trace.stage("probe", stages.probe);
                trace.stage("scan", stages.scan);
                trace.stage("merge", stages.merge);
                state.stats.latency.lock().unwrap().record_duration(t0.elapsed());
                state.stats.probes_total.fetch_add(hit.probed as u64, Ordering::Relaxed);
                // hand the served answer to the sampling auditor (a
                // bounded queue push; the re-answer runs off-thread and
                // the wire reply below is untouched)
                if let Some((a, w, ex)) = &audit_req {
                    a.offer(w, ex, hit.best);
                }
                let t_ser = Instant::now();
                let reply = if binary {
                    ok_bin(binproto::encode_hit(&hit))
                } else {
                    ok_json(protocol::hit_json(&hit))
                };
                let ser = t_ser.elapsed();
                tel.stage_serialize.observe_duration(ser);
                trace.stage("serialize", ser);
                reply
            }
            Err(_) => err_json(500, "batcher dropped the query"),
        },
        Err(SubmitError::Overloaded) => err_json(503, "overloaded: admission queue full"),
        Err(SubmitError::ShuttingDown) => err_json(503, "shutting down"),
    }
}

fn handle_topk(state: &Arc<State>, body: &[u8], binary: bool) -> Reply {
    let parsed = if binary {
        binproto::decode_topk(body, state.dim())
    } else {
        protocol::parse_topk(body, state.dim())
    };
    let (req, t) = match parsed {
        Ok(r) => r,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let eligible = |i: usize| req.exclude.as_ref().map_or(true, |ex| !ex.contains(&i));
    let hits = match &state.stack {
        Stack::Static(r) => {
            r.index().query_topk(r.family().as_ref(), &req.w, r.feats(), t, eligible)
        }
        Stack::Online(r) => r.index().query_topk(
            r.family().as_ref(),
            &req.w,
            r.feats(),
            t,
            r.budget(),
            eligible,
        ),
        Stack::Cluster(_) => unreachable!("dispatch routes cluster topk to handle_cluster_topk"),
    };
    if binary {
        ok_bin(binproto::encode_topk_hits(&hits))
    } else {
        ok_json(protocol::topk_json(&hits))
    }
}

/// The 421 a read replica answers mutations with: the op belongs on the
/// primary, whose address rides along in the body.
fn replica_redirect(primary: &str) -> Reply {
    Reply {
        status: 421,
        body: protocol::redirect_json(
            "read-only replica; send mutations to the primary",
            primary,
        )
        .into_bytes(),
        content_type: CT_JSON,
    }
}

fn handle_insert(state: &Arc<State>, body: &[u8], binary: bool) -> Reply {
    if let Some((_, primary)) = &state.replica {
        return replica_redirect(primary);
    }
    let parsed = if binary {
        binproto::decode_id(body, binproto::TAG_INSERT)
    } else {
        protocol::parse_id(body)
    };
    let id = match parsed {
        Ok(id) => id,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let Stack::Online(r) = &state.stack else {
        return err_json(400, "static index is immutable; serve with --mode online");
    };
    let n = r.feats().len();
    if id as usize >= n {
        return err_json(
            400,
            &format!("id {id} outside the serving feature store (n={n})"),
        );
    }
    if let Some(d) = &state.durable {
        // journal → apply → ack; a 200 means the op is durable per the
        // fsync policy
        if let Err(e) = d.insert_point(r.family().as_ref(), id, r.feats().row(id as usize))
        {
            return err_json(500, &format!("durable insert failed: {e:#}"));
        }
    } else {
        r.index().insert_point(r.family().as_ref(), id, r.feats().row(id as usize));
    }
    if binary {
        return ok_bin(binproto::encode_ack(true, id, r.index().len() as u64));
    }
    ok_json(obj(vec![
        ("inserted", Json::from(true)),
        ("id", Json::from(id as usize)),
        ("live", Json::from(r.index().len())),
    ]))
}

fn handle_remove(state: &Arc<State>, body: &[u8], binary: bool) -> Reply {
    if let Some((_, primary)) = &state.replica {
        return replica_redirect(primary);
    }
    let parsed = if binary {
        binproto::decode_id(body, binproto::TAG_REMOVE)
    } else {
        protocol::parse_id(body)
    };
    let id = match parsed {
        Ok(id) => id,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let Stack::Online(r) = &state.stack else {
        return err_json(400, "static index is immutable; serve with --mode online");
    };
    let removed = if let Some(d) = &state.durable {
        match d.remove(id) {
            Ok(removed) => removed,
            Err(e) => return err_json(500, &format!("durable remove failed: {e:#}")),
        }
    } else {
        r.index().remove(id)
    };
    if binary {
        return ok_bin(binproto::encode_ack(removed, id, r.index().len() as u64));
    }
    ok_json(obj(vec![
        ("removed", Json::from(removed)),
        ("id", Json::from(id as usize)),
        ("live", Json::from(r.index().len())),
    ]))
}

/// Reject a binary-negotiated request on a cluster route. The binary
/// hit/topk frames have no room for the degraded-answer flag, so the
/// router tier speaks JSON upstream; the binary wire stays the
/// router→partition transport.
fn cluster_binary_reply() -> Reply {
    err_json(
        400,
        "the router tier answers JSON upstream (the binary wire is partition-internal); \
         drop the application/x-chh-binary content type",
    )
}

fn cluster_err(e: crate::cluster::ClusterError) -> Reply {
    err_json(e.status, &e.msg)
}

/// Add the degraded-answer marker to a data-route reply. Upstream
/// parsers that predate the cluster tier ignore unknown keys, so the
/// flag is additive — but it is always present, and `true` means at
/// least one partition did not contribute (never a silent short list).
fn with_partial(v: Json, partial: bool) -> Json {
    match v {
        Json::Obj(mut m) => {
            m.insert("partial".to_string(), Json::from(partial));
            Json::Obj(m)
        }
        other => other,
    }
}

/// Fold one scatter-gather's router-side timing into the request trace
/// (so a slow line carries the full cross-tier breakdown under the
/// request id every partition also logged) and the per-partition
/// wait/straggler metrics.
fn note_cluster_read<T>(
    state: &Arc<State>,
    ans: &mut crate::cluster::ClusterAnswer<T>,
    trace: &mut Trace,
) {
    trace.stage("route_fanout", ans.fanout);
    trace.stage("merge", ans.merge);
    if let Some(ct) = &state.cluster_tel {
        ct.record(&ans.spans);
    }
    for s in std::mem::take(&mut ans.spans) {
        trace.partition(s);
    }
}

/// Scatter-gather `/query` across the cluster (JSON upstream only).
fn handle_cluster_query(
    state: &Arc<State>,
    c: &Arc<ClusterRouter>,
    body: &[u8],
    binary: bool,
    trace: &mut Trace,
) -> Reply {
    if binary {
        return cluster_binary_reply();
    }
    let req = match protocol::parse_query(body, state.dim()) {
        Ok(r) => r,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let t0 = Instant::now();
    match c.query(&req, Some(&trace.id)) {
        Ok(mut ans) => {
            state.stats.latency.lock().unwrap().record_duration(t0.elapsed());
            state.stats.probes_total.fetch_add(ans.value.probed as u64, Ordering::Relaxed);
            note_cluster_read(state, &mut ans, trace);
            ok_json(with_partial(protocol::hit_json(&ans.value), ans.partial()))
        }
        Err(e) => cluster_err(e),
    }
}

/// Scatter-gather `/query_topk` across the cluster (JSON upstream only).
fn handle_cluster_topk(
    state: &Arc<State>,
    c: &Arc<ClusterRouter>,
    body: &[u8],
    binary: bool,
    trace: &mut Trace,
) -> Reply {
    if binary {
        return cluster_binary_reply();
    }
    let (req, t) = match protocol::parse_topk(body, state.dim()) {
        Ok(r) => r,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let t0 = Instant::now();
    match c.query_topk(&req, t, Some(&trace.id)) {
        Ok(mut ans) => {
            state.stats.latency.lock().unwrap().record_duration(t0.elapsed());
            note_cluster_read(state, &mut ans, trace);
            ok_json(with_partial(protocol::topk_json(&ans.value), ans.partial()))
        }
        Err(e) => cluster_err(e),
    }
}

/// Route one `/insert`/`/remove` to the partition primary owning the id.
fn handle_cluster_mutate(
    c: &Arc<ClusterRouter>,
    body: &[u8],
    binary: bool,
    insert: bool,
    trace: &mut Trace,
) -> Reply {
    if binary {
        return cluster_binary_reply();
    }
    let id = match protocol::parse_id(body) {
        Ok(id) => id,
        Err(e) => return err_json(e.status, &e.msg),
    };
    match c.mutate(insert, id, Some(&trace.id)) {
        Ok((applied, live)) => ok_json(obj(vec![
            (if insert { "inserted" } else { "removed" }, Json::from(applied)),
            ("id", Json::from(id as usize)),
            // live count of the owning partition, not the whole cluster
            // (the cluster-wide figure is on the router's /stats)
            ("live", Json::from(live as usize)),
        ])),
        Err(e) => cluster_err(e),
    }
}

/// The installed partition map (routers only).
fn handle_map_get(state: &Arc<State>) -> Reply {
    let Stack::Cluster(c) = &state.stack else {
        return err_json(400, "not a router (serve with `chh route`)");
    };
    ok_json(c.map_json())
}

/// Atomically flip the router to a newer partition map (routers only).
/// The body is a serialized map; it must validate, match the cluster's
/// family fingerprint, and strictly increase the version.
fn handle_map_post(state: &Arc<State>, body: &[u8]) -> Reply {
    let Stack::Cluster(c) = &state.stack else {
        return err_json(400, "not a router (serve with `chh route`)");
    };
    let map = match PartitionMap::parse_bytes(body) {
        Ok(m) => m,
        Err(e) => return err_json(400, &e),
    };
    match c.install_map(map) {
        Ok(v) => ok_json(obj(vec![
            ("installed", Json::from(true)),
            ("version", Json::from(v as usize)),
        ])),
        Err(e) => cluster_err(e),
    }
}

/// `/stats` for the router role: no local index, batcher, or WAL — the
/// interesting state is the map and the per-partition health/counters.
fn handle_cluster_stats(state: &Arc<State>, c: &Arc<ClusterRouter>) -> Reply {
    let s = &state.stats;
    let meta = c.meta();
    ok_json(obj(vec![
        ("mode", Json::from(state.stack.mode())),
        ("role", Json::from(state.role())),
        ("dim", Json::from(meta.dim)),
        // the routable id space stands in for the feature-store size
        // (loadgen bounds its mutation ids by this, same as `points`)
        ("points", Json::from(c.id_space() as usize)),
        ("bits", Json::from(meta.bits)),
        ("family", Json::from(meta.family.as_str())),
        ("family_check", Json::from(state.family_check as usize)),
        ("uptime_secs", Json::Num(s.started.elapsed().as_secs_f64())),
        (
            "http",
            obj(vec![
                ("requests", Json::from(s.http_requests.load(Ordering::Relaxed) as usize)),
                ("bad_requests", Json::from(s.bad_requests.load(Ordering::Relaxed) as usize)),
                ("probes_total", Json::from(s.probes_total.load(Ordering::Relaxed) as usize)),
                ("latency", latency_json(s)),
            ]),
        ),
        ("transport", transport_json(state)),
        ("cluster", c.stats_json()),
    ]))
}

/// The `transport` sub-document of `/stats`, shared by every role.
fn transport_json(state: &Arc<State>) -> Json {
    obj(vec![
        ("model", Json::from(if cfg!(unix) { "event_loop" } else { "threaded" })),
        ("conn_workers", Json::from(state.conn_workers)),
        ("max_conns", Json::from(state.max_conns)),
        ("open_connections", Json::from(state.conns.open.load(Ordering::SeqCst))),
        (
            "connections_accepted",
            Json::from(state.conns.accepted.load(Ordering::Relaxed) as usize),
        ),
        // OS-level thread count of the whole process: the
        // transport-scale test and CI smoke assert this stays
        // O(conn_workers) while thousands of sockets sit open
        ("threads", process_threads().map_or(Json::Null, Json::from)),
    ])
}

/// The `latency` sub-document of `/stats`: one sort under the lock the
/// query path records into.
fn latency_json(s: &ServerStats) -> Json {
    let (pcts, lat_mean, lat_count) = {
        let lat = s.latency.lock().unwrap();
        (lat.percentiles(&[50.0, 95.0, 99.0]), lat.mean(), lat.len())
    };
    obj(vec![
        ("p50_us", Json::Num(pcts[0] * 1e6)),
        ("p95_us", Json::Num(pcts[1] * 1e6)),
        ("p99_us", Json::Num(pcts[2] * 1e6)),
        ("mean_us", Json::Num(lat_mean * 1e6)),
        ("count", Json::from(lat_count)),
    ])
}

fn handle_stats(state: &Arc<State>) -> Reply {
    if let Stack::Cluster(c) = &state.stack {
        return handle_cluster_stats(state, c);
    }
    let s = &state.stats;
    let router_stats = match &state.stack {
        Stack::Static(r) => r.stats(),
        Stack::Online(r) => r.stats(),
        Stack::Cluster(_) => unreachable!("handled above"),
    };
    let b = state.batcher().stats();
    let lat_json = latency_json(s);
    let mut fields = vec![
        ("mode", Json::from(state.stack.mode())),
        ("role", Json::from(state.role())),
        ("dim", Json::from(state.dim())),
        // feature-store size: the valid id range for /insert (loadgen
        // uses this to drive mutations)
        ("points", Json::from(state.stack.feats().len())),
        ("bits", Json::from(state.stack.family().bits())),
        ("family", Json::from(state.stack.family().name())),
        // content fingerprint: lets a replica verify it sampled the same
        // hyperplanes (name+bits alone cannot catch a --seed mismatch)
        ("family_check", Json::from(state.family_check as usize)),
        ("uptime_secs", Json::Num(s.started.elapsed().as_secs_f64())),
        (
            "http",
            obj(vec![
                ("requests", Json::from(s.http_requests.load(Ordering::Relaxed) as usize)),
                ("bad_requests", Json::from(s.bad_requests.load(Ordering::Relaxed) as usize)),
                ("probes_total", Json::from(s.probes_total.load(Ordering::Relaxed) as usize)),
                ("latency", lat_json),
            ]),
        ),
        (
            "router",
            obj(vec![
                (
                    "submitted",
                    Json::from(router_stats.submitted.load(Ordering::Relaxed) as usize),
                ),
                (
                    "completed",
                    Json::from(router_stats.completed.load(Ordering::Relaxed) as usize),
                ),
                (
                    "empty_lookups",
                    Json::from(router_stats.empty_lookups.load(Ordering::Relaxed) as usize),
                ),
                (
                    "candidates_scanned",
                    Json::from(router_stats.candidates_scanned.load(Ordering::Relaxed) as usize),
                ),
            ]),
        ),
        (
            "batcher",
            obj(vec![
                ("submitted", Json::from(b.submitted.load(Ordering::Relaxed) as usize)),
                ("rejected", Json::from(b.rejected.load(Ordering::Relaxed) as usize)),
                ("batches", Json::from(b.batches.load(Ordering::Relaxed) as usize)),
                ("flushed", Json::from(b.flushed.load(Ordering::Relaxed) as usize)),
                ("mean_batch", Json::Num(b.mean_batch())),
                ("max_batch", Json::Num(b.max_batch_seen())),
            ]),
        ),
        ("transport", transport_json(state)),
    ];
    match &state.stack {
        Stack::Static(r) => {
            let idx = r.index();
            fields.push((
                "static",
                obj(vec![
                    ("points", Json::from(idx.len())),
                    ("buckets", Json::from(idx.bucket_count())),
                    ("radius", Json::from(idx.radius())),
                    ("probe_volume", Json::from(idx.probe_volume() as usize)),
                    ("memory_bytes", Json::from(idx.memory_bytes())),
                ]),
            ));
        }
        Stack::Online(r) => {
            let idx = r.index();
            let (probes, top) = state.budget_desc.unwrap_or((usize::MAX, usize::MAX));
            fields.push((
                "online",
                obj(vec![
                    ("shards", Json::from(idx.shard_count())),
                    ("live", Json::from(idx.len())),
                    ("radius", Json::from(idx.radius())),
                    (
                        "epochs",
                        Json::Arr(idx.epochs().iter().map(|&e| Json::from(e as usize)).collect()),
                    ),
                    ("memory_bytes", Json::from(idx.memory_bytes())),
                    ("budget_probes", Json::from(probes.min(u32::MAX as usize))),
                    ("budget_top", Json::from(top.min(u32::MAX as usize))),
                ]),
            ));
        }
    }
    if let Some(d) = &state.durable {
        fields.push(("durability", d.stats_json()));
    }
    if let Some((r, primary)) = &state.replica {
        fields.push(("replication", r.stats_json(primary)));
    }
    ok_json(obj(fields))
}

/// Live thread count of this process, from `/proc/self/status` (linux
/// only; other platforms report `null` in `/stats`).
#[cfg(target_os = "linux")]
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

#[cfg(not(target_os = "linux"))]
fn process_threads() -> Option<usize> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;
    use crate::rng::Rng;
    use crate::table::HyperplaneIndex;

    fn static_state() -> Arc<State> {
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(200, 8, 3, &mut rng);
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(8, 6, &mut rng));
        let idx = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 3));
        let feats = Arc::new(ds.features().clone());
        let router = Arc::new(Router::new(fam, idx, feats, 1, 4));
        let stack = Stack::Static(router);
        let telemetry = Arc::new(Telemetry::new(0, None));
        let flush_stack = stack.clone();
        let pool = crate::par::Pool::serial();
        let ftel = telemetry.clone();
        let batcher = Batcher::new(
            BatcherConfig::default(),
            Box::new(move |reqs: &[QueryRequest]| {
                let (hits, stages) = flush_stack.query_batch_traced(reqs, &pool);
                ftel.record_stages(&stages);
                FlushOutcome { hits, stages }
            }),
        );
        let family_check = crate::replicate::family_fingerprint(
            stack.family().as_ref(),
            stack.feats().dim(),
        );
        let state = Arc::new(State {
            stack,
            batcher: Some(batcher),
            telemetry,
            cluster_tel: None,
            auditor: None,
            durable: None,
            replica: None,
            family_check,
            budget_desc: None,
            shutdown: AtomicBool::new(false),
            addr: "127.0.0.1:1".parse().unwrap(),
            max_conns: 4,
            conn_workers: 2,
            conns: Arc::new(ConnCounts::default()),
            shedding_conns: AtomicUsize::new(0),
            idle_timeout: Duration::from_secs(1),
            stats: Arc::new(ServerStats {
                started: Instant::now(),
                http_requests: AtomicU64::new(0),
                bad_requests: AtomicU64::new(0),
                probes_total: AtomicU64::new(0),
                latency: Mutex::new(Histogram::with_capacity(
                    crate::metrics::SERVING_RESERVOIR,
                )),
            }),
        });
        register_metrics(
            &state.telemetry,
            &state.stack,
            &state.stats,
            state.batcher.as_ref().map(|b| b.stats()),
            &state.conns,
            None,
            None,
            state.role(),
        );
        state
    }

    fn post(path: &str, body: &str) -> http::Request {
        http::Request {
            method: "POST".to_string(),
            path: path.to_string(),
            keep_alive: true,
            request_id: None,
            binary: false,
            body: body.as_bytes().to_vec(),
        }
    }

    /// `dispatch` with a throwaway trace (route-level tests).
    fn disp(state: &Arc<State>, req: &http::Request) -> Reply {
        dispatch(state, req, &mut Trace::new(obs::gen_request_id()))
    }

    #[test]
    fn dispatch_routes_and_statuses() {
        let state = static_state();
        let get = |p: &str| http::Request {
            method: "GET".to_string(),
            path: p.to_string(),
            keep_alive: true,
            request_id: None,
            binary: false,
            body: Vec::new(),
        };
        assert_eq!(disp(&state, &get("/healthz")).status, 200);
        assert_eq!(disp(&state, &get("/stats")).status, 200);
        assert_eq!(disp(&state, &get("/metrics")).status, 200);
        assert_eq!(disp(&state, &get("/nope")).status, 404);
        assert_eq!(disp(&state, &get("/query")).status, 405, "GET on a POST route");
        assert_eq!(disp(&state, &post("/query", "junk")).status, 400);
        let wrong_dim = protocol::query_body(&[1.0; 3]);
        assert_eq!(disp(&state, &post("/query", &wrong_dim)).status, 400);
        let good = protocol::query_body(&[0.5; 8]);
        let reply = disp(&state, &post("/query", &good));
        assert_eq!(reply.status, 200);
        assert_eq!(reply.content_type, CT_JSON);
        assert!(protocol::parse_hit(&reply.body).is_ok());
        // static stack refuses mutations
        assert_eq!(disp(&state, &post("/insert", &protocol::id_body(3))).status, 400);
        assert_eq!(disp(&state, &post("/remove", &protocol::id_body(3))).status, 400);
        // replication endpoints exist but need a WAL-backed primary
        assert_eq!(
            disp(&state, &get("/wal/stream?seg=1&off=0")).status,
            400,
            "stream without --wal-dir"
        );
        assert_eq!(disp(&state, &get("/wal/bootstrap")).status, 400);
        assert_eq!(disp(&state, &post("/wal/stream", "")).status, 405);
    }

    #[test]
    fn metrics_exposition_covers_requests_and_stages() {
        let state = static_state();
        let good = protocol::query_body(&[0.5; 8]);
        let mut trace = Trace::new("fixed-id".to_string());
        for _ in 0..4 {
            let t0 = Instant::now();
            let reply = dispatch(&state, &post("/query", &good), &mut trace);
            assert_eq!(reply.status, 200);
            state.telemetry.finish_request(&trace, "/query", reply.status, t0.elapsed());
        }
        let reply = disp(
            &state,
            &http::Request {
                method: "GET".to_string(),
                path: "/metrics".to_string(),
                keep_alive: true,
                request_id: None,
                binary: false,
                body: Vec::new(),
            },
        );
        assert_eq!(reply.status, 200);
        assert_eq!(reply.content_type, METRICS_CONTENT_TYPE);
        let text = String::from_utf8(reply.body).unwrap();
        let scrape = obs::parse_scrape(&text);
        assert_eq!(
            obs::series_value(&scrape, "chh_http_requests_total", r#"route="/query""#),
            Some(4.0)
        );
        // every stage histogram saw the four queries (per-request stages
        // count per request; batch-level ones once per single-item flush)
        for stage in STAGES {
            let label = format!(r#"stage="{stage}""#);
            let n = obs::series_value(&scrape, "chh_stage_seconds_count", &label)
                .unwrap_or_else(|| panic!("missing stage series {stage}"));
            assert_eq!(n, 4.0, "stage {stage} count");
        }
        assert_eq!(obs::series_value(&scrape, "chh_index_points", ""), Some(200.0));
        assert_eq!(
            obs::series_value(&scrape, "chh_batcher_flushed_total", ""),
            Some(4.0)
        );
        // the trace accumulated a stage entry set per request
        assert_eq!(trace.stages().len(), 4 * 6, "six stages per traced query");
        assert!(text.contains("chh_build_info{"), "build info series missing");
    }

    #[test]
    fn stats_body_is_valid_json_with_counters() {
        let state = static_state();
        let good = protocol::query_body(&[0.25; 8]);
        for _ in 0..3 {
            assert_eq!(disp(&state, &post("/query", &good)).status, 200);
        }
        let reply = disp(
            &state,
            &http::Request {
                method: "GET".to_string(),
                path: "/stats".to_string(),
                keep_alive: true,
                request_id: None,
                binary: false,
                body: Vec::new(),
            },
        );
        let v = Json::parse_bytes(&reply.body).unwrap();
        assert_eq!(v.get("mode").unwrap().as_str(), Some("static"));
        assert_eq!(v.get("role").unwrap().as_str(), Some("standalone"));
        assert_eq!(v.get("dim").unwrap().as_usize(), Some(8));
        let batcher = v.get("batcher").unwrap();
        assert_eq!(batcher.get("flushed").unwrap().as_usize(), Some(3));
        let latency = v.get("http").unwrap().get("latency").unwrap();
        assert_eq!(latency.get("count").unwrap().as_usize(), Some(3));
        assert!(v.get("static").unwrap().get("memory_bytes").unwrap().as_usize().unwrap() > 0);
        let transport = v.get("transport").unwrap();
        assert_eq!(transport.get("conn_workers").unwrap().as_usize(), Some(2));
        assert_eq!(transport.get("open_connections").unwrap().as_usize(), Some(0));
        let model = transport.get("model").unwrap().as_str().unwrap();
        assert!(model == "event_loop" || model == "threaded");
    }

    #[test]
    fn binary_dispatch_matches_json_bit_for_bit() {
        let state = static_state();
        let w = [0.5f32, -0.25, 0.125, -0.0, 1.5, -1.0, 0.75, 0.0625];
        let jrep = disp(&state, &post("/query", &protocol::query_body(&w)));
        assert_eq!(jrep.status, 200);
        let jhit = protocol::parse_hit(&jrep.body).unwrap();
        let mut breq = http::Request {
            method: "POST".to_string(),
            path: "/query".to_string(),
            keep_alive: true,
            request_id: None,
            binary: true,
            body: binproto::encode_query(&w, None),
        };
        let brep = disp(&state, &breq);
        assert_eq!(brep.status, 200);
        assert_eq!(brep.content_type, http::CT_CHH_BIN);
        let bhit = binproto::decode_hit(&brep.body).unwrap();
        match (jhit.best, bhit.best) {
            (Some((ji, jm)), Some((bi, bm))) => {
                assert_eq!(ji, bi, "winning id");
                assert_eq!(jm.to_bits(), bm.to_bits(), "margin bits");
            }
            (j, b) => assert_eq!(j.is_none(), b.is_none(), "both empty or both hits"),
        }
        assert_eq!(jhit.scanned, bhit.scanned);
        assert_eq!(jhit.probed, bhit.probed);
        assert_eq!(jhit.nonempty, bhit.nonempty);
        // malformed binary bodies get a clean JSON 400, never a panic
        breq.body = vec![1, 2, 3];
        let bad = disp(&state, &breq);
        assert_eq!(bad.status, 400);
        assert_eq!(bad.content_type, CT_JSON);
        // both wire protocols were attributed on the data routes
        let text = state.telemetry.registry.render();
        let scrape = obs::parse_scrape(&text);
        assert_eq!(
            obs::series_value(&scrape, "chh_requests_by_protocol", r#"proto="json""#),
            Some(1.0)
        );
        assert_eq!(
            obs::series_value(&scrape, "chh_requests_by_protocol", r#"proto="binary""#),
            Some(2.0)
        );
        assert_eq!(obs::series_value(&scrape, "chh_open_connections", ""), Some(0.0));
    }

    #[test]
    fn shutdown_endpoint_sets_the_flag() {
        let state = static_state();
        // state.addr points nowhere routable-free; the poke connects fail
        // silently, which is fine for this unit test
        let reply = disp(&state, &post("/shutdown", ""));
        assert_eq!(reply.status, 200);
        assert!(state.shutdown.load(Ordering::SeqCst));
    }
}
