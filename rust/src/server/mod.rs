//! The network serving subsystem: a std-only HTTP/1.1 front-end that
//! exposes the routers over the wire, with dynamic micro-batching into
//! the data-parallel engine.
//!
//! ```text
//!                    ┌────────────────────── Server ──────────────────────┐
//!  clients ── TCP ──▶│ acceptor → per-connection threads (≤ max_conns)    │
//!                    │   /query ───▶ Batcher ──▶ query_batch_pooled ──┐   │
//!                    │   /query_topk /insert /remove /healthz /stats  │   │
//!                    │◀─ JSON responses ◀─────────── per-query hits ◀─┘   │
//!                    └────────────────────────────────────────────────────┘
//! ```
//!
//! * **Framing** ([`http`]) — hand-rolled HTTP/1.1 with keep-alive and
//!   `Content-Length` bodies; total parsing, hard size limits.
//! * **Protocol** ([`protocol`]) — JSON bodies via [`crate::jsonio`];
//!   float payloads round-trip bit-exactly, so wire responses are
//!   bit-identical to direct router calls.
//! * **Micro-batching** ([`batcher`]) — concurrent `/query` requests
//!   coalesce (flush on `max_batch` or `max_wait`) into one
//!   `query_batch_pooled` call; a bounded admission queue rejects
//!   overload with HTTP 503 instead of queueing unboundedly.
//! * **Serving stacks** — [`Stack::Static`] (prebuilt
//!   [`crate::table::HyperplaneIndex`] behind a
//!   [`crate::coordinator::Router`]) or [`Stack::Online`] (dynamic
//!   [`crate::online::ShardedIndex`] behind an
//!   [`crate::coordinator::OnlineRouter`], with `/insert` + `/remove`).
//!
//! * **Durability** (optional) — [`Server::spawn_with_durability`]
//!   routes `/insert`/`/remove` through a [`crate::wal::DurableIndex`]
//!   (journal → apply → ack once durable), runs a background
//!   snapshotter, reports WAL/snapshot counters on `/stats`, and writes
//!   a final checkpoint on graceful shutdown so a clean stop never
//!   needs replay. See `docs/DURABILITY.md`.
//! * **Replication** — a durable server is automatically a replication
//!   *primary*: `GET /wal/stream` serves fsynced WAL frames and
//!   `GET /wal/bootstrap` serves snapshot windows ([`crate::replicate`]).
//!   [`Server::spawn_replica`] runs the read-only *replica* role: reads
//!   as usual, mutations answered `421` with the primary's address, a
//!   `replication` lag section in `/stats`, and the background tailer
//!   joined on shutdown. See `docs/REPLICATION.md`.
//!
//! `chh serve-http` wires a stack to this server; `chh loadgen` drives
//! it. See `docs/SERVING.md` for the protocol and operational notes.

pub mod batcher;
pub mod http;
pub mod protocol;

pub use batcher::{Batcher, BatcherConfig, BatcherStats, SubmitError};
pub use http::{HttpClient, HttpError};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{OnlineRouter, QueryRequest, Router};
use crate::data::FeatureStore;
use crate::hash::HashFamily;
use crate::jsonio::{obj, Json};
use crate::metrics::Histogram;
use crate::replicate::{ReplicaIndex, Tailer};
use crate::table::QueryHit;
use crate::wal::DurableIndex;

/// Durability wiring for an online stack: mutations journal through
/// `durable` (which must wrap the same [`crate::online::ShardedIndex`]
/// the router serves), and a background snapshotter checkpoints every
/// `snapshot_every_ops` journaled mutations (0 = only on shutdown).
pub struct Durability {
    pub durable: Arc<DurableIndex>,
    pub snapshot_every_ops: u64,
}

/// Replica wiring for an online stack: `replica` must wrap the same
/// [`crate::online::ShardedIndex`] the router serves; `tailer` (if
/// given) is stopped and joined on graceful shutdown.
pub struct ReplicaRole {
    pub replica: Arc<ReplicaIndex>,
    pub primary_addr: String,
    pub tailer: Option<Tailer>,
}

/// Which index the server fronts. Both variants answer `/query` through
/// the micro-batcher; only `Online` accepts `/insert` + `/remove`.
#[derive(Clone)]
pub enum Stack {
    Static(Arc<Router>),
    Online(Arc<OnlineRouter>),
}

impl Stack {
    pub fn mode(&self) -> &'static str {
        match self {
            Stack::Static(_) => "static",
            Stack::Online(_) => "online",
        }
    }

    fn family(&self) -> &Arc<dyn HashFamily> {
        match self {
            Stack::Static(r) => r.family(),
            Stack::Online(r) => r.family(),
        }
    }

    fn feats(&self) -> &Arc<FeatureStore> {
        match self {
            Stack::Static(r) => r.feats(),
            Stack::Online(r) => r.feats(),
        }
    }

    fn query_batch_pooled(&self, reqs: &[QueryRequest], pool: &crate::par::Pool) -> Vec<QueryHit> {
        match self {
            Stack::Static(r) => r.query_batch_pooled(reqs, pool),
            Stack::Online(r) => r.query_batch_pooled(reqs, pool),
        }
    }
}

/// Server configuration (see `docs/SERVING.md` for the knobs).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// listen address; port 0 binds an ephemeral port (tests)
    pub addr: String,
    /// concurrent-connection cap; the acceptor sheds connections beyond
    /// it with an immediate 503 (keep-alive clients hold one each)
    pub max_conns: usize,
    /// micro-batcher policy
    pub batch: BatcherConfig,
    /// worker threads of the flush pool (0 = all cores,
    /// [`crate::par::effective`])
    pub pool_workers: usize,
    /// reap keep-alive connections idle this long
    pub idle_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 256,
            batch: BatcherConfig::default(),
            pool_workers: 0,
            idle_timeout: Duration::from_secs(5),
        }
    }
}

struct ServerStats {
    started: Instant,
    http_requests: AtomicU64,
    bad_requests: AtomicU64,
    /// buckets probed across all answered queries
    probes_total: AtomicU64,
    /// submit→reply wall time of /query requests
    latency: Mutex<Histogram>,
}

struct State {
    stack: Stack,
    batcher: Batcher,
    /// journaling wrapper around the online index, when serving durably
    /// (a durable server doubles as a replication primary)
    durable: Option<Arc<DurableIndex>>,
    /// replica role: the tailed index plus the primary's address
    /// (mutations are answered 421 pointing there)
    replica: Option<(Arc<ReplicaIndex>, String)>,
    /// content fingerprint of the serving hash family, computed once at
    /// spawn (immutable for the server's lifetime; /stats is polled)
    family_check: u32,
    budget_desc: Option<(usize, usize)>,
    shutdown: AtomicBool,
    addr: SocketAddr,
    max_conns: usize,
    active_conns: AtomicUsize,
    /// over-cap connections currently being refused on shed threads
    shedding_conns: AtomicUsize,
    idle_timeout: Duration,
    stats: ServerStats,
}

/// Cap on concurrent courtesy-503 shed threads; past this, over-cap
/// connections are dropped outright so the acceptor keeps draining.
const MAX_SHEDDING: usize = 64;

impl State {
    fn dim(&self) -> usize {
        self.stack.feats().dim()
    }

    /// Serving role for `/healthz` and `/stats`.
    fn role(&self) -> &'static str {
        if self.replica.is_some() {
            "replica"
        } else if self.durable.is_some() {
            "primary"
        } else {
            "standalone"
        }
    }
}

/// Handle to trigger shutdown from another thread (timers, signal shims).
#[derive(Clone)]
pub struct Stopper {
    state: Arc<State>,
}

impl Stopper {
    pub fn trigger(&self) {
        trigger_shutdown(&self.state);
    }
}

fn trigger_shutdown(state: &State) {
    if !state.shutdown.swap(true, Ordering::SeqCst) {
        // one poke unblocks the acceptor; connection threads notice the
        // flag at their next request boundary or idle timeout
        let _ = TcpStream::connect(state.addr);
    }
}

/// A running server; join it with [`Self::wait`] or stop it with
/// [`Self::shutdown`].
pub struct ServerHandle {
    state: Arc<State>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    /// background snapshotter (durable serving only): stop flag + thread
    snapshotter: Option<(Arc<AtomicBool>, std::thread::JoinHandle<()>)>,
    /// background WAL tailer (replica serving only), joined on shutdown
    tailer: Option<Tailer>,
}

impl ServerHandle {
    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// A cloneable trigger usable from other threads.
    pub fn stopper(&self) -> Stopper {
        Stopper { state: self.state.clone() }
    }

    /// Block until the server shuts down (a `POST /shutdown`, or any
    /// [`Stopper`]): joins the acceptor, waits for the connection
    /// threads to drain (bounded by `idle_timeout` + in-flight work),
    /// writes a final WAL checkpoint when serving durably (so a clean
    /// stop replays nothing on restart), then drains the batcher.
    pub fn wait(mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        while self.state.active_conns.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // connection threads are gone ⇒ no more mutations can arrive;
        // stop the snapshotter first so the final checkpoint below is
        // the last word, then flush + checkpoint the WAL
        if let Some((stop, h)) = self.snapshotter.take() {
            stop.store(true, Ordering::SeqCst);
            let _ = h.join();
        }
        // replica role: stop tailing before the server object unwinds so
        // no apply races the final stats readers
        if let Some(t) = self.tailer.take() {
            t.stop();
        }
        if let Some(d) = &self.state.durable {
            match d.checkpoint() {
                Ok(gen) => eprintln!("serve-http: shutdown checkpoint gen {gen}"),
                Err(e) => eprintln!("serve-http: shutdown checkpoint FAILED: {e:#}"),
            }
        }
        // the batcher (owned by `state`) drains and joins when the last
        // Arc drops — force that here if we hold the last one, so
        // callers observe a fully-stopped server
        drop(self.state);
    }

    /// Trigger shutdown and wait for a clean stop.
    pub fn shutdown(self) {
        trigger_shutdown(&self.state);
        self.wait();
    }
}

/// The HTTP front-end.
pub struct Server;

impl Server {
    /// Bind, spawn the batcher + acceptor, return immediately.
    pub fn spawn(stack: Stack, cfg: ServerConfig) -> anyhow::Result<ServerHandle> {
        Self::spawn_with_durability(stack, cfg, None)
    }

    /// [`Self::spawn`] with WAL-backed durability: `/insert`/`/remove`
    /// journal through `durability.durable` before applying, `/stats`
    /// gains a `durability` section, a background snapshotter
    /// checkpoints on the configured cadence, graceful shutdown writes a
    /// final checkpoint — and the server answers the replication
    /// endpoints (`/wal/stream`, `/wal/bootstrap`) as a primary.
    pub fn spawn_with_durability(
        stack: Stack,
        cfg: ServerConfig,
        durability: Option<Durability>,
    ) -> anyhow::Result<ServerHandle> {
        Self::spawn_inner(stack, cfg, durability, None)
    }

    /// Run the read-replica role: reads as usual off `stack`'s index
    /// (which `role.replica` keeps in sync by tailing the primary),
    /// mutations answered `421` with the primary's address, replication
    /// lag in `/stats`, and the tailer joined on graceful shutdown.
    pub fn spawn_replica(
        stack: Stack,
        cfg: ServerConfig,
        role: ReplicaRole,
    ) -> anyhow::Result<ServerHandle> {
        if !matches!(stack, Stack::Online(_)) {
            anyhow::bail!("the replica role requires the online stack");
        }
        Self::spawn_inner(stack, cfg, None, Some(role))
    }

    fn spawn_inner(
        stack: Stack,
        cfg: ServerConfig,
        durability: Option<Durability>,
        replica_role: Option<ReplicaRole>,
    ) -> anyhow::Result<ServerHandle> {
        if durability.is_some() && !matches!(stack, Stack::Online(_)) {
            anyhow::bail!("durability requires the online stack");
        }
        if durability.is_some() && replica_role.is_some() {
            anyhow::bail!("a server is a primary or a replica, not both");
        }
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let flush_stack = stack.clone();
        let pool = crate::par::Pool::new(cfg.pool_workers);
        let batcher = Batcher::new(
            cfg.batch,
            Box::new(move |reqs: &[QueryRequest]| flush_stack.query_batch_pooled(reqs, &pool)),
        );
        let budget_desc = match &stack {
            Stack::Online(r) => {
                let b = r.budget();
                Some((b.probes, b.top))
            }
            Stack::Static(_) => None,
        };
        let (durable, snapshot_every_ops) = match durability {
            Some(d) => (Some(d.durable), d.snapshot_every_ops),
            None => (None, 0),
        };
        let (replica, tailer) = match replica_role {
            Some(r) => (Some((r.replica, r.primary_addr)), r.tailer),
            None => (None, None),
        };
        let family_check = crate::replicate::family_fingerprint(
            stack.family().as_ref(),
            stack.feats().dim(),
        );
        let state = Arc::new(State {
            stack,
            batcher,
            durable,
            replica,
            family_check,
            budget_desc,
            shutdown: AtomicBool::new(false),
            addr,
            max_conns: cfg.max_conns.max(1),
            active_conns: AtomicUsize::new(0),
            shedding_conns: AtomicUsize::new(0),
            idle_timeout: cfg.idle_timeout,
            stats: ServerStats {
                started: Instant::now(),
                http_requests: AtomicU64::new(0),
                bad_requests: AtomicU64::new(0),
                probes_total: AtomicU64::new(0),
                // bounded ring: a long-lived server must not grow memory
                // per request, and /stats sorts this under the same mutex
                // the query path records into
                latency: Mutex::new(Histogram::with_capacity(
                    crate::metrics::SERVING_RESERVOIR,
                )),
            },
        });
        let astate = state.clone();
        let acceptor = std::thread::Builder::new()
            .name("chh-http-accept".to_string())
            .spawn(move || acceptor_loop(&listener, &astate))
            .expect("spawn http acceptor");
        // background snapshotter: checkpoint once enough mutations have
        // been journaled since the last snapshot; polling (rather than
        // waking per op) keeps the mutation path free of extra signaling
        let snapshotter = match (&state.durable, snapshot_every_ops) {
            (Some(d), every) if every > 0 => {
                let stop = Arc::new(AtomicBool::new(false));
                let (sstop, sd) = (stop.clone(), d.clone());
                let h = std::thread::Builder::new()
                    .name("chh-wal-snapshot".to_string())
                    .spawn(move || {
                        while !sstop.load(Ordering::SeqCst) {
                            std::thread::sleep(Duration::from_millis(200));
                            if sstop.load(Ordering::SeqCst) {
                                break;
                            }
                            if sd.ops_since_snapshot() >= every {
                                if let Err(e) = sd.checkpoint() {
                                    eprintln!("snapshotter: checkpoint failed: {e:#}");
                                }
                            }
                        }
                    })
                    .expect("spawn wal snapshotter");
                Some((stop, h))
            }
            _ => None,
        };
        Ok(ServerHandle { state, acceptor: Some(acceptor), snapshotter, tailer })
    }
}

fn acceptor_loop(listener: &TcpListener, state: &Arc<State>) {
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return; // the accept was a shutdown poke
                }
                // connection cap: shed load at the edge with a 503
                // instead of growing an unbounded thread count. The
                // courtesy 503 (write + drain) blocks for up to ~400ms
                // on a misbehaving client, so it runs on a short-lived
                // detached thread — the acceptor itself must never
                // stall, least of all under overload. Past MAX_SHEDDING
                // concurrent sheds, degrade to a plain drop.
                if state.active_conns.load(Ordering::SeqCst) >= state.max_conns {
                    if state.shedding_conns.fetch_add(1, Ordering::SeqCst) < MAX_SHEDDING {
                        let sstate = state.clone();
                        let spawned = std::thread::Builder::new()
                            .name("chh-http-shed".to_string())
                            .spawn(move || {
                                shed_connection(&stream);
                                sstate.shedding_conns.fetch_sub(1, Ordering::SeqCst);
                            });
                        if spawned.is_err() {
                            state.shedding_conns.fetch_sub(1, Ordering::SeqCst);
                        }
                    } else {
                        state.shedding_conns.fetch_sub(1, Ordering::SeqCst);
                        // dropped without ceremony: shed capacity is full
                    }
                    continue;
                }
                state.active_conns.fetch_add(1, Ordering::SeqCst);
                let cstate = state.clone();
                let spawned = std::thread::Builder::new()
                    .name("chh-http-conn".to_string())
                    .spawn(move || {
                        let _guard = ConnGuard(&cstate);
                        handle_conn(&cstate, &stream);
                    });
                if spawned.is_err() {
                    // thread spawn failed (resource exhaustion): undo
                    state.active_conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(_) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // transient accept error (EMFILE, aborted handshake):
                // back off briefly rather than spinning
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Refuse an over-cap connection with a 503 the client can actually
/// read: write the response first, then [`drain_and_close`].
fn shed_connection(stream: &TcpStream) {
    let body = protocol::error_json("overloaded: connection limit reached");
    let mut out = stream;
    if http::write_response(&mut out, 503, body.as_bytes(), false).is_ok() {
        drain_and_close(stream);
    }
}

/// Bounded drain, then close. Dropping a socket with unread request
/// bytes makes the kernel send RST, which can race ahead of a
/// just-written response and surface client-side as a bare transport
/// error instead of the clean status we sent. Pulling the pending bytes
/// out first lets the FIN (and the response) land. Best-effort and
/// bounded — short timeout, few reads — so a misbehaving or very large
/// sender cannot hold the thread; payloads beyond the drain window may
/// still observe a reset.
fn drain_and_close(stream: &TcpStream) {
    use std::io::Read;
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 4096];
    let mut reader = stream;
    for _ in 0..8 {
        match reader.read(&mut sink) {
            Ok(0) | Err(_) => break, // client closed (read our reply) or idle
            Ok(_) => {}
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Decrements the live-connection counter even if a handler panics.
struct ConnGuard<'a>(&'a Arc<State>);

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.active_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

fn handle_conn(state: &Arc<State>, stream: &TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(state.idle_timeout));
    let mut reader = http::MessageReader::new(stream);
    loop {
        match reader.request() {
            Ok(req) => {
                state.stats.http_requests.fetch_add(1, Ordering::Relaxed);
                let reply = dispatch(state, &req);
                let keep = req.keep_alive && !state.shutdown.load(Ordering::SeqCst);
                let mut out = stream;
                if http::write_response(&mut out, reply.status, &reply.body, keep).is_err()
                    || !keep
                {
                    return;
                }
            }
            // clean close / idle reap / transport error: nothing to say
            Err(HttpError::Closed) | Err(HttpError::Timeout) | Err(HttpError::Io(_)) => return,
            Err(e) => {
                // framing is unreliable after a malformed request — answer
                // and close (draining first, so the 4xx isn't destroyed
                // by a reset triggered by unread request bytes)
                state.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                let status = if matches!(e, HttpError::TooLarge(_)) { 413 } else { 400 };
                let body = protocol::error_json(&e.to_string());
                let mut out = stream;
                let _ = http::write_response(&mut out, status, body.as_bytes(), false);
                drain_and_close(stream);
                return;
            }
        }
    }
}

struct Reply {
    status: u16,
    /// JSON on every route except the replication transfers, which are
    /// binary ([`crate::replicate::wire`])
    body: Vec<u8>,
}

fn ok_json(v: Json) -> Reply {
    Reply { status: 200, body: v.to_string_compact().into_bytes() }
}

fn err_json(status: u16, msg: &str) -> Reply {
    Reply { status, body: protocol::error_json(msg).into_bytes() }
}

const ROUTES: &[&str] = &[
    "/healthz",
    "/stats",
    "/query",
    "/query_topk",
    "/insert",
    "/remove",
    "/shutdown",
    "/wal/stream",
    "/wal/bootstrap",
];

fn dispatch(state: &Arc<State>, req: &http::Request) -> Reply {
    // the replication endpoints carry `?seg=...`-style parameters; every
    // other route ignores its query string
    let (route, query) = match req.path.split_once('?') {
        Some((r, q)) => (r, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), route) {
        ("GET", "/healthz") => handle_healthz(state),
        ("GET", "/stats") => handle_stats(state),
        ("POST", "/query") => handle_query(state, &req.body),
        ("POST", "/query_topk") => handle_topk(state, &req.body),
        ("POST", "/insert") => handle_insert(state, &req.body),
        ("POST", "/remove") => handle_remove(state, &req.body),
        ("GET", "/wal/stream") => handle_wal_stream(state, query),
        ("GET", "/wal/bootstrap") => handle_wal_bootstrap(state, query),
        ("POST", "/shutdown") => {
            trigger_shutdown(state);
            ok_json(obj(vec![("shutting_down", Json::from(true))]))
        }
        (_, path) if ROUTES.contains(&path) => {
            err_json(405, &format!("wrong method for {path}"))
        }
        (_, path) => err_json(404, &format!("no route {path}")),
    }
}

fn handle_healthz(state: &Arc<State>) -> Reply {
    ok_json(obj(vec![
        ("status", Json::from("ok")),
        ("mode", Json::from(state.stack.mode())),
        ("role", Json::from(state.role())),
        ("uptime_secs", Json::Num(state.stats.started.elapsed().as_secs_f64())),
    ]))
}

/// Serve fsynced WAL frames to a tailing replica (primaries only).
fn handle_wal_stream(state: &Arc<State>, query: &str) -> Reply {
    let Some(d) = &state.durable else {
        return err_json(400, "not a replication primary (serve with --wal-dir)");
    };
    match crate::replicate::primary::handle_stream(d, query) {
        Ok(chunk) => {
            Reply { status: 200, body: crate::replicate::wire::encode_stream_chunk(&chunk) }
        }
        Err(e) => err_json(e.status, &e.msg),
    }
}

/// Serve a snapshot window for replica bootstrap (primaries only).
fn handle_wal_bootstrap(state: &Arc<State>, query: &str) -> Reply {
    let Some(d) = &state.durable else {
        return err_json(400, "not a replication primary (serve with --wal-dir)");
    };
    match crate::replicate::primary::handle_bootstrap(d, query) {
        Ok(chunk) => Reply {
            status: 200,
            body: crate::replicate::wire::encode_bootstrap_chunk(&chunk),
        },
        Err(e) => err_json(e.status, &e.msg),
    }
}

fn handle_query(state: &Arc<State>, body: &[u8]) -> Reply {
    let req = match protocol::parse_query(body, state.dim()) {
        Ok(r) => r,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let t0 = Instant::now();
    match state.batcher.submit(req) {
        Ok(rx) => match rx.recv() {
            Ok(hit) => {
                state.stats.latency.lock().unwrap().record_duration(t0.elapsed());
                state.stats.probes_total.fetch_add(hit.probed as u64, Ordering::Relaxed);
                ok_json(protocol::hit_json(&hit))
            }
            Err(_) => err_json(500, "batcher dropped the query"),
        },
        Err(SubmitError::Overloaded) => err_json(503, "overloaded: admission queue full"),
        Err(SubmitError::ShuttingDown) => err_json(503, "shutting down"),
    }
}

fn handle_topk(state: &Arc<State>, body: &[u8]) -> Reply {
    let (req, t) = match protocol::parse_topk(body, state.dim()) {
        Ok(r) => r,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let eligible = |i: usize| req.exclude.as_ref().map_or(true, |ex| !ex.contains(&i));
    let hits = match &state.stack {
        Stack::Static(r) => {
            r.index().query_topk(r.family().as_ref(), &req.w, r.feats(), t, eligible)
        }
        Stack::Online(r) => r.index().query_topk(
            r.family().as_ref(),
            &req.w,
            r.feats(),
            t,
            r.budget(),
            eligible,
        ),
    };
    ok_json(protocol::topk_json(&hits))
}

/// The 421 a read replica answers mutations with: the op belongs on the
/// primary, whose address rides along in the body.
fn replica_redirect(primary: &str) -> Reply {
    Reply {
        status: 421,
        body: protocol::redirect_json(
            "read-only replica; send mutations to the primary",
            primary,
        )
        .into_bytes(),
    }
}

fn handle_insert(state: &Arc<State>, body: &[u8]) -> Reply {
    if let Some((_, primary)) = &state.replica {
        return replica_redirect(primary);
    }
    let id = match protocol::parse_id(body) {
        Ok(id) => id,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let Stack::Online(r) = &state.stack else {
        return err_json(400, "static index is immutable; serve with --mode online");
    };
    let n = r.feats().len();
    if id as usize >= n {
        return err_json(
            400,
            &format!("id {id} outside the serving feature store (n={n})"),
        );
    }
    if let Some(d) = &state.durable {
        // journal → apply → ack; a 200 means the op is durable per the
        // fsync policy
        if let Err(e) = d.insert_point(r.family().as_ref(), id, r.feats().row(id as usize))
        {
            return err_json(500, &format!("durable insert failed: {e:#}"));
        }
    } else {
        r.index().insert_point(r.family().as_ref(), id, r.feats().row(id as usize));
    }
    ok_json(obj(vec![
        ("inserted", Json::from(true)),
        ("id", Json::from(id as usize)),
        ("live", Json::from(r.index().len())),
    ]))
}

fn handle_remove(state: &Arc<State>, body: &[u8]) -> Reply {
    if let Some((_, primary)) = &state.replica {
        return replica_redirect(primary);
    }
    let id = match protocol::parse_id(body) {
        Ok(id) => id,
        Err(e) => return err_json(e.status, &e.msg),
    };
    let Stack::Online(r) = &state.stack else {
        return err_json(400, "static index is immutable; serve with --mode online");
    };
    let removed = if let Some(d) = &state.durable {
        match d.remove(id) {
            Ok(removed) => removed,
            Err(e) => return err_json(500, &format!("durable remove failed: {e:#}")),
        }
    } else {
        r.index().remove(id)
    };
    ok_json(obj(vec![
        ("removed", Json::from(removed)),
        ("id", Json::from(id as usize)),
        ("live", Json::from(r.index().len())),
    ]))
}

fn handle_stats(state: &Arc<State>) -> Reply {
    let s = &state.stats;
    let router_stats = match &state.stack {
        Stack::Static(r) => r.stats(),
        Stack::Online(r) => r.stats(),
    };
    let b = state.batcher.stats();
    // one sort under the lock the query path records into
    let (pcts, lat_mean, lat_count) = {
        let lat = s.latency.lock().unwrap();
        (lat.percentiles(&[50.0, 95.0, 99.0]), lat.mean(), lat.len())
    };
    let lat_json = obj(vec![
        ("p50_us", Json::Num(pcts[0] * 1e6)),
        ("p95_us", Json::Num(pcts[1] * 1e6)),
        ("p99_us", Json::Num(pcts[2] * 1e6)),
        ("mean_us", Json::Num(lat_mean * 1e6)),
        ("count", Json::from(lat_count)),
    ]);
    let mut fields = vec![
        ("mode", Json::from(state.stack.mode())),
        ("role", Json::from(state.role())),
        ("dim", Json::from(state.dim())),
        // feature-store size: the valid id range for /insert (loadgen
        // uses this to drive mutations)
        ("points", Json::from(state.stack.feats().len())),
        ("bits", Json::from(state.stack.family().bits())),
        ("family", Json::from(state.stack.family().name())),
        // content fingerprint: lets a replica verify it sampled the same
        // hyperplanes (name+bits alone cannot catch a --seed mismatch)
        ("family_check", Json::from(state.family_check as usize)),
        ("uptime_secs", Json::Num(s.started.elapsed().as_secs_f64())),
        (
            "http",
            obj(vec![
                ("requests", Json::from(s.http_requests.load(Ordering::Relaxed) as usize)),
                ("bad_requests", Json::from(s.bad_requests.load(Ordering::Relaxed) as usize)),
                ("probes_total", Json::from(s.probes_total.load(Ordering::Relaxed) as usize)),
                ("latency", lat_json),
            ]),
        ),
        (
            "router",
            obj(vec![
                (
                    "submitted",
                    Json::from(router_stats.submitted.load(Ordering::Relaxed) as usize),
                ),
                (
                    "completed",
                    Json::from(router_stats.completed.load(Ordering::Relaxed) as usize),
                ),
                (
                    "empty_lookups",
                    Json::from(router_stats.empty_lookups.load(Ordering::Relaxed) as usize),
                ),
                (
                    "candidates_scanned",
                    Json::from(router_stats.candidates_scanned.load(Ordering::Relaxed) as usize),
                ),
            ]),
        ),
        (
            "batcher",
            obj(vec![
                ("submitted", Json::from(b.submitted.load(Ordering::Relaxed) as usize)),
                ("rejected", Json::from(b.rejected.load(Ordering::Relaxed) as usize)),
                ("batches", Json::from(b.batches.load(Ordering::Relaxed) as usize)),
                ("flushed", Json::from(b.flushed.load(Ordering::Relaxed) as usize)),
                ("mean_batch", Json::Num(b.mean_batch())),
                ("max_batch", Json::Num(b.max_batch_seen())),
            ]),
        ),
    ];
    match &state.stack {
        Stack::Static(r) => {
            let idx = r.index();
            fields.push((
                "static",
                obj(vec![
                    ("points", Json::from(idx.len())),
                    ("buckets", Json::from(idx.bucket_count())),
                    ("radius", Json::from(idx.radius())),
                    ("probe_volume", Json::from(idx.probe_volume() as usize)),
                    ("memory_bytes", Json::from(idx.memory_bytes())),
                ]),
            ));
        }
        Stack::Online(r) => {
            let idx = r.index();
            let (probes, top) = state.budget_desc.unwrap_or((usize::MAX, usize::MAX));
            fields.push((
                "online",
                obj(vec![
                    ("shards", Json::from(idx.shard_count())),
                    ("live", Json::from(idx.len())),
                    ("radius", Json::from(idx.radius())),
                    (
                        "epochs",
                        Json::Arr(idx.epochs().iter().map(|&e| Json::from(e as usize)).collect()),
                    ),
                    ("memory_bytes", Json::from(idx.memory_bytes())),
                    ("budget_probes", Json::from(probes.min(u32::MAX as usize))),
                    ("budget_top", Json::from(top.min(u32::MAX as usize))),
                ]),
            ));
        }
    }
    if let Some(d) = &state.durable {
        fields.push(("durability", d.stats_json()));
    }
    if let Some((r, primary)) = &state.replica {
        fields.push(("replication", r.stats_json(primary)));
    }
    ok_json(obj(fields))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;
    use crate::rng::Rng;
    use crate::table::HyperplaneIndex;

    fn static_state() -> Arc<State> {
        let mut rng = Rng::seed_from_u64(3);
        let ds = test_blobs(200, 8, 3, &mut rng);
        let fam: Arc<dyn HashFamily> = Arc::new(BhHash::sample(8, 6, &mut rng));
        let idx = Arc::new(HyperplaneIndex::build(fam.as_ref(), ds.features(), 3));
        let feats = Arc::new(ds.features().clone());
        let router = Arc::new(Router::new(fam, idx, feats, 1, 4));
        let stack = Stack::Static(router);
        let flush_stack = stack.clone();
        let pool = crate::par::Pool::serial();
        let batcher = Batcher::new(
            BatcherConfig::default(),
            Box::new(move |reqs: &[QueryRequest]| flush_stack.query_batch_pooled(reqs, &pool)),
        );
        let family_check = crate::replicate::family_fingerprint(
            stack.family().as_ref(),
            stack.feats().dim(),
        );
        Arc::new(State {
            stack,
            batcher,
            durable: None,
            replica: None,
            family_check,
            budget_desc: None,
            shutdown: AtomicBool::new(false),
            addr: "127.0.0.1:1".parse().unwrap(),
            max_conns: 4,
            active_conns: AtomicUsize::new(0),
            shedding_conns: AtomicUsize::new(0),
            idle_timeout: Duration::from_secs(1),
            stats: ServerStats {
                started: Instant::now(),
                http_requests: AtomicU64::new(0),
                bad_requests: AtomicU64::new(0),
                probes_total: AtomicU64::new(0),
                latency: Mutex::new(Histogram::with_capacity(
                    crate::metrics::SERVING_RESERVOIR,
                )),
            },
        })
    }

    fn post(path: &str, body: &str) -> http::Request {
        http::Request {
            method: "POST".to_string(),
            path: path.to_string(),
            keep_alive: true,
            body: body.as_bytes().to_vec(),
        }
    }

    #[test]
    fn dispatch_routes_and_statuses() {
        let state = static_state();
        let get = |p: &str| http::Request {
            method: "GET".to_string(),
            path: p.to_string(),
            keep_alive: true,
            body: Vec::new(),
        };
        assert_eq!(dispatch(&state, &get("/healthz")).status, 200);
        assert_eq!(dispatch(&state, &get("/stats")).status, 200);
        assert_eq!(dispatch(&state, &get("/nope")).status, 404);
        assert_eq!(dispatch(&state, &get("/query")).status, 405, "GET on a POST route");
        assert_eq!(dispatch(&state, &post("/query", "junk")).status, 400);
        let wrong_dim = protocol::query_body(&[1.0; 3]);
        assert_eq!(dispatch(&state, &post("/query", &wrong_dim)).status, 400);
        let good = protocol::query_body(&[0.5; 8]);
        let reply = dispatch(&state, &post("/query", &good));
        assert_eq!(reply.status, 200);
        assert!(protocol::parse_hit(&reply.body).is_ok());
        // static stack refuses mutations
        assert_eq!(dispatch(&state, &post("/insert", &protocol::id_body(3))).status, 400);
        assert_eq!(dispatch(&state, &post("/remove", &protocol::id_body(3))).status, 400);
        // replication endpoints exist but need a WAL-backed primary
        assert_eq!(
            dispatch(&state, &get("/wal/stream?seg=1&off=0")).status,
            400,
            "stream without --wal-dir"
        );
        assert_eq!(dispatch(&state, &get("/wal/bootstrap")).status, 400);
        assert_eq!(dispatch(&state, &post("/wal/stream", "")).status, 405);
    }

    #[test]
    fn stats_body_is_valid_json_with_counters() {
        let state = static_state();
        let good = protocol::query_body(&[0.25; 8]);
        for _ in 0..3 {
            assert_eq!(dispatch(&state, &post("/query", &good)).status, 200);
        }
        let reply = dispatch(
            &state,
            &http::Request {
                method: "GET".to_string(),
                path: "/stats".to_string(),
                keep_alive: true,
                body: Vec::new(),
            },
        );
        let v = Json::parse_bytes(&reply.body).unwrap();
        assert_eq!(v.get("mode").unwrap().as_str(), Some("static"));
        assert_eq!(v.get("role").unwrap().as_str(), Some("standalone"));
        assert_eq!(v.get("dim").unwrap().as_usize(), Some(8));
        let batcher = v.get("batcher").unwrap();
        assert_eq!(batcher.get("flushed").unwrap().as_usize(), Some(3));
        let latency = v.get("http").unwrap().get("latency").unwrap();
        assert_eq!(latency.get("count").unwrap().as_usize(), Some(3));
        assert!(v.get("static").unwrap().get("memory_bytes").unwrap().as_usize().unwrap() > 0);
    }

    #[test]
    fn shutdown_endpoint_sets_the_flag() {
        let state = static_state();
        // state.addr points nowhere routable-free; the poke connects fail
        // silently, which is fine for this unit test
        let reply = dispatch(&state, &post("/shutdown", ""));
        assert_eq!(reply.status, 200);
        assert!(state.shutdown.load(Ordering::SeqCst));
    }
}
