//! Readiness-polled connection transport: one loop thread multiplexes
//! every client socket through `poll(2)`, and a bounded worker pool runs
//! the request handlers.
//!
//! The thread-per-connection acceptor (still compiled on non-unix
//! targets, see `server/mod.rs`) costs one OS thread per keep-alive
//! client; 10k idle connections would cost 10k stacks. Here idle
//! connections cost one slab slot each and zero threads: the loop owns
//! the nonblocking listener plus every connection, parses requests with
//! the resumable [`FrameParser`], and hands complete requests to
//! `conn_workers` worker threads. Workers never touch sockets — they
//! return serialized response bytes through a channel and wake the loop
//! via a self-pipe. Total thread count is O(workers), not
//! O(connections).
//!
//! Everything above the transport seam is byte-identical to the threaded
//! path: both call `process_request`, so routing, batching, tracing and
//! the 503/4xx shed paths behave the same.
//!
//! The shim calls `poll(2)` directly through a two-line FFI declaration —
//! std exposes no readiness API and the registry has no mio/libc, but
//! `poll` is POSIX and its ABI is stable.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::http::{FrameParser, HttpError, Request};
use super::{State, MAX_SHEDDING};

// ─────────────────────── poll(2) FFI shim ───────────────────────

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;

#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = u32;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
}

/// `poll(2)` with EINTR retry; any other failure is returned (the loop
/// treats it as a transient and continues after a short sleep).
fn poll_wait(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = std::io::Error::last_os_error();
        if err.kind() != ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

// ─────────────────────── connection state ───────────────────────

/// How long a connection that answered a framing error (or a shed 503)
/// lingers after flushing, discarding input, so the response's FIN isn't
/// destroyed by a reset triggered by unread request bytes — the
/// event-loop analogue of the threaded path's `drain_and_close`.
const LINGER: Duration = Duration::from_millis(50);
/// Hard deadline for flushing in-flight work after shutdown triggers.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);
/// Poll timeout: bounds idle-sweep latency and shutdown-notice latency.
const POLL_MS: i32 = 100;
/// Reads per readable event before yielding back to the loop (level-
/// triggered poll re-signals), so one blasting client can't starve the
/// rest.
const MAX_READS_PER_EVENT: usize = 16;

struct Conn {
    stream: TcpStream,
    parser: FrameParser,
    out: Vec<u8>,
    out_pos: usize,
    last_activity: Instant,
    /// one request is at the workers; POLLIN is not armed meanwhile, so
    /// the kernel backpressures pipelining clients and ordering holds
    in_flight: bool,
    close_after_flush: bool,
    /// framing broke (or the conn was shed): read and discard input,
    /// never parse it
    discard_input: bool,
    peer_eof: bool,
    linger_until: Option<Instant>,
    /// an over-cap courtesy-503 connection: counted in `shedding_conns`,
    /// not `conns.open`
    shed: bool,
}

impl Conn {
    fn new(stream: TcpStream, shed: bool) -> Self {
        Conn {
            stream,
            parser: FrameParser::new(),
            out: Vec::new(),
            out_pos: 0,
            last_activity: Instant::now(),
            in_flight: false,
            close_after_flush: shed,
            discard_input: shed,
            peer_eof: false,
            linger_until: None,
            shed,
        }
    }

    fn flushed(&self) -> bool {
        self.out_pos >= self.out.len()
    }
}

/// Slab slot: `gen` increments on close so a completion for a previous
/// occupant of the token is recognized as stale and dropped.
struct Slot {
    gen: u64,
    conn: Option<Conn>,
}

struct Job {
    token: usize,
    gen: u64,
    req: Request,
}

struct Done {
    token: usize,
    gen: u64,
    bytes: Vec<u8>,
    keep_alive: bool,
}

fn worker(
    state: Arc<State>,
    jobs: Arc<Mutex<Receiver<Job>>>,
    done_tx: Sender<Done>,
    wake_tx: UnixStream,
) {
    loop {
        // the lock is scoped to the recv: exactly one worker parks in
        // recv; the rest park on the mutex
        let job = { jobs.lock().unwrap().recv() };
        let Ok(job) = job else { return };
        let (bytes, keep_alive) = super::process_request(&state, &job.req);
        if done_tx.send(Done { token: job.token, gen: job.gen, bytes, keep_alive }).is_err() {
            return;
        }
        // nonblocking self-pipe: a full pipe means the loop is already
        // due to wake, EPIPE means it is gone — both ignorable
        let _ = (&wake_tx).write(&[1u8]);
    }
}

// ─────────────────────── the loop ───────────────────────

/// Run the transport until shutdown: owns the listener, every client
/// socket, and the worker pool. Called on the `chh-http-loop` thread;
/// when it returns, all connections are closed and all workers joined.
pub(crate) fn run(listener: TcpListener, state: &Arc<State>) {
    if let Err(e) = listener.set_nonblocking(true) {
        eprintln!("event-loop: set_nonblocking failed: {e}; serving aborted");
        return;
    }
    let (wake_rx, wake_tx) = match UnixStream::pair() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("event-loop: wake pipe failed: {e}; serving aborted");
            return;
        }
    };
    let _ = wake_rx.set_nonblocking(true);
    let _ = wake_tx.set_nonblocking(true);

    let workers_n = state.conn_workers.max(1);
    let (job_tx, job_rx) = sync_channel::<Job>(workers_n * 8 + 16);
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (done_tx, done_rx) = channel::<Done>();
    let mut workers = Vec::with_capacity(workers_n);
    for i in 0..workers_n {
        let (st, jr, dt) = (state.clone(), job_rx.clone(), done_tx.clone());
        let wk = match wake_tx.try_clone() {
            Ok(w) => w,
            Err(e) => {
                eprintln!("event-loop: wake pipe clone failed: {e}; serving aborted");
                return;
            }
        };
        let h = std::thread::Builder::new()
            .name(format!("chh-http-worker-{i}"))
            .spawn(move || worker(st, jr, dt, wk))
            .expect("spawn http worker");
        workers.push(h);
    }
    drop(done_tx);

    let mut slots: Vec<Slot> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut fds: Vec<PollFd> = Vec::new();
    // pollfd index → slab token; the listener and wake pipe use sentinels
    let mut meta: Vec<usize> = Vec::new();
    const T_LISTENER: usize = usize::MAX;
    const T_WAKE: usize = usize::MAX - 1;

    let mut draining = false;
    let mut drain_deadline = Instant::now();

    loop {
        let now = Instant::now();
        if !draining && state.shutdown.load(Ordering::SeqCst) {
            draining = true;
            drain_deadline = now + DRAIN_DEADLINE;
            for token in 0..slots.len() {
                let gone = match slots[token].conn.as_mut() {
                    Some(c) if !c.in_flight && c.flushed() && !c.parser.has_buffered_input() => {
                        true // idle: close outright
                    }
                    Some(c) => {
                        // finish the current request, then close; any
                        // pipelined backlog is dropped
                        c.close_after_flush = true;
                        c.discard_input = true;
                        false
                    }
                    None => false,
                };
                if gone {
                    close_slot(state, &mut slots, &mut free, token);
                }
            }
        }
        if draining {
            let live = slots.iter().filter(|s| s.conn.is_some()).count();
            if live == 0 || now >= drain_deadline {
                break;
            }
        }

        // completions from the workers
        while let Ok(done) = done_rx.try_recv() {
            apply_completion(state, &job_tx, &mut slots, &mut free, done);
        }

        // rebuild the interest set (level-triggered: cheap and race-free)
        fds.clear();
        meta.clear();
        if !draining {
            fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
            meta.push(T_LISTENER);
        }
        fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        meta.push(T_WAKE);
        for (token, slot) in slots.iter().enumerate() {
            let Some(c) = &slot.conn else { continue };
            let mut ev = 0i16;
            if !c.in_flight {
                ev |= POLLIN;
            }
            if !c.flushed() {
                ev |= POLLOUT;
            }
            if ev != 0 {
                fds.push(PollFd { fd: c.stream.as_raw_fd(), events: ev, revents: 0 });
                meta.push(token);
            }
        }

        match poll_wait(&mut fds, if draining { 25 } else { POLL_MS }) {
            Ok(_) => {}
            Err(e) => {
                eprintln!("event-loop: poll failed: {e}; backing off");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        }

        for i in 0..fds.len() {
            if fds[i].revents == 0 {
                continue;
            }
            match meta[i] {
                T_LISTENER => accept_ready(&listener, state, &job_tx, &mut slots, &mut free),
                T_WAKE => drain_wake(&wake_rx),
                token => {
                    let gen = slots[token].gen;
                    let mut dead = false;
                    if let Some(conn) = slots[token].conn.as_mut() {
                        if fds[i].revents & POLLIN != 0 {
                            dead = !fill_from_socket(conn);
                        }
                        if !dead {
                            dead = !service(state, &job_tx, conn, token, gen);
                        }
                    }
                    if dead {
                        close_slot(state, &mut slots, &mut free, token);
                    }
                }
            }
        }

        // idle / linger sweep
        let now = Instant::now();
        for token in 0..slots.len() {
            let reap = match slots[token].conn.as_ref() {
                Some(c) => {
                    let lingered = c.linger_until.is_some_and(|t| now >= t);
                    let idle = !c.in_flight
                        && now.duration_since(c.last_activity) > state.idle_timeout;
                    lingered || idle
                }
                None => false,
            };
            if reap {
                close_slot(state, &mut slots, &mut free, token);
            }
        }
    }

    // teardown: sockets first, then the workers (dropping the job sender
    // breaks their recv loop; in-flight handlers finish first)
    for token in 0..slots.len() {
        close_slot(state, &mut slots, &mut free, token);
    }
    drop(job_tx);
    for h in workers {
        let _ = h.join();
    }
}

fn drain_wake(wake_rx: &UnixStream) {
    let mut buf = [0u8; 256];
    let mut r = wake_rx;
    while matches!(r.read(&mut buf), Ok(n) if n > 0) {}
}

fn accept_ready(
    listener: &TcpListener,
    state: &Arc<State>,
    job_tx: &SyncSender<Job>,
    slots: &mut Vec<Slot>,
    free: &mut Vec<usize>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if state.shutdown.load(Ordering::SeqCst) {
                    // a shutdown poke, or a client racing it
                    continue;
                }
                state.conns.accepted.fetch_add(1, Ordering::Relaxed);
                let _ = stream.set_nonblocking(true);
                let _ = stream.set_nodelay(true);
                if state.conns.open.load(Ordering::SeqCst) >= state.max_conns {
                    // over cap: courtesy 503 if shed slots allow, else a
                    // plain drop so the loop keeps draining the backlog
                    if state.shedding_conns.load(Ordering::SeqCst) < MAX_SHEDDING {
                        state.shedding_conns.fetch_add(1, Ordering::SeqCst);
                        let mut c = Conn::new(stream, true);
                        c.out = super::overload_response_bytes();
                        let token = alloc_slot(slots, free, c);
                        // optimistic flush; most clients get the 503 here
                        let gen = slots[token].gen;
                        let conn = slots[token].conn.as_mut().expect("just allocated");
                        if !service(state, job_tx, conn, token, gen) {
                            close_slot(state, slots, free, token);
                        }
                    }
                    continue;
                }
                state.conns.open.fetch_add(1, Ordering::SeqCst);
                alloc_slot(slots, free, Conn::new(stream, false));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(_) => return, // transient (EMFILE, aborted handshake)
        }
    }
}

fn alloc_slot(slots: &mut Vec<Slot>, free: &mut Vec<usize>, conn: Conn) -> usize {
    match free.pop() {
        Some(t) => {
            slots[t].conn = Some(conn);
            t
        }
        None => {
            slots.push(Slot { gen: 0, conn: Some(conn) });
            slots.len() - 1
        }
    }
}

fn close_slot(state: &Arc<State>, slots: &mut [Slot], free: &mut Vec<usize>, token: usize) {
    let slot = &mut slots[token];
    if let Some(conn) = slot.conn.take() {
        let _ = conn.stream.shutdown(Shutdown::Both);
        if conn.shed {
            state.shedding_conns.fetch_sub(1, Ordering::SeqCst);
        } else {
            state.conns.open.fetch_sub(1, Ordering::SeqCst);
        }
        slot.gen += 1;
        free.push(token);
    }
}

fn apply_completion(
    state: &Arc<State>,
    job_tx: &SyncSender<Job>,
    slots: &mut Vec<Slot>,
    free: &mut Vec<usize>,
    done: Done,
) {
    if done.token >= slots.len() || slots[done.token].gen != done.gen {
        return; // stale: the connection was closed and the slot reused
    }
    let gen = slots[done.token].gen;
    let token = done.token;
    let mut dead = false;
    if let Some(conn) = slots[token].conn.as_mut() {
        conn.in_flight = false;
        conn.last_activity = Instant::now();
        append_out(conn, &done.bytes);
        if !done.keep_alive {
            conn.close_after_flush = true;
            conn.discard_input = true;
        }
        dead = !service(state, job_tx, conn, token, gen);
    }
    if dead {
        close_slot(state, slots, free, token);
    }
}

fn append_out(conn: &mut Conn, bytes: &[u8]) {
    if conn.flushed() {
        conn.out.clear();
        conn.out_pos = 0;
    }
    conn.out.extend_from_slice(bytes);
}

/// Read whatever the socket has (bounded per event). `false` = hard
/// transport error, close the connection.
fn fill_from_socket(conn: &mut Conn) -> bool {
    let mut buf = [0u8; 16 * 1024];
    let mut reads = 0;
    loop {
        match (&conn.stream).read(&mut buf) {
            Ok(0) => {
                conn.peer_eof = true;
                conn.parser.feed_eof();
                return true;
            }
            Ok(n) => {
                conn.last_activity = Instant::now();
                if !conn.discard_input {
                    conn.parser.feed(&buf[..n]);
                }
                reads += 1;
                if n < buf.len() || reads >= MAX_READS_PER_EVENT {
                    return true;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Parse-and-dispatch, flush, then close-state bookkeeping. `false` =
/// the connection is finished and must be closed by the caller.
fn service(
    state: &Arc<State>,
    job_tx: &SyncSender<Job>,
    conn: &mut Conn,
    token: usize,
    gen: u64,
) -> bool {
    pump(state, job_tx, conn, token, gen);
    if !flush_out(conn) {
        return false;
    }
    if conn.flushed() && conn.close_after_flush && !conn.in_flight {
        if conn.discard_input && !conn.peer_eof {
            // linger briefly, discarding input, so the just-written
            // response survives any unread request bytes (see LINGER)
            if conn.linger_until.is_none() {
                conn.linger_until = Some(Instant::now() + LINGER);
            }
        } else {
            return false;
        }
    }
    true
}

/// Feed complete requests to the workers until the parser runs dry, the
/// connection enters a closing state, or a request is put in flight.
fn pump(state: &Arc<State>, job_tx: &SyncSender<Job>, conn: &mut Conn, token: usize, gen: u64) {
    while !conn.in_flight && !conn.close_after_flush && !conn.discard_input {
        match conn.parser.next_request() {
            Ok(Some(req)) => match job_tx.try_send(Job { token, gen, req }) {
                Ok(()) => conn.in_flight = true,
                Err(TrySendError::Full(job)) => {
                    // the worker queue is saturated: answer 503 from the
                    // loop itself — overload must not be able to wedge
                    // the transport
                    let keep = job.req.keep_alive && !state.shutdown.load(Ordering::SeqCst);
                    append_out(conn, &super::busy_response_bytes(keep));
                    if !keep {
                        conn.close_after_flush = true;
                        conn.discard_input = true;
                    }
                }
                Err(TrySendError::Disconnected(_)) => {
                    conn.close_after_flush = true;
                    conn.discard_input = true;
                }
            },
            Ok(None) => break,
            Err(HttpError::Closed) => {
                // clean EOF between requests: flush anything pending,
                // then close
                conn.close_after_flush = true;
                conn.discard_input = true;
            }
            Err(e) => {
                // framing is unreliable after a malformed request —
                // answer 400/413 and close, mirroring the threaded path
                append_out(conn, &super::bad_request_bytes(state, &e));
                conn.close_after_flush = true;
                conn.discard_input = true;
            }
        }
    }
}

/// Write as much buffered output as the socket accepts. `false` = hard
/// transport error.
fn flush_out(conn: &mut Conn) -> bool {
    while !conn.flushed() {
        match (&conn.stream).write(&conn.out[conn.out_pos..]) {
            Ok(0) => return false,
            Ok(n) => {
                conn.out_pos += n;
                conn.last_activity = Instant::now();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}
