//! Minimal HTTP/1.1 framing over `std::io` streams.
//!
//! The vendored registry has no hyper/tokio, so the serving front-end
//! frames requests by hand: request line + headers + `Content-Length`
//! body (no chunked encoding — every client we ship sends sized bodies).
//! Framing is factored into a *resumable* incremental parser,
//! [`FrameParser`]: feed it whatever bytes the transport delivered and it
//! yields complete messages (or `Ok(None)` for "need more"). The same
//! parser serves both sides of the wire — the blocking
//! [`MessageReader`] + [`HttpClient`] used by `chh loadgen`, the replica
//! tailer and the integration tests, and the nonblocking event loop in
//! [`crate::server::event_loop`], which cannot afford a parser that
//! blocks mid-message.
//!
//! Requests and responses carry a `binary` flag: a body tagged
//! `Content-Type: application/x-chh-binary` ([`CT_CHH_BIN`]) selects the
//! binary wire protocol ([`crate::server::binproto`]) on the data routes.
//!
//! All limits are hard errors, not truncations: oversized heads/bodies,
//! malformed request lines and non-numeric lengths each map to a
//! [`HttpError`] the connection loop turns into a `400`/`413` response
//! (or a clean close). Parsing never panics on adversarial input.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Cap on request/status line + headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on a request or response body.
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// Content type selecting the binary wire protocol on the data routes.
pub const CT_CHH_BIN: &str = "application/x-chh-binary";

#[derive(Debug, thiserror::Error)]
pub enum HttpError {
    /// Peer closed the connection before sending any bytes (normal end
    /// of a keep-alive session).
    #[error("connection closed")]
    Closed,
    /// Read timed out (idle keep-alive connection reaped).
    #[error("read timed out")]
    Timeout,
    #[error("message too large: {0}")]
    TooLarge(&'static str),
    #[error("malformed http: {0}")]
    Malformed(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

fn io_err(e: std::io::Error) -> HttpError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => HttpError::Timeout,
        _ => HttpError::Io(e),
    }
}

/// Header carrying the request correlation id (see `docs/OBSERVABILITY.md`).
pub const REQUEST_ID_HEADER: &str = "x-chh-request-id";

/// Response header carrying the server's per-stage timing breakdown in
/// the compact `name=micros;name=micros` form of [`crate::obs`]'s stage
/// codec. Partitions emit it on every answer; the router reads it back
/// to assemble cross-tier slow-log lines (see `docs/OBSERVABILITY.md`).
pub const STAGES_HEADER: &str = "x-chh-stages";

/// Upper bound on an accepted `x-chh-stages` value: 6 stages at ~20
/// bytes each fits comfortably; anything longer is hostile or corrupt.
const MAX_STAGES_CHARS: usize = 256;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// path only (any `?query` suffix is kept verbatim — no routes use one)
    pub path: String,
    pub keep_alive: bool,
    pub body: Vec<u8>,
    /// client-supplied `x-chh-request-id`, if any (the server generates
    /// one when absent and echoes it in the response)
    pub request_id: Option<String>,
    /// `Content-Type: application/x-chh-binary` — the body (and the
    /// 200 response) use the binary wire protocol
    pub binary: bool,
}

/// One parsed HTTP response (client side).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub keep_alive: bool,
    pub body: Vec<u8>,
    /// the `x-chh-request-id` the server echoed back, if any
    pub request_id: Option<String>,
    /// the server's `x-chh-stages` per-stage breakdown, if any
    /// (undecoded — the router forwards/decodes it lazily)
    pub stages: Option<String>,
    /// the body is binary-wire encoded ([`CT_CHH_BIN`])
    pub binary: bool,
}

fn find_blank_line(b: &[u8]) -> Option<usize> {
    b.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parsed head fields common to both message kinds.
struct HeadFields {
    content_length: usize,
    keep_alive: bool,
    request_id: Option<String>,
    stages: Option<String>,
    binary: bool,
}

enum Head {
    Req { method: String, path: String, fields: HeadFields },
    Resp { status: u16, fields: HeadFields },
}

impl Head {
    fn fields(&self) -> &HeadFields {
        match self {
            Head::Req { fields, .. } | Head::Resp { fields, .. } => fields,
        }
    }
}

fn parse_request_head(head: &[u8]) -> Result<Head, HttpError> {
    let head = std::str::from_utf8(head)
        .map_err(|_| HttpError::Malformed("head is not utf-8".to_string()))?;
    let mut lines = head.lines();
    let first = lines.next().unwrap_or("");
    let mut parts = first.split_ascii_whitespace();
    let (Some(method), Some(path), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Err(HttpError::Malformed(format!("bad request line {first:?}")));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("unsupported version {version:?}")));
    }
    let fields = parse_headers(lines, version == "HTTP/1.1")?;
    Ok(Head::Req { method: method.to_string(), path: path.to_string(), fields })
}

fn parse_response_head(head: &[u8]) -> Result<Head, HttpError> {
    let head = std::str::from_utf8(head)
        .map_err(|_| HttpError::Malformed("head is not utf-8".to_string()))?;
    let mut lines = head.lines();
    let first = lines.next().unwrap_or("");
    let mut parts = first.split_ascii_whitespace();
    let (Some(version), Some(code)) = (parts.next(), parts.next()) else {
        return Err(HttpError::Malformed(format!("bad status line {first:?}")));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("unsupported version {version:?}")));
    }
    let status = code
        .parse::<u16>()
        .map_err(|_| HttpError::Malformed(format!("bad status code {code:?}")))?;
    let fields = parse_headers(lines, version == "HTTP/1.1")?;
    Ok(Head::Resp { status, fields })
}

/// Resumable HTTP message parser: feed bytes as the transport delivers
/// them, pull complete messages out. `Ok(None)` means "incomplete — feed
/// more"; after [`FrameParser::feed_eof`] an incomplete message becomes a
/// hard error ([`HttpError::Closed`] only for a clean between-messages
/// hangup). Bytes beyond the current message stay buffered, so pipelined
/// keep-alive messages never lose data.
#[derive(Default)]
pub struct FrameParser {
    buf: Vec<u8>,
    head: Option<Head>,
    eof: bool,
}

impl FrameParser {
    pub fn new() -> Self {
        FrameParser::default()
    }

    /// Buffer bytes read from the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Mark end-of-stream: no more bytes will ever arrive.
    pub fn feed_eof(&mut self) {
        self.eof = true;
    }

    /// Bytes buffered but not yet consumed by a complete message.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// True when enough bytes are buffered that the *next* poll might
    /// yield a message without further transport reads (the event loop
    /// uses this to drain pipelined requests before re-arming POLLIN).
    pub fn has_buffered_input(&self) -> bool {
        !self.buf.is_empty() || self.head.is_some()
    }

    /// Advance the head state machine; `Ok(true)` means a head is parsed
    /// and waiting for its body.
    fn advance_head(&mut self, parse: fn(&[u8]) -> Result<Head, HttpError>) -> Result<bool, HttpError> {
        if self.head.is_some() {
            return Ok(true);
        }
        match find_blank_line(&self.buf) {
            Some(end) => {
                if end > MAX_HEAD_BYTES {
                    return Err(HttpError::TooLarge("head"));
                }
                let head_bytes: Vec<u8> = self.buf.drain(..end + 4).collect();
                self.head = Some(parse(&head_bytes[..end])?);
                Ok(true)
            }
            None => {
                if self.buf.len() > MAX_HEAD_BYTES {
                    return Err(HttpError::TooLarge("head"));
                }
                if self.eof {
                    if self.buf.is_empty() {
                        return Err(HttpError::Closed);
                    }
                    return Err(HttpError::Malformed("eof inside head".to_string()));
                }
                Ok(false)
            }
        }
    }

    /// Take the body once buffered; `Ok(None)` means "need more bytes".
    fn take_body(&mut self) -> Result<Option<(Head, Vec<u8>)>, HttpError> {
        let need = self.head.as_ref().map(|h| h.fields().content_length).unwrap_or(0);
        if self.buf.len() < need {
            if self.eof {
                return Err(HttpError::Malformed("eof inside body".to_string()));
            }
            return Ok(None);
        }
        let body: Vec<u8> = self.buf.drain(..need).collect();
        Ok(Some((self.head.take().expect("head parsed before body"), body)))
    }

    /// Try to pull one complete request out of the buffer.
    pub fn next_request(&mut self) -> Result<Option<Request>, HttpError> {
        if !self.advance_head(parse_request_head)? {
            return Ok(None);
        }
        let Some((head, body)) = self.take_body()? else {
            return Ok(None);
        };
        match head {
            Head::Req { method, path, fields } => Ok(Some(Request {
                method,
                path,
                keep_alive: fields.keep_alive,
                body,
                request_id: fields.request_id,
                binary: fields.binary,
            })),
            Head::Resp { .. } => {
                Err(HttpError::Malformed("expected a request, got a status line".to_string()))
            }
        }
    }

    /// Try to pull one complete response out of the buffer (client side).
    pub fn next_response(&mut self) -> Result<Option<Response>, HttpError> {
        if !self.advance_head(parse_response_head)? {
            return Ok(None);
        }
        let Some((head, body)) = self.take_body()? else {
            return Ok(None);
        };
        match head {
            Head::Resp { status, fields } => Ok(Some(Response {
                status,
                keep_alive: fields.keep_alive,
                body,
                request_id: fields.request_id,
                stages: fields.stages,
                binary: fields.binary,
            })),
            Head::Req { .. } => {
                Err(HttpError::Malformed("expected a response, got a request line".to_string()))
            }
        }
    }
}

/// Blocking message framing over a stream: loops transport reads into a
/// [`FrameParser`] until a complete message (or an error) emerges.
pub struct MessageReader<R: Read> {
    inner: R,
    parser: FrameParser,
}

impl<R: Read> MessageReader<R> {
    pub fn new(inner: R) -> Self {
        MessageReader { inner, parser: FrameParser::new() }
    }

    /// The underlying stream (the client writes its next request here).
    pub fn get_mut(&mut self) -> &mut R {
        &mut self.inner
    }

    fn fill(&mut self) -> Result<(), HttpError> {
        let mut chunk = [0u8; 4096];
        let n = self.inner.read(&mut chunk).map_err(io_err)?;
        if n == 0 {
            self.parser.feed_eof();
        } else {
            self.parser.feed(&chunk[..n]);
        }
        Ok(())
    }

    /// Read and parse one request. `Err(Closed)` means the peer hung up
    /// cleanly between requests.
    pub fn request(&mut self) -> Result<Request, HttpError> {
        loop {
            if let Some(r) = self.parser.next_request()? {
                return Ok(r);
            }
            self.fill()?;
        }
    }

    /// Read and parse one response (client side).
    pub fn response(&mut self) -> Result<Response, HttpError> {
        loop {
            if let Some(r) = self.parser.next_response()? {
                return Ok(r);
            }
            self.fill()?;
        }
    }
}

/// Parse headers (after the first line) into the fields the framing,
/// negotiation and tracing need; `default_keep_alive` comes from the
/// HTTP version.
fn parse_headers(
    lines: std::str::Lines<'_>,
    default_keep_alive: bool,
) -> Result<HeadFields, HttpError> {
    let mut fields = HeadFields {
        content_length: 0,
        keep_alive: default_keep_alive,
        request_id: None,
        stages: None,
        binary: false,
    };
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(HttpError::Malformed(format!("bad header line {line:?}")));
        };
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim();
        match k.as_str() {
            "content-length" => {
                fields.content_length = v
                    .parse::<usize>()
                    .map_err(|_| HttpError::Malformed(format!("bad content-length {v:?}")))?;
                if fields.content_length > MAX_BODY_BYTES {
                    return Err(HttpError::TooLarge("body"));
                }
            }
            "connection" => {
                let v = v.to_ascii_lowercase();
                if v.contains("close") {
                    fields.keep_alive = false;
                } else if v.contains("keep-alive") {
                    fields.keep_alive = true;
                }
            }
            "content-type" => {
                // only the media type matters; ignore any `; charset=…`
                let ct = v.split(';').next().unwrap_or("").trim();
                fields.binary = ct.eq_ignore_ascii_case(CT_CHH_BIN);
            }
            "transfer-encoding" => {
                return Err(HttpError::Malformed("chunked bodies unsupported".to_string()));
            }
            REQUEST_ID_HEADER => {
                // bound the id so a hostile client can't bloat logs;
                // ids we generate are 16 hex chars
                if !v.is_empty() && v.len() <= 64 {
                    fields.request_id = Some(v.to_string());
                }
            }
            STAGES_HEADER => {
                if !v.is_empty() && v.len() <= MAX_STAGES_CHARS {
                    fields.stages = Some(v.to_string());
                }
            }
            _ => {}
        }
    }
    Ok(fields)
}

/// Human reason phrase for the handful of statuses the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        421 => "Misdirected Request",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one JSON response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_ex(w, status, body, keep_alive, "application/json", None)
}

/// Write one response with an explicit content type (the `/metrics`
/// exposition is `text/plain`, binary-wire answers are
/// [`CT_CHH_BIN`]) and an optional echoed request id.
pub fn write_response_ex<W: Write>(
    w: &mut W,
    status: u16,
    body: &[u8],
    keep_alive: bool,
    content_type: &str,
    request_id: Option<&str>,
) -> std::io::Result<()> {
    write_response_traced(w, status, body, keep_alive, content_type, request_id, None)
}

/// [`write_response_ex`] plus an optional `x-chh-stages` per-stage
/// breakdown (encoded with [`crate::obs::encode_stages`]); the serving
/// loop attaches it to every traced answer so upstream tiers (the
/// router) can fold partition timing into their own slow-log lines.
pub fn write_response_traced<W: Write>(
    w: &mut W,
    status: u16,
    body: &[u8],
    keep_alive: bool,
    content_type: &str,
    request_id: Option<&str>,
    stages: Option<&str>,
) -> std::io::Result<()> {
    let id_line = match request_id {
        Some(id) => format!("{REQUEST_ID_HEADER}: {id}\r\n"),
        None => String::new(),
    };
    let stages_line = match stages {
        Some(s) if !s.is_empty() && s.len() <= MAX_STAGES_CHARS => {
            format!("{STAGES_HEADER}: {s}\r\n")
        }
        _ => String::new(),
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{id_line}{stages_line}Connection: {}\r\n\r\n",
        status,
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Write one request (client side).
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    body: &[u8],
) -> std::io::Result<()> {
    write_request_ex(w, method, path, body, None)
}

/// Write one request carrying an `x-chh-request-id` (the replica tailer
/// and loadgen use this so server logs correlate with client attempts).
pub fn write_request_ex<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    body: &[u8],
    request_id: Option<&str>,
) -> std::io::Result<()> {
    write_request_ct(w, method, path, body, request_id, "application/json")
}

/// Write one request with an explicit content type — [`CT_CHH_BIN`]
/// selects the binary wire protocol server-side.
pub fn write_request_ct<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    body: &[u8],
    request_id: Option<&str>,
    content_type: &str,
) -> std::io::Result<()> {
    let id_line = match request_id {
        Some(id) => format!("{REQUEST_ID_HEADER}: {id}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{id_line}Connection: keep-alive\r\n\r\n",
        body.len()
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// A keep-alive HTTP client for `chh loadgen` and tests; speaks both the
/// JSON and the binary wire protocol.
pub struct HttpClient {
    conn: MessageReader<TcpStream>,
}

impl HttpClient {
    pub fn connect(addr: &str) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(HttpClient { conn: MessageReader::new(stream) })
    }

    /// [`Self::connect`] with a bounded connect timeout per resolved
    /// address — a blackholed peer costs `timeout`, not the OS's
    /// multi-minute SYN retry schedule. Used by reconnect loops (the
    /// replica tailer, loadgen's per-target connections) that must keep
    /// making progress past a dead host.
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> std::io::Result<Self> {
        use std::net::ToSocketAddrs;
        let mut last = std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("{addr} resolved to no addresses"),
        );
        for sa in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(HttpClient { conn: MessageReader::new(stream) });
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// Connect, retrying for up to `wait` (the server may still be
    /// binding — loadgen and the CI smoke test start right after
    /// spawning it).
    pub fn connect_retry(addr: &str, wait: Duration) -> std::io::Result<Self> {
        let deadline = Instant::now() + wait;
        loop {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Bound both directions of the socket: a stalled server can't park
    /// this client forever mid-read *or* mid-write.
    pub fn set_timeout(&self, d: Duration) -> std::io::Result<()> {
        self.conn.inner.set_read_timeout(Some(d))?;
        self.conn.inner.set_write_timeout(Some(d))
    }

    /// One request/response round trip on the persistent connection.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<Response, HttpError> {
        write_request(self.conn.get_mut(), method, path, body)?;
        self.conn.response()
    }

    /// [`Self::request`] carrying an `x-chh-request-id` header.
    pub fn request_with_id(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        request_id: &str,
    ) -> Result<Response, HttpError> {
        write_request_ex(self.conn.get_mut(), method, path, body, Some(request_id))?;
        self.conn.response()
    }

    pub fn post(&mut self, path: &str, body: &str) -> Result<Response, HttpError> {
        self.request("POST", path, body.as_bytes())
    }

    /// `POST` a binary-wire body ([`crate::server::binproto`]); the
    /// content type tells the server to answer in kind.
    pub fn post_binary(&mut self, path: &str, body: &[u8]) -> Result<Response, HttpError> {
        write_request_ct(self.conn.get_mut(), "POST", path, body, None, CT_CHH_BIN)?;
        self.conn.response()
    }

    /// [`Self::post_binary`] carrying an `x-chh-request-id` — the router
    /// forwards the client's correlation id on every downstream hop so
    /// router and partition slow logs line up under one id.
    pub fn post_binary_with_id(
        &mut self,
        path: &str,
        body: &[u8],
        request_id: Option<&str>,
    ) -> Result<Response, HttpError> {
        write_request_ct(self.conn.get_mut(), "POST", path, body, request_id, CT_CHH_BIN)?;
        self.conn.response()
    }

    pub fn get(&mut self, path: &str) -> Result<Response, HttpError> {
        self.request("GET", path, &[])
    }

    /// `GET` with an `x-chh-request-id` (replica tailer polls).
    pub fn get_with_id(&mut self, path: &str, request_id: &str) -> Result<Response, HttpError> {
        self.request_with_id("GET", path, &[], request_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(raw: &[u8]) -> Result<Request, HttpError> {
        MessageReader::new(Cursor::new(raw.to_vec())).request()
    }

    #[test]
    fn parses_post_with_body() {
        let r = req(b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello").unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/query");
        assert!(r.keep_alive, "http/1.1 defaults to keep-alive");
        assert_eq!(r.body, b"hello");
        assert!(!r.binary, "no content-type means json");
    }

    #[test]
    fn parses_get_without_body_and_connection_close() {
        let r = req(b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert!(!r.keep_alive);
        assert!(r.body.is_empty());
    }

    #[test]
    fn http10_defaults_to_close() {
        let r = req(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!r.keep_alive);
    }

    #[test]
    fn clean_eof_is_closed() {
        assert!(matches!(req(b""), Err(HttpError::Closed)));
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert!(matches!(req(b"garbage\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(req(b"GET /\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(req(b"GET / SPDY/9\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(
            req(b"POST / HTTP/1.1\r\nContent-Length: x\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            req(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        // truncated body
        assert!(req(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
    }

    #[test]
    fn oversized_head_and_body_rejected() {
        let mut big = b"GET / HTTP/1.1\r\n".to_vec();
        big.extend_from_slice(format!("X: {}\r\n\r\n", "y".repeat(MAX_HEAD_BYTES)).as_bytes());
        assert!(matches!(req(&big), Err(HttpError::TooLarge("head"))));
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(req(huge.as_bytes()), Err(HttpError::TooLarge("body"))));
    }

    #[test]
    fn response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, br#"{"ok":true}"#, true).unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert_eq!(resp.status, 200);
        assert!(resp.keep_alive);
        assert_eq!(resp.body, br#"{"ok":true}"#);
        assert!(!resp.binary);
        let mut wire = Vec::new();
        write_response(&mut wire, 503, b"{}", false).unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert_eq!(resp.status, 503);
        assert!(!resp.keep_alive);
    }

    #[test]
    fn request_roundtrip() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/query", br#"{"w":[1]}"#).unwrap();
        let r = req(&wire).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/query");
        assert_eq!(r.body, br#"{"w":[1]}"#);
    }

    #[test]
    fn binary_content_type_negotiates() {
        // request side, via the typed writer
        let mut wire = Vec::new();
        write_request_ct(&mut wire, "POST", "/query", b"\x01\x02", None, CT_CHH_BIN).unwrap();
        let r = req(&wire).unwrap();
        assert!(r.binary);
        assert_eq!(r.body, b"\x01\x02");
        // case-insensitive match, parameters ignored
        let r = req(
            b"POST /q HTTP/1.1\r\nContent-Type: Application/X-CHH-Binary; charset=x\r\nContent-Length: 0\r\n\r\n",
        )
        .unwrap();
        assert!(r.binary);
        // other content types are not binary
        let r = req(b"POST /q HTTP/1.1\r\nContent-Type: text/plain\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        assert!(!r.binary);
        // response side
        let mut wire = Vec::new();
        write_response_ex(&mut wire, 200, b"\xff", true, CT_CHH_BIN, None).unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert!(resp.binary);
        assert_eq!(resp.body, b"\xff");
    }

    #[test]
    fn request_id_header_is_parsed_and_echoed() {
        // request side: header captured, oversized/empty values dropped
        let r = req(b"GET /q HTTP/1.1\r\nx-chh-request-id: abc123\r\n\r\n").unwrap();
        assert_eq!(r.request_id.as_deref(), Some("abc123"));
        let r = req(b"GET /q HTTP/1.1\r\nX-CHH-Request-Id: UPPER\r\n\r\n").unwrap();
        assert_eq!(r.request_id.as_deref(), Some("UPPER"), "header match is case-insensitive");
        let long = format!("GET /q HTTP/1.1\r\nx-chh-request-id: {}\r\n\r\n", "z".repeat(100));
        assert_eq!(req(long.as_bytes()).unwrap().request_id, None, "oversized id dropped");
        let r = req(b"GET /q HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(r.request_id, None);
        // wire roundtrip via the ex writers
        let mut wire = Vec::new();
        write_request_ex(&mut wire, "POST", "/query", b"{}", Some("rid-1")).unwrap();
        assert_eq!(req(&wire).unwrap().request_id.as_deref(), Some("rid-1"));
        let mut wire = Vec::new();
        write_response_ex(&mut wire, 200, b"ok", true, "text/plain; version=0.0.4", Some("rid-1"))
            .unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert_eq!(resp.request_id.as_deref(), Some("rid-1"));
        assert_eq!(resp.body, b"ok");
    }

    #[test]
    fn stages_header_roundtrips_and_is_bounded() {
        // traced writer emits the header; the client parser captures it
        let mut wire = Vec::new();
        write_response_traced(
            &mut wire,
            200,
            b"ok",
            true,
            "application/json",
            Some("rid-7"),
            Some("encode=12;scan=345"),
        )
        .unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert_eq!(resp.request_id.as_deref(), Some("rid-7"));
        assert_eq!(resp.stages.as_deref(), Some("encode=12;scan=345"));
        assert_eq!(resp.body, b"ok");
        // absent header → None; plain write_response_ex emits none
        let mut wire = Vec::new();
        write_response_ex(&mut wire, 200, b"ok", true, "application/json", None).unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert_eq!(resp.stages, None);
        // oversized values are dropped on both sides
        let huge = "s=1;".repeat(200);
        let mut wire = Vec::new();
        write_response_traced(&mut wire, 200, b"", true, "application/json", None, Some(&huge))
            .unwrap();
        let resp = MessageReader::new(Cursor::new(wire)).response().unwrap();
        assert_eq!(resp.stages, None, "oversized stages never hit the wire");
        let raw = format!(
            "HTTP/1.1 200 OK\r\nContent-Length: 0\r\nx-chh-stages: {huge}\r\n\r\n"
        );
        let resp = MessageReader::new(Cursor::new(raw.into_bytes())).response().unwrap();
        assert_eq!(resp.stages, None, "oversized stages dropped at parse");
    }

    #[test]
    fn pipelined_requests_keep_their_bytes() {
        // two requests land in one transport buffer: the reader must
        // frame both without losing or mixing bytes
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/a", b"one").unwrap();
        write_request(&mut wire, "POST", "/b", b"two!").unwrap();
        let mut reader = MessageReader::new(Cursor::new(wire));
        let r1 = reader.request().unwrap();
        let r2 = reader.request().unwrap();
        assert_eq!((r1.path.as_str(), r1.body.as_slice()), ("/a", b"one".as_slice()));
        assert_eq!((r2.path.as_str(), r2.body.as_slice()), ("/b", b"two!".as_slice()));
        assert!(matches!(reader.request(), Err(HttpError::Closed)));
    }

    #[test]
    fn frame_parser_resumes_byte_at_a_time() {
        // the nonblocking loop feeds whatever the socket had; the parser
        // must yield Ok(None) at every prefix and the full message at
        // the end — with no transport reads of its own
        let mut wire = Vec::new();
        write_request_ex(&mut wire, "POST", "/query", b"{\"w\":[1]}", Some("rid-9")).unwrap();
        let mut p = FrameParser::new();
        for (i, b) in wire.iter().enumerate() {
            assert!(
                p.next_request().unwrap().is_none(),
                "no message before byte {i} arrived"
            );
            p.feed(std::slice::from_ref(b));
        }
        let r = p.next_request().unwrap().expect("complete after the last byte");
        assert_eq!(r.path, "/query");
        assert_eq!(r.body, b"{\"w\":[1]}");
        assert_eq!(r.request_id.as_deref(), Some("rid-9"));
        assert!(p.next_request().unwrap().is_none(), "buffer drained");
        assert!(!p.has_buffered_input());
    }

    #[test]
    fn frame_parser_pipelines_and_reports_eof() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/a", b"one").unwrap();
        write_request(&mut wire, "POST", "/b", b"two!").unwrap();
        let mut p = FrameParser::new();
        p.feed(&wire);
        assert!(p.has_buffered_input());
        let r1 = p.next_request().unwrap().unwrap();
        assert!(p.has_buffered_input(), "second request still buffered");
        let r2 = p.next_request().unwrap().unwrap();
        assert_eq!((r1.path.as_str(), r2.path.as_str()), ("/a", "/b"));
        assert!(p.next_request().unwrap().is_none(), "no eof yet: just incomplete");
        p.feed_eof();
        assert!(matches!(p.next_request(), Err(HttpError::Closed)));
        // eof mid-head and mid-body are malformed, not Closed
        let mut p = FrameParser::new();
        p.feed(b"POST /x HTTP/1.1\r\nConte");
        assert!(p.next_request().unwrap().is_none());
        p.feed_eof();
        assert!(matches!(p.next_request(), Err(HttpError::Malformed(_))));
        let mut p = FrameParser::new();
        p.feed(b"POST /x HTTP/1.1\r\nContent-Length: 9\r\n\r\nabc");
        assert!(p.next_request().unwrap().is_none());
        p.feed_eof();
        assert!(matches!(p.next_request(), Err(HttpError::Malformed(_))));
    }
}
