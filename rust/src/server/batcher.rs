//! Dynamic micro-batching: coalesce concurrent point-to-hyperplane
//! queries into one pooled batch call.
//!
//! The data-parallel engine (`docs/PARALLEL.md`) is fastest when it sees
//! whole batches, but network traffic arrives one request at a time. The
//! [`Batcher`] sits between the two: HTTP handler threads submit single
//! [`QueryRequest`]s and block on a reply channel; one collector thread
//! drains the shared queue and flushes a batch whenever
//!
//! * `max_batch` queries are waiting, or
//! * the **oldest** waiting query has been held for `max_wait`
//!
//! — classic size-or-deadline batching, so a lone query pays at most
//! `max_wait` extra latency while a burst is answered as one
//! `query_batch_pooled` call. Because every query is answered by the
//! same deterministic pooled path, coalescing never changes results:
//! the response for a request is bit-identical whether it was flushed
//! alone or inside a batch (the parity tests in
//! `rust/tests/http_server.rs` assert exactly this).
//!
//! **Admission control**: the submit queue is a bounded `sync_channel`;
//! when it is full, [`Batcher::submit`] fails immediately with
//! [`SubmitError::Overloaded`] instead of blocking the connection
//! thread — the server maps that to HTTP 503 so overload sheds load at
//! the edge rather than growing an unbounded backlog.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::QueryRequest;
use crate::metrics::Histogram;
use crate::table::QueryHit;

/// Batching policy knobs (see `docs/SERVING.md`).
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// flush as soon as this many queries are waiting
    pub max_batch: usize,
    /// flush once the oldest waiting query has been held this long
    pub max_wait: Duration,
    /// admission queue bound; a full queue rejects with `Overloaded`
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
        }
    }
}

/// Why a submit was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// admission queue full — shed load (HTTP 503)
    Overloaded,
    /// batcher already shut down
    ShuttingDown,
}

/// Counters exposed on `/stats`.
pub struct BatcherStats {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    /// flush calls made
    pub batches: AtomicU64,
    /// queries flushed (sum of batch sizes)
    pub flushed: AtomicU64,
    /// recent batch sizes (bounded ring — the batcher is long-lived)
    batch_sizes: Mutex<Histogram>,
}

impl Default for BatcherStats {
    fn default() -> Self {
        BatcherStats {
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            flushed: AtomicU64::new(0),
            batch_sizes: Mutex::new(Histogram::with_capacity(
                crate::metrics::SERVING_RESERVOIR,
            )),
        }
    }
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        self.batch_sizes.lock().unwrap().mean()
    }

    pub fn max_batch_seen(&self) -> f64 {
        let h = self.batch_sizes.lock().unwrap();
        if h.is_empty() {
            0.0
        } else {
            h.max()
        }
    }
}

struct Slot {
    req: QueryRequest,
    /// when the request entered the admission queue (batcher-wait timing)
    submitted: Instant,
    reply: std::sync::mpsc::Sender<BatchedReply>,
}

/// What a submitter receives back: the hit plus the observability
/// context of the flush that answered it. `wait` is exact per request
/// (submit → flush start); `stages` is the whole batch's stage
/// breakdown, shared by every request coalesced into it.
pub struct BatchedReply {
    pub hit: QueryHit,
    pub wait: Duration,
    pub stages: crate::obs::StageTimes,
}

/// A flush's results: the hits (request order) plus the batch's
/// per-stage wall-clock, recorded by the traced query path.
pub struct FlushOutcome {
    pub hits: Vec<QueryHit>,
    pub stages: crate::obs::StageTimes,
}

impl FlushOutcome {
    /// Hits with no stage breakdown (tests, untraced callers).
    pub fn plain(hits: Vec<QueryHit>) -> Self {
        FlushOutcome { hits, stages: crate::obs::StageTimes::default() }
    }
}

/// The flush target: answers a whole batch in request order (the server
/// wires this to `Router::query_batch_pooled_traced` /
/// `OnlineRouter::query_batch_pooled_traced`).
pub type FlushFn = Box<dyn Fn(&[QueryRequest]) -> FlushOutcome + Send>;

/// The micro-batcher: a bounded submit queue plus one collector thread.
pub struct Batcher {
    tx: Option<SyncSender<Slot>>,
    collector: Option<std::thread::JoinHandle<()>>,
    stats: Arc<BatcherStats>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig, flush: FlushFn) -> Self {
        let stats = Arc::new(BatcherStats::default());
        let (tx, rx) = sync_channel::<Slot>(cfg.queue_cap.max(1));
        let tstats = stats.clone();
        let collector = std::thread::Builder::new()
            .name("chh-batcher".to_string())
            .spawn(move || collector_loop(rx, cfg, flush, tstats))
            .expect("spawn batcher thread");
        Batcher { tx: Some(tx), collector: Some(collector), stats }
    }

    pub fn stats(&self) -> &Arc<BatcherStats> {
        &self.stats
    }

    /// Enqueue one query. Returns the channel the reply arrives on, or
    /// an immediate rejection when the admission queue is full.
    pub fn submit(
        &self,
        req: QueryRequest,
    ) -> Result<Receiver<BatchedReply>, SubmitError> {
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::ShuttingDown);
        };
        let (reply, rx) = std::sync::mpsc::channel();
        match tx.try_send(Slot { req, submitted: Instant::now(), reply }) {
            Ok(()) => {
                self.stats.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
        }
    }

    /// Stop accepting, flush everything still queued, join the collector.
    pub fn shutdown(mut self) {
        self.tx.take(); // disconnect ⇒ collector drains and exits
        if let Some(h) = self.collector.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.collector.take() {
            let _ = h.join();
        }
    }
}

fn collector_loop(
    rx: Receiver<Slot>,
    cfg: BatcherConfig,
    flush: FlushFn,
    stats: Arc<BatcherStats>,
) {
    let max_batch = cfg.max_batch.max(1);
    loop {
        // block for the batch's first query
        let first = match rx.recv() {
            Ok(s) => s,
            Err(_) => return, // all senders gone and queue drained
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        let mut disconnected = false;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(s) => batch.push(s),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // split requests from reply handles instead of cloning the
        // dim-sized w vectors — this thread is the /query bottleneck
        let flush_start = Instant::now();
        let (reqs, replies): (Vec<QueryRequest>, Vec<_>) =
            batch.into_iter().map(|s| (s.req, (s.submitted, s.reply))).unzip();
        let out = flush(&reqs);
        debug_assert_eq!(out.hits.len(), reqs.len(), "flush must answer the whole batch");
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.flushed.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        stats.batch_sizes.lock().unwrap().record(reqs.len() as f64);
        for ((submitted, reply), hit) in replies.into_iter().zip(out.hits) {
            // a dropped receiver (client hung up mid-flight) is fine
            let _ = reply.send(BatchedReply {
                hit,
                wait: flush_start.saturating_duration_since(submitted),
                stages: out.stages,
            });
        }
        if disconnected {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(tag: f32) -> QueryRequest {
        QueryRequest { w: vec![tag, 1.0], exclude: None }
    }

    /// Flush that echoes the first w component into `scanned`, so tests
    /// can check each reply went to the right submitter.
    fn echo_flush() -> FlushFn {
        Box::new(|reqs| {
            FlushOutcome::plain(
                reqs.iter()
                    .map(|r| QueryHit {
                        best: None,
                        scanned: r.w[0] as usize,
                        probed: reqs.len(), // batch size, to observe coalescing
                        nonempty: false,
                    })
                    .collect(),
            )
        })
    }

    #[test]
    fn replies_routed_to_their_submitters() {
        let b = Batcher::new(
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5), queue_cap: 64 },
            echo_flush(),
        );
        let rxs: Vec<_> = (0..20).map(|i| b.submit(req(i as f32)).unwrap()).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let hit = rx.recv().expect("reply").hit;
            assert_eq!(hit.scanned, i, "reply {i} routed to wrong submitter");
        }
        assert_eq!(b.stats().submitted.load(Ordering::Relaxed), 20);
        assert_eq!(b.stats().flushed.load(Ordering::Relaxed), 20);
        b.shutdown();
    }

    #[test]
    fn burst_coalesces_into_batches() {
        // long max_wait: the first flush waits for the whole burst, so
        // batches must hit max_batch, not dribble out one by one (the
        // wait is generous only so a preempted CI runner can't split
        // the burst; the flush fires the moment all 8 arrive)
        let b = Batcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(1), queue_cap: 64 },
            echo_flush(),
        );
        let rxs: Vec<_> = (0..8).map(|i| b.submit(req(i as f32)).unwrap()).collect();
        let sizes: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().hit.probed).collect();
        // every query sees the batch size its flush had; with an idle
        // collector the burst lands in a few batches totalling 8
        assert_eq!(sizes.len(), 8);
        assert!(
            sizes.iter().any(|&s| s >= 4),
            "burst should coalesce, got batch sizes {sizes:?}"
        );
        assert!(b.stats().batches.load(Ordering::Relaxed) <= 4);
        b.shutdown();
    }

    #[test]
    fn overload_rejects_immediately() {
        // gate the flush so the queue can be filled deterministically
        let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let flush: FlushFn = Box::new(move |reqs| {
            started_tx.send(()).unwrap();
            release_rx.recv().unwrap();
            FlushOutcome::plain(reqs.iter().map(|_| QueryHit::default()).collect())
        });
        let b = Batcher::new(
            BatcherConfig { max_batch: 1, max_wait: Duration::ZERO, queue_cap: 2 },
            flush,
        );
        let rx1 = b.submit(req(1.0)).unwrap();
        started_rx.recv().unwrap(); // collector is now blocked inside flush
        let _rx2 = b.submit(req(2.0)).unwrap(); // queue slot 1
        let _rx3 = b.submit(req(3.0)).unwrap(); // queue slot 2
        assert_eq!(b.submit(req(4.0)).unwrap_err(), SubmitError::Overloaded);
        assert_eq!(b.stats().rejected.load(Ordering::Relaxed), 1);
        // release all flushes and drain
        for _ in 0..3 {
            release_tx.send(()).unwrap();
        }
        rx1.recv().unwrap();
        b.shutdown();
    }

    #[test]
    fn shutdown_flushes_the_backlog() {
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let flush: FlushFn = Box::new(move |reqs| {
            // slow first flush lets a backlog build up
            let _ = release_rx.recv_timeout(Duration::from_millis(100));
            FlushOutcome::plain(
                reqs.iter()
                    .map(|r| QueryHit { scanned: r.w[0] as usize, ..QueryHit::default() })
                    .collect(),
            )
        });
        let b = Batcher::new(
            BatcherConfig { max_batch: 2, max_wait: Duration::ZERO, queue_cap: 16 },
            flush,
        );
        let rxs: Vec<_> = (0..6).map(|i| b.submit(req(i as f32)).unwrap()).collect();
        drop(release_tx);
        b.shutdown(); // must drain all 6 before returning
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().expect("drained on shutdown").hit.scanned, i);
        }
    }

    #[test]
    fn submit_after_shutdown_is_rejected_cleanly() {
        let b = Batcher::new(BatcherConfig::default(), echo_flush());
        let rx = b.submit(req(5.0)).unwrap();
        assert_eq!(rx.recv().unwrap().hit.scanned, 5);
        // dropping is the same as shutdown; a new Batcher is cheap
        b.shutdown();
    }

    #[test]
    fn replies_carry_wait_and_batch_stages() {
        let flush: FlushFn = Box::new(|reqs| FlushOutcome {
            hits: reqs.iter().map(|_| QueryHit::default()).collect(),
            stages: crate::obs::StageTimes {
                encode: Duration::from_micros(7),
                ..Default::default()
            },
        });
        let b = Batcher::new(
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2), queue_cap: 16 },
            flush,
        );
        let reply = b.submit(req(1.0)).unwrap().recv().unwrap();
        // wait is measured (submit → flush start) and the flush's stage
        // breakdown rides along for the slow-query log
        assert!(reply.wait < Duration::from_secs(5), "wait is sane: {:?}", reply.wait);
        assert_eq!(reply.stages.encode, Duration::from_micros(7));
        b.shutdown();
    }

    #[test]
    fn single_query_pays_at_most_max_wait() {
        let b = Batcher::new(
            BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(10), queue_cap: 8 },
            echo_flush(),
        );
        let t0 = Instant::now();
        let rx = b.submit(req(0.0)).unwrap();
        rx.recv().unwrap();
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_millis(500),
            "lone query must flush at the deadline, waited {waited:?}"
        );
        b.shutdown();
    }
}
