//! Binary wire protocol of the serving front-end.
//!
//! The JSON protocol in [`crate::server::protocol`] spends most of a
//! query's bytes (and a measurable slice of its CPU) on shortest-decimal
//! float text; the paper's whole speed argument is compact codes and
//! cheap bitwise work, so the hot routes also speak a length-prefixed
//! binary encoding negotiated via `Content-Type:
//! application/x-chh-binary`. Floats travel as raw little-endian IEEE-754
//! bits — bit-exact by construction, no `-0.0`/round-trip machinery
//! needed — and decoding is *total*: truncation at any byte, a hostile
//! length field, a wrong magic/version/tag, or trailing junk is a clean
//! [`ProtoError`] (HTTP 400), never a panic. The framing idiom (magic +
//! version header, checked cursor, trailing-bytes rejection) is the same
//! one [`crate::replicate::wire`] uses for CHWS/CHWB.
//!
//! ```text
//! header      "CHBP" | u32 ver | u32 tag                      (12 bytes)
//! query    1  hdr | u32 flags | u32 dim | dim × f32-bits
//!             [flags bit0: u64 n | n × u64 exclude ids]
//! topk     2  hdr | u32 flags | u32 t | u32 dim | dim × f32-bits
//!             [flags bit0: u64 n | n × u64 exclude ids]
//! insert   3  hdr | u32 id
//! remove   4  hdr | u32 id
//! hit     17  hdr | u32 flags (bit0 has_best, bit1 nonempty)
//!             [bit0: u64 id | u32 margin-bits] | u64 scanned | u64 probed
//! topk …  18  hdr | u64 count | count × (u64 id | u32 margin-bits)
//! ack     19  hdr | u32 applied | u32 id | u64 live
//! ```
//!
//! Version policy: `VERSION` bumps on any layout change; a decoder only
//! accepts its own version (clients fall back to JSON, which is always
//! served). Request tags and response tags live in disjoint ranges so a
//! cross-wired client gets "unexpected tag", not garbage fields.

use std::collections::HashSet;
use std::sync::Arc;

use crate::coordinator::QueryRequest;
use crate::server::protocol::ProtoError;
use crate::table::QueryHit;

/// Frame magic: all binary serving bodies start with these 4 bytes.
pub const MAGIC: &[u8; 4] = b"CHBP";
/// Wire version; bumped on any layout change, never negotiated down.
pub const VERSION: u32 = 1;

/// Request tag: `POST /query`.
pub const TAG_QUERY: u32 = 1;
/// Request tag: `POST /query_topk`.
pub const TAG_TOPK: u32 = 2;
/// Request tag: `POST /insert`.
pub const TAG_INSERT: u32 = 3;
/// Request tag: `POST /remove`.
pub const TAG_REMOVE: u32 = 4;
/// Response tag: a [`QueryHit`].
pub const TAG_HIT: u32 = 17;
/// Response tag: a top-`t` short list.
pub const TAG_TOPK_HITS: u32 = 18;
/// Response tag: an insert/remove acknowledgement.
pub const TAG_ACK: u32 = 19;

const FLAG_EXCLUDE: u32 = 1;
const FLAG_HAS_BEST: u32 = 1;
const FLAG_NONEMPTY: u32 = 2;

// ───────────────────────── encode ─────────────────────────

fn push_header(b: &mut Vec<u8>, tag: u32) {
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&VERSION.to_le_bytes());
    b.extend_from_slice(&tag.to_le_bytes());
}

fn push_w(b: &mut Vec<u8>, w: &[f32]) {
    b.extend_from_slice(&(w.len() as u32).to_le_bytes());
    for x in w {
        b.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn push_exclude(b: &mut Vec<u8>, exclude: Option<&HashSet<usize>>) -> u32 {
    let Some(ex) = exclude else { return 0 };
    // sorted so the encoding of a given request is deterministic
    let mut ids: Vec<u64> = ex.iter().map(|&id| id as u64).collect();
    ids.sort_unstable();
    b.extend_from_slice(&(ids.len() as u64).to_le_bytes());
    for id in ids {
        b.extend_from_slice(&id.to_le_bytes());
    }
    FLAG_EXCLUDE
}

/// Encode a `/query` body (client half — loadgen, tests, tools).
pub fn encode_query(w: &[f32], exclude: Option<&HashSet<usize>>) -> Vec<u8> {
    let mut b = Vec::with_capacity(20 + 4 * w.len());
    push_header(&mut b, TAG_QUERY);
    let mut tail = Vec::new();
    let flags = push_exclude(&mut tail, exclude);
    b.extend_from_slice(&flags.to_le_bytes());
    push_w(&mut b, w);
    b.extend_from_slice(&tail);
    b
}

/// Encode a `/query_topk` body.
pub fn encode_topk(w: &[f32], t: usize, exclude: Option<&HashSet<usize>>) -> Vec<u8> {
    let mut b = Vec::with_capacity(24 + 4 * w.len());
    push_header(&mut b, TAG_TOPK);
    let mut tail = Vec::new();
    let flags = push_exclude(&mut tail, exclude);
    b.extend_from_slice(&flags.to_le_bytes());
    b.extend_from_slice(&(t as u32).to_le_bytes());
    push_w(&mut b, w);
    b.extend_from_slice(&tail);
    b
}

/// Encode an `/insert` ([`TAG_INSERT`]) or `/remove` ([`TAG_REMOVE`]) body.
pub fn encode_id(tag: u32, id: u32) -> Vec<u8> {
    let mut b = Vec::with_capacity(16);
    push_header(&mut b, tag);
    b.extend_from_slice(&id.to_le_bytes());
    b
}

/// Encode a [`QueryHit`] response (server half).
pub fn encode_hit(hit: &QueryHit) -> Vec<u8> {
    let mut b = Vec::with_capacity(44);
    push_header(&mut b, TAG_HIT);
    let mut flags = 0u32;
    if hit.best.is_some() {
        flags |= FLAG_HAS_BEST;
    }
    if hit.nonempty {
        flags |= FLAG_NONEMPTY;
    }
    b.extend_from_slice(&flags.to_le_bytes());
    if let Some((id, m)) = hit.best {
        b.extend_from_slice(&(id as u64).to_le_bytes());
        b.extend_from_slice(&m.to_bits().to_le_bytes());
    }
    b.extend_from_slice(&(hit.scanned as u64).to_le_bytes());
    b.extend_from_slice(&(hit.probed as u64).to_le_bytes());
    b
}

/// Encode a `/query_topk` response.
pub fn encode_topk_hits(hits: &[(usize, f32)]) -> Vec<u8> {
    let mut b = Vec::with_capacity(20 + 12 * hits.len());
    push_header(&mut b, TAG_TOPK_HITS);
    b.extend_from_slice(&(hits.len() as u64).to_le_bytes());
    for &(id, m) in hits {
        b.extend_from_slice(&(id as u64).to_le_bytes());
        b.extend_from_slice(&m.to_bits().to_le_bytes());
    }
    b
}

/// Encode an insert/remove acknowledgement: whether the mutation applied,
/// the id it named, and the live point count afterwards.
pub fn encode_ack(applied: bool, id: u32, live: u64) -> Vec<u8> {
    let mut b = Vec::with_capacity(28);
    push_header(&mut b, TAG_ACK);
    b.extend_from_slice(&(applied as u32).to_le_bytes());
    b.extend_from_slice(&id.to_le_bytes());
    b.extend_from_slice(&live.to_le_bytes());
    b
}

// ───────────────────────── decode ─────────────────────────

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        // checked: a hostile length field near usize::MAX must error,
        // not wrap past the bounds check into a slice panic
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                ProtoError::bad(format!("truncated binary message at byte {}", self.pos))
            })?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn finish(&self) -> Result<(), ProtoError> {
        if self.pos != self.b.len() {
            return Err(ProtoError::bad(format!(
                "binary message has {} trailing bytes",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn header<'a>(b: &'a [u8], want_tag: u32, what: &str) -> Result<Cursor<'a>, ProtoError> {
    let mut c = Cursor { b, pos: 0 };
    if c.take(4)? != MAGIC {
        return Err(ProtoError::bad(format!("bad magic — not a binary {what} message")));
    }
    let ver = c.u32()?;
    if ver != VERSION {
        return Err(ProtoError::bad(format!("unsupported binary wire version {ver}")));
    }
    let tag = c.u32()?;
    if tag != want_tag {
        return Err(ProtoError::bad(format!(
            "unexpected tag {tag} — not a binary {what} message"
        )));
    }
    Ok(c)
}

fn read_w(c: &mut Cursor, dim: usize) -> Result<Vec<f32>, ProtoError> {
    let n = c.u32()? as usize;
    if n != dim {
        return Err(ProtoError::bad(format!("\"w\" has {n} dims, index expects {dim}")));
    }
    let mut w = Vec::with_capacity(n);
    for _ in 0..n {
        let x = f32::from_bits(c.u32()?);
        // same validation contract as the JSON route: NaN/inf margins
        // would poison the scan, so reject them at the wire
        if !x.is_finite() {
            return Err(ProtoError::bad("\"w\" entries must be finite f32s"));
        }
        w.push(x);
    }
    Ok(w)
}

fn read_exclude(
    c: &mut Cursor,
    flags: u32,
) -> Result<Option<Arc<HashSet<usize>>>, ProtoError> {
    if flags & FLAG_EXCLUDE == 0 {
        return Ok(None);
    }
    let n = c.u64()?;
    // bound before looping: a hostile count must fail fast, not spin
    if n > (c.remaining() / 8) as u64 {
        return Err(ProtoError::bad(format!("exclude count {n} exceeds message size")));
    }
    let mut set = HashSet::with_capacity(n as usize);
    for _ in 0..n {
        let id = c.u64()?;
        set.insert(usize::try_from(id).map_err(|_| {
            ProtoError::bad(format!("exclude id {id} exceeds this platform's usize"))
        })?);
    }
    Ok(Some(Arc::new(set)))
}

/// Decode a binary `/query` body into a router request.
pub fn decode_query(body: &[u8], dim: usize) -> Result<QueryRequest, ProtoError> {
    let mut c = header(body, TAG_QUERY, "query")?;
    let flags = c.u32()?;
    let w = read_w(&mut c, dim)?;
    let exclude = read_exclude(&mut c, flags)?;
    c.finish()?;
    Ok(QueryRequest { w, exclude })
}

/// Decode a binary `/query_topk` body: the request plus list length `t`.
pub fn decode_topk(body: &[u8], dim: usize) -> Result<(QueryRequest, usize), ProtoError> {
    let mut c = header(body, TAG_TOPK, "query_topk")?;
    let flags = c.u32()?;
    let t = c.u32()? as usize;
    if t == 0 {
        return Err(ProtoError::bad("\"t\" must be >= 1"));
    }
    let w = read_w(&mut c, dim)?;
    let exclude = read_exclude(&mut c, flags)?;
    c.finish()?;
    Ok((QueryRequest { w, exclude }, t))
}

/// Decode a binary `/insert` or `/remove` body (tag names the route).
pub fn decode_id(body: &[u8], tag: u32) -> Result<u32, ProtoError> {
    let what = if tag == TAG_INSERT { "insert" } else { "remove" };
    let mut c = header(body, tag, what)?;
    let id = c.u32()?;
    c.finish()?;
    Ok(id)
}

/// Decode a binary [`QueryHit`] response (client half).
pub fn decode_hit(body: &[u8]) -> Result<QueryHit, ProtoError> {
    let mut c = header(body, TAG_HIT, "hit")?;
    let flags = c.u32()?;
    let best = if flags & FLAG_HAS_BEST != 0 {
        let id = c.u64()?;
        let m = f32::from_bits(c.u32()?);
        let id = usize::try_from(id)
            .map_err(|_| ProtoError::bad(format!("hit id {id} exceeds usize")))?;
        Some((id, m))
    } else {
        None
    };
    let scanned = c.u64()? as usize;
    let probed = c.u64()? as usize;
    c.finish()?;
    Ok(QueryHit { best, scanned, probed, nonempty: flags & FLAG_NONEMPTY != 0 })
}

/// Decode a binary `/query_topk` response (client half).
pub fn decode_topk_hits(body: &[u8]) -> Result<Vec<(usize, f32)>, ProtoError> {
    let mut c = header(body, TAG_TOPK_HITS, "topk_hits")?;
    let n = c.u64()?;
    if n > (c.remaining() / 12) as u64 {
        return Err(ProtoError::bad(format!("hit count {n} exceeds message size")));
    }
    let mut hits = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let id = c.u64()?;
        let m = f32::from_bits(c.u32()?);
        let id = usize::try_from(id)
            .map_err(|_| ProtoError::bad(format!("hit id {id} exceeds usize")))?;
        hits.push((id, m));
    }
    c.finish()?;
    Ok(hits)
}

/// Decode a binary insert/remove acknowledgement: `(applied, id, live)`.
pub fn decode_ack(body: &[u8]) -> Result<(bool, u32, u64), ProtoError> {
    let mut c = header(body, TAG_ACK, "ack")?;
    let applied = c.u32()?;
    let id = c.u32()?;
    let live = c.u64()?;
    c.finish()?;
    Ok((applied != 0, id, live))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(ids: &[usize]) -> HashSet<usize> {
        ids.iter().copied().collect()
    }

    #[test]
    fn query_roundtrips_bit_exact() {
        let w = vec![1.0f32, -0.0, f32::MIN_POSITIVE, 3.4e38, -2.718_281_8, 1.0e-8];
        let req = decode_query(&encode_query(&w, None), w.len()).unwrap();
        for (a, b) in w.iter().zip(req.w.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 roundtrip must be exact");
        }
        assert!(req.exclude.is_none());

        let excl = ex(&[3, 5, 1_000_000]);
        let req = decode_query(&encode_query(&w, Some(&excl)), w.len()).unwrap();
        assert_eq!(*req.exclude.unwrap(), excl);
    }

    #[test]
    fn topk_roundtrips() {
        let w = vec![0.5f32, -0.5];
        let (req, t) = decode_topk(&encode_topk(&w, 7, Some(&ex(&[9]))), 2).unwrap();
        assert_eq!(t, 7);
        assert_eq!(req.w, w);
        assert!(req.exclude.unwrap().contains(&9));
        assert!(decode_topk(&encode_topk(&w, 0, None), 2).is_err(), "t=0 rejected");
    }

    #[test]
    fn id_and_ack_roundtrip() {
        assert_eq!(decode_id(&encode_id(TAG_INSERT, 42), TAG_INSERT).unwrap(), 42);
        assert_eq!(decode_id(&encode_id(TAG_REMOVE, 7), TAG_REMOVE).unwrap(), 7);
        // route/tag mismatch is a clean 400
        assert!(decode_id(&encode_id(TAG_INSERT, 42), TAG_REMOVE).is_err());
        let (applied, id, live) = decode_ack(&encode_ack(true, 42, 1999)).unwrap();
        assert!(applied);
        assert_eq!((id, live), (42, 1999));
        let (applied, _, _) = decode_ack(&encode_ack(false, 0, 0)).unwrap();
        assert!(!applied);
    }

    #[test]
    fn hit_roundtrips_bit_exact() {
        let hit = QueryHit {
            best: Some((123, 0.123_456_79_f32)),
            scanned: 9,
            probed: 4,
            nonempty: true,
        };
        let back = decode_hit(&encode_hit(&hit)).unwrap();
        assert_eq!(back.best.unwrap().0, 123);
        assert_eq!(back.best.unwrap().1.to_bits(), hit.best.unwrap().1.to_bits());
        assert_eq!((back.scanned, back.probed), (9, 4));
        assert!(back.nonempty);
        let empty = QueryHit::default();
        let back = decode_hit(&encode_hit(&empty)).unwrap();
        assert!(back.best.is_none());
        assert!(!back.nonempty);
    }

    #[test]
    fn topk_hits_roundtrip() {
        let hits = vec![(1usize, 0.25f32), (7, -0.0), (2, f32::MIN_POSITIVE)];
        let back = decode_topk_hits(&encode_topk_hits(&hits)).unwrap();
        assert_eq!(back.len(), 3);
        for ((ia, ma), (ib, mb)) in hits.iter().zip(back.iter()) {
            assert_eq!(ia, ib);
            assert_eq!(ma.to_bits(), mb.to_bits());
        }
        assert!(decode_topk_hits(&encode_topk_hits(&[])).unwrap().is_empty());
    }

    #[test]
    fn dim_mismatch_rejected() {
        let err = decode_query(&encode_query(&[1.0, 2.0], None), 3).unwrap_err();
        assert_eq!(err.status, 400);
        assert!(err.msg.contains("dims"));
    }

    #[test]
    fn non_finite_w_rejected() {
        // patch w[0]'s raw bits to NaN / +inf: the decoder must reject
        // exactly what the JSON route rejects
        for bits in [f32::NAN.to_bits(), f32::INFINITY.to_bits()] {
            let mut b = encode_query(&[1.0, 2.0], None);
            // header 12 | flags 4 | dim 4 → w[0] at byte 20
            b[20..24].copy_from_slice(&bits.to_le_bytes());
            let err = decode_query(&b, 2).unwrap_err();
            assert!(err.msg.contains("finite"), "got: {}", err.msg);
        }
    }

    #[test]
    fn truncation_at_every_byte_is_a_clean_error() {
        let msgs: Vec<Vec<u8>> = vec![
            encode_query(&[1.0, -0.0, 3.5], Some(&ex(&[1, 2, 3]))),
            encode_topk(&[0.25, -4.0], 5, Some(&ex(&[9]))),
            encode_id(TAG_INSERT, 7),
            encode_hit(&QueryHit { best: Some((3, 0.5)), scanned: 1, probed: 2, nonempty: true }),
            encode_topk_hits(&[(1, 0.5), (2, -0.5)]),
            encode_ack(true, 3, 100),
        ];
        for (i, m) in msgs.iter().enumerate() {
            for cut in 0..m.len() {
                let b = &m[..cut];
                let all_err = decode_query(b, 3).is_err()
                    && decode_topk(b, 2).is_err()
                    && decode_id(b, TAG_INSERT).is_err()
                    && decode_hit(b).is_err()
                    && decode_topk_hits(b).is_err()
                    && decode_ack(b).is_err();
                assert!(all_err, "msg {i} cut at {cut} must error under every decoder");
            }
        }
    }

    #[test]
    fn malformed_messages_rejected() {
        // wrong magic, cross-tag decoding, bad version, trailing junk,
        // hostile length fields — all clean errors, no panics
        assert!(decode_query(b"nope", 2).is_err());
        let q = encode_query(&[1.0, 2.0], None);
        assert!(decode_topk(&q, 2).is_err(), "query bytes are not a topk");
        assert!(decode_hit(&q).is_err(), "query bytes are not a hit");
        let mut bad_ver = q.clone();
        bad_ver[4] = 99;
        assert!(decode_query(&bad_ver, 2).is_err());
        let mut trailing = q.clone();
        trailing.push(0);
        assert!(decode_query(&trailing, 2).is_err());
        // exclude count u64::MAX (count lives right after the w block:
        // header 12 | flags 4 | dim 4 | 2×4 w = byte 28)
        let mut huge = encode_query(&[1.0, 2.0], Some(&ex(&[1])));
        huge[28..36].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_query(&huge, 2).is_err());
        // topk_hits count u64::MAX (count at byte 12)
        let mut huge_hits = encode_topk_hits(&[(1, 0.5)]);
        huge_hits[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_topk_hits(&huge_hits).is_err());
    }

    /// A finite f32 drawn from raw bit patterns: exercises subnormals,
    /// extreme exponents and odd mantissas — not just "nice" values.
    fn adversarial_f32(rng: &mut crate::rng::Rng) -> f32 {
        loop {
            let v = f32::from_bits(rng.next_u64() as u32);
            if v.is_finite() {
                return v;
            }
        }
    }

    #[test]
    fn bodies_roundtrip_bit_exact_forall() {
        crate::testing::forall("binproto roundtrip", 64, |rng| {
            let dim = rng.range(1, 33);
            let mut w: Vec<f32> = (0..dim).map(|_| adversarial_f32(rng)).collect();
            // plant the canonical adversaries deterministically
            w[0] = -0.0;
            if dim > 1 {
                w[1] = f32::from_bits(1); // smallest subnormal
            }
            if dim > 2 {
                w[2] = f32::MAX;
            }
            if dim > 3 {
                w[3] = -f32::MAX;
            }
            let excl = if rng.below(2) == 0 {
                None
            } else {
                Some((0..rng.below(16)).map(|_| rng.below(1 << 20)).collect::<HashSet<_>>())
            };
            let req = decode_query(&encode_query(&w, excl.as_ref()), dim)
                .map_err(|e| format!("decode_query: {}", e.msg))?;
            for (i, (a, b)) in w.iter().zip(req.w.iter()).enumerate() {
                crate::prop_assert!(a.to_bits() == b.to_bits(), "query w[{i}]: {a:?} != {b:?}");
            }
            crate::prop_assert!(
                req.exclude.as_deref() == excl.as_ref(),
                "exclude roundtrip"
            );
            let t = rng.range(1, 100);
            let (req2, t2) = decode_topk(&encode_topk(&w, t, excl.as_ref()), dim)
                .map_err(|e| format!("decode_topk: {}", e.msg))?;
            crate::prop_assert!(t2 == t, "t roundtrip");
            for (a, b) in w.iter().zip(req2.w.iter()) {
                crate::prop_assert!(a.to_bits() == b.to_bits(), "topk w bits");
            }
            let hit = QueryHit {
                best: if rng.below(8) == 0 {
                    None
                } else {
                    Some((rng.below(1 << 20), adversarial_f32(rng)))
                },
                scanned: rng.below(10_000),
                probed: rng.below(10_000),
                nonempty: rng.below(2) == 1,
            };
            let back =
                decode_hit(&encode_hit(&hit)).map_err(|e| format!("decode_hit: {}", e.msg))?;
            match (hit.best, back.best) {
                (Some((ia, ma)), Some((ib, mb))) => {
                    crate::prop_assert!(ia == ib, "best id");
                    crate::prop_assert!(ma.to_bits() == mb.to_bits(), "margin bits");
                }
                (None, None) => {}
                (a, b) => return Err(format!("best mismatch {a:?} vs {b:?}")),
            }
            crate::prop_assert!(
                back.scanned == hit.scanned && back.probed == hit.probed,
                "counters"
            );
            crate::prop_assert!(back.nonempty == hit.nonempty, "nonempty");
            let hits: Vec<(usize, f32)> = (0..rng.below(20))
                .map(|_| (rng.below(1 << 20), adversarial_f32(rng)))
                .collect();
            let back = decode_topk_hits(&encode_topk_hits(&hits))
                .map_err(|e| format!("decode_topk_hits: {}", e.msg))?;
            crate::prop_assert!(back.len() == hits.len(), "topk len");
            for ((ia, ma), (ib, mb)) in hits.iter().zip(back.iter()) {
                crate::prop_assert!(ia == ib && ma.to_bits() == mb.to_bits(), "topk entry");
            }
            Ok(())
        });
    }
}
