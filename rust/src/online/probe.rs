//! Probability-ordered multi-probe planning.
//!
//! [`crate::table::HyperplaneIndex`] enumerates the Hamming ball in blind
//! radius order: every weight-2 mask before any weight-3 mask, regardless
//! of *which* bits flip. The online planner replaces that with a
//! **best-first** sequence: candidate lookup codes ordered by modeled
//! collision mass, where flipping bit `j` costs `c_j ≥ 0` in −log mass
//! (see [`crate::hash::collision::CollisionModel`]) and a mask's mass is
//! `exp(−Σ_{j∈mask} c_j)`. With uniform costs the order degenerates to the
//! classic radius order; with query-adaptive costs (scaled by the query's
//! per-bit score magnitudes from [`crate::hash::HashFamily::query_bit_scores`])
//! low-confidence bits are flipped first, the way query-directed
//! multi-probe LSH spends its probes.
//!
//! Enumeration uses the Lv-style two-successor heap walk over bits sorted
//! by ascending cost: pop the cheapest frontier mask, emit it, push its
//! *shift* (advance the highest flipped bit) and *expand* (also flip the
//! next bit) successors. Each mask of weight ≤ radius is generated exactly
//! once, in nondecreasing total cost, in O(log heap) per probe — no
//! materialization of the full ball.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::hash::collision::{probe_mass, CollisionModel};

/// Immutable per-index probe policy: code length, maximum flip weight
/// (the Hamming-ball radius being refined) and per-bit flip costs.
#[derive(Clone, Debug)]
pub struct ProbePlanner {
    k: usize,
    radius: usize,
    costs: Vec<f64>,
}

impl ProbePlanner {
    /// Planner with explicit per-bit costs (one per code bit). Non-finite
    /// or negative costs are clamped to 0.
    pub fn with_costs(k: usize, radius: usize, costs: Vec<f64>) -> Self {
        assert!((1..=64).contains(&k));
        assert_eq!(costs.len(), k, "one flip cost per bit");
        let costs = costs
            .into_iter()
            .map(|c| if c.is_finite() && c > 0.0 { c } else { 0.0 })
            .collect();
        ProbePlanner { k, radius: radius.min(k), costs }
    }

    /// Uniform costs: best-first order degenerates to radius order (ties
    /// within a weight class broken arbitrarily), matching the static
    /// table's Hamming-ball enumeration set-for-set.
    pub fn uniform(k: usize, radius: usize) -> Self {
        Self::with_costs(k, radius, vec![1.0; k])
    }

    /// Costs derived from the family's collision model (Lemma 1): every
    /// bit costs the model's target-vs-background log-odds.
    pub fn from_model(k: usize, radius: usize, model: &CollisionModel) -> Self {
        Self::with_costs(k, radius, vec![model.bit_cost().max(1e-9); k])
    }

    /// Query-adaptive refinement: scale each bit's cost by the query's
    /// normalized score magnitude, so low-confidence bits (pre-sign score
    /// near 0) are cheap to flip and get probed first. The scale factor is
    /// clamped to [0.05, 20] to keep the plan well conditioned.
    pub fn query_scaled(&self, scores: &[f32]) -> ProbePlanner {
        if scores.len() != self.k {
            return self.clone();
        }
        let mean = scores.iter().map(|&s| s as f64).sum::<f64>() / self.k as f64;
        if !(mean.is_finite() && mean > 0.0) {
            return self.clone();
        }
        let costs = self
            .costs
            .iter()
            .zip(scores.iter())
            .map(|(&c, &s)| c * ((s as f64 / mean).clamp(0.05, 20.0)))
            .collect();
        ProbePlanner { k: self.k, radius: self.radius, costs }
    }

    pub fn bits(&self) -> usize {
        self.k
    }

    pub fn radius(&self) -> usize {
        self.radius
    }

    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Modeled collision mass of a flip mask, relative to the exact bucket.
    pub fn mass(&self, mask: u64) -> f64 {
        probe_mass(mask, &self.costs)
    }

    /// Number of probes a full (budget-unlimited) plan emits: the ball
    /// volume Σ_{i≤r} C(k,i).
    pub fn full_volume(&self) -> u64 {
        crate::hash::codes::ball_volume(self.k, self.radius)
    }

    /// The first `budget` planned flip masks paired with their modeled
    /// collision mass, in best-first plan order — the "modeled" half of
    /// the `chh_probe_model_calibration` audit metric
    /// ([`crate::obs::audit`]).
    pub fn planned_masses(&self, budget: usize) -> Vec<(u64, f64)> {
        self.plan(budget).map(|m| (m, self.mass(m))).collect()
    }

    /// Best-first probe sequence, at most `budget` flip masks (the empty
    /// mask — the exact bucket — is always probe #1). XOR each yielded
    /// mask with the lookup code to get the bucket to probe.
    pub fn plan(&self, budget: usize) -> ProbePlan {
        // sort bit positions by ascending cost; the heap walk needs the
        // "next bit" to never be cheaper than the current one
        let mut perm: Vec<u16> = (0..self.k as u16).collect();
        perm.sort_by(|&a, &b| {
            self.costs[a as usize]
                .partial_cmp(&self.costs[b as usize])
                .unwrap_or(Ordering::Equal)
        });
        let sorted_costs: Vec<f64> = perm.iter().map(|&j| self.costs[j as usize]).collect();
        let mut heap = BinaryHeap::new();
        if self.radius >= 1 {
            heap.push(Frontier { cost: sorted_costs[0], set: vec![0] });
        }
        ProbePlan {
            perm,
            costs: sorted_costs,
            k: self.k,
            radius: self.radius,
            remaining: budget,
            emitted_root: false,
            heap,
        }
    }
}

/// Heap node: a flip set as strictly increasing indices into the
/// cost-sorted bit order, with its total cost.
struct Frontier {
    cost: f64,
    set: Vec<u16>,
}

impl PartialEq for Frontier {
    fn eq(&self, other: &Self) -> bool {
        self.cost.total_cmp(&other.cost) == Ordering::Equal
    }
}

impl Eq for Frontier {}

impl PartialOrd for Frontier {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Frontier {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the cheapest mask pops first
        other.cost.total_cmp(&self.cost)
    }
}

/// Iterator over the planned flip masks, best-first.
pub struct ProbePlan {
    perm: Vec<u16>,
    costs: Vec<f64>,
    k: usize,
    radius: usize,
    remaining: usize,
    emitted_root: bool,
    heap: BinaryHeap<Frontier>,
}

impl ProbePlan {
    fn mask_of(&self, set: &[u16]) -> u64 {
        set.iter().fold(0u64, |m, &i| m | (1u64 << self.perm[i as usize]))
    }
}

impl Iterator for ProbePlan {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if self.remaining == 0 {
            return None;
        }
        if !self.emitted_root {
            self.emitted_root = true;
            self.remaining -= 1;
            return Some(0); // the exact bucket
        }
        let top = self.heap.pop()?;
        let last = *top.set.last().expect("frontier sets are non-empty") as usize;
        if last + 1 < self.k {
            // shift: advance the highest flipped bit to the next position
            let mut shifted = top.set.clone();
            *shifted.last_mut().unwrap() = (last + 1) as u16;
            self.heap.push(Frontier {
                cost: top.cost - self.costs[last] + self.costs[last + 1],
                set: shifted,
            });
            // expand: additionally flip the next position
            if top.set.len() < self.radius {
                let mut expanded = top.set.clone();
                expanded.push((last + 1) as u16);
                self.heap.push(Frontier {
                    cost: top.cost + self.costs[last + 1],
                    set: expanded,
                });
            }
        }
        self.remaining -= 1;
        Some(self.mask_of(&top.set))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::codes::{ball_volume, HammingBall};
    use crate::testing::forall;
    use std::collections::HashSet;

    #[test]
    fn full_budget_covers_exactly_the_hamming_ball() {
        forall("plan == ball as a set", 32, |rng| {
            let k = rng.range(2, 18);
            let r = rng.range(0, k.min(4) + 1);
            let costs: Vec<f64> = (0..k).map(|_| 0.1 + 4.9 * rng.f64()).collect();
            let planner = ProbePlanner::with_costs(k, r, costs);
            let got: HashSet<u64> = planner.plan(usize::MAX).collect();
            let want: HashSet<u64> = HammingBall::new(k, r).collect();
            crate::prop_assert!(
                got == want,
                "k={k} r={r}: plan {} masks vs ball {}",
                got.len(),
                want.len()
            );
            Ok(())
        });
    }

    #[test]
    fn masses_nonincreasing_along_plan() {
        forall("best-first order", 32, |rng| {
            let k = rng.range(2, 16);
            let r = rng.range(1, k.min(4) + 1);
            let costs: Vec<f64> = (0..k).map(|_| 0.1 + 4.9 * rng.f64()).collect();
            let planner = ProbePlanner::with_costs(k, r, costs);
            let masses: Vec<f64> = planner.plan(usize::MAX).map(|m| planner.mass(m)).collect();
            for (i, pair) in masses.windows(2).enumerate() {
                crate::prop_assert!(
                    pair[0] >= pair[1] - 1e-12,
                    "probe {i}: mass {} then {}",
                    pair[0],
                    pair[1]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn budget_t_plan_is_top_t_by_modeled_mass() {
        // The satellite property: a budget-T best-first plan visits exactly
        // the T ball masks with the highest modeled collision mass.
        forall("top-T by mass", 24, |rng| {
            let k = rng.range(3, 14);
            let r = rng.range(1, k.min(4) + 1);
            // distinct random costs ⇒ distinct subset sums almost surely
            let costs: Vec<f64> = (0..k).map(|_| 0.1 + 4.9 * rng.f64()).collect();
            let planner = ProbePlanner::with_costs(k, r, costs);
            let mut ranked: Vec<u64> = HammingBall::new(k, r).collect();
            ranked.sort_by(|&a, &b| {
                planner.mass(b).partial_cmp(&planner.mass(a)).unwrap()
            });
            let t = rng.range(1, ranked.len() + 1);
            let got: HashSet<u64> = planner.plan(t).collect();
            let want: HashSet<u64> = ranked[..t].iter().copied().collect();
            crate::prop_assert!(
                got == want,
                "k={k} r={r} T={t}: plan set differs from top-T"
            );
            Ok(())
        });
    }

    #[test]
    fn uniform_costs_reproduce_radius_order() {
        let planner = ProbePlanner::uniform(12, 3);
        assert_eq!(planner.full_volume(), ball_volume(12, 3));
        let mut last_w = 0u32;
        let mut n = 0u64;
        for mask in planner.plan(usize::MAX) {
            let w = mask.count_ones();
            assert!(w >= last_w, "weights must be nondecreasing under uniform costs");
            assert!(w as usize <= 3);
            last_w = w;
            n += 1;
        }
        assert_eq!(n, ball_volume(12, 3));
    }

    #[test]
    fn budget_truncates_and_root_is_first() {
        let planner = ProbePlanner::uniform(16, 4);
        let plan: Vec<u64> = planner.plan(5).collect();
        assert_eq!(plan.len(), 5);
        assert_eq!(plan[0], 0, "exact bucket probes first");
        assert!(plan[1..].iter().all(|&m| m.count_ones() == 1));
        assert!(planner.plan(0).next().is_none());
        // radius 0: only the exact bucket regardless of budget
        let exact = ProbePlanner::uniform(8, 0);
        let plan: Vec<u64> = exact.plan(100).collect();
        assert_eq!(plan, vec![0]);
    }

    #[test]
    fn query_scaled_prefers_low_confidence_bits() {
        let planner = ProbePlanner::from_model(8, 2, &CollisionModel::bh_default());
        // bit 5 has a tiny score ⇒ cheapest flip ⇒ first single-bit probe
        let mut scores = vec![1.0f32; 8];
        scores[5] = 1e-3;
        let scaled = planner.query_scaled(&scores);
        let plan: Vec<u64> = scaled.plan(3).collect();
        assert_eq!(plan[0], 0);
        assert_eq!(plan[1], 1u64 << 5, "lowest-confidence bit flips first");
        // mismatched score length falls back to the unscaled plan
        let fallback = planner.query_scaled(&[1.0; 3]);
        assert_eq!(fallback.costs(), planner.costs());
    }

    #[test]
    fn planned_masses_pair_plan_order_with_mass() {
        let planner = ProbePlanner::uniform(10, 3);
        let pm = planner.planned_masses(7);
        assert_eq!(pm.len(), 7);
        let plan: Vec<u64> = planner.plan(7).collect();
        for (i, &(mask, mass)) in pm.iter().enumerate() {
            assert_eq!(mask, plan[i], "same best-first order as plan()");
            assert_eq!(mass, planner.mass(mask));
        }
        // masses are nonincreasing and the exact bucket has mass 1
        assert_eq!(pm[0], (0, 1.0));
        for w in pm.windows(2) {
            assert!(w[0].1 >= w[1].1 - 1e-12);
        }
    }

    #[test]
    fn k64_masks_do_not_overflow() {
        let planner = ProbePlanner::uniform(64, 1);
        let plan: Vec<u64> = planner.plan(usize::MAX).collect();
        assert_eq!(plan.len(), 65);
        let set: HashSet<u64> = plan.into_iter().collect();
        assert!(set.contains(&(1u64 << 63)));
    }
}
