//! Online serving: a sharded *dynamic* hyperplane index with
//! probability-ordered multi-probe.
//!
//! [`crate::table::HyperplaneIndex`] is build-once/static — the right shape
//! for reproducing the paper's figures, the wrong shape for the serving
//! deployment the roadmap targets (heavy traffic, millions of points,
//! continuous active-learning label churn). This module adds the dynamic
//! half of the stack:
//!
//! * [`ShardedIndex`] — N independent [`Shard`]s, each a frozen generation
//!   plus a small mutable delta with `insert`/`remove`/`compact` and
//!   epoch-versioned snapshots ([`ShardView`]), so readers never block
//!   writers and writers never invalidate an in-flight query.
//! * [`ProbePlanner`] — replaces blind radius-order Hamming-ball
//!   enumeration with a best-first probe sequence: candidate lookup codes
//!   ordered by modeled collision mass under the bilinear collision model
//!   `p₁ = 1/2 − 2α²/π²` (Lemma 1, [`crate::hash::collision`]), optionally
//!   sharpened per query by the family's pre-sign bit scores.
//! * [`QueryBudget`] — per-query probe budget `T` plus a `top` early-exit:
//!   stop probing once that many candidates have been margin-ranked.
//!
//! The fan-out/merge serving layer on top of this lives in
//! [`crate::coordinator::OnlineRouter`]; snapshot persistence in
//! [`crate::persist::save_sharded`]. See `docs/ONLINE.md` for the full
//! architecture notes.

mod probe;
mod shard;

pub use probe::{ProbePlan, ProbePlanner};
pub use shard::{Shard, ShardView};

use crate::data::{FeatRef, FeatureStore};
use crate::hash::codes::CodeArray;
use crate::hash::collision::CollisionModel;
use crate::hash::HashFamily;
use crate::table::QueryHit;

/// Per-query probe spending policy. Both limits apply **per shard** —
/// shards are probed independently (and, in the coordinator, in
/// parallel), so they cannot cheaply coordinate a global candidate
/// count. [`ShardedIndex::query_code`] and
/// [`crate::coordinator::OnlineRouter`] share these semantics exactly.
#[derive(Clone, Copy, Debug)]
pub struct QueryBudget {
    /// maximum buckets probed (best-first), per shard
    pub probes: usize,
    /// stop probing a shard once this many of its candidates have been
    /// margin-ranked
    pub top: usize,
}

impl QueryBudget {
    pub fn new(probes: usize, top: usize) -> Self {
        QueryBudget { probes, top }
    }

    /// No limits: probe the full ball — the static-table behavior.
    pub fn unlimited() -> Self {
        QueryBudget { probes: usize::MAX, top: usize::MAX }
    }
}

impl Default for QueryBudget {
    fn default() -> Self {
        Self::unlimited()
    }
}

/// Merge shard-local partial hits into one [`QueryHit`]: best = smallest
/// margin, counters summed.
pub fn merge_hits(parts: &[QueryHit]) -> QueryHit {
    let mut out = QueryHit::default();
    for p in parts {
        out.scanned += p.scanned;
        out.probed += p.probed;
        out.nonempty |= p.nonempty;
        if let Some((id, m)) = p.best {
            if out.best.map_or(true, |(_, bm)| m < bm) {
                out.best = Some((id, m));
            }
        }
    }
    out
}

/// Sharded dynamic hyperplane index.
///
/// Ids are row indices into the serving [`FeatureStore`] (the store itself
/// is append-only in a deployment; the index controls visibility). Routing
/// is `id % shards`, so sequential id spaces balance perfectly and a
/// persisted snapshot reloads onto the same layout.
pub struct ShardedIndex {
    k: usize,
    radius: usize,
    planner: ProbePlanner,
    shards: Vec<Shard>,
    /// auto-compact a shard when its delta reaches this many slots
    /// (0 disables auto-compaction)
    compact_threshold: usize,
    /// advisory serving budget carried with the index (persisted by
    /// [`crate::persist::save_sharded`]); queries still take an explicit
    /// budget — this is the operational default a server falls back to.
    /// Atomics (not a field behind `&mut`) so a server can write the
    /// resolved budget back into an already-shared index at startup;
    /// the pair is not updated atomically together — set it before
    /// serving, not concurrently with readers that need consistency.
    default_probes: std::sync::atomic::AtomicUsize,
    default_top: std::sync::atomic::AtomicUsize,
}

impl ShardedIndex {
    /// Empty index over `k`-bit codes with flip radius `radius` and
    /// `n_shards` shards, probe order from the default BH collision model.
    pub fn new(k: usize, radius: usize, n_shards: usize) -> Self {
        Self::with_planner(
            ProbePlanner::from_model(k, radius, &CollisionModel::bh_default()),
            n_shards,
        )
    }

    /// Empty index with an explicit probe policy.
    pub fn with_planner(planner: ProbePlanner, n_shards: usize) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        ShardedIndex {
            k: planner.bits(),
            radius: planner.radius(),
            planner,
            shards: (0..n_shards).map(|_| Shard::new()).collect(),
            compact_threshold: 4096,
            default_probes: std::sync::atomic::AtomicUsize::new(usize::MAX),
            default_top: std::sync::atomic::AtomicUsize::new(usize::MAX),
        }
    }

    /// Bulk-load precomputed codes (ids 0..n), then compact every shard so
    /// serving starts from frozen generations.
    pub fn from_codes(codes: &CodeArray, radius: usize, n_shards: usize) -> Self {
        let idx = Self::new(codes.k, radius, n_shards);
        for (i, &c) in codes.codes.iter().enumerate() {
            idx.insert(i as u32, c);
        }
        idx.compact();
        idx
    }

    /// Auto-compaction threshold (delta slots per shard); 0 disables.
    pub fn set_compact_threshold(&mut self, slots: usize) {
        self.compact_threshold = slots;
    }

    pub fn compact_threshold(&self) -> usize {
        self.compact_threshold
    }

    /// The operational default budget carried with (and persisted
    /// alongside) this index. Purely advisory: every query method takes
    /// an explicit [`QueryBudget`]. Takes `&self` so a startup path can
    /// write the resolved budget back into a shared index (see the
    /// field docs for the consistency caveat).
    pub fn set_default_budget(&self, budget: QueryBudget) {
        use std::sync::atomic::Ordering;
        self.default_probes.store(budget.probes, Ordering::Relaxed);
        self.default_top.store(budget.top, Ordering::Relaxed);
    }

    pub fn default_budget(&self) -> QueryBudget {
        use std::sync::atomic::Ordering;
        QueryBudget::new(
            self.default_probes.load(Ordering::Relaxed),
            self.default_top.load(Ordering::Relaxed),
        )
    }

    pub fn bits(&self) -> usize {
        self.k
    }

    pub fn radius(&self) -> usize {
        self.radius
    }

    pub fn planner(&self) -> &ProbePlanner {
        &self.planner
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    #[inline]
    pub fn shard_of(&self, id: u32) -> usize {
        id as usize % self.shards.len()
    }

    /// Live points across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// Per-shard compaction epochs.
    pub fn epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch()).collect()
    }

    /// Sum of shard epochs — a monotone global version counter.
    pub fn total_epoch(&self) -> u64 {
        self.shards.iter().map(|s| s.epoch()).sum()
    }

    /// Approximate heap footprint across shards.
    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.memory_bytes()).sum()
    }

    #[inline]
    fn maybe_compact(&self, shard: &Shard) {
        if self.compact_threshold > 0 && shard.pending_len() >= self.compact_threshold {
            shard.compact();
        }
    }

    /// Insert (or upsert) a precomputed code.
    pub fn insert(&self, id: u32, code: u64) {
        let shard = &self.shards[self.shard_of(id)];
        shard.insert(id, code);
        self.maybe_compact(shard);
    }

    /// Encode a feature row with `family` and insert it.
    pub fn insert_point(&self, family: &dyn HashFamily, id: u32, x: FeatRef<'_>) {
        debug_assert_eq!(family.bits(), self.k, "family code length mismatch");
        self.insert(id, family.encode_point(x));
    }

    /// Remove a point; returns whether it was live. Remove-heavy phases
    /// auto-compact too — frozen tombstones count toward the threshold,
    /// keeping per-query view snapshots cheap.
    pub fn remove(&self, id: u32) -> bool {
        let shard = &self.shards[self.shard_of(id)];
        let removed = shard.remove(id);
        if removed {
            self.maybe_compact(shard);
        }
        removed
    }

    /// Whether `id` is currently live.
    pub fn contains(&self, id: u32) -> bool {
        self.shards[self.shard_of(id)].contains(id)
    }

    /// Compact every shard.
    pub fn compact(&self) {
        for s in &self.shards {
            s.compact();
        }
    }

    /// Point-in-time views of all shards (one epoch-consistent snapshot
    /// per shard; the set is the unit the coordinator fans out over).
    pub fn views(&self) -> Vec<ShardView> {
        self.shards.iter().map(|s| s.view()).collect()
    }

    /// Materialize the best-first probe sequence for a query: at most
    /// `probes` flip masks (never more than the radius-`r` ball volume —
    /// the plan iterator is exhausted before that), query-adapted when
    /// per-bit scores are given. Materialization is what lets the
    /// coordinator share one plan across parallel shard jobs; with large
    /// `k`/`radius`, pass a finite `probes` rather than relying on `top`
    /// alone, since `top` only bounds probing, not planning.
    pub fn plan_masks(&self, scores: Option<&[f32]>, probes: usize) -> Vec<u64> {
        match scores {
            Some(s) => self.planner.query_scaled(s).plan(probes).collect(),
            None => self.planner.plan(probes).collect(),
        }
    }

    /// Query with a precomputed lookup code (and optional per-bit scores),
    /// probing every shard inline: one shared probe plan, one
    /// [`ShardView::query`] per shard, partials merged with
    /// [`merge_hits`] — the same semantics (and per-shard `top`) as the
    /// coordinator's parallel path, minus the threads. `probed`/`scanned`
    /// therefore count per-shard work summed over shards.
    pub fn query_code(
        &self,
        lookup: u64,
        scores: Option<&[f32]>,
        w: &[f32],
        feats: &FeatureStore,
        budget: QueryBudget,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        let masks = self.plan_masks(scores, budget.probes);
        let parts: Vec<QueryHit> = self
            .views()
            .iter()
            .map(|v| v.query(&masks, lookup, w, feats, budget.top, &eligible))
            .collect();
        merge_hits(&parts)
    }

    /// Full query: encode the hyperplane, adapt the probe order to the
    /// query's bit confidences, probe, margin-rank.
    pub fn query(
        &self,
        family: &dyn HashFamily,
        w: &[f32],
        feats: &FeatureStore,
        budget: QueryBudget,
        eligible: impl Fn(usize) -> bool,
    ) -> QueryHit {
        let lookup = family.encode_query(w);
        let scores = family.query_bit_scores(w);
        self.query_code(lookup, scores.as_deref(), w, feats, budget, eligible)
    }

    /// Top-T near-to-hyperplane neighbors — the dynamic-index analogue of
    /// [`crate::table::HyperplaneIndex::query_topk`]: probe every shard
    /// with the query-adapted plan (same per-shard budget semantics as
    /// [`Self::query`]), merge the margin-ranked candidates and return up
    /// to `t` of them sorted by ascending margin (ties by id, so the
    /// order is deterministic across shard layouts).
    pub fn query_topk(
        &self,
        family: &dyn HashFamily,
        w: &[f32],
        feats: &FeatureStore,
        t: usize,
        budget: QueryBudget,
        eligible: impl Fn(usize) -> bool,
    ) -> Vec<(usize, f32)> {
        let lookup = family.encode_query(w);
        let scores = family.query_bit_scores(w);
        let masks = self.plan_masks(scores.as_deref(), budget.probes);
        let mut scored: Vec<(usize, f32)> = Vec::new();
        for v in self.views() {
            v.query_topk(&masks, lookup, w, feats, budget.top, &eligible, &mut scored);
        }
        scored.sort_by(|a, b| {
            a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        scored.truncate(t);
        scored
    }

    /// [`Self::query_code`] with the per-shard probes fanned out over
    /// `pool` (one work unit per shard). Partials merge in shard order,
    /// so the hit is bit-identical to the inline path for any worker
    /// count. This is the shard fan-out the coordinator's synchronous
    /// batch path reuses.
    #[allow(clippy::too_many_arguments)]
    pub fn query_code_pool(
        &self,
        lookup: u64,
        scores: Option<&[f32]>,
        w: &[f32],
        feats: &FeatureStore,
        budget: QueryBudget,
        eligible: impl Fn(usize) -> bool + Sync,
        pool: &crate::par::Pool,
    ) -> QueryHit {
        self.query_code_pool_timed(
            lookup,
            scores,
            w,
            feats,
            budget,
            eligible,
            pool,
            &mut crate::obs::StageTimes::default(),
        )
    }

    /// [`Self::query_code_pool`] with per-stage wall-clock accumulated
    /// into `times` (probe planning / shard scan / merge — encoding
    /// happens in the caller). The computation is identical — the
    /// untimed entry point delegates here — so timed and untimed
    /// answers are bit-identical by construction.
    #[allow(clippy::too_many_arguments)]
    pub fn query_code_pool_timed(
        &self,
        lookup: u64,
        scores: Option<&[f32]>,
        w: &[f32],
        feats: &FeatureStore,
        budget: QueryBudget,
        eligible: impl Fn(usize) -> bool + Sync,
        pool: &crate::par::Pool,
        times: &mut crate::obs::StageTimes,
    ) -> QueryHit {
        let t0 = std::time::Instant::now();
        let masks = self.plan_masks(scores, budget.probes);
        let t1 = std::time::Instant::now();
        let views = self.views();
        let parts: Vec<QueryHit> = pool
            .map(views.len(), 1, |range| {
                range
                    .map(|si| views[si].query(&masks, lookup, w, feats, budget.top, &eligible))
                    .collect::<Vec<_>>()
            })
            .into_iter()
            .flatten()
            .collect();
        let t2 = std::time::Instant::now();
        let hit = merge_hits(&parts);
        times.probe += t1 - t0;
        times.scan += t2 - t1;
        times.merge += t2.elapsed();
        hit
    }

    /// [`Self::query`] with pooled shard fan-out.
    pub fn query_pool(
        &self,
        family: &dyn HashFamily,
        w: &[f32],
        feats: &FeatureStore,
        budget: QueryBudget,
        eligible: impl Fn(usize) -> bool + Sync,
        pool: &crate::par::Pool,
    ) -> QueryHit {
        let lookup = family.encode_query(w);
        let scores = family.query_bit_scores(w);
        self.query_code_pool(lookup, scores.as_deref(), w, feats, budget, eligible, pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::test_blobs;
    use crate::hash::BhHash;
    use crate::rng::Rng;
    use crate::testing::unit_vec;

    #[test]
    fn routing_balances_sequential_ids() {
        let idx = ShardedIndex::new(8, 2, 4);
        for id in 0..1000u32 {
            idx.insert(id, (id % 13) as u64);
        }
        assert_eq!(idx.len(), 1000);
        for s in idx.shards() {
            assert_eq!(s.len(), 250);
        }
    }

    #[test]
    fn query_finds_minimum_margin_like_static_index() {
        let mut rng = Rng::seed_from_u64(21);
        let ds = test_blobs(400, 16, 4, &mut rng);
        let fam = BhHash::sample(16, 8, &mut rng);
        // radius = bits ⇒ the whole code space: every point is a candidate
        let codes = fam.encode_all(ds.features());
        let idx = ShardedIndex::from_codes(&codes, 8, 3);
        let w = unit_vec(&mut rng, 16);
        let hit = idx.query(&fam, &w, ds.features(), QueryBudget::unlimited(), |_| true);
        assert!(hit.nonempty);
        let (best_i, best_m) = hit.best.unwrap();
        let wn = crate::linalg::nrm2(&w);
        let mut bf = (0usize, f32::INFINITY);
        for i in 0..ds.len() {
            let m = crate::linalg::margin_feat(ds.features().row(i), &w, wn);
            if m < bf.1 {
                bf = (i, m);
            }
        }
        assert_eq!(best_i, bf.0);
        assert!((best_m - bf.1).abs() < 1e-6);
    }

    #[test]
    fn removed_points_never_returned() {
        let mut rng = Rng::seed_from_u64(22);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let fam = BhHash::sample(16, 10, &mut rng);
        let codes = fam.encode_all(ds.features());
        let idx = ShardedIndex::from_codes(&codes, 10, 4);
        let w = unit_vec(&mut rng, 16);
        // peel off the best candidate 20 times; it must never reappear
        let mut removed = Vec::new();
        for _ in 0..20 {
            let hit = idx.query(&fam, &w, ds.features(), QueryBudget::unlimited(), |_| true);
            let (best, _) = hit.best.expect("full-space query finds something");
            assert!(
                !removed.contains(&(best as u32)),
                "removed id {best} resurfaced"
            );
            assert!(idx.remove(best as u32));
            removed.push(best as u32);
        }
        assert_eq!(idx.len(), 280);
    }

    #[test]
    fn probe_budget_limits_buckets() {
        let mut rng = Rng::seed_from_u64(23);
        let ds = test_blobs(500, 16, 3, &mut rng);
        let fam = BhHash::sample(16, 12, &mut rng);
        let codes = fam.encode_all(ds.features());
        let idx = ShardedIndex::from_codes(&codes, 3, 2);
        let w = unit_vec(&mut rng, 16);
        let hit = idx.query(&fam, &w, ds.features(), QueryBudget::new(17, usize::MAX), |_| true);
        // budget is per shard; probed sums over the 2 shards
        assert!(hit.probed <= 2 * 17, "budget respected, probed {}", hit.probed);
        assert!(hit.probed >= 17, "both shards probe the planned masks");
    }

    #[test]
    fn top_early_exit_stops_probing() {
        let idx = ShardedIndex::new(8, 8, 1);
        // all points in one bucket at distance 1 from the lookup
        for id in 0..50u32 {
            idx.insert(id, 0b0000_0001);
        }
        let feats = FeatureStore::Dense(crate::linalg::Mat::zeros(50, 4));
        let hit = idx.query_code(
            0,
            None,
            &[1.0; 4],
            &feats,
            QueryBudget::new(usize::MAX, 10),
            |_| true,
        );
        // the planner needed only to reach the weight-1 ring
        assert!(hit.probed < 20, "early exit after top hit, probed {}", hit.probed);
        assert!(hit.scanned >= 10);
    }

    #[test]
    fn auto_compaction_bounds_delta() {
        let mut idx = ShardedIndex::new(8, 2, 2);
        idx.set_compact_threshold(64);
        for id in 0..1000u32 {
            idx.insert(id, (id % 5) as u64);
        }
        for s in idx.shards() {
            assert!(s.delta_len() < 64, "delta kept below threshold");
        }
        assert!(idx.total_epoch() > 0, "compactions happened");
        assert_eq!(idx.len(), 1000);
    }

    #[test]
    fn remove_heavy_churn_also_compacts() {
        let mut idx = ShardedIndex::new(8, 2, 2);
        idx.set_compact_threshold(32);
        for id in 0..600u32 {
            idx.insert(id, (id % 9) as u64);
        }
        idx.compact();
        // pure removal phase: tombstones alone must trigger compaction
        for id in 0..500u32 {
            idx.remove(id);
        }
        for s in idx.shards() {
            assert!(s.pending_len() < 32, "tombstone backlog bounded");
        }
        assert_eq!(idx.len(), 100);
    }

    // query_pool parity with the inline fan-out is covered by the
    // integration suite in rust/tests/batch_parallel.rs.

    #[test]
    fn query_topk_matches_static_table_on_full_ball() {
        let mut rng = Rng::seed_from_u64(27);
        let ds = test_blobs(300, 16, 3, &mut rng);
        let fam = BhHash::sample(16, 8, &mut rng);
        let codes = fam.encode_all(ds.features());
        let idx = ShardedIndex::from_codes(&codes, 8, 3); // radius = bits: full ball
        let table = crate::table::HyperplaneIndex::from_codes(codes, 8);
        let w = unit_vec(&mut rng, 16);
        let online = idx.query_topk(&fam, &w, ds.features(), 12, QueryBudget::unlimited(), |_| {
            true
        });
        let fixed = table.query_topk(&fam, &w, ds.features(), 12, |_| true);
        assert_eq!(online.len(), fixed.len());
        for ((ia, ma), (ib, mb)) in online.iter().zip(fixed.iter()) {
            assert_eq!(ia, ib, "same ids in same margin order");
            assert_eq!(ma.to_bits(), mb.to_bits(), "identical margins");
        }
        // sorted ascending, filter respected
        for pair in online.windows(2) {
            assert!(pair[0].1 <= pair[1].1);
        }
        let even = idx.query_topk(&fam, &w, ds.features(), 8, QueryBudget::unlimited(), |i| {
            i % 2 == 0
        });
        assert!(even.iter().all(|&(i, _)| i % 2 == 0));
    }

    #[test]
    fn merge_hits_takes_global_minimum() {
        let parts = vec![
            QueryHit { best: Some((3, 0.5)), scanned: 2, probed: 4, nonempty: true },
            QueryHit { best: Some((9, 0.1)), scanned: 3, probed: 4, nonempty: true },
            QueryHit { best: None, scanned: 0, probed: 4, nonempty: false },
        ];
        let m = merge_hits(&parts);
        assert_eq!(m.best, Some((9, 0.1)));
        assert_eq!(m.scanned, 5);
        assert_eq!(m.probed, 12);
        assert!(m.nonempty);
        assert_eq!(merge_hits(&[]).best, None);
    }
}
